//! Figure 3 — robustness to missing vocabulary: remove k% of each
//! benchmark's unique words from one-or-two random sub-models, merge with
//! ALiR / Concat / PCA, and score.
//!
//! Expected shape: ALiR's scores barely move (it reconstructs the removed
//! rows through the learned rotations and keeps the union vocabulary)
//! while Concat and PCA fall off sharply at 50% removal because every
//! removed word drops out of their intersection vocabulary entirely.

use dw2v::bench_util::{append_bench_trajectory, bench_scale, Table};
use dw2v::coordinator::leader;
use dw2v::embedding::Embedding;
use dw2v::eval::report::{evaluate_suite, format_cell, mean_score, scores_to_json, BenchmarkScore};
use dw2v::gen::benchmarks::Benchmark;
use dw2v::runtime::{load_backend, Backend};
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::json::{num, obj};
use dw2v::util::rng::Pcg64;
use dw2v::world::build_world;

fn remove_words(models: &mut [Embedding], words: &[u32], rng: &mut Pcg64) {
    let n = models.len();
    for &w in words {
        let hits = 1 + rng.gen_range_usize(2); // 1 or 2 sub-models affected
        for _ in 0..hits {
            let m = rng.gen_range_usize(n);
            models[m].present[w as usize] = false;
            models[m].row_mut(w).fill(0.0);
        }
    }
}

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = (80_000.0 * bench_scale()) as usize;
    cfg.vocab = 2000;
    cfg.dim = 32;
    cfg.epochs = 3;
    cfg.rate_percent = 10.0; // paper figure uses the 10% Shuffle setting
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.min_count_base = 20.0;
    let world = build_world(&cfg);
    let backend = load_backend(&cfg, world.vocab.len()).expect("backend");
    println!("backend: {}", backend.name());

    println!("training {} sub-models once…", cfg.num_submodels());
    let out = leader::train_submodels(&cfg, &world.corpus, &world.vocab, &backend).expect("train");

    let mut bench_words: Vec<u32> = world.suite.iter().flat_map(|b| b.unique_words()).collect();
    bench_words.sort_unstable();
    bench_words.dedup();

    let bench_names: Vec<String> = world.suite.iter().map(|b| b.name.clone()).collect();
    let mut headers: Vec<&str> = bench_names.iter().map(|x| x.as_str()).collect();
    headers.push("mean");
    headers.push("mean*cov");
    let mut table = Table::new(
        "fig3_missing",
        "Figure 3 — merge quality after removing k% of benchmark words",
        &headers,
    );

    // cross-PR trajectory: the coverage-penalized mean of each merge
    // method at 50% removal — the figure's headline robustness contrast
    let mut traj = vec![("sentences", num(cfg.sentences as f64))];
    for removal in [0.0, 0.1, 0.5] {
        let mut rng = Pcg64::new(cfg.seed ^ 0xF3);
        let k = (bench_words.len() as f64 * removal) as usize;
        let removed: Vec<u32> = rng
            .sample_indices(bench_words.len(), k)
            .into_iter()
            .map(|i| bench_words[i])
            .collect();
        let mut models = out.submodels.clone();
        remove_words(&mut models, &removed, &mut rng);
        for method in [MergeMethod::AlirPca, MergeMethod::Concat, MergeMethod::Pca] {
            cfg.merge = method.clone();
            let merged = leader::merge_trained(&cfg, &models);
            let scores = evaluate_suite(&merged.embedding, &world.suite, cfg.seed);
            let label = format!("{:.0}% removed, {}", removal * 100.0, method.name());
            let mut cells: Vec<String> = scores.iter().map(format_cell).collect();
            cells.push(format!("{:.3}", mean_score(&scores)));
            let penalized = coverage_penalized_mean(&scores, &world.suite);
            cells.push(format!("{penalized:.3}"));
            table.row(&label, cells, scores_to_json(&label, &scores));
            if removal == 0.5 {
                let key = match method {
                    MergeMethod::AlirPca => "alir_mean_cov_50pct",
                    MergeMethod::Concat => "concat_mean_cov_50pct",
                    _ => "pca_mean_cov_50pct",
                };
                traj.push((key, num(penalized)));
            } else if removal == 0.0 && matches!(method, MergeMethod::AlirPca) {
                traj.push(("alir_mean_cov_0pct", num(penalized)));
            }
        }
    }
    table.finish();
    append_bench_trajectory("fig3_missing", obj(traj));
    println!("\nexpected shape (mean*cov — score × fraction of benchmark items the");
    println!("model can even answer): ALiR nearly flat across removal levels, Concat/");
    println!("PCA drop sharply at 50% because removed words leave their intersection");
    println!("vocabulary entirely — paper Fig. 3. The raw mean hides the damage since");
    println!("skipped OOV pairs are excluded from it.");
}

/// Score × coverage per benchmark: a model that cannot answer a question
/// gets zero credit for it (the paper's Figure 3 protocol — Concat/PCA
/// "ignore words not present in sub-models").
fn coverage_penalized_mean(scores: &[BenchmarkScore], suite: &[Benchmark]) -> f64 {
    let mut sum = 0.0;
    for (sc, b) in scores.iter().zip(suite) {
        let total = b.len().max(1);
        sum += sc.score * (sc.items_used as f64 / total as f64);
    }
    sum / scores.len().max(1) as f64
}
