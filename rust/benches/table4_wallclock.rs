//! Table 4 — wall-clock time of training and merging per sampling rate,
//! plus Hogwild and MLlib-style baselines on the same corpus.
//!
//! Expected shape: training time grows ~linearly with r (each sub-model
//! sees r% of the data but rates are trained concurrently under a fixed
//! core budget); PCA merge time roughly flat; ALiR merge time grows with
//! the number of sub-models (100/r); merge ≪ train at practical rates;
//! Hogwild slowest of the single-pass systems.

use dw2v::baselines::param_avg;
use dw2v::bench_util::{append_bench_trajectory, bench_scale, Table};
use dw2v::coordinator::leader;
use dw2v::runtime::{load_backend, Backend};
use dw2v::sgns::hogwild;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::json::{num, obj, s};
use dw2v::world::build_world;

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = (100_000.0 * bench_scale()) as usize;
    cfg.vocab = 2000;
    cfg.dim = 32;
    cfg.epochs = 2;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.min_count_base = 20.0;
    let world = build_world(&cfg);
    let backend = load_backend(&cfg, world.vocab.len()).expect("backend");
    println!("backend: {}", backend.name());

    let mut table = Table::new(
        "table4_wallclock",
        "Table 4 — wall-clock per sampling rate (seconds)",
        &["phase", "train/model", "pca-merge", "alir-merge", "submodels"],
    );

    // headline numbers for the cross-PR trajectory file (rate 25% is in
    // every scale's rate set, so the series stays comparable)
    let mut traj: Vec<(&str, dw2v::util::json::Json)> = vec![
        ("sentences", num(cfg.sentences as f64)),
        ("backend", s(backend.name())),
    ];

    let rates: &[f64] = if bench_scale() >= 1.0 {
        &[5.0, 6.67, 10.0, 20.0, 25.0, 33.0, 50.0]
    } else {
        &[10.0, 20.0, 25.0, 33.0, 50.0]
    };
    for &rate in rates {
        cfg.rate_percent = rate;
        let out = leader::train_submodels(&cfg, &world.corpus, &world.vocab, &backend)
            .expect("train");
        cfg.merge = MergeMethod::Pca;
        let pca = leader::merge_trained(&cfg, &out.submodels);
        cfg.merge = MergeMethod::AlirPca;
        let alir = leader::merge_trained(&cfg, &out.submodels);
        let label = format!("shuffle {rate}%");
        table.row(
            &label,
            vec![
                format!("{:.2}", out.train_secs),
                format!("{:.3}", out.avg_reducer_busy_secs),
                format!("{:.3}", pca.seconds),
                format!("{:.3}", alir.seconds),
                format!("{}", out.submodels.len()),
            ],
            obj(vec![
                ("rate", num(rate)),
                ("train_secs", num(out.train_secs)),
                ("per_model_busy_secs", num(out.avg_reducer_busy_secs)),
                ("pca_merge_secs", num(pca.seconds)),
                ("alir_merge_secs", num(alir.seconds)),
                ("submodels", num(out.submodels.len() as f64)),
                ("pairs", num(out.pairs as f64)),
            ]),
        );
        if rate == 25.0 {
            traj.push(("inproc_train_secs", num(out.train_secs)));
            traj.push((
                "inproc_pairs_per_s",
                num(out.pairs as f64 / out.train_secs.max(1e-9)),
            ));
        }
    }

    // baselines on the same corpus
    let scfg = leader::sgns_config(&cfg);
    let (_, hog_stats) = hogwild::train(&world.corpus, &world.vocab, &scfg, 4, cfg.seed);
    table.row(
        "Hogwild (4 threads)",
        vec![
            format!("{:.2}", hog_stats.seconds),
            format!("{:.2}", hog_stats.seconds),
            "-".into(),
            "-".into(),
            "1".into(),
        ],
        obj(vec![("system", s("hogwild")), ("train_secs", num(hog_stats.seconds))]),
    );
    // telemetry overhead: the same Hogwild run with the metrics registry
    // disabled (control) vs enabled (instrumented). The hot loop's only
    // instrument cost is one relaxed bool load plus one extra fetch_add
    // per COUNTER_FLUSH pairs per thread, so this delta prices the whole
    // obs layer on the tightest loop in the repo — it should be < 2%.
    {
        let reg = dw2v::obs::metrics::global();
        let was_on = reg.enabled();
        let best = |on: bool| -> f64 {
            reg.set_enabled(on);
            let mut min_secs = f64::INFINITY;
            for _ in 0..3 {
                let (_, st) = hogwild::train(&world.corpus, &world.vocab, &scfg, 4, cfg.seed);
                min_secs = min_secs.min(st.seconds);
            }
            min_secs
        };
        let off_secs = best(false);
        let on_secs = best(true);
        reg.set_enabled(was_on);
        let overhead_pct = (on_secs / off_secs.max(1e-9) - 1.0) * 100.0;
        table.row(
            "telemetry overhead (hogwild 4t)",
            vec![
                format!("{on_secs:.2} vs {off_secs:.2}"),
                format!("{overhead_pct:+.2}%"),
                "-".into(),
                "-".into(),
                "1".into(),
            ],
            obj(vec![
                ("system", s("telemetry_overhead")),
                ("instrumented_secs", num(on_secs)),
                ("uninstrumented_secs", num(off_secs)),
                ("overhead_pct", num(overhead_pct)),
            ]),
        );
        traj.push(("telemetry_overhead_pct", num(overhead_pct)));
        if overhead_pct >= 2.0 {
            println!("WARNING: telemetry overhead {overhead_pct:.2}% >= 2% budget");
        }
    }
    for executors in [8, 32] {
        let (_, st) =
            param_avg::train(&world.corpus, &world.vocab, &scfg, &backend, executors, cfg.seed)
                .expect("mllib");
        table.row(
            &format!("MLlib-style ({executors} exec)"),
            vec![
                format!("{:.2}", st.seconds),
                format!("{:.2}", st.seconds),
                "-".into(),
                "-".into(),
                "1".into(),
            ],
            obj(vec![
                ("system", s("mllib")),
                ("executors", num(executors as f64)),
                ("train_secs", num(st.seconds)),
            ]),
        );
    }
    // multi-process system row: the same corpus persisted to shard files
    // and trained by 100/r worker OS processes (streaming the shards from
    // disk), coordinated + merged by coordinator::procs — the train number
    // includes process spawn and artifact I/O, i.e. the real end-to-end
    // cost of process isolation versus the in-process rows above
    {
        let dir = std::env::temp_dir().join(format!("dw2v_t4_procs_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("procs dir");
        world.corpus.write_sharded(&dir, 8).expect("write shards");
        std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).expect("write vocab");
        cfg.rate_percent = 25.0;
        cfg.merge = MergeMethod::AlirPca;
        let opts = dw2v::coordinator::procs::ProcsOptions {
            worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_dw2v")),
            shard_dir: dir.clone(),
            out_dir: dir.join("submodels"),
            extra_env: Vec::new(),
            connect: None,
        };
        match dw2v::coordinator::procs::run_multiprocess(&cfg, &[], &opts) {
            Ok(rep) => {
                let per_worker: f64 = rep
                    .outcomes
                    .iter()
                    .map(|o| o.secs)
                    .fold(0.0, f64::max);
                table.row(
                    "multi-process 25% (4 procs)",
                    vec![
                        format!("{:.2}", rep.train_secs),
                        format!("{:.3}", per_worker),
                        "-".into(),
                        format!("{:.3}", rep.tail.merged.seconds),
                        format!("{}", rep.survivors()),
                    ],
                    obj(vec![
                        ("system", s("procs")),
                        ("rate", num(25.0)),
                        ("train_secs", num(rep.train_secs)),
                        ("slowest_worker_secs", num(per_worker)),
                        ("alir_merge_secs", num(rep.tail.merged.seconds)),
                        ("survivors", num(rep.survivors() as f64)),
                    ]),
                );
                traj.push(("procs_train_secs", num(rep.train_secs)));
            }
            Err(e) => println!("multi-process row skipped: {e}"),
        }
        // supervised variant of the same run: beacons every 250 ms,
        // per-epoch checkpoints, supervisor poll loop — the row above is
        // the control, so the delta is the full cost of supervision on a
        // fault-free run (expected: small, dominated by checkpoint I/O)
        let sup = dw2v::coordinator::supervisor::SupervisorOptions::default();
        match dw2v::coordinator::supervisor::run_supervised(&cfg, &[], &opts, &sup) {
            Ok(rep) => {
                let per_worker: f64 = rep
                    .outcomes
                    .iter()
                    .map(|o| o.secs)
                    .fold(0.0, f64::max);
                table.row(
                    "supervised 25% (4 procs)",
                    vec![
                        format!("{:.2}", rep.train_secs),
                        format!("{:.3}", per_worker),
                        "-".into(),
                        format!("{:.3}", rep.tail.merged.seconds),
                        format!("{}", rep.survivors()),
                    ],
                    obj(vec![
                        ("system", s("procs-supervised")),
                        ("rate", num(25.0)),
                        ("train_secs", num(rep.train_secs)),
                        ("slowest_worker_secs", num(per_worker)),
                        ("alir_merge_secs", num(rep.tail.merged.seconds)),
                        ("survivors", num(rep.survivors() as f64)),
                        ("respawns", num(rep.stats.respawns as f64)),
                    ]),
                );
                traj.push(("supervised_train_secs", num(rep.train_secs)));
            }
            Err(e) => println!("supervised row skipped: {e}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    table.finish();
    append_bench_trajectory("table4_wallclock", obj(traj));
    println!("\nexpected shape: per-model train time ~linear in rate (this is the");
    println!("paper's 'Avg. Training Time' — one dedicated node per reducer); the");
    println!("phase column is work-conserving on this single-core testbed. merge ≪");
    println!("train; ALiR merge grows as sub-models multiply — cf. paper Table 4.");
}
