//! Figure 1 — KL divergence of sub-corpus unigram/bigram distributions
//! from the full corpus, RandomSampling vs EqualPartitioning (Shuffle
//! included as the extra row our implementation adds), averaged over 10
//! sub-corpora.
//!
//! Expected shape (paper): RandomSampling ≪ EqualPartitioning on both
//! unigram and bigram KL; random-sampling coverage of the vocabulary is
//! near-total.

use dw2v::bench_util::{append_bench_trajectory, bench_scale, Table};
use dw2v::coordinator::divider::Divider;
use dw2v::coordinator::stats::{bigram_kl, unigram_kl, vocab_coverage, DistStats};
use dw2v::util::config::{DivideStrategy, ExperimentConfig};
use dw2v::util::json::{num, obj, s};
use dw2v::world::build_world;

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = (80_000.0 * bench_scale()) as usize;
    cfg.vocab = 2000;
    cfg.rate_percent = 10.0;
    let world = build_world(&cfg);
    let corpus = &world.corpus;
    println!(
        "fig1: corpus {} sentences / {} tokens, r = {}%",
        corpus.len(),
        corpus.total_tokens(),
        cfg.rate_percent
    );
    let full = DistStats::from_corpus(corpus);

    let mut table = Table::new(
        "fig1_kl",
        "Figure 1 — divergence of sub-corpus distributions (avg over 10 sub-corpora)",
        &["unigram-KL", "bigram-KL", "union-cov", "intersect-cov"],
    );
    // headline numbers for the cross-PR trajectory file: the paper's
    // central contrast is random-sampling vs equal-partitioning unigram KL
    let mut traj = vec![("sentences", num(corpus.len() as f64))];
    for strategy in [
        DivideStrategy::EqualPartitioning,
        DivideStrategy::RandomSampling,
        DivideStrategy::Shuffle,
    ] {
        let divider = Divider::new(strategy.clone(), cfg.rate_percent, cfg.seed, corpus.len())
            .expect("valid rate");
        let take = 10.min(divider.num_submodels);
        let mut subs = Vec::new();
        let mut buf = Vec::new();
        for sub in 0..take {
            let mut st = DistStats::default();
            for (i, sent) in corpus.sentences.iter().enumerate() {
                divider.targets(0, i, &mut buf);
                if buf.contains(&sub) {
                    st.add_sentence(sent);
                }
            }
            subs.push(st);
        }
        let ukl = subs.iter().map(|x| unigram_kl(x, &full)).sum::<f64>() / take as f64;
        let bkl = subs.iter().map(|x| bigram_kl(x, &full)).sum::<f64>() / take as f64;
        let (union, inter) = vocab_coverage(&subs, &full);
        table.row(
            strategy.name(),
            vec![
                format!("{ukl:.4}"),
                format!("{bkl:.4}"),
                format!("{union:.3}"),
                format!("{inter:.3}"),
            ],
            obj(vec![
                ("strategy", s(strategy.name())),
                ("unigram_kl", num(ukl)),
                ("bigram_kl", num(bkl)),
                ("union_coverage", num(union)),
                ("intersection_coverage", num(inter)),
            ]),
        );
        match strategy {
            DivideStrategy::EqualPartitioning => {
                traj.push(("equal_unigram_kl", num(ukl)));
                traj.push(("equal_bigram_kl", num(bkl)));
            }
            DivideStrategy::RandomSampling => {
                traj.push(("random_unigram_kl", num(ukl)));
                traj.push(("random_bigram_kl", num(bkl)));
                traj.push(("random_union_coverage", num(union)));
            }
            DivideStrategy::Shuffle => {
                traj.push(("shuffle_unigram_kl", num(ukl)));
            }
        }
    }
    table.finish();
    append_bench_trajectory("fig1_kl", obj(traj));
    println!("\nexpected shape: random/shuffle KL well below equal-partitioning,");
    println!("coverage near 1.0 for sampled strategies (paper Fig. 1 + §3.1).");
}
