//! Raw-text ingestion throughput: the preprocess-side number every
//! corpus-scaling claim rests on (paper: Wikipedia/Web → tokenize →
//! vocab → HDFS shards before any training starts).
//!
//! Generates a Zipf-ish raw text file, then measures the full two-pass
//! ingest (parallel tokenize + vocab count, id-encode + shard write) at
//! 1/2/4 workers: MB/s per pass, end-to-end tokens/s, OOV rate, shard
//! count. DW2V_BENCH_SCALE=full quadruples the corpus.
//!
//! Run with: cargo bench --bench ingest_throughput

use dw2v::bench_util::{append_bench_trajectory, bench_scale, Table};
use dw2v::text::ingest::{ingest_file, ingest_file_overlapped, IngestConfig, OverlapOptions};
use dw2v::util::json::{num, obj, s};
use dw2v::util::rng::Pcg64;
use std::io::Write;
use std::path::PathBuf;

/// Write a synthetic raw-text corpus of roughly `target_bytes` and return
/// its path. Word ranks are drawn with a quadratic skew toward the head —
/// close enough to Zipf for tokenizer/vocab cache behaviour.
fn generate_text_file(dir: &PathBuf, target_bytes: usize, vocab: usize, seed: u64) -> PathBuf {
    let path = dir.join("corpus.txt");
    let mut out = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
    let mut rng = Pcg64::new(seed);
    let mut written = 0usize;
    while written < target_bytes {
        let len = 5 + rng.gen_range_usize(20);
        let mut line = String::with_capacity(len * 8);
        for i in 0..len {
            if i > 0 {
                line.push(' ');
            }
            let u = rng.gen_f64();
            let id = ((u * u) * vocab as f64) as usize;
            line.push_str(&format!("word{id}"));
        }
        line.push_str(".\n");
        written += line.len();
        out.write_all(line.as_bytes()).unwrap();
    }
    out.flush().unwrap();
    path
}

fn main() {
    let scale = bench_scale();
    let target_bytes = (24.0 * 1e6 * scale) as usize;
    let dir = std::env::temp_dir().join(format!("dw2v_ingest_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    println!("generating ~{:.1} MB raw text ...", target_bytes as f64 / 1e6);
    let input = generate_text_file(&dir, target_bytes, 30_000, 0xB3);

    let mut table = Table::new(
        "ingest_throughput",
        "Raw-text ingestion throughput (two-pass, streaming)",
        &["pass1 MB/s", "pass2 MB/s", "tokens/s", "oov %", "vocab", "shards"],
    );

    let mut seq4 = None; // 4-worker sequential stats, kept for the overlap comparison
    for workers in [1usize, 2, 4] {
        let cfg = IngestConfig {
            min_count: 2,
            max_vocab: 1_000_000,
            workers,
            chunk_bytes: 4 << 20,
            shard_tokens: 500_000,
        };
        let out_dir = dir.join(format!("shards_w{workers}"));
        let result = ingest_file(&input, &out_dir, &cfg).expect("ingest");
        let st = &result.stats;
        if workers == 4 {
            seq4 = Some(st.clone());
        }
        let p1 = st.bytes as f64 / st.pass1_secs.max(1e-9) / 1e6;
        let p2 = st.bytes as f64 / st.pass2_secs.max(1e-9) / 1e6;
        let tok_s = st.raw_tokens as f64 / (st.pass1_secs + st.pass2_secs).max(1e-9);
        table.row(
            &format!("{workers} workers"),
            vec![
                format!("{p1:.1}"),
                format!("{p2:.1}"),
                format!("{:.0}", tok_s),
                format!("{:.2}", 100.0 * st.oov_rate()),
                format!("{}", st.vocab_size),
                format!("{}", st.shards),
            ],
            obj(vec![
                ("label", s(&format!("{workers}_workers"))),
                ("workers", num(workers as f64)),
                ("bytes", num(st.bytes as f64)),
                ("pass1_mb_per_s", num(p1)),
                ("pass2_mb_per_s", num(p2)),
                ("tokens_per_s", num(tok_s)),
                ("oov_rate", num(st.oov_rate())),
                ("vocab", num(st.vocab_size as f64)),
                ("shards", num(st.shards as f64)),
            ]),
        );
    }

    // Overlap-mode ingest on the same corpus (4 workers): the extra
    // schedule pass + incremental manifest publication is the price of
    // letting the fleet train while the shards are still being written.
    let cfg = IngestConfig {
        min_count: 2,
        max_vocab: 1_000_000,
        workers: 4,
        chunk_bytes: 4 << 20,
        shard_tokens: 500_000,
    };
    let ocfg = OverlapOptions::new(5, 1e-3);
    let out_dir = dir.join("shards_overlap");
    let overlapped = ingest_file_overlapped(&input, &out_dir, &cfg, &ocfg).expect("overlap ingest");
    let ost = &overlapped.stats;
    let seq = seq4.expect("4-worker sequential run");
    let seq_secs = seq.pass1_secs + seq.pass2_secs;
    let ov_secs = ost.pass1_secs + ost.schedule_secs + ost.pass2_secs;
    let seq_mbps = seq.bytes as f64 / seq_secs.max(1e-9) / 1e6;
    let ov_mbps = ost.bytes as f64 / ov_secs.max(1e-9) / 1e6;
    println!(
        "\noverlap mode (4 workers): {ov_mbps:.1} MB/s end-to-end vs {seq_mbps:.1} sequential \
         ({:.1}% overhead, schedule pass {:.2}s)",
        100.0 * (ov_secs / seq_secs.max(1e-9) - 1.0),
        ost.schedule_secs
    );

    table.finish();
    append_bench_trajectory(
        "ingest_throughput",
        obj(vec![
            ("bytes", num(seq.bytes as f64)),
            ("workers", num(4.0)),
            ("sequential_mb_per_s", num(seq_mbps)),
            ("overlap_mb_per_s", num(ov_mbps)),
            ("schedule_secs", num(ost.schedule_secs)),
            (
                "overlap_overhead_pct",
                num(100.0 * (ov_secs / seq_secs.max(1e-9) - 1.0)),
            ),
        ]),
    );
    let _ = std::fs::remove_dir_all(&dir);
}
