//! Table 2 — benchmark quality per division strategy (EqualPartitioning /
//! RandomSampling / Shuffle) at two sampling rates, against the Hogwild
//! and MLlib-style baselines. Merging fixed to ALiR(PCA), as in the paper.
//!
//! Expected shape: Shuffle ≥ RandomSampling ≥ EqualPartitioning at the
//! small rate (where regularization matters most); Shuffle at the larger
//! rate competitive with (often beating) Hogwild; MLlib degrades as
//! executors grow.

use dw2v::baselines::param_avg;
use dw2v::bench_util::{append_bench_trajectory, bench_scale, Table};
use dw2v::coordinator::leader;
use dw2v::eval::report::{evaluate_suite, format_cell, mean_score};
use dw2v::runtime::{load_backend, Backend};
use dw2v::sgns::hogwild;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::json::{num, obj, s};
use dw2v::world::build_world;

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = (100_000.0 * bench_scale()) as usize;
    cfg.vocab = 2000;
    cfg.dim = 32;
    cfg.epochs = 3;
    cfg.merge = MergeMethod::AlirPca;
    // paper: thresholds at full scale; keep masks meaningful on this corpus
    cfg.min_count_base = 20.0;
    let world = build_world(&cfg);
    let backend = load_backend(&cfg, world.vocab.len()).expect("backend");
    println!("backend: {}", backend.name());

    let bench_names: Vec<String> = world.suite.iter().map(|b| b.name.clone()).collect();
    let headers: Vec<&str> = bench_names.iter().map(|x| x.as_str()).collect();
    let mut table = Table::new(
        "table2_sampling",
        "Table 2 — quality per division strategy (merge = ALiR(PCA))",
        &headers,
    );

    // paper rates {1%, 10%}; scaled setting uses {10%, 25%} (100 sub-models
    // at 1% needs the full-scale corpus to be meaningful — use
    // DW2V_BENCH_SCALE=full for rate 5%)
    let mut rates = vec![25.0, 10.0];
    if bench_scale() >= 1.0 {
        rates.push(5.0);
    }
    // cross-PR trajectory: mean suite score of each strategy at the
    // smallest common rate (10%) plus the Hogwild reference
    let mut traj: Vec<(&str, dw2v::util::json::Json)> = vec![
        ("sentences", num(cfg.sentences as f64)),
        ("backend", s(backend.name())),
    ];
    for &rate in &rates {
        for strategy in [
            DivideStrategy::EqualPartitioning,
            DivideStrategy::RandomSampling,
            DivideStrategy::Shuffle,
        ] {
            cfg.rate_percent = rate;
            cfg.strategy = strategy.clone();
            let rep =
                leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, &backend)
                    .expect("pipeline");
            let label = format!("{} {}%", strategy.name(), rate);
            table.row(
                &label,
                rep.scores.iter().map(format_cell).collect(),
                dw2v::eval::report::scores_to_json(&label, &rep.scores),
            );
            if rate == 10.0 {
                let key = match strategy {
                    DivideStrategy::EqualPartitioning => "equal_mean_10pct",
                    DivideStrategy::RandomSampling => "random_mean_10pct",
                    DivideStrategy::Shuffle => "shuffle_mean_10pct",
                };
                traj.push((key, num(mean_score(&rep.scores))));
            }
        }
    }

    // --- baselines -----------------------------------------------------------
    let scfg = leader::sgns_config(&cfg);
    let (hog, hog_stats) = hogwild::train(&world.corpus, &world.vocab, &scfg, 4, cfg.seed);
    let hog_scores = evaluate_suite(&hog, &world.suite, cfg.seed);
    table.row(
        "Hogwild",
        hog_scores.iter().map(format_cell).collect(),
        dw2v::eval::report::scores_to_json("hogwild", &hog_scores),
    );
    for executors in [8, 32] {
        let (emb, _) =
            param_avg::train(&world.corpus, &world.vocab, &scfg, &backend, executors, cfg.seed)
                .expect("mllib");
        let scores = evaluate_suite(&emb, &world.suite, cfg.seed);
        let label = format!("MLlib-style, {executors} exec");
        table.row(
            &label,
            scores.iter().map(format_cell).collect(),
            dw2v::eval::report::scores_to_json(&label, &scores),
        );
    }
    table.finish();
    traj.push(("hogwild_mean", num(mean_score(&hog_scores))));
    traj.push(("hogwild_secs", num(hog_stats.seconds)));
    append_bench_trajectory("table2_sampling", obj(traj));
    println!("\nexpected shape: shuffle ≥ random ≥ equal per rate; shuffle at the");
    println!("larger rate ≈/> hogwild; mllib quality drops with executors (paper Table 2).");
}
