//! Serving-layer throughput/recall bench: exact O(V) scan vs the HNSW
//! index vs HNSW + int8 quantized store, on the native backend with no
//! artifacts. Reports queries/sec and recall@10 (exact = 1.0 by
//! definition) plus the resident bytes of each row store — the
//! memory-for-speed-for-recall triangle the `serve/` subsystem trades in.
//!
//! `DW2V_BENCH_SCALE=full` runs the larger vocabulary; the default small
//! scale keeps the bench CI-smoke friendly (a few seconds).

use dw2v::bench_util::{append_bench_trajectory, bench_scale, time_it, Table};
use dw2v::embedding::Embedding;
use dw2v::serve::{AnnIndex, AnnParams};
use dw2v::util::json::{num, obj, s};
use dw2v::util::rng::Pcg64;
use std::collections::HashSet;
use std::hint::black_box;
use std::time::Instant;

fn main() {
    let scale = bench_scale();
    let vocab = (8000.0 * scale) as usize; // 2000 small, 8000 full
    let dim = 64usize;
    let k = 10usize;
    let n_queries = 200usize;

    // random unit-ish rows — serving cost depends on V and d, not content
    let mut emb = Embedding::zeros(vocab, dim);
    let mut rng = Pcg64::new(3);
    for w in 0..vocab as u32 {
        for v in emb.row_mut(w) {
            *v = rng.gen_gauss() as f32;
        }
    }
    let queries: Vec<u32> = (0..n_queries)
        .map(|i| ((i * vocab) / n_queries) as u32)
        .collect();

    let mut table = Table::new(
        "serve_qps",
        "§Serve — queries/sec + recall@10, exact vs ANN vs ANN+int8",
        &["metric", "value"],
    );

    let params = AnnParams::default();
    let t_build = Instant::now();
    let index = AnnIndex::build(&emb, params.clone());
    let build_secs = t_build.elapsed().as_secs_f64();
    let store = index.quantize();
    table.row(
        &format!("index build V={vocab} d={dim}"),
        vec![
            "secs | mode".into(),
            format!(
                "{build_secs:.2} | {}",
                if index.is_brute_force() { "brute" } else { "hnsw" }
            ),
        ],
        obj(vec![
            ("bench", s("index_build")),
            ("vocab", num(vocab as f64)),
            ("dim", num(dim as f64)),
            ("secs", num(build_secs)),
        ]),
    );

    // ground truth + recall bookkeeping (outside the timed sections)
    let norms = emb.row_norms();
    let exact_sets: Vec<HashSet<u32>> = queries
        .iter()
        .map(|&q| {
            emb.nearest_with_norms(emb.row(q), k, &[q], &norms)
                .into_iter()
                .map(|(w, _)| w)
                .collect()
        })
        .collect();
    let recall_of = |hits: &[Vec<(u32, f32)>]| -> f64 {
        let mut total = 0.0;
        for (set, h) in exact_sets.iter().zip(hits) {
            total += h.iter().filter(|(w, _)| set.contains(w)).count() as f64
                / set.len().max(1) as f64;
        }
        total / exact_sets.len() as f64
    };

    // ---- exact scan ----------------------------------------------------------
    let t_exact = time_it(1, 5, || {
        for &q in &queries {
            black_box(emb.nearest_with_norms(emb.row(q), k, &[q], &norms));
        }
    });
    let exact_qps = n_queries as f64 / t_exact.min_secs;
    table.row(
        "exact scan",
        vec![
            "qps | recall@10".into(),
            format!("{exact_qps:.0} | 1.000"),
        ],
        obj(vec![
            ("bench", s("exact_scan")),
            ("qps", num(exact_qps)),
            ("recall_at_10", num(1.0)),
        ]),
    );

    // ---- ANN over f32 rows ---------------------------------------------------
    let ann_hits: Vec<Vec<(u32, f32)>> = queries
        .iter()
        .map(|&q| index.search(emb.row(q), k, 0, &[q]))
        .collect();
    let t_ann = time_it(1, 5, || {
        for &q in &queries {
            black_box(index.search(emb.row(q), k, 0, &[q]));
        }
    });
    let ann_qps = n_queries as f64 / t_ann.min_secs;
    let ann_recall = recall_of(&ann_hits);
    table.row(
        &format!("ANN f32 (ef={})", params.ef_search),
        vec![
            "qps | recall@10".into(),
            format!("{ann_qps:.0} | {ann_recall:.3}"),
        ],
        obj(vec![
            ("bench", s("ann_f32")),
            ("qps", num(ann_qps)),
            ("recall_at_10", num(ann_recall)),
            ("ef_search", num(params.ef_search as f64)),
            ("speedup_vs_exact", num(ann_qps / exact_qps)),
        ]),
    );

    // ---- ANN over the int8 store ---------------------------------------------
    let annq_hits: Vec<Vec<(u32, f32)>> = queries
        .iter()
        .map(|&q| index.search_quantized(&store, emb.row(q), k, 0, &[q]))
        .collect();
    let t_annq = time_it(1, 5, || {
        for &q in &queries {
            black_box(index.search_quantized(&store, emb.row(q), k, 0, &[q]));
        }
    });
    let annq_qps = n_queries as f64 / t_annq.min_secs;
    let annq_recall = recall_of(&annq_hits);
    table.row(
        &format!("ANN int8 (ef={})", params.ef_search),
        vec![
            "qps | recall@10".into(),
            format!("{annq_qps:.0} | {annq_recall:.3}"),
        ],
        obj(vec![
            ("bench", s("ann_int8")),
            ("qps", num(annq_qps)),
            ("recall_at_10", num(annq_recall)),
            ("ef_search", num(params.ef_search as f64)),
            ("speedup_vs_exact", num(annq_qps / exact_qps)),
        ]),
    );

    // ---- resident store memory -----------------------------------------------
    let f32_bytes = index.rows().len() * 4;
    let int8_bytes = store.resident_bytes();
    table.row(
        "row store bytes f32 | int8",
        vec![
            "bytes | ratio".into(),
            format!(
                "{f32_bytes} | {int8_bytes} ({:.2}x)",
                f32_bytes as f64 / int8_bytes as f64
            ),
        ],
        obj(vec![
            ("bench", s("store_bytes")),
            ("f32_bytes", num(f32_bytes as f64)),
            ("int8_bytes", num(int8_bytes as f64)),
            ("ratio", num(f32_bytes as f64 / int8_bytes as f64)),
        ]),
    );

    table.finish();

    // longitudinal row: the headline qps/recall numbers, tracked across
    // PRs in BENCH_serve_qps.json (peak_rss_mb is stamped automatically)
    append_bench_trajectory(
        "serve_qps",
        obj(vec![
            ("vocab", num(vocab as f64)),
            ("dim", num(dim as f64)),
            ("exact_qps", num(exact_qps)),
            ("ann_qps", num(ann_qps)),
            ("ann_recall_at_10", num(ann_recall)),
            ("ann_int8_qps", num(annq_qps)),
            ("ann_int8_recall_at_10", num(annq_recall)),
        ]),
    );
}
