//! Figure 2 — training wallclock vs corpus proportion (25/50/75/100%),
//! Shuffle r=10% vs the MLlib-style synchronized baseline, plus the
//! Ordentlich column-partitioning *cost model* row the paper alludes to
//! in §4.2 (their implementation was too slow to include).
//!
//! Expected shape: both real systems scale ~linearly with corpus size;
//! the Shuffle pipeline's slope is the per-sub-model slope (asynchronous,
//! no parameter synchronization) while MLlib pays an averaging barrier
//! per epoch; the colpart model is latency-floored far above both.

use dw2v::baselines::{colpart, param_avg};
use dw2v::bench_util::{append_bench_trajectory, bench_scale, Table};
use dw2v::coordinator::leader;
use dw2v::runtime::{load_backend, Backend};
use dw2v::util::config::{DivideStrategy, ExperimentConfig};
use dw2v::util::json::{num, obj, s};
use dw2v::world::build_world;

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = (120_000.0 * bench_scale()) as usize;
    cfg.vocab = 2000;
    cfg.dim = 32;
    cfg.epochs = 2;
    cfg.rate_percent = 10.0;
    cfg.strategy = DivideStrategy::Shuffle;
    let world = build_world(&cfg);
    let backend = load_backend(&cfg, world.vocab.len()).expect("backend");
    println!("backend: {}", backend.name());
    let scfg = leader::sgns_config(&cfg);

    let mut table = Table::new(
        "fig2_scaling",
        "Figure 2 — training time (s) vs corpus proportion",
        &["25%", "50%", "75%", "100%"],
    );
    let proportions = [0.25, 0.5, 0.75, 1.0];

    // --- Shuffle 10% pipeline ---------------------------------------------
    let mut shuffle_secs = Vec::new();
    for &p in &proportions {
        let sub = world.corpus.proportion(p);
        let out = leader::train_submodels(&cfg, &sub, &world.vocab, &backend).expect("train");
        shuffle_secs.push(out.train_secs);
    }
    table.row(
        "Shuffle 10% (async)",
        shuffle_secs.iter().map(|t| format!("{t:.2}")).collect(),
        obj(vec![
            ("system", s("shuffle10")),
            ("secs", dw2v::util::json::arr(shuffle_secs.iter().map(|t| num(*t)).collect())),
        ]),
    );

    // --- MLlib-style parameter averaging ------------------------------------
    let mut mllib_secs = Vec::new();
    for &p in &proportions {
        let sub = world.corpus.proportion(p);
        let (_, stats) =
            param_avg::train(&sub, &world.vocab, &scfg, &backend, 8, cfg.seed).expect("mllib");
        mllib_secs.push(stats.seconds);
    }
    table.row(
        "MLlib-style (8 executors)",
        mllib_secs.iter().map(|t| format!("{t:.2}")).collect(),
        obj(vec![
            ("system", s("mllib8")),
            ("secs", dw2v::util::json::arr(mllib_secs.iter().map(|t| num(*t)).collect())),
        ]),
    );

    // --- Ordentlich cost model ----------------------------------------------
    // measured per-pair compute from the mllib run, + 50µs simulated RTT
    let total_tokens = world.corpus.total_tokens() as f64;
    let per_pair = mllib_secs[3] / (total_tokens * cfg.window as f64 * cfg.epochs as f64);
    let colpart_secs: Vec<f64> = proportions
        .iter()
        .map(|p| {
            let pairs =
                (total_tokens * p * cfg.window as f64 * cfg.epochs as f64) as u64;
            colpart::estimated_seconds(pairs, 10, per_pair, 50e-6)
        })
        .collect();
    table.row(
        "ColPart model (10 srv, 50µs RTT)",
        colpart_secs.iter().map(|t| format!("{t:.1}")).collect(),
        obj(vec![
            ("system", s("colpart_model")),
            ("secs", dw2v::util::json::arr(colpart_secs.iter().map(|t| num(*t)).collect())),
        ]),
    );
    table.finish();

    // linearity check for the headline system
    let r = shuffle_secs[3] / shuffle_secs[0].max(1e-9);
    // cross-PR trajectory: the full-corpus wallclock of each system plus
    // the scaling ratio — a regression in either shows up as a kink
    append_bench_trajectory(
        "fig2_scaling",
        obj(vec![
            ("sentences", num(cfg.sentences as f64)),
            ("backend", s(backend.name())),
            ("shuffle_full_secs", num(shuffle_secs[3])),
            ("mllib_full_secs", num(mllib_secs[3])),
            ("shuffle_scaling_ratio", num(r)),
        ]),
    );
    println!("\nShuffle 100%/25% time ratio: {r:.2} (linear scaling → ~4; paper Fig. 2)");
}
