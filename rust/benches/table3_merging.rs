//! Table 3 — benchmark quality per merge method (Concat / PCA /
//! ALiR(rand) / ALiR(PCA) / SingleModel / naive Average ablation) at
//! multiple sampling rates, over the SAME trained sub-models per rate.
//!
//! Expected shape: ALiR best-or-competitive per rate (clearly ahead on
//! OOV-heavy benchmarks), Concat the closest competitor at n·d
//! dimensionality, SingleModel notably worse, Average (the §3.3.1
//! counter-example) catastrophically worse.

use dw2v::bench_util::{append_bench_trajectory, bench_scale, Table};
use dw2v::coordinator::leader;
use dw2v::eval::report::{evaluate_suite, format_cell, mean_score, scores_to_json};
use dw2v::merge::average;
use dw2v::runtime::{load_backend, Backend};
use dw2v::sgns::hogwild;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::json::{num, obj};
use dw2v::world::build_world;

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = (100_000.0 * bench_scale()) as usize;
    cfg.vocab = 2000;
    cfg.dim = 32;
    cfg.epochs = 3;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.min_count_base = 20.0;
    let world = build_world(&cfg);
    let backend = load_backend(&cfg, world.vocab.len()).expect("backend");
    println!("backend: {}", backend.name());

    let bench_names: Vec<String> = world.suite.iter().map(|b| b.name.clone()).collect();
    let headers: Vec<&str> = bench_names.iter().map(|x| x.as_str()).collect();
    let mut table = Table::new(
        "table3_merging",
        "Table 3 — quality per merge method (divider = Shuffle)",
        &headers,
    );

    let mut rates = vec![25.0, 10.0];
    if bench_scale() >= 1.0 {
        rates.push(5.0);
    }
    // cross-PR trajectory: mean suite score per merge method at 10% —
    // the table's headline ALiR-vs-Concat-vs-average contrast
    let mut traj = vec![("sentences", num(cfg.sentences as f64))];
    for &rate in &rates {
        cfg.rate_percent = rate;
        let out = leader::train_submodels(&cfg, &world.corpus, &world.vocab, &backend)
            .expect("train");
        for method in [
            MergeMethod::Concat,
            MergeMethod::Pca,
            MergeMethod::AlirRand,
            MergeMethod::AlirPca,
            MergeMethod::Single,
        ] {
            cfg.merge = method.clone();
            let merged = leader::merge_trained(&cfg, &out.submodels);
            let scores = evaluate_suite(&merged.embedding, &world.suite, cfg.seed);
            let label = format!("{}% {}", rate, method.name());
            table.row(
                &label,
                scores.iter().map(format_cell).collect(),
                scores_to_json(&label, &scores),
            );
            if rate == 10.0 {
                let key = match method {
                    MergeMethod::Concat => "concat_mean_10pct",
                    MergeMethod::Pca => "pca_mean_10pct",
                    MergeMethod::AlirRand => "alir_rand_mean_10pct",
                    MergeMethod::AlirPca => "alir_pca_mean_10pct",
                    _ => "single_mean_10pct",
                };
                traj.push((key, num(mean_score(&scores))));
            }
        }
        // ablation: the naive averaging counter-example from §3.3.1
        let avg = average::merge(&out.submodels);
        let scores = evaluate_suite(&avg, &world.suite, cfg.seed);
        let label = format!("{rate}% average (ablation)");
        table.row(
            &label,
            scores.iter().map(format_cell).collect(),
            scores_to_json(&label, &scores),
        );
        if rate == 10.0 {
            traj.push(("average_mean_10pct", num(mean_score(&scores))));
        }
    }

    let scfg = leader::sgns_config(&cfg);
    let (hog, _) = hogwild::train(&world.corpus, &world.vocab, &scfg, 4, cfg.seed);
    let hog_scores = evaluate_suite(&hog, &world.suite, cfg.seed);
    table.row(
        "Hogwild",
        hog_scores.iter().map(format_cell).collect(),
        scores_to_json("hogwild", &hog_scores),
    );
    table.finish();
    traj.push(("hogwild_mean", num(mean_score(&hog_scores))));
    append_bench_trajectory("table3_merging", obj(traj));
    println!("\nexpected shape: ALiR best-or-competitive; higher rates beat lower;");
    println!("single model clearly below merged; naive average collapses (paper Table 3).");
}
