//! Performance micro/meso benches for the hot path — the §Perf evidence.
//!
//! Measures, at each layer:
//!   L3  kernel dot-product (scalar reference vs vectorized), the
//!       contended vs batched pair counter, Hogwild end-to-end pairs/s,
//!       batch assembly throughput (pairs/s), alias vs CDF negative
//!       sampling, merge-phase linalg (procrustes / PCA);
//!   bridge  PJRT dispatch latency per macro-batch and the cost of the
//!       device-resident design vs a forced host round-trip per step
//!       (the ablation that justifies the packed single-array state);
//!   end-to-end  PJRT trainer pairs/s vs the Hogwild scalar baseline.
//!
//! The PJRT sections need `artifacts/manifest.json` (`make artifacts`) and
//! a build with `--features xla`; without either they are skipped so the
//! CPU rows still land in `bench_results/perf_hotpath.json`.

use dw2v::bench_util::{append_bench_trajectory, time_it, Table};
use dw2v::gen::corpus::{build_ground_truth, generate_corpus, vocab_of, GeneratorConfig};
use dw2v::kernels;
use dw2v::linalg::mat::Mat;
use dw2v::linalg::pca;
use dw2v::linalg::procrustes::orthogonal_procrustes;
use dw2v::runtime::artifacts::Manifest;
use dw2v::runtime::client::Runtime;
use dw2v::runtime::native::NativeBackend;
use dw2v::runtime::params::SubModel;
use dw2v::runtime::{Backend, ModelShape};
use dw2v::sgns::batch::{BatchBuilder, BatchShape};
use dw2v::sgns::config::SgnsConfig;
use dw2v::sgns::hogwild;
use dw2v::sgns::negative::{AliasTable, CdfTable};
use dw2v::util::json::{num, obj, s};
use dw2v::util::rng::Pcg64;
use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};

fn main() {
    let mut table = Table::new(
        "perf_hotpath",
        "§Perf — hot-path measurements",
        &["metric", "value"],
    );

    // headline number captured along the way for the trajectory row
    let mut traj_hogwild_4t_mpairs = 0.0f64;

    // ---- L3: kernel dot product, scalar reference vs vectorized -------------
    // d=300 is the realistic upper row length; black_box the inputs per call
    // so the loop-invariant dot cannot be hoisted.
    let traj_dot_speedup = {
        let d = 300usize;
        let mut rk = Pcg64::new(11);
        let a: Vec<f32> = (0..d).map(|_| rk.gen_f32() - 0.5).collect();
        let b: Vec<f32> = (0..d).map(|_| rk.gen_f32() - 0.5).collect();
        let reps = 100_000u64;
        let t_scalar = time_it(2, 7, || {
            let mut acc = 0.0f32;
            for _ in 0..reps {
                acc += kernels::scalar::dot(black_box(&a), black_box(&b));
            }
            black_box(acc);
        });
        let t_vec = time_it(2, 7, || {
            let mut acc = 0.0f32;
            for _ in 0..reps {
                acc += kernels::dot(black_box(&a), black_box(&b));
            }
            black_box(acc);
        });
        let flops = (2 * d) as f64 * reps as f64;
        let scalar_gflops = flops / t_scalar.min_secs / 1e9;
        let vec_gflops = flops / t_vec.min_secs / 1e9;
        let speedup = t_scalar.min_secs / t_vec.min_secs;
        table.row(
            "kernel dot d=300",
            vec![
                "GFLOP/s scalar|vec|x".into(),
                format!("{scalar_gflops:.2} | {vec_gflops:.2} | {speedup:.2}x"),
            ],
            obj(vec![
                ("bench", s("kernel_dot_d300")),
                ("scalar_gflops", num(scalar_gflops)),
                ("vectorized_gflops", num(vec_gflops)),
                ("speedup", num(speedup)),
            ]),
        );
        speedup
    };

    // ---- L3: pair counter, contended fetch_add vs batched flush @ 4 threads --
    // the exact access patterns of the old and new Hogwild lr bookkeeping
    {
        let threads = 4usize;
        let n_per_thread = 2_000_000u64;
        let t_contended = time_it(1, 5, || {
            let ctr = AtomicU64::new(0);
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| {
                        for _ in 0..n_per_thread {
                            black_box(ctr.fetch_add(1, Ordering::Relaxed));
                        }
                    });
                }
            });
            assert_eq!(ctr.load(Ordering::Relaxed), threads as u64 * n_per_thread);
        });
        let t_batched = time_it(1, 5, || {
            let ctr = AtomicU64::new(0);
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| {
                        let mut snapshot = ctr.load(Ordering::Relaxed);
                        let mut pending = 0u64;
                        for _ in 0..n_per_thread {
                            black_box(snapshot + pending);
                            pending += 1;
                            if pending >= hogwild::COUNTER_FLUSH {
                                snapshot =
                                    ctr.fetch_add(pending, Ordering::Relaxed) + pending;
                                pending = 0;
                            }
                        }
                        ctr.fetch_add(pending, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(ctr.load(Ordering::Relaxed), threads as u64 * n_per_thread);
        });
        let total = (threads as u64 * n_per_thread) as f64;
        let contended_mops = total / t_contended.min_secs / 1e6;
        let batched_mops = total / t_batched.min_secs / 1e6;
        let speedup = t_contended.min_secs / t_batched.min_secs;
        table.row(
            "pair counter @4 threads",
            vec![
                "Mops/s cont|batch|x".into(),
                format!("{contended_mops:.0} | {batched_mops:.0} | {speedup:.1}x"),
            ],
            obj(vec![
                ("bench", s("pair_counter_4t")),
                ("contended_mops_per_s", num(contended_mops)),
                ("batched_mops_per_s", num(batched_mops)),
                ("speedup", num(speedup)),
            ]),
        );
    }

    // ---- L3: Hogwild end-to-end pairs/s (vectorized kernels + batched ctr) ---
    {
        let gcfg = GeneratorConfig {
            vocab: 2000,
            clusters: 20,
            truth_dim: 16,
            avg_sentence_len: 12,
            ..Default::default()
        };
        let gt = build_ground_truth(&gcfg, 5);
        let corpus = generate_corpus(&gt, 4000, 5);
        let vocab = vocab_of(&corpus, gcfg.vocab);
        let cfg = SgnsConfig {
            dim: 64,
            epochs: 2,
            ..Default::default()
        };
        for threads in [1usize, 4] {
            // report the best (minimum-wall-time) run's own pairs/seconds
            // so throughput and wall clock come from the same repetition
            let mut best_pairs_per_s = 0.0f64;
            let mut best_secs = f64::INFINITY;
            time_it(1, 3, || {
                let (emb, stats) = hogwild::train(&corpus, &vocab, &cfg, threads, 7);
                if stats.seconds < best_secs {
                    best_secs = stats.seconds;
                    best_pairs_per_s = stats.pairs as f64 / stats.seconds;
                }
                black_box(emb.data.len());
            });
            if threads == 4 {
                traj_hogwild_4t_mpairs = best_pairs_per_s / 1e6;
            }
            table.row(
                &format!("hogwild pairs/s ({threads}t, d=64)"),
                vec![
                    "Mpairs/s".into(),
                    format!("{:.2}", best_pairs_per_s / 1e6),
                ],
                obj(vec![
                    ("bench", s(&format!("hogwild_pairs_per_s_{threads}t"))),
                    ("mpairs_per_s", num(best_pairs_per_s / 1e6)),
                    ("wall_secs", num(best_secs)),
                ]),
            );
        }
    }

    // ---- L3: negative sampling ---------------------------------------------
    let mut rng = Pcg64::new(1);
    let weights: Vec<f64> = (0..10_000).map(|_| rng.gen_f64() + 0.01).collect();
    let alias = AliasTable::new(&weights);
    let cdf = CdfTable::new(&weights);
    let n_draws = 1_000_000u64;
    let t_alias = time_it(1, 5, || {
        let mut r = Pcg64::new(2);
        let mut acc = 0u64;
        for _ in 0..n_draws {
            acc += alias.sample(&mut r) as u64;
        }
        black_box(acc);
    });
    let t_cdf = time_it(1, 5, || {
        let mut r = Pcg64::new(2);
        let mut acc = 0u64;
        for _ in 0..n_draws {
            acc += cdf.sample(&mut r) as u64;
        }
        black_box(acc);
    });
    let traj_alias_mdraws = n_draws as f64 / t_alias.min_secs / 1e6;
    table.row(
        "alias sampling (10k vocab)",
        vec![
            "Mdraws/s".into(),
            format!("{:.1}", n_draws as f64 / t_alias.min_secs / 1e6),
        ],
        obj(vec![
            ("bench", s("alias_msamples_per_s")),
            ("value", num(n_draws as f64 / t_alias.min_secs / 1e6)),
        ]),
    );
    table.row(
        "cdf sampling (ablation)",
        vec![
            "Mdraws/s".into(),
            format!("{:.1}", n_draws as f64 / t_cdf.min_secs / 1e6),
        ],
        obj(vec![
            ("bench", s("cdf_msamples_per_s")),
            ("value", num(n_draws as f64 / t_cdf.min_secs / 1e6)),
        ]),
    );

    // ---- L3: batch assembly --------------------------------------------------
    let shape = BatchShape {
        batch: 256,
        steps: 8,
        negatives: 5,
        vocab: 10_000,
    };
    let sentences: Vec<Vec<u32>> = {
        let mut r = Pcg64::new(3);
        (0..2000)
            .map(|_| (0..20).map(|_| r.gen_range(10_000) as u32).collect())
            .collect()
    };
    let mut pairs_out = 0u64;
    let t_batch = time_it(1, 5, || {
        let mut b = BatchBuilder::new(
            shape,
            5,
            Vec::new(),
            AliasTable::new(&vec![1.0; 10_000]),
            Pcg64::new(4),
        );
        let mut sink = 0usize;
        for (i, sent) in sentences.iter().enumerate() {
            b.push_sentence(i as u64, sent, &mut |mb| sink += mb.real_pairs);
        }
        b.flush(&mut |mb| sink += mb.real_pairs);
        pairs_out = sink as u64;
        black_box(sink);
    });
    let traj_batch_mpairs = pairs_out as f64 / t_batch.min_secs / 1e6;
    table.row(
        "batch assembly",
        vec![
            "Mpairs/s".into(),
            format!("{:.2}", pairs_out as f64 / t_batch.min_secs / 1e6),
        ],
        obj(vec![
            ("bench", s("batch_mpairs_per_s")),
            ("value", num(pairs_out as f64 / t_batch.min_secs / 1e6)),
        ]),
    );

    // ---- merge-phase linalg ---------------------------------------------------
    let mut r = Pcg64::new(5);
    let m = Mat::from_vec(2000, 32, (0..2000 * 32).map(|_| r.gen_gauss()).collect());
    let y = Mat::from_vec(2000, 32, (0..2000 * 32).map(|_| r.gen_gauss()).collect());
    let t_proc = time_it(1, 5, || {
        black_box(orthogonal_procrustes(&m, &y));
    });
    table.row(
        "procrustes 2000x32",
        vec!["ms".into(), format!("{:.2}", t_proc.min_secs * 1e3)],
        obj(vec![("bench", s("procrustes_ms")), ("value", num(t_proc.min_secs * 1e3))]),
    );
    let x = Mat::from_vec(2000, 320, (0..2000 * 320).map(|_| r.gen_gauss()).collect());
    let t_pca = time_it(1, 3, || {
        black_box(pca::project(&x, 32));
    });
    table.row(
        "pca 2000x320 -> 32",
        vec!["ms".into(), format!("{:.1}", t_pca.min_secs * 1e3)],
        obj(vec![("bench", s("pca_ms")), ("value", num(t_pca.min_secs * 1e3))]),
    );

    // ---- native backend: macro-batch dispatch throughput ---------------------
    // the CPU twin of the PJRT dispatch rows below — always runs, so every
    // machine gets a backend-dispatch baseline in the JSON
    let traj_native_kpairs = {
        let be = NativeBackend::new(ModelShape::native(2000, 32, 64, 5, 4));
        let sh = be.shape().clone();
        let cap = sh.batch_capacity();
        let mut rb = Pcg64::new(66);
        let centers: Vec<i32> =
            (0..cap).map(|_| rb.gen_range(sh.vocab as u64) as i32).collect();
        let ctx: Vec<i32> = (0..cap * sh.k1())
            .map(|_| rb.gen_range(sh.vocab as u64) as i32)
            .collect();
        let weights = vec![1.0f32; cap];
        let mut model = SubModel::init(&be, 9).unwrap();
        let t_step = time_it(3, 20, || {
            model
                .train_macro_batch(&be, &centers, &ctx, &weights, 0.01)
                .unwrap();
        });
        let pairs_per_s = cap as f64 / t_step.p50_secs;
        table.row(
            "native dispatch v2000_d32_b64_k5_s4",
            vec![
                "ms/batch | Kpairs/s".into(),
                format!("{:.2} | {:.0}", t_step.p50_secs * 1e3, pairs_per_s / 1e3),
            ],
            obj(vec![
                ("bench", s("native_dispatch_v2000_d32")),
                ("ms_per_batch", num(t_step.p50_secs * 1e3)),
                ("kpairs_per_s", num(pairs_per_s / 1e3)),
            ]),
        );
        pairs_per_s / 1e3
    };

    // ---- bridge + end-to-end PJRT sections (need artifacts + xla feature) ----
    match Manifest::load(std::path::Path::new("artifacts")) {
        Ok(manifest) => pjrt_sections(&mut table, &manifest),
        Err(e) => eprintln!("skipping PJRT bench sections: {e}"),
    }

    table.finish();

    // longitudinal row in BENCH_perf_hotpath.json — the CPU headline
    // numbers every machine produces (peak_rss_mb stamped automatically)
    append_bench_trajectory(
        "perf_hotpath",
        obj(vec![
            ("dot_speedup_d300", num(traj_dot_speedup)),
            ("hogwild_4t_mpairs_per_s", num(traj_hogwild_4t_mpairs)),
            ("batch_mpairs_per_s", num(traj_batch_mpairs)),
            ("alias_mdraws_per_s", num(traj_alias_mdraws)),
            ("native_dispatch_kpairs_per_s", num(traj_native_kpairs)),
        ]),
    );
}

/// Resolve + compile one artifact, or announce the skip once and bail.
fn runtime_or_skip(manifest: &Manifest, name: &str) -> Option<Runtime> {
    let artifact = match manifest.by_name(name) {
        Some(a) => a,
        None => {
            eprintln!("skipping PJRT bench sections: artifact {name} not in manifest");
            return None;
        }
    };
    match Runtime::load(artifact) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT bench sections: {e}");
            None
        }
    }
}

fn pjrt_sections(table: &mut Table, manifest: &Manifest) {
    // ---- L2: scan-length (steps-per-call) ablation ---------------------------
    // same shapes, steps=1 vs steps=4: measures what the lax.scan macro-step
    // buys in dispatch amortization (per-pair cost at equal total work)
    {
        let mut per_pair = Vec::new();
        for name in ["v2000_d32_b64_k5_s1", "v2000_d32_b64_k5_s4"] {
            let Some(rt) = runtime_or_skip(manifest, name) else { return };
            let a = &rt.artifact;
            let cap = a.batch_capacity();
            let mut rb = Pcg64::new(66);
            let centers: Vec<i32> =
                (0..cap).map(|_| rb.gen_range(a.vocab as u64) as i32).collect();
            let ctx: Vec<i32> = (0..cap * a.k1())
                .map(|_| rb.gen_range(a.vocab as u64) as i32)
                .collect();
            let weights = vec![1.0f32; cap];
            let mut model = SubModel::init(&rt, 9).unwrap();
            // equal total pairs per measured iteration: s1 runs 4 dispatches
            let reps = 4 / a.steps.max(1);
            let t = time_it(3, 20, || {
                for _ in 0..reps.max(1) {
                    model
                        .train_macro_batch(&rt, &centers, &ctx, &weights, 0.01)
                        .unwrap();
                }
            });
            per_pair.push(t.p50_secs / (cap * reps.max(1)) as f64);
        }
        table.row(
            "scan ablation steps=1 vs 4",
            vec![
                "µs/pair | speedup".into(),
                format!(
                    "{:.2} vs {:.2} | {:.2}x",
                    per_pair[0] * 1e6,
                    per_pair[1] * 1e6,
                    per_pair[0] / per_pair[1]
                ),
            ],
            obj(vec![
                ("bench", s("scan_ablation")),
                ("s1_us_per_pair", num(per_pair[0] * 1e6)),
                ("s4_us_per_pair", num(per_pair[1] * 1e6)),
                ("speedup", num(per_pair[0] / per_pair[1])),
            ]),
        );
    }

    // ---- bridge: dispatch latency + device-resident ablation -----------------
    for name in ["v2000_d32_b64_k5_s4", "v10000_d64_b256_k5_s8"] {
        let Some(rt) = runtime_or_skip(manifest, name) else { return };
        let a = &rt.artifact;
        let cap = a.batch_capacity();
        let mut rb = Pcg64::new(6);
        let centers: Vec<i32> = (0..cap).map(|_| rb.gen_range(a.vocab as u64) as i32).collect();
        let ctx: Vec<i32> = (0..cap * a.k1())
            .map(|_| rb.gen_range(a.vocab as u64) as i32)
            .collect();
        let weights = vec![1.0f32; cap];
        let mut model = SubModel::init(&rt, 7).unwrap();
        let t_step = time_it(3, 20, || {
            model
                .train_macro_batch(&rt, &centers, &ctx, &weights, 0.01)
                .unwrap();
        });
        let pairs_per_s = cap as f64 / t_step.p50_secs;
        table.row(
            &format!("dispatch {name}"),
            vec![
                "ms/batch | Kpairs/s".into(),
                format!("{:.2} | {:.0}", t_step.p50_secs * 1e3, pairs_per_s / 1e3),
            ],
            obj(vec![
                ("bench", s(&format!("dispatch_{name}"))),
                ("ms_per_batch", num(t_step.p50_secs * 1e3)),
                ("kpairs_per_s", num(pairs_per_s / 1e3)),
            ]),
        );
        // ablation: force a full host round-trip of the state every step
        // (what a tuple-output / non-chained design would cost)
        let mut host_state = SubModel::init(&rt, 8).unwrap().download_packed(&rt).unwrap();
        let t_rt = time_it(2, 10, || {
            let mut m2 = SubModel::from_host(&rt, &host_state).unwrap();
            m2.train_macro_batch(&rt, &centers, &ctx, &weights, 0.01).unwrap();
            host_state = m2.download_packed(&rt).unwrap();
        });
        table.row(
            "  + host round-trip (ablation)",
            vec![
                "ms/batch".into(),
                format!("{:.2} ({:.1}x)", t_rt.p50_secs * 1e3, t_rt.p50_secs / t_step.p50_secs),
            ],
            obj(vec![
                ("bench", s(&format!("roundtrip_{name}"))),
                ("ms_per_batch", num(t_rt.p50_secs * 1e3)),
                ("slowdown", num(t_rt.p50_secs / t_step.p50_secs)),
            ]),
        );
    }
}
