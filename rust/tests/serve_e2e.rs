//! Serving-layer acceptance suite (ISSUE 3): on a generated corpus +
//! trained model,
//!   * ANN recall@10 ≥ 0.9 vs the exact scan at the default `ef_search`,
//!   * int8-quantized cosine within 2e-2 of f32,
//!   * batched concurrent queries identical to sequential answers,
//!   * missing-word reconstruction yields a finite vector and sane
//!     neighbors.
//!
//! Everything runs on the native backend with no artifacts or XLA.

use dw2v::embedding::Embedding;
use dw2v::kernels;
use dw2v::linalg::mat::Mat;
use dw2v::linalg::svd::svd;
use dw2v::serve::{AnnIndex, AnnParams, Query, QueryResult, ServeConfig, ServeEngine};
use dw2v::sgns::config::SgnsConfig;
use dw2v::sgns::hogwild;
use dw2v::util::config::ExperimentConfig;
use dw2v::util::rng::Pcg64;
use dw2v::world::build_world;

/// Train one small-but-real model on a generated corpus — cached in a
/// `OnceLock` so the recall / quantization / batching tests share one
/// training run per process.
fn trained_model() -> Embedding {
    static MODEL: std::sync::OnceLock<Embedding> = std::sync::OnceLock::new();
    MODEL.get_or_init(build_trained_model).clone()
}

fn build_trained_model() -> Embedding {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 2500;
    cfg.vocab = 600;
    cfg.clusters = 12;
    cfg.truth_dim = 8;
    cfg.seed = 41;
    let world = build_world(&cfg);
    let scfg = SgnsConfig {
        dim: 16,
        epochs: 2,
        ..Default::default()
    };
    let (emb, _) = hogwild::train(&world.corpus, &world.vocab, &scfg, 2, 41);
    assert!(emb.vocab > 128, "need the graph path, not the brute fallback");
    assert!(emb.data.iter().all(|v| v.is_finite()));
    emb
}

#[test]
fn ann_recall_at_10_meets_bar_at_default_ef() {
    let emb = trained_model();
    let index = AnnIndex::build(&emb, AnnParams::default());
    assert!(!index.is_brute_force());
    // every 7th word as a query, self-excluded, default ef_search
    let queries: Vec<u32> = (0..emb.vocab as u32).step_by(7).collect();
    let recall = index.measure_recall(&emb, &queries, 10, 0);
    assert!(
        recall >= 0.9,
        "ANN recall@10 = {recall} over {} queries",
        queries.len()
    );
}

#[test]
fn int8_cosine_stays_within_2e2_of_f32() {
    let emb = trained_model();
    let index = AnnIndex::build(&emb, AnnParams::default());
    let store = index.quantize();
    let n = index.len();
    let dim = index.dim();
    let rows = index.rows(); // unit rows → dot == cosine
    let mut rng = Pcg64::new(99);
    let mut worst = 0.0f32;
    for _ in 0..2000 {
        let i = rng.gen_range_usize(n);
        let j = rng.gen_range_usize(n);
        let q = &rows[j * dim..(j + 1) * dim];
        let exact = kernels::dot(&rows[i * dim..(i + 1) * dim], q);
        let approx = store.dot(i, q);
        worst = worst.max((exact - approx).abs());
    }
    assert!(worst < 2e-2, "worst |cos_f32 − cos_int8| = {worst}");
}

#[test]
fn batched_concurrent_queries_match_sequential() {
    let emb = trained_model();
    let engine = ServeEngine::new(emb, None, ServeConfig::default());
    let mut queries = Vec::new();
    for i in (0..500u32).step_by(9) {
        queries.push(Query::Nearest {
            word: format!("#{i}"),
            k: 10,
        });
        queries.push(Query::Analogy {
            a: format!("#{i}"),
            b: format!("#{}", i + 1),
            c: format!("#{}", i + 2),
            k: 5,
        });
    }
    // one deliberately failing query: errors must batch deterministically too
    queries.push(Query::Nearest {
        word: "#999999".to_string(),
        k: 3,
    });
    let sequential: Vec<QueryResult> = queries.iter().map(|q| engine.answer(q)).collect();
    assert!(sequential.last().unwrap().is_err());
    for round in 0..3 {
        let batched = engine.batch(&queries);
        assert_eq!(batched, sequential, "round {round}");
    }
}

/// Random d×d rotation via SVD of a gaussian matrix.
fn random_rotation(d: usize, rng: &mut Pcg64) -> Mat {
    let a = Mat::from_vec(d, d, (0..d * d).map(|_| rng.gen_gauss()).collect());
    let s = svd(&a);
    s.u.matmul(&s.v.transpose())
}

#[test]
fn missing_word_is_reconstructed_with_sane_neighbors() {
    // consensus embedding with clear cluster structure
    let (vocab, dim) = (240, 12);
    let mut rng = Pcg64::new(7);
    let mut truth = Embedding::zeros(vocab, dim);
    for w in 0..vocab as u32 {
        for v in truth.row_mut(w) {
            *v = rng.gen_gauss() as f32;
        }
    }
    // sub-models: rotated copies of the truth (what async training +
    // per-model coordinate frames produce)
    let truth_mat = Mat::from_f32(vocab, dim, &truth.data);
    let submodels: Vec<Embedding> = (0..3)
        .map(|_| {
            let rot = random_rotation(dim, &mut rng);
            Embedding::from_rows(vocab, dim, truth_mat.matmul(&rot).to_f32())
        })
        .collect();
    // the merged model lost a handful of words entirely
    let missing = [5u32, 77, 191];
    let mut merged = truth.clone();
    for &w in &missing {
        merged.present[w as usize] = false;
        merged.row_mut(w).fill(0.0);
    }
    let engine = ServeEngine::with_submodels(
        merged,
        None,
        ServeConfig::default(),
        submodels,
    );

    let norms = truth.row_norms();
    for &w in &missing {
        // reconstruction is finite and close to the true (never-stored) row
        let rec = engine.reconstruct(&format!("#{w}")).unwrap();
        assert_eq!(rec.len(), dim);
        assert!(rec.iter().all(|v| v.is_finite()));
        let cos = kernels::dot_wide(&rec, truth.row(w))
            / (kernels::norm_sq_wide(&rec).sqrt() * norms[w as usize]).max(1e-12);
        assert!(cos > 0.95, "word {w}: reconstruction cosine {cos}");

        // …and the served neighbors match the ground truth's neighborhood
        let served = engine.nearest_words(&format!("#{w}"), 5).unwrap();
        assert_eq!(served.len(), 5);
        assert!(served.iter().all(|n| n.score.is_finite() && n.id != w));
        // gold excludes every missing word — the index cannot return them
        let gold: Vec<u32> = truth
            .nearest_with_norms(truth.row(w), 5, &missing, &norms)
            .iter()
            .map(|(id, _)| *id)
            .collect();
        let overlap = served.iter().filter(|n| gold.contains(&n.id)).count();
        assert!(
            overlap >= 3,
            "word {w}: served {:?} vs gold {gold:?}",
            served.iter().map(|n| n.id).collect::<Vec<_>>()
        );
    }

    // a word absent everywhere is a clean error, not a crash
    let mut merged2 = truth.clone();
    merged2.present[3] = false;
    let engine2 = ServeEngine::with_submodels(
        merged2,
        None,
        ServeConfig::default(),
        vec![{
            let mut m = truth.clone();
            m.present[3] = false;
            m.row_mut(3).fill(0.0);
            m
        }],
    );
    assert!(engine2.nearest_words("#3", 5).is_err());
    assert!(engine2.reconstruct("#3").is_err());
}
