//! End-to-end telemetry test: a supervised chaos run (injected crash →
//! respawn → full coverage) must leave behind event journals that
//! `obs::report` can aggregate into a run report whose worker timeline
//! shows the failure and the recovery — the acceptance bar for the
//! observability layer. The live `status` renderer must work over the
//! same directory.

use dw2v::coordinator::procs::ProcsOptions;
use dw2v::coordinator::supervisor::{run_supervised, FailurePolicy, SupervisorOptions};
use dw2v::obs::report;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::world::build_world;
use std::path::PathBuf;
use std::time::Duration;

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_dw2v"))
}

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dw2v_obs_e2e_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Same small-but-real experiment the supervisor e2e uses: 2 sub-models,
/// 2 epochs, single mapper.
fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 1200;
    cfg.vocab = 250;
    cfg.clusters = 8;
    cfg.truth_dim = 8;
    cfg.dim = 16;
    cfg.window = 4;
    cfg.negatives = 4;
    cfg.epochs = 2;
    cfg.rate_percent = 50.0; // 2 sub-models
    cfg.mappers = 1;
    cfg.trainer_batch = 32;
    cfg.trainer_steps = 2;
    cfg.min_count_base = 8.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg
}

#[test]
fn chaos_run_report_shows_crash_and_respawn() {
    let cfg = small_cfg();
    let dir = tdir("report");
    let world = build_world(&cfg);
    world.corpus.write_sharded(&dir, 3).unwrap();
    std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).unwrap();

    let victim = 1usize;
    let out_dir = dir.join("submodels");
    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: out_dir.clone(),
        // crash early in epoch 0 — the respawn replays from scratch, and
        // both attempts append to the same journal file
        extra_env: vec![(
            "DW2V_FAULT".to_string(),
            format!("crash@pairs=50@submodel={victim}"),
        )],
        connect: None,
    };
    let sup = SupervisorOptions {
        policy: FailurePolicy::Retry,
        max_retries: 2,
        stall_timeout: Duration::from_secs(60),
        poll_interval: Duration::from_millis(10),
        backoff_base: Duration::from_millis(50),
        backoff_cap: Duration::from_millis(200),
        beacon_interval_ms: 50,
    };
    let rep = run_supervised(&cfg, &world.suite, &opts, &sup).unwrap();
    assert_eq!(rep.survivors(), 2, "retry must recover the crashed worker");
    assert!(rep.stats.respawns >= 1);

    // the journals the run must leave behind
    for role in ["coordinator", "worker_0", "worker_1"] {
        let p = out_dir.join(format!("events_{role}.jsonl"));
        assert!(p.is_file(), "missing journal {}", p.display());
    }

    // aggregate them — the report is the cross-run acceptance artifact
    let json_path = report::write_report(&out_dir).unwrap();
    assert!(json_path.is_file());
    assert!(out_dir.join(report::REPORT_HTML_FILE).is_file());
    let parsed = dw2v::util::json::Json::parse(
        &std::fs::read_to_string(&json_path).unwrap(),
    )
    .unwrap();

    let workers = parsed.get("workers").as_arr().expect("workers array").to_vec();
    assert_eq!(workers.len(), 2, "one rollup row per sub-model");
    let mut saw_victim = false;
    for w in &workers {
        let sub = w.get("submodel").as_usize().unwrap();
        assert_eq!(
            w.get("completed"),
            &dw2v::util::json::Json::Bool(true),
            "worker {sub} must end completed"
        );
        assert!(
            w.get("epochs").as_arr().map_or(0, |e| e.len()) >= cfg.epochs,
            "worker {sub} must journal every epoch_done"
        );
        if sub == victim {
            saw_victim = true;
            assert!(
                w.get("crashes").as_f64().unwrap_or(0.0) >= 1.0,
                "the injected crash must appear in the timeline: {w:?}"
            );
            assert!(
                w.get("respawns").as_f64().unwrap_or(0.0) >= 1.0,
                "the respawn must appear in the timeline: {w:?}"
            );
        }
    }
    assert!(saw_victim, "victim sub-model missing from the report");
    assert!(
        parsed.get("phases").get("train_secs").as_f64().unwrap_or(0.0) > 0.0,
        "fleet_done must land in the phase table"
    );
    assert!(
        parsed.get("phases").get("merge_secs").as_f64().is_some(),
        "merge_done must land in the phase table"
    );

    // the live-status renderer works over the finished run and reports done
    let mut prev = std::collections::BTreeMap::new();
    let (table, all_done) = report::render_status(&out_dir, &mut prev).unwrap();
    assert!(all_done, "every beacon says done:\n{table}");
    assert!(table.contains("done"), "{table}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A second supervised run in the same directory must not inherit the
/// first run's journals: the stale-file sweep replaces them, so a report
/// over the new run describes only the new run.
#[test]
fn rerun_starts_fresh_journals() {
    let cfg = small_cfg();
    let dir = tdir("fresh");
    let world = build_world(&cfg);
    world.corpus.write_sharded(&dir, 2).unwrap();
    std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).unwrap();

    let out_dir = dir.join("submodels");
    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: out_dir.clone(),
        extra_env: vec![(
            "DW2V_FAULT".to_string(),
            "crash@pairs=50@submodel=0".to_string(),
        )],
        connect: None,
    };
    let sup = SupervisorOptions {
        policy: FailurePolicy::Retry,
        max_retries: 2,
        stall_timeout: Duration::from_secs(60),
        poll_interval: Duration::from_millis(10),
        backoff_base: Duration::from_millis(50),
        backoff_cap: Duration::from_millis(200),
        beacon_interval_ms: 50,
    };
    run_supervised(&cfg, &world.suite, &opts, &sup).unwrap();

    // fault-free second run over the same directories
    let opts2 = ProcsOptions { extra_env: Vec::new(), ..opts };
    run_supervised(&cfg, &world.suite, &opts2, &sup).unwrap();

    let parsed = dw2v::util::json::Json::parse(
        &std::fs::read_to_string(report::write_report(&out_dir).unwrap()).unwrap(),
    )
    .unwrap();
    for w in parsed.get("workers").as_arr().expect("workers").iter() {
        assert_eq!(
            w.get("crashes").as_f64().unwrap_or(-1.0),
            0.0,
            "run 1's crash leaked into run 2's report: {w:?}"
        );
        assert_eq!(w.get("completed"), &dw2v::util::json::Json::Bool(true));
    }
    let _ = std::fs::remove_dir_all(&dir);
}
