//! Chaos end-to-end tests for the supervised multi-process path: real
//! `dw2v train-worker` OS processes (via `CARGO_BIN_EXE_dw2v`) with
//! deterministic faults injected through `DW2V_FAULT`, recovered by
//! `coordinator::supervisor::run_supervised`.
//!
//! The headline properties:
//!
//! * **crash → retry → bitwise equal** — a worker crashed mid-epoch-1 is
//!   respawned, resumes from its epoch-boundary checkpoint, and the
//!   finished run (weights *and* loss curves) is bitwise identical to an
//!   uninterrupted in-process run on the native backend;
//! * **stall → timeout → respawn** — a hung worker is detected via its
//!   frozen beacon within the configured timeout, killed and respawned;
//! * **corrupt artifact → rejected → degrade** — a worker that exits 0
//!   with a torn artifact is caught by coordinator-side validation, the
//!   error names the sub-model, and the merge proceeds over the
//!   survivors within tolerance of the full run (PR 5's SIGKILL
//!   semantics);
//! * **fail-fast** — the first failure kills the remaining pool.
//!
//! Plus the pure properties underneath: stateless Divider routing makes
//! a resumed worker consume exactly the sentences an uninterrupted one
//! would, stale artifacts are swept before a run spawns anything, and
//! artifact corruption is always attributed to its worker.

use dw2v::coordinator::leader;
use dw2v::coordinator::mapper::pack_sid;
use dw2v::coordinator::procs::{self, checkpoint_path, ProcsOptions, WorkerFate};
use dw2v::coordinator::supervisor::{run_supervised, FailurePolicy, SupervisorOptions};
use dw2v::embedding::{ArtifactMeta, Embedding, SubModelArtifact};
use dw2v::eval::report::mean_score;
use dw2v::runtime::backend::ModelShape;
use dw2v::runtime::native::NativeBackend;
use dw2v::text::corpus::Corpus;
use dw2v::text::vocab::Vocab;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::rng::Pcg64;
use dw2v::world::build_world;
use std::path::PathBuf;
use std::time::Duration;

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_dw2v"))
}

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dw2v_sup_e2e_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Same small-but-real experiment as `procs_e2e`; `mappers = 1` for the
/// deterministic delivery order the bitwise assertions need.
fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 1200;
    cfg.vocab = 250;
    cfg.clusters = 8;
    cfg.truth_dim = 8;
    cfg.dim = 16;
    cfg.window = 4;
    cfg.negatives = 4;
    cfg.epochs = 2;
    cfg.rate_percent = 50.0; // 2 sub-models
    cfg.mappers = 1;
    cfg.trainer_batch = 32;
    cfg.trainer_steps = 2;
    cfg.min_count_base = 8.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg
}

fn persist_world(
    dir: &std::path::Path,
    cfg: &ExperimentConfig,
    shards: usize,
) -> dw2v::world::World {
    let world = build_world(cfg);
    world.corpus.write_sharded(dir, shards).unwrap();
    std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).unwrap();
    world
}

/// Supervisor tuned for tests: tight polling, fast backoff, fast beacons.
fn test_sup(policy: FailurePolicy, stall_timeout: Duration) -> SupervisorOptions {
    SupervisorOptions {
        policy,
        max_retries: 2,
        stall_timeout,
        poll_interval: Duration::from_millis(10),
        backoff_base: Duration::from_millis(50),
        backoff_cap: Duration::from_millis(200),
        beacon_interval_ms: 50,
    }
}

fn inprocess_reference(
    cfg: &ExperimentConfig,
    dir: &std::path::Path,
) -> (leader::TrainOutput, Vocab) {
    let corpus = Corpus::read_sharded(dir).unwrap();
    let vocab = Vocab::from_tsv(&std::fs::read_to_string(dir.join("vocab.tsv")).unwrap()).unwrap();
    let backend = NativeBackend::new(ModelShape::for_experiment(cfg, vocab.len()));
    let out = leader::train_submodels(cfg, &corpus, &vocab, &backend).unwrap();
    (out, vocab)
}

#[test]
fn crashed_worker_resumes_from_checkpoint_bitwise() {
    let cfg = small_cfg();
    let dir = tdir("crash");
    let world = persist_world(&dir, &cfg, 3);

    // in-process reference over the exact bytes the workers will stream;
    // its per-sub-model pair counts place the crash threshold inside
    // epoch 1 — after the epoch-0 checkpoint exists, before the artifact
    let (inproc, _vocab) = inprocess_reference(&cfg, &dir);
    assert_eq!(inproc.pairs_per_submodel.len(), 2);
    let victim = 1usize;
    let threshold = (inproc.pairs_per_submodel[victim] * 3 / 4).max(1);

    let out_dir = dir.join("submodels");
    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: out_dir.clone(),
        extra_env: vec![(
            "DW2V_FAULT".to_string(),
            format!("crash@pairs={threshold}@submodel={victim}"),
        )],
        connect: None,
    };
    let sup = test_sup(FailurePolicy::Retry, Duration::from_secs(60));
    let rep = run_supervised(&cfg, &world.suite, &opts, &sup).unwrap();

    assert_eq!(rep.outcomes.len(), 2);
    assert_eq!(rep.survivors(), 2, "retry must recover the crashed worker");
    assert!(rep.stats.failures_seen >= 1, "the crash must be observed");
    assert!(rep.stats.respawns >= 1, "the crashed worker must be respawned");
    assert!(
        out_dir.join(format!("fault_{victim}_crash.fired")).exists(),
        "the injected crash must actually have fired"
    );
    for o in &rep.outcomes {
        assert_eq!(o.fate, WorkerFate::Completed, "worker {}", o.submodel);
    }
    // the published artifact supersedes the checkpoint
    assert!(
        !checkpoint_path(&out_dir.join(format!("submodel_{victim}.dwsm"))).exists(),
        "checkpoint must be removed after publication"
    );

    // crash → respawn → resume must be invisible in the result: weights
    // AND loss curves bitwise identical to the uninterrupted reference
    for o in &rep.outcomes {
        let artifact = o.artifact.as_ref().expect("survivor has artifact");
        let s = o.submodel;
        let reference = &inproc.submodels[s];
        assert_eq!(artifact.embedding.present, reference.present);
        assert_eq!(artifact.embedding.data.len(), reference.data.len());
        for (i, (a, b)) in artifact.embedding.data.iter().zip(&reference.data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "sub-model {s}: weight {i} diverges after crash+resume"
            );
        }
        assert_eq!(artifact.meta.pairs, inproc.pairs_per_submodel[s]);
        let loss: Vec<u64> = artifact.meta.epoch_loss.iter().map(|l| l.to_bits()).collect();
        let want: Vec<u64> = inproc.epoch_loss[s].iter().map(|l| l.to_bits()).collect();
        assert_eq!(
            loss, want,
            "sub-model {s}: loss curve diverges after crash+resume \
             (exact-counter restore broken?)"
        );
    }
    assert!(rep.tail.scores.iter().all(|s| s.score.is_finite()));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stalled_worker_is_killed_and_respawned() {
    let cfg = small_cfg();
    let dir = tdir("stall");
    let world = persist_world(&dir, &cfg, 3);
    let victim = 1usize;

    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: dir.join("submodels"),
        extra_env: vec![(
            "DW2V_FAULT".to_string(),
            format!("stall@epoch=1@submodel={victim}"),
        )],
        connect: None,
    };
    // the victim hangs forever before epoch 1; a 1.5 s beacon timeout
    // must catch it — an undetected stall would hang this test, not fail it
    let sup = test_sup(FailurePolicy::Retry, Duration::from_millis(1500));
    let rep = run_supervised(&cfg, &world.suite, &opts, &sup).unwrap();

    assert_eq!(rep.survivors(), 2, "respawn must recover the stalled worker");
    assert!(
        rep.stats.stalls_detected >= 1,
        "the frozen beacon must be classified as a stall"
    );
    assert!(rep.stats.respawns >= 1);
    for o in &rep.outcomes {
        assert_eq!(o.fate, WorkerFate::Completed, "worker {}", o.submodel);
    }
    // detection cost is bounded by the timeout, not by the hang: the whole
    // run (train both workers + detect + respawn + resume) stays far under
    // the forever-hang it replaced
    assert!(
        rep.train_secs < 60.0,
        "stall detection took implausibly long: {:.1}s",
        rep.train_secs
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_artifact_is_attributed_and_degraded_around() {
    let mut cfg = small_cfg();
    cfg.sentences = 1600;
    cfg.rate_percent = 25.0; // 4 sub-models
    let dir = tdir("corrupt");
    let world = persist_world(&dir, &cfg, 4);
    let victim = 1usize;

    // reference: the full 4-model run (same comparison as PR 5's SIGKILL
    // test — degrade must merge the survivors the same way)
    let (full, _vocab) = inprocess_reference(&cfg, &dir);
    let full_tail = leader::merge_and_eval(&cfg, &full.submodels, &world.suite);
    let full_mean = mean_score(&full_tail.scores);

    let out_dir = dir.join("submodels");
    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: out_dir.clone(),
        extra_env: vec![(
            "DW2V_FAULT".to_string(),
            format!("corrupt-artifact@submodel={victim}"),
        )],
        connect: None,
    };
    let sup = test_sup(FailurePolicy::Degrade, Duration::from_secs(60));
    let rep = run_supervised(&cfg, &world.suite, &opts, &sup).unwrap();

    assert_eq!(rep.outcomes.len(), 4);
    assert_eq!(rep.survivors(), 3, "exactly the corrupted worker is lost");
    assert_eq!(rep.stats.respawns, 0, "degrade never respawns");
    let dead = &rep.outcomes[victim];
    match &dead.fate {
        WorkerFate::Failed(why) => {
            assert!(
                why.contains(&format!("sub-model {victim}")),
                "failure must name its worker: {why}"
            );
            assert!(why.contains("rejected"), "{why}");
        }
        other => panic!("victim should have failed, got {other:?}"),
    }
    assert!(
        !out_dir.join(format!("submodel_{victim}.dwsm")).exists(),
        "a rejected artifact must not linger on disk"
    );

    // the survivor merge stays within tolerance of the full 4-model run
    assert!(rep.tail.merged.embedding.present_count() > 0);
    assert!(rep.tail.scores.iter().all(|s| s.score.is_finite()));
    let mean3 = mean_score(&rep.tail.scores);
    assert!(
        (mean3 - full_mean).abs() < 0.2,
        "3-survivor eval {mean3:.3} strayed too far from the 4-model run {full_mean:.3}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fail_fast_kills_the_remaining_pool() {
    let cfg = small_cfg();
    let dir = tdir("failfast");
    let world = persist_world(&dir, &cfg, 3);

    // worker 0 crashes almost immediately; worker 1 is slowed hard enough
    // (2 ms per sentence) to still be mid-run when the crash lands
    let out_dir = dir.join("submodels");
    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: out_dir.clone(),
        extra_env: vec![(
            "DW2V_FAULT".to_string(),
            "crash@pairs=1@submodel=0;slow@factor=2000@submodel=1".to_string(),
        )],
        connect: None,
    };
    let sup = test_sup(FailurePolicy::FailFast, Duration::from_secs(60));
    let err = run_supervised(&cfg, &world.suite, &opts, &sup).unwrap_err();
    assert!(err.contains("fail-fast"), "{err}");
    assert!(err.contains("worker 0"), "{err}");
    assert!(err.contains("exit code 102"), "injected crash exit code: {err}");
    assert!(
        !out_dir.join("submodel_0.dwsm").exists(),
        "the crashed worker published nothing"
    );
    assert!(
        !out_dir.join("submodel_1.dwsm").exists(),
        "fail-fast must kill the surviving worker before it publishes"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: stateless-routing property. A worker resumed at any
/// `(epoch, sentence-index)` boundary consumes exactly the routed-sid
/// suffix an uninterrupted worker would — the property checkpoint/resume
/// rests on (the Divider carries no mutable state, so replaying from a
/// boundary re-derives identical routing decisions).
#[test]
fn resumed_routing_is_a_suffix_of_uninterrupted_routing() {
    let mut rng = Pcg64::new(0xC0FFEE);
    let route = |divider: &dw2v::coordinator::divider::Divider,
                 submodel: usize,
                 corpus_len: usize,
                 from_epoch: usize,
                 from_idx: usize,
                 epochs: usize|
     -> Vec<u64> {
        let mut out = Vec::new();
        let mut buf = Vec::new();
        for epoch in from_epoch..epochs {
            let start = if epoch == from_epoch { from_idx } else { 0 };
            for idx in start..corpus_len {
                divider.targets(epoch, idx, &mut buf);
                if buf.contains(&submodel) {
                    out.push(pack_sid(epoch, idx));
                }
            }
        }
        out
    };
    for trial in 0..25u64 {
        let corpus_len = 40 + (rng.next_u64() % 300) as usize;
        let epochs = 2 + (rng.next_u64() % 4) as usize;
        let resume_epoch = 1 + (rng.next_u64() % (epochs as u64 - 1)) as usize;
        let resume_idx = (rng.next_u64() % corpus_len as u64) as usize;
        let mut cfg = ExperimentConfig::default();
        cfg.seed = rng.next_u64();
        cfg.rate_percent = if rng.next_u64() % 2 == 0 { 25.0 } else { 50.0 };
        cfg.strategy = match rng.next_u64() % 3 {
            0 => DivideStrategy::EqualPartitioning,
            1 => DivideStrategy::RandomSampling,
            _ => DivideStrategy::Shuffle,
        };
        let divider = leader::run_divider(&cfg, corpus_len).unwrap();
        let boundary = pack_sid(resume_epoch, resume_idx);
        for submodel in 0..divider.num_submodels.min(3) {
            let whole = route(&divider, submodel, corpus_len, 0, 0, epochs);
            let resumed = route(&divider, submodel, corpus_len, resume_epoch, resume_idx, epochs);
            let suffix: Vec<u64> = whole.iter().copied().filter(|&sid| sid >= boundary).collect();
            assert_eq!(
                resumed, suffix,
                "trial {trial}: resume at (epoch {resume_epoch}, idx {resume_idx}) diverges \
                 for sub-model {submodel} ({} len {corpus_len}, rate {}%)",
                cfg.strategy.name(),
                cfg.rate_percent
            );
        }
    }
}

/// Satellite: stale artifacts/checkpoints from a previous run are swept
/// before anything spawns, so a worker dying pre-publication can never
/// let an old file masquerade as this run's output.
#[test]
fn prepare_run_sweeps_stale_artifacts_and_checkpoints() {
    let cfg = small_cfg();
    let dir = tdir("stale");
    persist_world(&dir, &cfg, 2);
    let out_dir = dir.join("submodels");
    std::fs::create_dir_all(&out_dir).unwrap();
    // plant leftovers of an "earlier run" — including an index this run
    // would never spawn, which unswept would silently ride into a merge
    for stale in [
        "submodel_0.dwsm",
        "submodel_9.dwsm",
        "submodel_1.ckpt",
        "submodel_0.tmp",
        "beacon_0.json",
        "fault_1_crash.fired",
    ] {
        std::fs::write(out_dir.join(stale), b"stale junk").unwrap();
    }
    std::fs::write(out_dir.join("notes.txt"), b"keep").unwrap();

    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: out_dir.clone(),
        extra_env: Vec::new(),
        connect: None,
    };
    let (n, config_path) = procs::prepare_run(&cfg, &opts).unwrap();
    assert_eq!(n, 2);
    assert!(config_path.is_file());
    for swept in [
        "submodel_0.dwsm",
        "submodel_9.dwsm",
        "submodel_1.ckpt",
        "submodel_0.tmp",
        "beacon_0.json",
        "fault_1_crash.fired",
    ] {
        assert!(!out_dir.join(swept).exists(), "{swept} must be swept");
    }
    assert!(out_dir.join("notes.txt").exists(), "unrelated files survive");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: artifact collection attributes every rejection to its
/// sub-model — truncated body, truncated/corrupt meta, or a run-identity
/// mismatch — instead of surfacing a bare parse error (or panicking).
#[test]
fn artifact_rejection_names_the_failing_submodel() {
    let dir = tdir("attr");
    let emb = Embedding::from_rows(6, 4, vec![0.25f32; 24]);
    let artifact = SubModelArtifact {
        meta: ArtifactMeta {
            submodel: 3,
            num_submodels: 4,
            root_seed: 77,
            trainer_seed: 1234,
            strategy: "shuffle".to_string(),
            rate_percent: 25.0,
            epochs: 2,
            pairs: 999,
            epoch_loss: vec![0.5, 0.25],
        },
        embedding: emb,
    };
    let good = dir.join("submodel_3.dwsm");
    artifact.save(&good).unwrap();
    let bytes = std::fs::read(&good).unwrap();

    // the pristine artifact collects fine
    let ok = procs::collect_artifact(&good, 3, 77, 4).unwrap();
    assert_eq!(ok.meta.pairs, 999);

    // truncated body: the f32 payload is cut short
    let t_body = dir.join("t_body.dwsm");
    std::fs::write(&t_body, &bytes[..bytes.len() - 9]).unwrap();
    let err = procs::collect_artifact(&t_body, 3, 77, 4).unwrap_err();
    assert!(err.contains("sub-model 3"), "{err}");
    assert!(err.contains("rejected"), "{err}");

    // truncated meta: the file ends inside the JSON header
    let t_meta = dir.join("t_meta.dwsm");
    std::fs::write(&t_meta, &bytes[..15]).unwrap();
    let err = procs::collect_artifact(&t_meta, 3, 77, 4).unwrap_err();
    assert!(err.contains("sub-model 3"), "{err}");

    // syntactically corrupt meta: stomp a byte inside the JSON region —
    // must come back as an attributed error, never a parse panic
    let c_meta = dir.join("c_meta.dwsm");
    let mut stomped = bytes.clone();
    stomped[14] = 0xFF;
    std::fs::write(&c_meta, &stomped).unwrap();
    let err = procs::collect_artifact(&c_meta, 3, 77, 4).unwrap_err();
    assert!(err.contains("sub-model 3"), "{err}");

    // meta/config mismatch: a healthy artifact from a *different* run
    let err = procs::collect_artifact(&good, 3, 78, 4).unwrap_err();
    assert!(err.contains("sub-model 3"), "{err}");
    assert!(err.contains("different run"), "{err}");
    let err = procs::collect_artifact(&good, 2, 77, 4).unwrap_err();
    assert!(err.contains("sub-model 2"), "{err}");

    // a missing file is attributed too
    let err = procs::collect_artifact(&dir.join("absent.dwsm"), 1, 77, 4).unwrap_err();
    assert!(err.contains("sub-model 1"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
