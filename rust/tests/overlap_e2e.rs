//! End-to-end tests for ingest-while-training overlap: a raw text file
//! is ingested into a shard directory **while** real `dw2v train-worker`
//! OS processes train out of it in feed mode.
//!
//! The two headline properties:
//!
//! * **determinism** — the overlapped run merges bitwise identical to
//!   ingest-then-train over the same text on the native backend (the
//!   schedule block carries the exact totals a sequential worker would
//!   compute itself, and the feed preserves global sentence order);
//! * **overlap is real** — with the ingest throttled via
//!   `OverlapOptions::shard_delay`, the workers' published
//!   `feedstat_<s>.json` proves training started before the last shard
//!   existed (`shards_at_train_start < shards_final`).

use dw2v::coordinator::leader;
use dw2v::coordinator::overlap::{run_overlapped, OverlapRunOptions};
use dw2v::coordinator::procs::ProcsOptions;
use dw2v::coordinator::supervisor::{run_supervised, SupervisorOptions};
use dw2v::text::feed::{FeedOptions, ShardManifest};
use dw2v::text::ingest::{ingest_file, IngestConfig, OverlapOptions};
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::json::Json;
use dw2v::util::rng::Pcg64;
use dw2v::world::World;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_dw2v"))
}

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dw2v_overlap_e2e_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a Zipf-ish synthetic raw-text corpus: `sentences` lines of
/// 5–14 words drawn from `vocab` ranks with a quadratic head skew.
fn write_text_corpus(dir: &Path, sentences: usize, vocab: usize, seed: u64) -> PathBuf {
    let path = dir.join("corpus.txt");
    let mut out = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
    let mut rng = Pcg64::new(seed);
    for _ in 0..sentences {
        let len = 5 + rng.gen_range_usize(10);
        let mut line = String::new();
        for i in 0..len {
            if i > 0 {
                line.push(' ');
            }
            let u = rng.gen_f64();
            let id = ((u * u) * vocab as f64) as usize;
            line.push_str(&format!("word{id}"));
        }
        line.push('\n');
        out.write_all(line.as_bytes()).unwrap();
    }
    out.flush().unwrap();
    path
}

/// Small-but-real experiment over raw text; `mappers = 1` for
/// deterministic delivery order (same knob as the procs bitwise test).
fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dim = 16;
    cfg.window = 4;
    cfg.negatives = 4;
    cfg.epochs = 2;
    cfg.rate_percent = 50.0; // 2 sub-models
    cfg.mappers = 1;
    cfg.trainer_batch = 32;
    cfg.trainer_steps = 2;
    cfg.min_count_base = 2.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg
}

/// Ingest knobs sized so the corpus splits into several shards — the
/// overlap is meaningless with everything in shard 0.
fn small_ingest(workers: usize) -> IngestConfig {
    IngestConfig {
        min_count: 2,
        max_vocab: 100_000,
        workers,
        chunk_bytes: 64 << 10,
        shard_tokens: 2_000,
    }
}

fn overlap_run_opts(
    cfg: &ExperimentConfig,
    input: PathBuf,
    ingest: IngestConfig,
    shard_delay: Duration,
) -> OverlapRunOptions {
    let scfg = leader::sgns_config(cfg);
    let mut overlap = OverlapOptions::new(scfg.window, scfg.subsample_t);
    overlap.shard_delay = shard_delay;
    OverlapRunOptions {
        input,
        ingest,
        overlap,
        eval: None,
        feed: FeedOptions::default(),
    }
}

#[test]
fn overlapped_run_is_bitwise_identical_to_back_to_back() {
    let cfg = small_cfg();
    let dir = tdir("bitwise");
    let input = write_text_corpus(&dir, 1400, 220, 0x0517);
    let icfg = small_ingest(2);

    // reference: ingest to completion, then train the fleet over the
    // finished directory (snapshot mode — workers estimate their own
    // pair totals from the full shard set)
    let seq_dir = dir.join("seq_shards");
    let seq_ingest = ingest_file(&input, &seq_dir, &icfg).expect("sequential ingest");
    assert!(
        seq_ingest.stats.shards >= 3,
        "need several shards for the overlap to mean anything, got {}",
        seq_ingest.stats.shards
    );
    let (seq_vocab, suite) =
        World::vocab_and_suite_from_shards(&seq_dir, None).expect("coordinator inputs");
    let sup = SupervisorOptions::default();
    let seq_opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: seq_dir.clone(),
        out_dir: dir.join("seq_models"),
        extra_env: Vec::new(),
        connect: None,
    };
    let seq_rep = run_supervised(&cfg, &suite, &seq_opts, &sup).expect("sequential run");
    assert_eq!(seq_rep.survivors(), 2);

    // overlapped: same text, same config, shards throttled so they are
    // still being published while the workers train
    let ov_opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.join("ov_shards"),
        out_dir: dir.join("ov_models"),
        extra_env: Vec::new(),
        connect: None,
    };
    let ov = overlap_run_opts(&cfg, input, icfg, Duration::from_millis(60));
    let ov_rep = run_overlapped(&cfg, &ov_opts, &sup, &ov).expect("overlapped run");
    assert_eq!(ov_rep.sup.survivors(), 2);

    // the ingest side saw the identical corpus …
    assert_eq!(ov_rep.ingest.stats.shards, seq_ingest.stats.shards);
    assert_eq!(ov_rep.ingest.stats.kept_tokens, seq_ingest.stats.kept_tokens);
    assert_eq!(ov_rep.vocab.len(), seq_vocab.len());

    // … and the merged consensus is bitwise identical to back-to-back
    let a = &seq_rep.tail.merged.embedding;
    let b = &ov_rep.sup.tail.merged.embedding;
    assert_eq!(a.vocab, b.vocab);
    assert_eq!(a.dim, b.dim);
    assert_eq!(a.present, b.present, "presence masks must match");
    assert_eq!(a.data.len(), b.data.len());
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "weight {i} differs between overlapped and back-to-back runs"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn throttled_ingest_proves_training_started_before_shards_finished() {
    let cfg = small_cfg();
    let dir = tdir("throttle");
    let input = write_text_corpus(&dir, 1000, 180, 0x0907);
    let icfg = small_ingest(2);

    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.join("shards"),
        out_dir: dir.join("models"),
        extra_env: Vec::new(),
        connect: None,
    };
    let sup = SupervisorOptions::default();
    // 200 ms per shard: several shards' worth of publication still ahead
    // by the time the workers' feeds open
    let ov = overlap_run_opts(&cfg, input, icfg, Duration::from_millis(200));
    let rep = run_overlapped(&cfg, &opts, &sup, &ov).expect("overlapped run");
    assert_eq!(rep.sup.survivors(), 2);

    let man = ShardManifest::load(&opts.shard_dir)
        .expect("manifest readable")
        .expect("manifest exists");
    assert!(man.complete, "ingest must have finished");
    let final_shards = man.num_shards();
    assert!(final_shards >= 3, "got only {final_shards} shards");

    // every worker published its feed stats; at least one demonstrably
    // opened its feed before the ingest was done
    let mut overlapped = false;
    for s in 0..2usize {
        let path = opts.out_dir.join(format!("feedstat_{s}.json"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        let stat = Json::parse(&text).expect("feedstat parses");
        let at_start = stat.get("shards_at_train_start").as_usize().unwrap();
        let at_end = stat.get("shards_final").as_usize().unwrap();
        assert_eq!(at_end, final_shards, "feedstat_{s} final count");
        if at_start < at_end {
            overlapped = true;
        }
    }
    assert!(
        overlapped,
        "no worker saw a growing shard dir — the throttle failed to overlap \
         ingest with training"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
