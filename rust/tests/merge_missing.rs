//! Merge robustness to missing vocabulary (the paper's Fig-3 scenario):
//! sub-models with deliberately partial — down to fully disjoint —
//! presence masks must merge without panicking under **every**
//! `MergeMethod`, and words present in at least one sub-model must be
//! reconstructed wherever the method's vocabulary semantics allow
//! (union for ALiR, intersection for Concat/PCA).

use dw2v::embedding::Embedding;
use dw2v::merge::alir::AlirOptions;
use dw2v::merge::merge_models;
use dw2v::util::config::MergeMethod;
use dw2v::util::rng::Pcg64;

const ALL_METHODS: [MergeMethod; 5] = [
    MergeMethod::Concat,
    MergeMethod::Pca,
    MergeMethod::AlirRand,
    MergeMethod::AlirPca,
    MergeMethod::Single,
];

fn random_model(vocab: usize, dim: usize, seed: u64) -> Embedding {
    let mut rng = Pcg64::new(seed);
    let data = (0..vocab * dim).map(|_| rng.gen_gauss() as f32).collect();
    Embedding::from_rows(vocab, dim, data)
}

fn drop_word(m: &mut Embedding, w: u32) {
    m.present[w as usize] = false;
    m.row_mut(w).fill(0.0);
}

/// 4 models over 40 words, each missing a different 10-word block —
/// pairwise-overlapping presence, empty intersection on the blocks.
fn partial_models(dim: usize) -> Vec<Embedding> {
    (0..4u64)
        .map(|i| {
            let mut m = random_model(40, dim, 100 + i);
            let lo = (i as u32) * 10;
            for w in lo..lo + 10 {
                drop_word(&mut m, w);
            }
            m
        })
        .collect()
}

/// 4 models over 40 words with fully disjoint presence: model i owns
/// exactly words [10·i, 10·i+10).
fn disjoint_models(dim: usize) -> Vec<Embedding> {
    (0..4u64)
        .map(|i| {
            let mut m = random_model(40, dim, 200 + i);
            let lo = (i as u32) * 10;
            for w in 0..40u32 {
                if !(lo..lo + 10).contains(&w) {
                    drop_word(&mut m, w);
                }
            }
            m
        })
        .collect()
}

fn assert_finite(e: &Embedding) {
    assert!(
        e.data.iter().all(|x| x.is_finite()),
        "merged embedding contains non-finite values"
    );
}

#[test]
fn partial_vocab_merges_without_panic_for_every_method() {
    let models = partial_models(8);
    for method in ALL_METHODS {
        let r = merge_models(&models, &method, &AlirOptions::default(), 7);
        assert_finite(&r.embedding);
        match method {
            // union semantics: every word is present somewhere, so the
            // merged model reconstructs all 40
            MergeMethod::AlirRand | MergeMethod::AlirPca => {
                assert_eq!(
                    r.embedding.present_count(),
                    40,
                    "{} must reconstruct the union",
                    method.name()
                );
                // reconstructed rows are usable, not zero placeholders
                for w in 0..40u32 {
                    let norm: f32 = r.embedding.row(w).iter().map(|x| x * x).sum();
                    assert!(norm > 0.0, "{} left word {w} empty", method.name());
                }
            }
            // intersection semantics: every word is missing somewhere
            MergeMethod::Concat | MergeMethod::Pca => {
                assert_eq!(
                    r.embedding.present_count(),
                    0,
                    "{} keeps only the (empty) intersection",
                    method.name()
                );
            }
            MergeMethod::Single => {
                assert_eq!(r.embedding.present_count(), 30);
            }
        }
    }
}

#[test]
fn disjoint_vocab_merges_without_panic_for_every_method() {
    let models = disjoint_models(8);
    for method in ALL_METHODS {
        let r = merge_models(&models, &method, &AlirOptions::default(), 9);
        assert_finite(&r.embedding);
        match method {
            MergeMethod::AlirRand | MergeMethod::AlirPca => {
                assert_eq!(r.embedding.present_count(), 40);
            }
            MergeMethod::Concat | MergeMethod::Pca => {
                assert_eq!(r.embedding.present_count(), 0);
            }
            MergeMethod::Single => {
                assert_eq!(r.embedding.present_count(), 10);
            }
        }
    }
}

#[test]
fn word_present_in_one_model_survives_alir_and_correlates() {
    // near-identical copies of one truth matrix, with word 5 present only
    // in model 2 — ALiR must keep it AND place it consistently with the
    // consensus (cosine structure, not just non-zero)
    let vocab = 24;
    let dim = 6;
    let truth = random_model(vocab, dim, 77);
    let models: Vec<Embedding> = (0..4)
        .map(|i| {
            let mut m = truth.clone();
            // small per-model perturbation so models aren't identical
            let mut nrng = Pcg64::new_stream(31, i as u64);
            for v in m.data.iter_mut() {
                *v += 0.01 * nrng.gen_gauss() as f32;
            }
            if i != 2 {
                drop_word(&mut m, 5);
            }
            m
        })
        .collect();
    for method in [MergeMethod::AlirPca, MergeMethod::AlirRand] {
        let r = merge_models(&models, &method, &AlirOptions::default(), 13);
        assert!(r.embedding.is_present(5), "{}", method.name());
        assert_finite(&r.embedding);
        // word 5's nearest relations should mirror the truth's: compare
        // cosine to a word it is similar/dissimilar to in truth space
        let mut best = (0u32, -1.0f64);
        for w in 0..vocab as u32 {
            if w == 5 {
                continue;
            }
            let c = truth.cosine(5, w).unwrap();
            if c > best.1 {
                best = (w, c);
            }
        }
        let merged_cos = r.embedding.cosine(5, best.0).unwrap();
        assert!(
            merged_cos > 0.3,
            "{}: reconstructed word lost its structure (cos {merged_cos:.3} to truth-nearest)",
            method.name()
        );
    }
}

#[test]
fn single_missing_word_per_method_keeps_everyone_else() {
    // the gentle version: one word missing from one model — Concat/PCA
    // drop exactly that word, ALiR keeps everything
    let mut models: Vec<Embedding> = (0..3u64).map(|i| random_model(20, 6, 300 + i)).collect();
    drop_word(&mut models[1], 7);
    for method in ALL_METHODS {
        let r = merge_models(&models, &method, &AlirOptions::default(), 5);
        assert_finite(&r.embedding);
        let present = r.embedding.present_count();
        match method {
            MergeMethod::Concat | MergeMethod::Pca => {
                assert_eq!(present, 19, "{}", method.name());
                assert!(!r.embedding.is_present(7));
            }
            MergeMethod::AlirRand | MergeMethod::AlirPca => {
                assert_eq!(present, 20, "{}", method.name());
            }
            MergeMethod::Single => assert_eq!(present, 20), // model 0 is full
        }
    }
}
