//! Integration tests over the real AOT bridge: these load the HLO
//! artifacts produced by `make artifacts` and exercise the PJRT runtime,
//! the trainers and the full pipeline end to end.
//!
//! Requires `artifacts/manifest.json` (run `make artifacts` first) — the
//! tests fail with an actionable message otherwise — and the `xla`
//! feature: without it the whole file compiles to nothing, because the
//! stub runtime cannot execute anything.
#![cfg(feature = "xla")]

use dw2v::coordinator::leader;
use dw2v::eval::report::{evaluate_suite, mean_score};
use dw2v::runtime::artifacts::Manifest;
use dw2v::runtime::client::Runtime;
use dw2v::runtime::params::SubModel;
use dw2v::sgns::config::SgnsConfig;
use dw2v::sgns::trainer::SubModelTrainer;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::world::build_world;
use std::path::Path;
use std::sync::OnceLock;

fn artifact_dir() -> &'static Path {
    Path::new("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| {
        Manifest::load(artifact_dir()).expect("run `make artifacts` before cargo test")
    })
}

/// One shared runtime per artifact across the whole test binary (PJRT
/// client construction is cheap, but compilation isn't).
fn unit_runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        let artifact = manifest().by_name("v64_d8_b8_k2_s2").expect("unit artifact");
        Runtime::load(artifact).expect("compile unit artifact")
    })
}

fn tiny_runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        let artifact = manifest()
            .by_name("v2000_d32_b64_k5_s4")
            .expect("tiny artifact");
        Runtime::load(artifact).expect("compile tiny artifact")
    })
}

// ---------------------------------------------------------------- runtime

#[test]
fn metrics_row_starts_zero_and_counts_steps() {
    let rt = unit_runtime();
    let mut model = SubModel::init(rt, 1).unwrap();
    let m0 = model.metrics(rt).unwrap();
    assert_eq!(m0.loss_sum, 0.0);
    assert_eq!(m0.micro_steps, 0.0);

    let a = &rt.artifact;
    let cap = a.batch_capacity();
    let centers = vec![0i32; cap];
    let ctx = vec![1i32; cap * a.k1()];
    let weights = vec![1.0f32; cap];
    model.train_macro_batch(rt, &centers, &ctx, &weights, 0.01).unwrap();
    let m1 = model.metrics(rt).unwrap();
    assert_eq!(m1.micro_steps, a.steps as f64);
    assert_eq!(m1.examples, cap as f64);
    assert!(m1.loss_sum > 0.0);
    // untrained loss per example ≈ (1+k)·ln2
    let per = m1.loss_sum / m1.examples;
    let expect = (1.0 + a.negatives as f64) * std::f64::consts::LN_2;
    assert!((per - expect).abs() < 0.2, "per-example loss {per} vs {expect}");
}

#[test]
fn padding_batches_touch_nothing_but_metrics() {
    let rt = unit_runtime();
    let a = &rt.artifact;
    let mut model = SubModel::init(rt, 2).unwrap();
    let before = {
        // download through the embedding API (full present mask)
        let m = SubModel::init(rt, 2).unwrap();
        m.into_embedding(rt, a.vocab, vec![true; a.vocab]).unwrap()
    };
    let cap = a.batch_capacity();
    let centers = vec![a.vocab as i32; cap]; // all padding sentinel
    let ctx = vec![a.vocab as i32; cap * a.k1()];
    let weights = vec![0.0f32; cap];
    model.train_macro_batch(rt, &centers, &ctx, &weights, 0.5).unwrap();
    let after = model.into_embedding(rt, a.vocab, vec![true; a.vocab]).unwrap();
    assert_eq!(before.data, after.data, "padding must not move parameters");
}

#[test]
fn training_reduces_loss_on_planted_pattern() {
    let rt = unit_runtime();
    let a = &rt.artifact;
    let mut model = SubModel::init(rt, 3).unwrap();
    let cap = a.batch_capacity();
    // planted: word i co-occurs with word i+32; negatives from 0..32
    let mut rng = dw2v::util::rng::Pcg64::new(5);
    let mut make_batch = |rng: &mut dw2v::util::rng::Pcg64| {
        let mut centers = Vec::with_capacity(cap);
        let mut ctx = Vec::with_capacity(cap * a.k1());
        for _ in 0..cap {
            let c = rng.gen_range(32) as i32;
            centers.push(c);
            ctx.push(c + 32); // positive
            for _ in 0..a.negatives {
                ctx.push(rng.gen_range(32) as i32);
            }
        }
        (centers, ctx, vec![1.0f32; cap])
    };
    let mut losses = Vec::new();
    let mut prev = 0.0;
    for _ in 0..80 {
        let (c, x, w) = make_batch(&mut rng);
        model.train_macro_batch(rt, &c, &x, &w, 0.3).unwrap();
        let m = model.metrics(rt).unwrap();
        losses.push(m.loss_sum - prev);
        prev = m.loss_sum;
    }
    let early: f64 = losses[..5].iter().sum();
    let late: f64 = losses[75..].iter().sum();
    assert!(
        late < early * 0.8,
        "loss should drop: early {early:.2} late {late:.2}"
    );
}

#[test]
fn on_device_similarity_matches_host_cosine() {
    let rt = unit_runtime();
    let a = &rt.artifact;
    let mut model = SubModel::init(rt, 7).unwrap();
    // a couple of training steps to make embeddings non-trivial
    let cap = a.batch_capacity();
    let centers: Vec<i32> = (0..cap as i32).map(|i| i % 60).collect();
    let ctx: Vec<i32> = (0..(cap * a.k1()) as i32).map(|i| i % 60).collect();
    model
        .train_macro_batch(rt, &centers, &ctx, &vec![1.0; cap], 0.5)
        .unwrap();
    let pairs: Vec<(u32, u32)> = vec![(0, 1), (2, 3), (10, 50), (5, 5)];
    let dev = model.similarity(rt, &pairs).unwrap();
    let emb = model.into_embedding(rt, a.vocab, vec![true; a.vocab]).unwrap();
    for ((x, y), d) in pairs.iter().zip(dev) {
        let host = emb.cosine(*x, *y).unwrap();
        assert!(
            (host - d as f64).abs() < 1e-4,
            "({x},{y}): host {host} device {d}"
        );
    }
}

// ---------------------------------------------------------------- trainer

#[test]
fn trainer_presence_mask_respects_min_count() {
    let rt = unit_runtime();
    let vocab = dw2v::text::vocab::Vocab::from_ordered(
        (0..60).map(|i| (format!("w{i}"), 10)).collect(),
    );
    let cfg = SgnsConfig {
        dim: 8,
        negatives: 2,
        ..Default::default()
    };
    let mut trainer = SubModelTrainer::new(rt, &vocab, &cfg, 1000, 11).unwrap();
    // words 0..5 appear 4 times each, word 6 once
    for _ in 0..4 {
        trainer.push_sentence(0, &[0, 1, 2, 3, 4, 5]).unwrap();
    }
    trainer.push_sentence(99, &[6, 0]).unwrap();
    let mask = trainer.present_mask(3);
    assert!(mask[..6].iter().all(|&m| m));
    assert!(!mask[6]);
    assert!(!mask[30]);
    let emb = trainer.into_embedding(3).unwrap();
    assert_eq!(emb.present_count(), 6);
    assert_eq!(emb.vocab, 60);
}

// ---------------------------------------------------------------- pipeline

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 2500;
    cfg.vocab = 500;
    cfg.clusters = 10;
    cfg.truth_dim = 8;
    cfg.dim = 32; // matches tiny artifact
    cfg.epochs = 2;
    cfg.rate_percent = 25.0; // 4 sub-models
    cfg.mappers = 2;
    // paper threshold 100/k assumes full-corpus scale; scale it to this
    // tiny test corpus so presence masks stay meaningful
    cfg.min_count_base = 8.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg
}

#[test]
fn full_pipeline_beats_random_and_covers_vocab() {
    let cfg = small_cfg();
    let world = build_world(&cfg);
    let rt = tiny_runtime();
    let rep = leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, rt)
        .expect("pipeline");
    assert_eq!(rep.train.submodels.len(), 4);
    assert!(rep.train.pairs > 50_000, "pairs={}", rep.train.pairs);
    // each sub-model saw a different sample but similar volume
    for m in &rep.train.submodels {
        let frac = m.present_count() as f64 / world.vocab.len() as f64;
        assert!(frac > 0.5, "sub-model covers too little vocab: {frac}");
    }
    // merged union must cover nearly everything
    assert!(rep.merged_vocab as f64 > 0.9 * world.vocab.len() as f64);
    // quality: clearly better than a random embedding on similarity
    let mut rng = dw2v::util::rng::Pcg64::new(1);
    let mut rand_emb = dw2v::embedding::Embedding::zeros(world.vocab.len(), cfg.dim);
    for v in rand_emb.data.iter_mut() {
        *v = rng.gen_gauss() as f32;
    }
    let rand_scores = evaluate_suite(&rand_emb, &world.suite, 1);
    let sim_mean = |scores: &[dw2v::eval::report::BenchmarkScore]| {
        let sims: Vec<f64> = scores
            .iter()
            .filter(|s| s.name.starts_with("sim"))
            .map(|s| s.score)
            .collect();
        sims.iter().sum::<f64>() / sims.len() as f64
    };
    let trained = sim_mean(&rep.scores);
    let random = sim_mean(&rand_scores);
    assert!(
        trained > random + 0.15,
        "trained {trained:.3} vs random {random:.3}"
    );
    // loss curves: every sub-model's epoch-2 loss below epoch-1
    for losses in &rep.train.epoch_loss {
        assert_eq!(losses.len(), 2);
        assert!(losses[1] < losses[0], "loss curve not decreasing: {losses:?}");
    }
}

#[test]
fn shuffle_differs_from_random_sampling_deterministically() {
    let mut cfg = small_cfg();
    cfg.sentences = 800;
    cfg.epochs = 2;
    let world = build_world(&cfg);
    let rt = tiny_runtime();
    cfg.strategy = DivideStrategy::Shuffle;
    let a = leader::train_submodels(&cfg, &world.corpus, &world.vocab, rt).unwrap();
    let b = leader::train_submodels(&cfg, &world.corpus, &world.vocab, rt).unwrap();
    cfg.strategy = DivideStrategy::RandomSampling;
    let c = leader::train_submodels(&cfg, &world.corpus, &world.vocab, rt).unwrap();
    // determinism: identical run -> identical pair counts per submodel
    assert_eq!(a.pairs, b.pairs);
    // shuffle vs random-sampling route different sentences
    assert_ne!(a.pairs, c.pairs);
}

#[test]
fn merge_method_comparison_runs_on_shared_submodels() {
    let mut cfg = small_cfg();
    cfg.sentences = 1200;
    let world = build_world(&cfg);
    let rt = tiny_runtime();
    let out = leader::train_submodels(&cfg, &world.corpus, &world.vocab, rt).unwrap();
    let mut means = Vec::new();
    for method in [
        MergeMethod::Concat,
        MergeMethod::Pca,
        MergeMethod::AlirPca,
        MergeMethod::Single,
    ] {
        cfg.merge = method.clone();
        let merged = leader::merge_trained(&cfg, &out.submodels);
        let scores = evaluate_suite(&merged.embedding, &world.suite, cfg.seed);
        means.push((method, mean_score(&scores)));
    }
    // all methods produce usable embeddings
    for (m, score) in &means {
        assert!(score.is_finite(), "{m:?} produced NaN");
    }
    // a merged model should beat a single sub-model on average
    let single = means.iter().find(|(m, _)| *m == MergeMethod::Single).unwrap().1;
    let alir = means.iter().find(|(m, _)| *m == MergeMethod::AlirPca).unwrap().1;
    assert!(
        alir > single - 0.02,
        "ALiR ({alir:.3}) should not lose badly to a single sub-model ({single:.3})"
    );
}
