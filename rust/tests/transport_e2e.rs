//! End-to-end tests for the pluggable transport layer: the same seeded
//! run driven over the filesystem transport and over a loopback
//! `dw2v shard-server` must be indistinguishable.
//!
//! The headline properties:
//!
//! * **transport equivalence** — with `mappers = 1` on the native
//!   backend, a supervised run whose workers stream shards from and
//!   upload artifacts to a TCP shard-server merges bitwise identical
//!   (weights, loss curves, pair counts) to the same run over the local
//!   filesystem;
//! * **failure parity** — a remote worker that dies (SIGKILL, or an
//!   injected `DW2V_FAULT` crash under the degrade policy) costs exactly
//!   its sub-model, same as a local one: same fate text, no artifact
//!   left in the run dir, survivors merged within tolerance;
//! * **mirroring** — every worker upload (beacons, journals, fault
//!   markers) lands in the server's run dir as ordinary files, so the
//!   supervisor and `dw2v status`/`report` never know the fleet was
//!   remote.

use dw2v::coordinator::leader;
use dw2v::coordinator::procs::{self, ProcsOptions, WorkerFate};
use dw2v::coordinator::supervisor::{run_supervised, FailurePolicy, SupervisorOptions};
use dw2v::eval::report::mean_score;
use dw2v::obs::journal::journal_file_name;
use dw2v::runtime::backend::ModelShape;
use dw2v::runtime::native::NativeBackend;
use dw2v::text::corpus::Corpus;
use dw2v::text::vocab::Vocab;
use dw2v::transport::server::ShardServer;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::world::build_world;
use std::path::PathBuf;
use std::time::Duration;

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_dw2v"))
}

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dw2v_tx_e2e_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Same small-but-real experiment as `procs_e2e`; `mappers = 1` for the
/// deterministic delivery order the bitwise assertions need.
fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 1200;
    cfg.vocab = 250;
    cfg.clusters = 8;
    cfg.truth_dim = 8;
    cfg.dim = 16;
    cfg.window = 4;
    cfg.negatives = 4;
    cfg.epochs = 2;
    cfg.rate_percent = 50.0; // 2 sub-models
    cfg.mappers = 1;
    cfg.trainer_batch = 32;
    cfg.trainer_steps = 2;
    cfg.min_count_base = 8.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg
}

fn persist_world(
    dir: &std::path::Path,
    cfg: &ExperimentConfig,
    shards: usize,
) -> dw2v::world::World {
    let world = build_world(cfg);
    world.corpus.write_sharded(dir, shards).unwrap();
    std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).unwrap();
    world
}

fn test_sup(policy: FailurePolicy) -> SupervisorOptions {
    SupervisorOptions {
        policy,
        max_retries: 2,
        stall_timeout: Duration::from_secs(60),
        poll_interval: Duration::from_millis(10),
        backoff_base: Duration::from_millis(50),
        backoff_cap: Duration::from_millis(200),
        beacon_interval_ms: 50,
    }
}

/// Start a loopback shard-server over `shard_dir` mirroring into
/// `out_dir`, and return the `--connect` address.
fn loopback_server(shard_dir: &std::path::Path, out_dir: &std::path::Path) -> String {
    let server = ShardServer::bind("127.0.0.1:0", shard_dir, out_dir).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    server.spawn();
    addr
}

#[test]
fn fs_and_tcp_loopback_runs_merge_bitwise_identical() {
    let cfg = small_cfg();
    let dir = tdir("bitwise");
    let world = persist_world(&dir, &cfg, 3);
    let sup = test_sup(FailurePolicy::Retry);

    // the filesystem reference run
    let fs_out = dir.join("fs_models");
    let fs_opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: fs_out.clone(),
        extra_env: Vec::new(),
        connect: None,
    };
    let fs_rep = run_supervised(&cfg, &world.suite, &fs_opts, &sup).unwrap();
    assert_eq!(fs_rep.survivors(), 2);

    // the same seeded run with every worker connected to a loopback
    // shard-server; the server mirrors uploads into the run dir the
    // supervisor is watching
    let tcp_out = dir.join("tcp_models");
    let addr = loopback_server(&dir, &tcp_out);
    let tcp_opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: tcp_out.clone(),
        extra_env: Vec::new(),
        connect: Some(addr),
    };
    let tcp_rep = run_supervised(&cfg, &world.suite, &tcp_opts, &sup).unwrap();
    assert_eq!(tcp_rep.survivors(), 2);
    assert_eq!(tcp_rep.stats.respawns, 0, "a healthy remote fleet never respawns");

    // per-sub-model artifacts bitwise identical across transports
    for (f, t) in fs_rep.outcomes.iter().zip(&tcp_rep.outcomes) {
        assert_eq!(f.submodel, t.submodel);
        let fa = f.artifact.as_ref().expect("fs survivor has artifact");
        let ta = t.artifact.as_ref().expect("tcp survivor has artifact");
        let s = f.submodel;
        assert_eq!(fa.embedding.present, ta.embedding.present);
        assert_eq!(fa.embedding.data.len(), ta.embedding.data.len());
        for (i, (a, b)) in fa.embedding.data.iter().zip(&ta.embedding.data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "sub-model {s}: weight {i} differs between fs and tcp transports"
            );
        }
        assert_eq!(fa.meta.pairs, ta.meta.pairs, "sub-model {s}: pair counts differ");
        let fl: Vec<u64> = fa.meta.epoch_loss.iter().map(|l| l.to_bits()).collect();
        let tl: Vec<u64> = ta.meta.epoch_loss.iter().map(|l| l.to_bits()).collect();
        assert_eq!(fl, tl, "sub-model {s}: loss curves differ between transports");
    }

    // ... so the merged consensus is bitwise identical too
    let fs_merged = &fs_rep.tail.merged.embedding;
    let tcp_merged = &tcp_rep.tail.merged.embedding;
    assert_eq!(fs_merged.present, tcp_merged.present);
    assert_eq!(fs_merged.data.len(), tcp_merged.data.len());
    for (i, (a, b)) in fs_merged.data.iter().zip(&tcp_merged.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "merged weight {i} differs");
    }

    // mirroring: the remote run dir holds the same observability files a
    // local run leaves — beacons and per-worker journals status/report read
    for s in 0..2 {
        assert!(
            tcp_out.join(format!("beacon_{s}.json")).exists(),
            "worker {s}: beacon must be mirrored into the run dir"
        );
        assert!(
            tcp_out.join(journal_file_name(&format!("worker_{s}"))).exists(),
            "worker {s}: journal must be mirrored into the run dir"
        );
    }
    assert!(
        tcp_out.join(journal_file_name("server")).exists(),
        "the server keeps its own journal of registrations and uploads"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn remote_crash_degrades_exactly_like_a_local_one() {
    let cfg = small_cfg();
    let victim = 1usize;
    let fault = format!("crash@pairs=40@submodel={victim}");
    let sup = test_sup(FailurePolicy::Degrade);

    // local reference: one worker crashes with exit 102, degrade abandons it
    let local_dir = tdir("crash_local");
    let world = persist_world(&local_dir, &cfg, 3);
    let local_opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: local_dir.clone(),
        out_dir: local_dir.join("submodels"),
        extra_env: vec![("DW2V_FAULT".to_string(), fault.clone())],
        connect: None,
    };
    let local_rep = run_supervised(&cfg, &world.suite, &local_opts, &sup).unwrap();

    // the same fault in a TCP-connected worker
    let tcp_dir = tdir("crash_tcp");
    persist_world(&tcp_dir, &cfg, 3);
    let tcp_out = tcp_dir.join("submodels");
    let addr = loopback_server(&tcp_dir, &tcp_out);
    let tcp_opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: tcp_dir.clone(),
        out_dir: tcp_out.clone(),
        extra_env: vec![("DW2V_FAULT".to_string(), fault)],
        connect: Some(addr),
    };
    let tcp_rep = run_supervised(&cfg, &world.suite, &tcp_opts, &sup).unwrap();

    // identical degrade outcome: the victim is lost with the same exit
    // code, the survivor's artifact is collected, nothing is respawned
    for rep in [&local_rep, &tcp_rep] {
        assert_eq!(rep.outcomes.len(), 2);
        assert_eq!(rep.survivors(), 1, "exactly the crashed worker is lost");
        assert_eq!(rep.stats.respawns, 0, "degrade never respawns");
        match &rep.outcomes[victim].fate {
            WorkerFate::Failed(why) => {
                assert!(why.contains("exit code 102"), "injected crash exit code: {why}")
            }
            other => panic!("victim should have failed, got {other:?}"),
        }
        assert!(rep.tail.scores.iter().all(|s| s.score.is_finite()));
    }
    // the one-shot fault marker is mirrored through the control plane, so
    // a respawned remote worker would not crash twice either
    assert!(
        tcp_out.join(format!("fault_{victim}_crash.fired")).exists(),
        "the remote worker's fault marker must be mirrored into the run dir"
    );
    assert!(
        !tcp_out.join(format!("submodel_{victim}.dwsm")).exists(),
        "the crashed remote worker must not leave an artifact"
    );
    // and the surviving sub-model is bitwise the same over either transport
    let la = local_rep.outcomes[0].artifact.as_ref().unwrap();
    let ta = tcp_rep.outcomes[0].artifact.as_ref().unwrap();
    assert_eq!(la.meta.pairs, ta.meta.pairs);
    for (i, (a, b)) in la.embedding.data.iter().zip(&ta.embedding.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "survivor weight {i} differs");
    }
    let _ = std::fs::remove_dir_all(&local_dir);
    let _ = std::fs::remove_dir_all(&tcp_dir);
}

#[cfg(unix)]
#[test]
fn sigkilled_remote_worker_costs_exactly_its_submodel() {
    let mut cfg = small_cfg();
    cfg.sentences = 1600;
    cfg.rate_percent = 25.0; // 4 sub-models
    let dir = tdir("kill");
    let world = persist_world(&dir, &cfg, 4);

    // reference: the full 4-model run, in-process (bitwise-equal to what
    // the 4 connected workers would produce, per the equivalence test)
    let corpus = Corpus::read_sharded(&dir).unwrap();
    let vocab =
        Vocab::from_tsv(&std::fs::read_to_string(dir.join("vocab.tsv")).unwrap()).unwrap();
    let backend = NativeBackend::new(ModelShape::for_experiment(&cfg, vocab.len()));
    let full = leader::train_submodels(&cfg, &corpus, &vocab, &backend).unwrap();
    let full_tail = leader::merge_and_eval(&cfg, &full.submodels, &world.suite);
    let full_mean = mean_score(&full_tail.scores);

    // 4 TCP-connected workers that hold still long enough to be killed
    let out_dir = dir.join("submodels");
    let addr = loopback_server(&dir, &out_dir);
    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: out_dir.clone(),
        extra_env: vec![("DW2V_WORKER_STARTUP_SLEEP_MS".to_string(), "1500".to_string())],
        connect: Some(addr),
    };
    let pool = procs::spawn_workers(&cfg, &opts).unwrap();
    std::thread::sleep(Duration::from_millis(300));
    let victim = 1usize;
    let pid = pool.pid(victim).expect("victim pid");
    let status = std::process::Command::new("kill")
        .args(["-9", &pid.to_string()])
        .status()
        .expect("run kill");
    assert!(status.success(), "kill -9 must succeed");

    let (outcomes, _train_secs) = pool.wait();
    assert_eq!(outcomes.len(), 4);

    // same failure report as the local SIGKILL test in procs_e2e
    let dead = &outcomes[victim];
    assert!(!dead.survived());
    match &dead.fate {
        WorkerFate::Failed(why) => {
            assert!(why.contains("signal 9"), "fate should name the signal: {why}")
        }
        other => panic!("victim should have failed, got {other:?}"),
    }
    assert!(
        !out_dir.join(format!("submodel_{victim}.dwsm")).exists(),
        "a killed remote worker must not leave an artifact on the server"
    );

    let survivors: Vec<_> = outcomes.iter().filter(|o| o.survived()).collect();
    assert_eq!(survivors.len(), 3);

    // survivors uploaded the exact sub-models the in-process run computes
    for o in &survivors {
        let artifact = o.artifact.as_ref().unwrap();
        let reference = &full.submodels[o.submodel];
        for (i, (a, b)) in artifact.embedding.data.iter().zip(&reference.data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "sub-model {}: weight {i} differs from the in-process run",
                o.submodel
            );
        }
    }

    // and the survivor merge stays within tolerance of the full run
    let submodels: Vec<_> = survivors
        .iter()
        .map(|o| o.artifact.as_ref().unwrap().embedding.clone())
        .collect();
    let tail = leader::merge_and_eval(&cfg, &submodels, &world.suite);
    assert!(tail.merged.embedding.present_count() > 0);
    let mean3 = mean_score(&tail.scores);
    assert!(
        (mean3 - full_mean).abs() < 0.2,
        "3-survivor eval {mean3:.3} strayed too far from the 4-model run {full_mean:.3}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
