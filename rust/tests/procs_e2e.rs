//! End-to-end tests for the multi-process training path: real
//! `dw2v train-worker` OS processes (via `CARGO_BIN_EXE_dw2v`) trained
//! over real shard files, coordinated by `coordinator::procs`.
//!
//! The two headline properties:
//!
//! * **equivalence** — with `mappers = 1`, a multi-process run produces
//!   sub-models bitwise identical to the in-process leader path on the
//!   native backend (same seeds, same stateless routing, same shard-file
//!   sentence order, same lr schedule);
//! * **fault tolerance** — SIGKILLing a worker mid-run loses exactly that
//!   sub-model: the coordinator reports the failure, merges the
//!   survivors, and eval accuracy stays within tolerance of the full
//!   run (the paper's missing-sub-model robustness).

use dw2v::coordinator::leader;
use dw2v::coordinator::procs::{self, ProcsOptions};
use dw2v::eval::report::mean_score;
use dw2v::runtime::backend::ModelShape;
use dw2v::runtime::native::NativeBackend;
use dw2v::text::corpus::Corpus;
use dw2v::text::vocab::Vocab;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::world::build_world;
use std::path::PathBuf;

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_dw2v"))
}

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dw2v_procs_e2e_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Small-but-real experiment; `mappers = 1` for deterministic delivery
/// order (the same knob the in-process bitwise test uses).
fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 1200;
    cfg.vocab = 250;
    cfg.clusters = 8;
    cfg.truth_dim = 8;
    cfg.dim = 16;
    cfg.window = 4;
    cfg.negatives = 4;
    cfg.epochs = 2;
    cfg.rate_percent = 50.0; // 2 sub-models
    cfg.mappers = 1;
    cfg.trainer_batch = 32;
    cfg.trainer_steps = 2;
    cfg.min_count_base = 8.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg
}

/// Persist a synthetic world as the shard + vocab.tsv layout the workers
/// consume, and sanity-check the round trip is id-exact.
fn persist_world(dir: &std::path::Path, cfg: &ExperimentConfig, shards: usize) -> dw2v::world::World {
    let world = build_world(cfg);
    world.corpus.write_sharded(dir, shards).unwrap();
    std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).unwrap();
    let reloaded = Corpus::read_sharded(dir).unwrap();
    assert_eq!(reloaded, world.corpus, "shard round trip must be exact");
    let v = Vocab::from_tsv(&std::fs::read_to_string(dir.join("vocab.tsv")).unwrap()).unwrap();
    assert_eq!(v.len(), world.vocab.len());
    for id in 0..v.len() as u32 {
        assert_eq!(v.word(id), world.vocab.word(id), "vocab ids must survive tsv");
    }
    world
}

#[test]
fn multiprocess_matches_inprocess_bitwise() {
    let cfg = small_cfg();
    let dir = tdir("bitwise");
    let world = persist_world(&dir, &cfg, 3);

    // in-process reference over the exact bytes the workers will stream
    let corpus = Corpus::read_sharded(&dir).unwrap();
    let vocab =
        Vocab::from_tsv(&std::fs::read_to_string(dir.join("vocab.tsv")).unwrap()).unwrap();
    let backend = NativeBackend::new(ModelShape::for_experiment(&cfg, vocab.len()));
    let inproc = leader::train_submodels(&cfg, &corpus, &vocab, &backend).unwrap();
    assert_eq!(inproc.submodels.len(), 2);

    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: dir.join("submodels"),
        extra_env: Vec::new(),
        connect: None,
    };
    let report = procs::run_multiprocess(&cfg, &world.suite, &opts).unwrap();
    assert_eq!(report.outcomes.len(), 2);
    assert_eq!(report.survivors(), 2, "both workers must survive");

    for outcome in &report.outcomes {
        let artifact = outcome.artifact.as_ref().expect("survivor has artifact");
        let s = outcome.submodel;
        let reference = &inproc.submodels[s];
        assert_eq!(artifact.embedding.vocab, reference.vocab);
        assert_eq!(artifact.embedding.dim, reference.dim);
        assert_eq!(
            artifact.embedding.present, reference.present,
            "sub-model {s}: presence masks must match"
        );
        assert_eq!(artifact.embedding.data.len(), reference.data.len());
        for (i, (a, b)) in artifact.embedding.data.iter().zip(&reference.data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "sub-model {s}: weight {i} differs between the process and thread paths"
            );
        }
        // loss curves replay exactly too (they ride through JSON meta)
        let loss: Vec<u64> = artifact.meta.epoch_loss.iter().map(|l| l.to_bits()).collect();
        let want: Vec<u64> = inproc.epoch_loss[s].iter().map(|l| l.to_bits()).collect();
        assert_eq!(loss, want, "sub-model {s}: epoch loss curve must match");
        assert_eq!(artifact.meta.trainer_seed, leader::submodel_seed(cfg.seed, s));
        assert_eq!(artifact.meta.strategy, "shuffle");
    }

    // the shared tail produced finite scores over the gold suite
    assert_eq!(report.tail.scores.len(), world.suite.len());
    assert!(report.tail.scores.iter().all(|s| s.score.is_finite()));
    let _ = std::fs::remove_dir_all(&dir);
}

#[cfg(unix)]
#[test]
fn coordinator_survives_a_sigkilled_worker() {
    let mut cfg = small_cfg();
    cfg.sentences = 1600;
    cfg.rate_percent = 25.0; // 4 sub-models
    let dir = tdir("kill");
    let world = persist_world(&dir, &cfg, 4);

    // reference: the full 4-model run, in-process (bitwise-equal to what
    // the 4 workers would produce, per the test above)
    let corpus = Corpus::read_sharded(&dir).unwrap();
    let vocab =
        Vocab::from_tsv(&std::fs::read_to_string(dir.join("vocab.tsv")).unwrap()).unwrap();
    let backend = NativeBackend::new(ModelShape::for_experiment(&cfg, vocab.len()));
    let full = leader::train_submodels(&cfg, &corpus, &vocab, &backend).unwrap();
    let full_tail = leader::merge_and_eval(&cfg, &full.submodels, &world.suite);
    let full_mean = mean_score(&full_tail.scores);

    // spawn 4 workers that hold still long enough to be killed mid-run
    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: dir.join("submodels"),
        extra_env: vec![("DW2V_WORKER_STARTUP_SLEEP_MS".to_string(), "1500".to_string())],
        connect: None,
    };
    let pool = procs::spawn_workers(&cfg, &opts).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(300));
    let victim = 1usize;
    let pid = pool.pid(victim).expect("victim pid");
    let status = std::process::Command::new("kill")
        .args(["-9", &pid.to_string()])
        .status()
        .expect("run kill");
    assert!(status.success(), "kill -9 must succeed");

    let (outcomes, _train_secs) = pool.wait();
    assert_eq!(outcomes.len(), 4);

    // the coordinator reports the failure precisely …
    let dead = &outcomes[victim];
    assert!(!dead.survived());
    match &dead.fate {
        procs::WorkerFate::Failed(why) => {
            assert!(why.contains("signal 9"), "fate should name the signal: {why}")
        }
        other => panic!("victim should have failed, got {other:?}"),
    }
    assert!(
        !dir.join("submodels").join("submodel_1.dwsm").exists(),
        "a killed worker must not leave an artifact"
    );

    // … the other three survived …
    let survivors: Vec<_> = outcomes.iter().filter(|o| o.survived()).collect();
    assert_eq!(survivors.len(), 3);

    // … and the merge + eval over the survivors stays within tolerance
    // of the full 4-model run (missing-sub-model robustness)
    let submodels: Vec<_> = survivors
        .iter()
        .map(|o| o.artifact.as_ref().unwrap().embedding.clone())
        .collect();
    let tail = leader::merge_and_eval(&cfg, &submodels, &world.suite);
    assert!(
        tail.merged.embedding.present_count() > 0,
        "survivor merge must produce a usable consensus"
    );
    assert!(tail.scores.iter().all(|s| s.score.is_finite()));
    let mean3 = mean_score(&tail.scores);
    assert!(
        (mean3 - full_mean).abs() < 0.2,
        "3-survivor eval {mean3:.3} strayed too far from the 4-model run {full_mean:.3}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn worker_rejects_bad_inputs_with_nonzero_exit() {
    let cfg = small_cfg();
    let dir = tdir("badworker");
    persist_world(&dir, &cfg, 2);

    // sub-model index out of range for rate 50% (2 sub-models)
    let out = dir.join("nope.dwsm");
    let status = std::process::Command::new(worker_exe())
        .args([
            "train-worker",
            "--shard-dir",
            dir.to_str().unwrap(),
            "--rate",
            "50",
            "--submodel",
            "7",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawn worker");
    assert!(!status.success(), "out-of-range sub-model must fail");
    assert!(!out.exists());

    // a directory with no shards at all
    let empty = dir.join("empty");
    std::fs::create_dir_all(&empty).unwrap();
    std::fs::write(empty.join("vocab.tsv"), "w\t3\n").unwrap();
    let status = std::process::Command::new(worker_exe())
        .args([
            "train-worker",
            "--shard-dir",
            empty.to_str().unwrap(),
            "--submodel",
            "0",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawn worker");
    assert!(!status.success(), "shardless dir must fail");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spawn_workers_validates_the_shard_dir_up_front() {
    let cfg = small_cfg();
    let dir = tdir("noshards");
    // no vocab.tsv, no shards: must error before spawning anything
    let opts = ProcsOptions {
        worker_exe: worker_exe(),
        shard_dir: dir.clone(),
        out_dir: dir.join("submodels"),
        extra_env: Vec::new(),
        connect: None,
    };
    let err = procs::spawn_workers(&cfg, &opts).unwrap_err();
    assert!(err.contains("vocab.tsv"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
