//! End-to-end pipeline tests on the **native** backend: divide → train →
//! merge → eval over a small synthetic corpus, with no `xla` feature and
//! no AOT artifacts required. This is the suite default builds (and CI)
//! run — the PJRT twin lives in `integration.rs` behind the feature.

use dw2v::coordinator::leader;
use dw2v::embedding::Embedding;
use dw2v::eval::report::{evaluate_suite, BenchmarkScore};
use dw2v::runtime::backend::{Backend, ModelShape};
use dw2v::runtime::native::NativeBackend;
use dw2v::runtime::{load_backend, AnyBackend};
use dw2v::util::config::{BackendKind, DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::rng::Pcg64;
use dw2v::world::build_world;

/// Small-but-real experiment: 4 sub-models, 2 epochs, ALiR merge.
fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 2000;
    cfg.vocab = 400;
    cfg.clusters = 10;
    cfg.truth_dim = 8;
    cfg.dim = 16;
    cfg.window = 4;
    cfg.negatives = 4;
    cfg.epochs = 2;
    cfg.rate_percent = 25.0; // 4 sub-models
    cfg.mappers = 2;
    cfg.trainer_batch = 32;
    cfg.trainer_steps = 2;
    // paper threshold 100/k assumes full-corpus scale; scale it to this
    // tiny test corpus so presence masks stay meaningful
    cfg.min_count_base = 8.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg
}

fn native_backend(cfg: &ExperimentConfig, vocab: usize) -> NativeBackend {
    NativeBackend::new(ModelShape::for_experiment(cfg, vocab))
}

fn sim_mean(scores: &[BenchmarkScore]) -> f64 {
    let sims: Vec<f64> = scores
        .iter()
        .filter(|s| s.name.starts_with("sim"))
        .map(|s| s.score)
        .collect();
    sims.iter().sum::<f64>() / sims.len().max(1) as f64
}

#[test]
fn full_pipeline_native_end_to_end() {
    let cfg = small_cfg();
    let world = build_world(&cfg);
    let backend = native_backend(&cfg, world.vocab.len());
    let rep = leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, &backend)
        .expect("pipeline");

    // the divide phase produced 100/r sub-models, all of which trained
    assert_eq!(rep.train.submodels.len(), 4);
    assert!(rep.train.pairs > 20_000, "pairs={}", rep.train.pairs);
    assert!(rep.train.dispatches > 0);

    // every sub-model covers a solid share of the vocabulary and the
    // merged union covers nearly everything
    for m in &rep.train.submodels {
        let frac = m.present_count() as f64 / world.vocab.len() as f64;
        assert!(frac > 0.5, "sub-model covers too little vocab: {frac}");
        assert!(m.data.iter().all(|x| x.is_finite()));
    }
    assert!(
        rep.merged_vocab as f64 > 0.85 * world.vocab.len() as f64,
        "merged vocab {} of {}",
        rep.merged_vocab,
        world.vocab.len()
    );

    // loss curves: finite and decreasing across epochs for every sub-model
    for losses in &rep.train.epoch_loss {
        assert_eq!(losses.len(), 2);
        assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0));
        assert!(losses[1] < losses[0], "loss curve not decreasing: {losses:?}");
    }

    // eval ran over the whole suite with finite scores
    assert_eq!(rep.scores.len(), world.suite.len());
    assert!(rep.scores.iter().all(|s| s.score.is_finite()));

    // quality: clearly better than a random embedding on similarity
    let mut rng = Pcg64::new(1);
    let mut rand_emb = Embedding::zeros(world.vocab.len(), cfg.dim);
    for v in rand_emb.data.iter_mut() {
        *v = rng.gen_gauss() as f32;
    }
    let rand_scores = evaluate_suite(&rand_emb, &world.suite, 1);
    let trained = sim_mean(&rep.scores);
    let random = sim_mean(&rand_scores);
    assert!(
        trained > random + 0.08,
        "trained {trained:.3} vs random {random:.3}"
    );
}

#[test]
fn same_seed_runs_are_bitwise_identical() {
    let mut cfg = small_cfg();
    cfg.sentences = 600;
    cfg.vocab = 200;
    cfg.rate_percent = 50.0; // 2 sub-models
    // one mapper => a deterministic delivery order into each reducer, so
    // the whole run (not just pair extraction) replays exactly
    cfg.mappers = 1;
    let world = build_world(&cfg);
    let backend = native_backend(&cfg, world.vocab.len());

    let a = leader::train_submodels(&cfg, &world.corpus, &world.vocab, &backend).unwrap();
    let b = leader::train_submodels(&cfg, &world.corpus, &world.vocab, &backend).unwrap();
    assert_eq!(a.pairs, b.pairs);
    assert_eq!(a.submodels.len(), b.submodels.len());
    for (ma, mb) in a.submodels.iter().zip(&b.submodels) {
        assert_eq!(ma.present, mb.present);
        assert_eq!(ma.data, mb.data, "sub-model weights must replay bitwise");
    }
    assert_eq!(a.epoch_loss, b.epoch_loss);

    // and the merge on top is deterministic too
    let merged_a = leader::merge_trained(&cfg, &a.submodels);
    let merged_b = leader::merge_trained(&cfg, &b.submodels);
    assert_eq!(merged_a.embedding.data, merged_b.embedding.data);
}

#[test]
fn different_seeds_train_different_models() {
    let mut cfg = small_cfg();
    cfg.sentences = 500;
    cfg.vocab = 150;
    cfg.rate_percent = 50.0;
    cfg.mappers = 1;
    let world = build_world(&cfg);
    let backend = native_backend(&cfg, world.vocab.len());
    let a = leader::train_submodels(&cfg, &world.corpus, &world.vocab, &backend).unwrap();
    cfg.seed ^= 0xDEAD;
    // the corpus stays fixed; only divider + model seeds change
    let b = leader::train_submodels(&cfg, &world.corpus, &world.vocab, &backend).unwrap();
    assert_ne!(a.submodels[0].data, b.submodels[0].data);
}

#[test]
fn auto_backend_falls_back_to_native_and_runs_the_pipeline() {
    let mut cfg = small_cfg();
    cfg.sentences = 400;
    cfg.vocab = 120;
    cfg.epochs = 1;
    cfg.backend = BackendKind::Auto;
    cfg.artifact_dir = "/nonexistent/artifact/dir".to_string();
    let world = build_world(&cfg);
    // no manifest anywhere (and no xla feature in default builds): auto
    // must hand back a working native engine, not an error
    let backend = load_backend(&cfg, world.vocab.len()).expect("auto backend");
    assert_eq!(backend.name(), "native");
    assert!(matches!(backend, AnyBackend::Native(_)));
    let rep = leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, &backend)
        .expect("pipeline through AnyBackend");
    assert!(rep.train.pairs > 0);
    assert!(rep.merged_vocab > 0);
}

#[test]
fn equal_and_random_strategies_run_end_to_end() {
    for strategy in [
        DivideStrategy::EqualPartitioning,
        DivideStrategy::RandomSampling,
    ] {
        let mut cfg = small_cfg();
        cfg.sentences = 600;
        cfg.vocab = 150;
        cfg.epochs = 1;
        cfg.strategy = strategy;
        cfg.merge = MergeMethod::Concat;
        let world = build_world(&cfg);
        let backend = native_backend(&cfg, world.vocab.len());
        let rep =
            leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, &backend)
                .expect("pipeline");
        assert!(rep.train.pairs > 0);
        assert!(rep.scores.iter().all(|s| s.score.is_finite()));
    }
}
