//! Raw-text ingestion acceptance suite: a synthetic corpus rendered to a
//! plain text file must survive ingest → shards → reload **exactly**
//! (token stream + counts), and the full divide → train → merge → eval
//! pipeline must run end-to-end from the text file on the native backend
//! with quality matching the direct synthetic run.

use dw2v::coordinator::leader;
use dw2v::embedding::Embedding;
use dw2v::eval::report::evaluate_suite;
use dw2v::gen::benchmarks::Benchmark;
use dw2v::runtime::backend::ModelShape;
use dw2v::runtime::native::NativeBackend;
use dw2v::text::corpus::Corpus;
use dw2v::text::ingest::{ingest_file, IngestConfig};
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::rng::Pcg64;
use dw2v::world::{build_world, TextWorldOptions, World};
use std::path::{Path, PathBuf};

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 2000;
    cfg.vocab = 300;
    cfg.clusters = 10;
    cfg.truth_dim = 8;
    cfg.dim = 16;
    cfg.window = 4;
    cfg.negatives = 4;
    cfg.epochs = 2;
    cfg.rate_percent = 25.0; // 4 sub-models
    cfg.mappers = 2;
    cfg.trainer_batch = 32;
    cfg.trainer_steps = 2;
    cfg.min_count_base = 8.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "dw2v_ingest_e2e_{name}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Render an id corpus as raw text, one sentence per line (`w<id>` words,
/// a few CRLF line endings and punctuation variants for realism).
fn render_text(corpus: &Corpus, path: &Path) {
    use std::io::Write;
    let mut out = std::io::BufWriter::new(std::fs::File::create(path).unwrap());
    for (i, sent) in corpus.sentences.iter().enumerate() {
        let words: Vec<String> = sent.iter().map(|&t| format!("w{t}")).collect();
        let terminator = match i % 4 {
            0 => ".",
            1 => "!",
            2 => "?",
            _ => "",
        };
        let ending = if i % 3 == 0 { "\r\n" } else { "\n" };
        write!(out, "{}{terminator}{ending}", words.join(" ")).unwrap();
    }
}

/// Token counts per word id, straight from an id corpus.
fn corpus_counts(corpus: &Corpus, vocab_size: usize) -> Vec<u64> {
    let mut counts = vec![0u64; vocab_size];
    for s in &corpus.sentences {
        for &t in s {
            counts[t as usize] += 1;
        }
    }
    counts
}

#[test]
fn text_round_trip_preserves_stream_and_counts() {
    let cfg = small_cfg();
    let world = build_world(&cfg);
    let dir = tmpdir("roundtrip");
    let text_path = dir.join("corpus.txt");
    render_text(&world.corpus, &text_path);

    let icfg = IngestConfig {
        min_count: 1,
        max_vocab: usize::MAX,
        workers: 4,
        chunk_bytes: 8 << 10,
        shard_tokens: 4_000, // ~36k tokens → ~9 shards
    };
    let out = ingest_file(&text_path, &dir.join("shards"), &icfg).unwrap();

    // memory-bounded sharding really sharded
    assert!(out.stats.shards >= 2, "expected several shards, got {}", out.stats.shards);
    assert_eq!(out.stats.oov_tokens, 0, "min_count 1 must keep everything");
    assert_eq!(out.stats.raw_tokens, world.corpus.total_tokens());

    // per-word counts survive the text round trip
    let original = corpus_counts(&world.corpus, cfg.vocab);
    for (id, &count) in original.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let new_id = out
            .vocab
            .id(&format!("w{id}"))
            .unwrap_or_else(|| panic!("w{id} missing from ingested vocab"));
        assert_eq!(out.vocab.count(new_id), count, "count mismatch for w{id}");
    }

    // the concatenated decoded stream equals the original token stream
    let reloaded = Corpus::read_sharded(&dir.join("shards")).unwrap();
    let decoded: Vec<String> = reloaded
        .sentences
        .iter()
        .flatten()
        .map(|&id| out.vocab.word(id).to_string())
        .collect();
    let expected: Vec<String> = world
        .corpus
        .sentences
        .iter()
        .flatten()
        .map(|&id| format!("w{id}"))
        .collect();
    assert_eq!(decoded, expected);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipeline_trains_end_to_end_from_text() {
    let cfg = small_cfg();
    let world = build_world(&cfg);
    let dir = tmpdir("pipeline");
    let text_path = dir.join("corpus.txt");
    render_text(&world.corpus, &text_path);

    let mut opts = TextWorldOptions::default();
    opts.ingest.min_count = 1;
    opts.ingest.workers = 2;
    opts.ingest.shard_tokens = 8_000;
    opts.shard_dir = Some(dir.join("shards"));
    let (text_world, stats) = World::from_text(&text_path, &opts).unwrap();
    assert!(stats.shards >= 2);
    assert!(text_world.gt.is_none());

    // translate the gold suite into the ingested id space
    let remap = |w: u32| text_world.vocab.id(&format!("w{w}"));
    let suite: Vec<Benchmark> = world.suite.iter().map(|b| b.remap_words(remap)).collect();
    let kept: usize = suite.iter().map(|b| b.len()).sum();
    let total: usize = world.suite.iter().map(|b| b.len()).sum();
    assert!(
        kept as f64 > 0.9 * total as f64,
        "suite lost too many items in the remap: {kept}/{total}"
    );

    let backend = NativeBackend::new(ModelShape::for_experiment(&cfg, text_world.vocab.len()));
    let rep = leader::run_pipeline(&cfg, &text_world.corpus, &text_world.vocab, &suite, &backend)
        .expect("pipeline from text");
    assert_eq!(rep.train.submodels.len(), 4);
    assert!(rep.train.pairs > 20_000, "pairs={}", rep.train.pairs);
    assert!(rep.scores.iter().all(|s| s.score.is_finite()));

    // quality: clearly better than a random embedding on similarity
    let sim_mean = |scores: &[dw2v::eval::report::BenchmarkScore]| {
        let sims: Vec<f64> = scores
            .iter()
            .filter(|s| s.name.starts_with("sim"))
            .map(|s| s.score)
            .collect();
        sims.iter().sum::<f64>() / sims.len().max(1) as f64
    };
    let mut rng = Pcg64::new(1);
    let mut rand_emb = Embedding::zeros(text_world.vocab.len(), cfg.dim);
    for v in rand_emb.data.iter_mut() {
        *v = rng.gen_gauss() as f32;
    }
    let rand_scores = evaluate_suite(&rand_emb, &suite, 1);
    let trained = sim_mean(&rep.scores);
    let random = sim_mean(&rand_scores);
    assert!(
        trained > random + 0.08,
        "trained {trained:.3} vs random {random:.3}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The checked-in CI fixture must ingest, parse its questions file, and
/// train — the same artifacts the workflow's smoke run drives from the
/// CLI.
#[test]
fn fixture_corpus_ingests_and_evaluates() {
    let fixtures = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let mut opts = TextWorldOptions::default();
    opts.ingest.min_count = 1;
    opts.ingest.workers = 2;
    opts.questions = Some(fixtures.join("questions-words-tiny.txt"));
    let (world, stats) =
        World::from_text(&fixtures.join("tiny_corpus.txt"), &opts).unwrap();
    assert!(stats.lines >= 30);
    assert!(world.vocab.id("king").is_some());
    assert!(world.vocab.id("don't").is_some(), "apostrophes survive");
    assert_eq!(world.suite.len(), 2, "both question sections in-vocab");
    let total: usize = world.suite.iter().map(|b| b.len()).sum();
    assert_eq!(total, 10, "all fixture questions map into the vocab");

    // a quick hogwild run produces finite scores over the real benchmark
    let mut cfg = small_cfg();
    cfg.dim = 12;
    let mut scfg = leader::sgns_config(&cfg);
    scfg.epochs = 3;
    let (emb, _) = dw2v::sgns::hogwild::train(&world.corpus, &world.vocab, &scfg, 2, 3);
    let scores = evaluate_suite(&emb, &world.suite, 3);
    assert_eq!(scores.len(), 2);
    assert!(scores.iter().all(|s| s.score.is_finite()));
    assert!(scores.iter().all(|s| s.oov_words == 0));
}

/// The hogwild baseline also trains from an ingested world, and its lr
/// schedule (regression-fixed in `sgns::schedule`) anneals to the floor
/// on a real token-frequency distribution, not just the synthetic one.
#[test]
fn hogwild_from_text_anneals_and_learns() {
    let mut cfg = small_cfg();
    cfg.sentences = 1200;
    let world = build_world(&cfg);
    let dir = tmpdir("hogwild");
    let text_path = dir.join("corpus.txt");
    render_text(&world.corpus, &text_path);

    let mut opts = TextWorldOptions::default();
    opts.ingest.min_count = 1;
    opts.ingest.workers = 2;
    let (text_world, _) = World::from_text(&text_path, &opts).unwrap();

    let scfg = leader::sgns_config(&cfg);
    let (emb, stats) =
        dw2v::sgns::hogwild::train(&text_world.corpus, &text_world.vocab, &scfg, 2, 7);
    assert!(emb.data.iter().all(|x| x.is_finite()));
    let ratio = stats.pairs as f64 / stats.expected_pairs.max(1) as f64;
    assert!(
        (ratio - 1.0).abs() < 0.12,
        "emitted {} vs expected {} (ratio {ratio:.3})",
        stats.pairs,
        stats.expected_pairs
    );
    assert!(
        stats.final_lr <= scfg.lr0 * 0.12 + scfg.lr_min,
        "final lr {} did not anneal",
        stats.final_lr
    );
    let _ = std::fs::remove_dir_all(&dir);
}
