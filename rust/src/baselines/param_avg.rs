//! Parameter-averaging distributed SGNS — the Spark-MLlib baseline.
//!
//! MLlib's word2vec is synchronized data parallelism: every iteration each
//! of E executors trains a replica of the full model on its partition,
//! then the driver averages the replicas into the next global model. This
//! reproduces the paper's observation (Tables 2/4) that quality *degrades*
//! as executors grow — unlike sub-model training + alignment-aware
//! merging, naive averaging of diverging replicas cancels signal — while
//! wall-clock improves with parallelism until averaging overhead bites.

use crate::embedding::Embedding;
use crate::kernels;
use crate::kernels::SigmoidTable;
use crate::sgns::batch::BatchBuilder;
use crate::sgns::config::SgnsConfig;
use crate::sgns::negative::AliasTable;
use crate::text::corpus::Corpus;
use crate::text::vocab::Vocab;
use crate::util::rng::Pcg64;

/// Train one executor's replica in place over its sentence partition.
#[allow(clippy::too_many_arguments)]
fn train_replica(
    w: &mut [f32],
    c: &mut [f32],
    sentences: &[Vec<u32>],
    cfg: &SgnsConfig,
    noise: &AliasTable,
    keep: &[f32],
    sigmoid: &SigmoidTable,
    lr: f32,
    rng: &mut Pcg64,
) -> u64 {
    let d = cfg.dim;
    let mut pairs = 0u64;
    let mut kept: Vec<u32> = Vec::new();
    let mut neu = vec![0.0f32; d];
    for sent in sentences {
        kept.clear();
        for &word in sent {
            let p = keep.get(word as usize).copied().unwrap_or(1.0);
            if p >= 1.0 || rng.gen_f32() < p {
                kept.push(word);
            }
        }
        if kept.len() < 2 {
            continue;
        }
        for pos in 0..kept.len() {
            let center = kept[pos] as usize;
            let win = 1 + rng.gen_range_usize(cfg.window);
            let lo = pos.saturating_sub(win);
            let hi = (pos + win + 1).min(kept.len());
            for other in lo..hi {
                if other == pos {
                    continue;
                }
                let target = kept[other] as usize;
                neu.fill(0.0);
                for s in 0..=cfg.negatives {
                    let (ctx_id, label) = if s == 0 {
                        (target, 1.0f32)
                    } else {
                        (noise.sample(rng) as usize, 0.0f32)
                    };
                    let crow = &mut c[ctx_id * d..(ctx_id + 1) * d];
                    let wrow = &w[center * d..(center + 1) * d];
                    kernels::dot_sigmoid_update(wrow, crow, &mut neu, label, lr, sigmoid);
                }
                let wrow = &mut w[center * d..(center + 1) * d];
                kernels::axpy(1.0, &neu, wrow);
                pairs += 1;
            }
        }
    }
    pairs
}

#[derive(Debug, Clone, Default)]
pub struct ParamAvgStats {
    pub pairs: u64,
    pub seconds: f64,
    pub sync_rounds: usize,
}

/// Train with `executors` synchronized replicas, averaging every epoch.
pub fn train(
    corpus: &Corpus,
    vocab: &Vocab,
    cfg: &SgnsConfig,
    executors: usize,
    seed: u64,
) -> (Embedding, ParamAvgStats) {
    let v = vocab.len();
    let d = cfg.dim;
    let executors = executors.max(1);
    let mut rng = Pcg64::new_stream(seed, 0x7061); // "pa"
    let mut w_global = vec![0.0f32; v * d];
    for x in &mut w_global {
        *x = (rng.gen_f32() - 0.5) / d as f32;
    }
    let mut c_global = vec![0.0f32; v * d];
    let noise = AliasTable::unigram_noise(vocab.counts(), cfg.noise_power);
    let keep = BatchBuilder::keep_table(vocab.counts(), cfg.subsample_t);
    let sigmoid = SigmoidTable::new();
    let start = std::time::Instant::now();
    let mut stats = ParamAvgStats::default();

    for epoch in 0..cfg.epochs {
        // linear decay per epoch (MLlib decays per iteration)
        let lr = cfg.lr_at(
            (epoch as u64) * corpus.total_tokens(),
            (cfg.epochs as u64) * corpus.total_tokens(),
        );
        // every executor starts from the current global model
        let results: Vec<(Vec<f32>, Vec<f32>, u64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..executors)
                .map(|e| {
                    let range = corpus.shard_range(e, executors);
                    let sentences = &corpus.sentences[range];
                    let mut w = w_global.clone();
                    let mut c = c_global.clone();
                    let cfg = cfg.clone();
                    let noise = &noise;
                    let keep = &keep;
                    let sigmoid = &sigmoid;
                    let mut erng =
                        Pcg64::new_stream(seed ^ 0x6578, (epoch * executors + e) as u64);
                    scope.spawn(move || {
                        let pairs = train_replica(
                            &mut w, &mut c, sentences, &cfg, noise, keep, sigmoid, lr,
                            &mut erng,
                        );
                        (w, c, pairs)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // the synchronization the paper's approach avoids: average replicas
        w_global.iter_mut().for_each(|x| *x = 0.0);
        c_global.iter_mut().for_each(|x| *x = 0.0);
        let inv = 1.0 / executors as f32;
        for (w, c, pairs) in results {
            stats.pairs += pairs;
            kernels::axpy(inv, &w, &mut w_global);
            kernels::axpy(inv, &c, &mut c_global);
        }
        stats.sync_rounds += 1;
    }
    stats.seconds = start.elapsed().as_secs_f64();
    (Embedding::from_rows(v, d, w_global), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::corpus::{build_ground_truth, generate_corpus, vocab_of, GeneratorConfig};

    fn setup() -> (Corpus, Vocab) {
        let gcfg = GeneratorConfig {
            vocab: 60,
            clusters: 6,
            truth_dim: 8,
            avg_sentence_len: 10,
            ..Default::default()
        };
        let gt = build_ground_truth(&gcfg, 11);
        let corpus = generate_corpus(&gt, 1200, 11);
        let vocab = vocab_of(&corpus, gcfg.vocab);
        (corpus, vocab)
    }

    #[test]
    fn single_executor_learns() {
        let (corpus, vocab) = setup();
        let cfg = SgnsConfig {
            dim: 12,
            epochs: 3,
            ..Default::default()
        };
        let (emb, stats) = train(&corpus, &vocab, &cfg, 1, 3);
        assert!(stats.pairs > 5000);
        assert_eq!(stats.sync_rounds, 3);
        assert!(emb.data.iter().all(|x| x.is_finite()));
        // learned something: embeddings moved away from tiny init
        let max_abs = emb.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(max_abs > 0.1, "max_abs={max_abs}");
    }

    #[test]
    fn many_executors_still_produce_finite_model() {
        let (corpus, vocab) = setup();
        let cfg = SgnsConfig {
            dim: 8,
            epochs: 2,
            ..Default::default()
        };
        let (emb, stats) = train(&corpus, &vocab, &cfg, 8, 5);
        assert!(emb.data.iter().all(|x| x.is_finite()));
        assert_eq!(stats.sync_rounds, 2);
    }

    #[test]
    fn averaging_degrades_vs_single_executor() {
        // the MLlib pathology the paper points at: with few epochs, more
        // executors => averaged replicas diverge => weaker structure.
        let (corpus, vocab) = setup();
        let gcfg = GeneratorConfig {
            vocab: 60,
            clusters: 6,
            truth_dim: 8,
            avg_sentence_len: 10,
            ..Default::default()
        };
        let gt = build_ground_truth(&gcfg, 11);
        let cfg = SgnsConfig {
            dim: 12,
            epochs: 2,
            ..Default::default()
        };
        let score = |emb: &Embedding| {
            // same-cluster minus cross-cluster mean cosine
            let mut rng = Pcg64::new(2);
            let (mut same, mut cross) = (Vec::new(), Vec::new());
            for _ in 0..4000 {
                let a = rng.gen_range(60) as u32;
                let b = rng.gen_range(60) as u32;
                if a == b {
                    continue;
                }
                let cos = emb.cosine(a, b).unwrap();
                if gt.cluster_of[a as usize] == gt.cluster_of[b as usize] {
                    same.push(cos);
                } else {
                    cross.push(cos);
                }
            }
            let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            avg(&same) - avg(&cross)
        };
        let (e1, _) = train(&corpus, &vocab, &cfg, 1, 7);
        let (e16, _) = train(&corpus, &vocab, &cfg, 16, 7);
        let (s1, s16) = (score(&e1), score(&e16));
        assert!(
            s1 > s16,
            "expected single-executor to beat 16 executors: {s1} vs {s16}"
        );
    }
}
