//! Parameter-averaging distributed SGNS — the Spark-MLlib baseline.
//!
//! MLlib's word2vec is synchronized data parallelism: every iteration each
//! of E executors trains a replica of the full model on its partition,
//! then the driver averages the replicas into the next global model. This
//! reproduces the paper's observation (Tables 2/4) that quality *degrades*
//! as executors grow — unlike sub-model training + alignment-aware
//! merging, naive averaging of diverging replicas cancels signal — while
//! wall-clock improves with parallelism until averaging overhead bites.
//!
//! Backend-generic: each executor replica is a [`SubModel`] trained
//! through the same macro-batch [`Backend`] protocol as the paper
//! system's reducers (native kernels by default, PJRT with artifacts), so
//! baseline and system rows of a table always measure the same compute
//! engine. Averaging happens on the downloaded packed states.

use crate::embedding::Embedding;
use crate::kernels;
use crate::runtime::backend::Backend;
use crate::runtime::params::{init_host, SubModel};
use crate::sgns::batch::{BatchBuilder, BatchShape};
use crate::sgns::config::SgnsConfig;
use crate::sgns::negative::AliasTable;
use crate::text::corpus::Corpus;
use crate::text::vocab::Vocab;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone, Default)]
pub struct ParamAvgStats {
    pub pairs: u64,
    pub seconds: f64,
    pub sync_rounds: usize,
}

/// Train one executor's replica from the current global state over its
/// sentence partition; returns the trained packed state + pair count.
#[allow(clippy::too_many_arguments)]
fn train_replica<B: Backend>(
    backend: &B,
    global: &[f32],
    sentences: &[Vec<u32>],
    first_sentence: usize,
    epoch: usize,
    cfg: &SgnsConfig,
    noise: &AliasTable,
    keep: &[f32],
    lr: f32,
    seed: u64,
) -> Result<(Vec<f32>, u64), String> {
    let sh = backend.shape();
    let shape = BatchShape {
        batch: sh.batch,
        steps: sh.steps,
        negatives: sh.negatives,
        vocab: sh.vocab,
    };
    let rng = Pcg64::new_stream(seed, 0x7061); // "pa"
    let mut builder = BatchBuilder::new(shape, cfg.window, keep.to_vec(), noise.clone(), rng);
    let mut model = SubModel::from_host(backend, global)?;
    let mut ready = Vec::new();
    for (i, sent) in sentences.iter().enumerate() {
        let sid = (epoch as u64) << 40 | (first_sentence + i) as u64;
        builder.push_sentence(sid, sent, &mut |mb| ready.push(mb));
        for mb in ready.drain(..) {
            model.train_macro_batch(backend, &mb.centers, &mb.ctx, &mb.weights, lr)?;
        }
    }
    builder.flush(&mut |mb| ready.push(mb));
    for mb in ready.drain(..) {
        model.train_macro_batch(backend, &mb.centers, &mb.ctx, &mb.weights, lr)?;
    }
    let pairs = builder.pairs_emitted;
    Ok((model.download_packed(backend)?, pairs))
}

/// Train with `executors` synchronized replicas, averaging every epoch.
pub fn train<B: Backend>(
    corpus: &Corpus,
    vocab: &Vocab,
    cfg: &SgnsConfig,
    backend: &B,
    executors: usize,
    seed: u64,
) -> Result<(Embedding, ParamAvgStats), String> {
    let sh = backend.shape();
    assert!(vocab.len() <= sh.vocab, "vocab exceeds backend capacity");
    assert_eq!(cfg.dim, sh.dim, "dim mismatch with backend shape");
    let executors = executors.max(1);
    let mut global = init_host(sh, seed ^ 0x7061_7661); // "pava"
    // built once and shared; replicas clone the (cheap) finished tables
    // instead of re-deriving them from counts every epoch
    let noise = AliasTable::unigram_noise(vocab.counts(), cfg.noise_power);
    let keep = BatchBuilder::keep_table(vocab.counts(), cfg.subsample_t);
    let start = std::time::Instant::now();
    let mut stats = ParamAvgStats::default();

    for epoch in 0..cfg.epochs {
        // linear decay per epoch (MLlib decays per iteration)
        let lr = cfg.lr_at(
            (epoch as u64) * corpus.total_tokens(),
            (cfg.epochs as u64) * corpus.total_tokens(),
        );
        // every executor starts from the current global model
        let results: Vec<Result<(Vec<f32>, u64), String>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..executors)
                .map(|e| {
                    let range = corpus.shard_range(e, executors);
                    let first = range.start;
                    let sentences = &corpus.sentences[range];
                    let (global, noise, keep) = (&global, &noise, &keep);
                    let eseed = seed ^ 0x6578 ^ ((epoch * executors + e) as u64).rotate_left(23);
                    scope.spawn(move || {
                        train_replica(
                            backend, global, sentences, first, epoch, cfg, noise, keep, lr,
                            eseed,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // the synchronization the paper's approach avoids: average replicas
        global.iter_mut().for_each(|x| *x = 0.0);
        let inv = 1.0 / executors as f32;
        for r in results {
            let (packed, pairs) = r?;
            stats.pairs += pairs;
            kernels::axpy(inv, &packed, &mut global);
        }
        stats.sync_rounds += 1;
    }
    stats.seconds = start.elapsed().as_secs_f64();
    let v = vocab.len();
    let emb = Embedding::from_rows(v, sh.dim, global[..v * sh.dim].to_vec());
    Ok((emb, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::corpus::{build_ground_truth, generate_corpus, vocab_of, GeneratorConfig};
    use crate::runtime::backend::ModelShape;
    use crate::runtime::native::NativeBackend;

    fn setup() -> (Corpus, Vocab) {
        let gcfg = GeneratorConfig {
            vocab: 60,
            clusters: 6,
            truth_dim: 8,
            avg_sentence_len: 10,
            ..Default::default()
        };
        let gt = build_ground_truth(&gcfg, 11);
        let corpus = generate_corpus(&gt, 1200, 11);
        let vocab = vocab_of(&corpus, gcfg.vocab);
        (corpus, vocab)
    }

    fn backend(dim: usize, negatives: usize) -> NativeBackend {
        NativeBackend::new(ModelShape::native(60, dim, 16, negatives, 2))
    }

    #[test]
    fn single_executor_learns() {
        let (corpus, vocab) = setup();
        let cfg = SgnsConfig {
            dim: 12,
            epochs: 3,
            ..Default::default()
        };
        let be = backend(12, cfg.negatives);
        let (emb, stats) = train(&corpus, &vocab, &cfg, &be, 1, 3).unwrap();
        assert!(stats.pairs > 5000);
        assert_eq!(stats.sync_rounds, 3);
        assert!(emb.data.iter().all(|x| x.is_finite()));
        // learned something: embeddings moved away from tiny init
        let max_abs = emb.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(max_abs > 0.1, "max_abs={max_abs}");
    }

    #[test]
    fn many_executors_still_produce_finite_model() {
        let (corpus, vocab) = setup();
        let cfg = SgnsConfig {
            dim: 8,
            epochs: 2,
            ..Default::default()
        };
        let be = backend(8, cfg.negatives);
        let (emb, stats) = train(&corpus, &vocab, &cfg, &be, 8, 5).unwrap();
        assert!(emb.data.iter().all(|x| x.is_finite()));
        assert_eq!(stats.sync_rounds, 2);
    }

    #[test]
    fn averaging_degrades_vs_single_executor() {
        // the MLlib pathology the paper points at: with few epochs, more
        // executors => averaged replicas diverge => weaker structure.
        let (corpus, vocab) = setup();
        let gcfg = GeneratorConfig {
            vocab: 60,
            clusters: 6,
            truth_dim: 8,
            avg_sentence_len: 10,
            ..Default::default()
        };
        let gt = build_ground_truth(&gcfg, 11);
        let cfg = SgnsConfig {
            dim: 12,
            epochs: 2,
            ..Default::default()
        };
        let score = |emb: &Embedding| {
            // same-cluster minus cross-cluster mean cosine
            let mut rng = Pcg64::new(2);
            let (mut same, mut cross) = (Vec::new(), Vec::new());
            for _ in 0..4000 {
                let a = rng.gen_range(60) as u32;
                let b = rng.gen_range(60) as u32;
                if a == b {
                    continue;
                }
                let cos = emb.cosine(a, b).unwrap();
                if gt.cluster_of[a as usize] == gt.cluster_of[b as usize] {
                    same.push(cos);
                } else {
                    cross.push(cos);
                }
            }
            let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            avg(&same) - avg(&cross)
        };
        let be = backend(12, cfg.negatives);
        let (e1, _) = train(&corpus, &vocab, &cfg, &be, 1, 7).unwrap();
        let (e16, _) = train(&corpus, &vocab, &cfg, &be, 16, 7).unwrap();
        let (s1, s16) = (score(&e1), score(&e16));
        assert!(
            s1 > s16,
            "expected single-executor to beat 16 executors: {s1} vs {s16}"
        );
    }

    #[test]
    fn deterministic_given_seed_and_executors() {
        let (corpus, vocab) = setup();
        let cfg = SgnsConfig {
            dim: 8,
            epochs: 2,
            ..Default::default()
        };
        let be = backend(8, cfg.negatives);
        let (e1, s1) = train(&corpus, &vocab, &cfg, &be, 4, 9).unwrap();
        let (e2, s2) = train(&corpus, &vocab, &cfg, &be, 4, 9).unwrap();
        assert_eq!(s1.pairs, s2.pairs);
        assert_eq!(e1.data, e2.data, "param-avg must be reproducible");
    }
}
