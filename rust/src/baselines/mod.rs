//! Comparator implementations from the paper's evaluation: the MLlib-style
//! parameter-averaging trainer and the Ordentlich-style column-partitioned
//! trainer (with its latency cost model).
pub mod colpart;
pub mod param_avg;
