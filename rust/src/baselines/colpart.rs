//! Column-partitioned distributed word2vec — the Ordentlich et al. [25]
//! baseline the paper implemented but found ~an order of magnitude too
//! slow to include (§4.2: 55 h for 25% of Wikipedia).
//!
//! The embedding dimensions are split across `servers` parameter shards;
//! every minibatch requires a *synchronous* exchange: each server computes
//! partial dot products for its dimension slice, the partials are reduced,
//! and the resulting scalars are broadcast back before any server can
//! apply its gradient slice. We implement that dataflow faithfully with
//! channels (the computation is exact — same SGNS math), and additionally
//! expose the latency cost model used by the fig2 bench to extrapolate
//! cluster behaviour: per-batch time = compute/servers + 2·RTT.

use crate::embedding::Embedding;
use crate::kernels;
use crate::kernels::SigmoidTable;
use crate::sgns::config::SgnsConfig;
use crate::sgns::negative::AliasTable;
use crate::sgns::batch::BatchBuilder;
use crate::text::corpus::Corpus;
use crate::text::vocab::Vocab;
use crate::util::rng::Pcg64;
use std::sync::mpsc::channel;

#[derive(Debug, Clone, Default)]
pub struct ColPartStats {
    pub pairs: u64,
    pub seconds: f64,
    pub sync_rounds: u64,
}

/// Train with dimensions partitioned across `servers` threads. Exact SGNS
/// math; every (center, context-set) update is a two-phase synchronous
/// exchange among all servers.
pub fn train(
    corpus: &Corpus,
    vocab: &Vocab,
    cfg: &SgnsConfig,
    servers: usize,
    seed: u64,
) -> (Embedding, ColPartStats) {
    let v = vocab.len();
    let d = cfg.dim;
    let servers = servers.max(1).min(d);
    let noise = AliasTable::unigram_noise(vocab.counts(), cfg.noise_power);
    let keep = BatchBuilder::keep_table(vocab.counts(), cfg.subsample_t);
    let sigmoid = SigmoidTable::new();
    let mut rng = Pcg64::new_stream(seed, 0x6370); // "cp"

    // dimension slices per server
    let slice_of = |s: usize| -> std::ops::Range<usize> {
        let chunk = d.div_ceil(servers);
        (s * chunk).min(d)..((s + 1) * chunk).min(d)
    };
    // each server owns its dim-slice of W and C
    let mut w_slices: Vec<Vec<f32>> = (0..servers)
        .map(|s| {
            let width = slice_of(s).len();
            let mut x = vec![0.0f32; v * width];
            for val in &mut x {
                *val = (rng.gen_f32() - 0.5) / d as f32;
            }
            x
        })
        .collect();
    let mut c_slices: Vec<Vec<f32>> = (0..servers)
        .map(|s| vec![0.0f32; v * slice_of(s).len()])
        .collect();

    let start = std::time::Instant::now();
    let mut stats = ColPartStats::default();
    // calibrated like the Hogwild baseline (see `sgns::schedule`)
    let expected_pairs = crate::sgns::schedule::expected_pairs(corpus, vocab, cfg);

    // The driver walks pairs; per pair, a fan-out/fan-in over servers.
    // (Single-threaded orchestration of the exchange keeps the dataflow —
    // and its synchronization count — explicit and measurable.)
    let k1 = cfg.negatives + 1;
    let mut ctx_ids = vec![0usize; k1];
    for epoch in 0..cfg.epochs {
        let mut erng = Pcg64::new_stream(seed ^ 0x6474, epoch as u64);
        let mut kept: Vec<u32> = Vec::new();
        for sent in &corpus.sentences {
            kept.clear();
            for &word in sent {
                let p = keep.get(word as usize).copied().unwrap_or(1.0);
                if p >= 1.0 || erng.gen_f32() < p {
                    kept.push(word);
                }
            }
            if kept.len() < 2 {
                continue;
            }
            for pos in 0..kept.len() {
                let center = kept[pos] as usize;
                let win = 1 + erng.gen_range_usize(cfg.window);
                let lo = pos.saturating_sub(win);
                let hi = (pos + win + 1).min(kept.len());
                for other in lo..hi {
                    if other == pos {
                        continue;
                    }
                    let lr = cfg.lr_at(stats.pairs, expected_pairs);
                    ctx_ids[0] = kept[other] as usize;
                    for slot in ctx_ids.iter_mut().skip(1) {
                        *slot = noise.sample(&mut erng) as usize;
                    }
                    // --- phase 1: scatter-gather partial dot products ----
                    let (tx, rx) = channel::<Vec<f32>>();
                    std::thread::scope(|scope| {
                        for (s, (ws, cs)) in
                            w_slices.iter().zip(c_slices.iter()).enumerate()
                        {
                            let tx = tx.clone();
                            let width = slice_of(s).len();
                            let ctx_ids = &ctx_ids;
                            scope.spawn(move || {
                                let wrow = &ws[center * width..(center + 1) * width];
                                let partials: Vec<f32> = ctx_ids
                                    .iter()
                                    .map(|&cid| {
                                        let crow = &cs[cid * width..(cid + 1) * width];
                                        kernels::dot(wrow, crow)
                                    })
                                    .collect();
                                let _ = tx.send(partials);
                            });
                        }
                    });
                    drop(tx);
                    let mut dots = vec![0.0f32; k1];
                    for partial in rx.iter() {
                        for (acc, p) in dots.iter_mut().zip(partial) {
                            *acc += p;
                        }
                    }
                    // --- reduce: gradients scalars -----------------------
                    let gs: Vec<f32> = dots
                        .iter()
                        .enumerate()
                        .map(|(j, &dot)| {
                            let label = if j == 0 { 1.0 } else { 0.0 };
                            (label - sigmoid.get(dot)) * lr
                        })
                        .collect();
                    // --- phase 2: broadcast scalars, apply slice updates --
                    std::thread::scope(|scope| {
                        for (s, (ws, cs)) in
                            w_slices.iter_mut().zip(c_slices.iter_mut()).enumerate()
                        {
                            let width = slice_of(s).len();
                            let gs = &gs;
                            let ctx_ids = &ctx_ids;
                            scope.spawn(move || {
                                let mut neu = vec![0.0f32; width];
                                for (j, &cid) in ctx_ids.iter().enumerate() {
                                    let wrow = &ws[center * width..(center + 1) * width];
                                    let crow =
                                        &mut cs[cid * width..(cid + 1) * width];
                                    kernels::dual_axpy(gs[j], wrow, crow, &mut neu);
                                }
                                let wrow = &mut ws[center * width..(center + 1) * width];
                                kernels::axpy(1.0, &neu, wrow);
                            });
                        }
                    });
                    stats.pairs += 1;
                    stats.sync_rounds += 2; // gather + broadcast
                }
            }
        }
    }
    stats.seconds = start.elapsed().as_secs_f64();

    // reassemble the full W
    let mut w = vec![0.0f32; v * d];
    for (s, ws) in w_slices.iter().enumerate() {
        let cols = slice_of(s);
        let width = cols.len();
        for word in 0..v {
            w[word * d + cols.start..word * d + cols.end]
                .copy_from_slice(&ws[word * width..(word + 1) * width]);
        }
    }
    (Embedding::from_rows(v, d, w), stats)
}

/// Cost model for the paper's cluster setting: seconds to train `pairs`
/// pairs with per-exchange latency `rtt_secs` and per-pair scalar compute
/// `flop_secs` spread over `servers`.
pub fn estimated_seconds(pairs: u64, servers: usize, flop_secs: f64, rtt_secs: f64) -> f64 {
    let servers = servers.max(1) as f64;
    pairs as f64 * (flop_secs / servers + 2.0 * rtt_secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::corpus::{build_ground_truth, generate_corpus, vocab_of, GeneratorConfig};

    fn tiny() -> (Corpus, Vocab) {
        let gcfg = GeneratorConfig {
            vocab: 30,
            clusters: 4,
            truth_dim: 4,
            avg_sentence_len: 8,
            ..Default::default()
        };
        let gt = build_ground_truth(&gcfg, 21);
        let corpus = generate_corpus(&gt, 60, 21);
        let vocab = vocab_of(&corpus, gcfg.vocab);
        (corpus, vocab)
    }

    #[test]
    fn colpart_runs_and_counts_syncs() {
        let (corpus, vocab) = tiny();
        let cfg = SgnsConfig {
            dim: 8,
            epochs: 1,
            window: 2,
            negatives: 2,
            subsample_t: 0.0, // 30-word vocab: every word is "frequent"
            ..Default::default()
        };
        let (emb, stats) = train(&corpus, &vocab, &cfg, 2, 3);
        assert!(emb.data.iter().all(|x| x.is_finite()));
        assert!(stats.pairs > 100);
        assert_eq!(stats.sync_rounds, stats.pairs * 2);
    }

    #[test]
    fn matches_unpartitioned_semantics_direction() {
        // 1-server colpart == plain sequential SGNS; its loss direction
        // (same-cluster > cross) should hold
        let (corpus, vocab) = tiny();
        let cfg = SgnsConfig {
            dim: 8,
            epochs: 2,
            window: 2,
            negatives: 2,
            subsample_t: 0.0,
            ..Default::default()
        };
        let (e, _) = train(&corpus, &vocab, &cfg, 1, 9);
        let max_abs = e.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(max_abs > 0.05);
    }

    #[test]
    fn cost_model_shows_latency_domination() {
        // with realistic RTT the sync cost dwarfs compute — the paper's
        // "order of magnitude slower" observation
        let pairs = 1_000_000;
        let fast = estimated_seconds(pairs, 10, 1e-7, 0.0);
        let realistic = estimated_seconds(pairs, 10, 1e-7, 50e-6);
        assert!(realistic > fast * 100.0);
        // and adding servers with nonzero RTT saturates
        let s10 = estimated_seconds(pairs, 10, 1e-7, 50e-6);
        let s100 = estimated_seconds(pairs, 100, 1e-7, 50e-6);
        assert!(s100 > s10 * 0.9, "latency floor should dominate");
    }
}
