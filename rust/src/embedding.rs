//! The common embedding representation shared by trainers, the merge
//! phase and the evaluation harness.
//!
//! All sub-models live in the same global id space `0..V`; a sub-model
//! trained on a sub-corpus simply marks words it never (sufficiently) saw
//! as absent via the `present` mask — that sparsity is exactly what the
//! ALiR merge reconstructs (paper §3.3.2).

#[derive(Clone, Debug)]
pub struct Embedding {
    pub vocab: usize,
    pub dim: usize,
    /// row-major vocab × dim
    pub data: Vec<f32>,
    /// presence mask: false = this word is missing from this sub-model
    pub present: Vec<bool>,
}

impl Embedding {
    pub fn zeros(vocab: usize, dim: usize) -> Self {
        Self {
            vocab,
            dim,
            data: vec![0.0; vocab * dim],
            present: vec![true; vocab],
        }
    }

    pub fn from_rows(vocab: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), vocab * dim);
        Self {
            vocab,
            dim,
            data,
            present: vec![true; vocab],
        }
    }

    #[inline]
    pub fn row(&self, w: u32) -> &[f32] {
        &self.data[w as usize * self.dim..(w as usize + 1) * self.dim]
    }

    #[inline]
    pub fn row_mut(&mut self, w: u32) -> &mut [f32] {
        &mut self.data[w as usize * self.dim..(w as usize + 1) * self.dim]
    }

    pub fn is_present(&self, w: u32) -> bool {
        self.present[w as usize]
    }

    pub fn present_count(&self) -> usize {
        self.present.iter().filter(|&&p| p).count()
    }

    /// Cosine similarity; returns None if either word is absent.
    pub fn cosine(&self, a: u32, b: u32) -> Option<f64> {
        if !self.is_present(a) || !self.is_present(b) {
            return None;
        }
        let ra = self.row(a);
        let rb = self.row(b);
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for (x, y) in ra.iter().zip(rb) {
            dot += (*x as f64) * (*y as f64);
            na += (*x as f64) * (*x as f64);
            nb += (*y as f64) * (*y as f64);
        }
        Some(dot / (na.sqrt() * nb.sqrt()).max(1e-12))
    }

    /// L2-normalized copy of the present rows (absent rows zeroed) — the
    /// usual preprocessing for analogy search.
    pub fn normalized(&self) -> Embedding {
        let mut out = self.clone();
        for w in 0..self.vocab as u32 {
            if !self.is_present(w) {
                out.row_mut(w).fill(0.0);
                continue;
            }
            let norm: f32 = self.row(w).iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 1e-12 {
                for v in out.row_mut(w) {
                    *v /= norm;
                }
            }
        }
        out
    }

    /// Indices of the `k` nearest present rows to `query` by cosine,
    /// excluding `exclude`.
    pub fn nearest(&self, query: &[f32], k: usize, exclude: &[u32]) -> Vec<(u32, f64)> {
        let qn: f64 = query.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let mut scored: Vec<(u32, f64)> = (0..self.vocab as u32)
            .filter(|w| self.is_present(*w) && !exclude.contains(w))
            .map(|w| {
                let row = self.row(w);
                let dot: f64 = row
                    .iter()
                    .zip(query)
                    .map(|(a, b)| (*a as f64) * (*b as f64))
                    .sum();
                let rn: f64 = row.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
                (w, dot / (qn * rn).max(1e-12))
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.truncate(k);
        scored
    }
}

impl Embedding {
    const MAGIC: u32 = 0x6457_4532; // "dWE2"

    /// Persist as a simple binary: magic | vocab | dim | present bitmapish
    /// bytes | f32 rows.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(&Self::MAGIC.to_le_bytes())?;
        w.write_all(&(self.vocab as u64).to_le_bytes())?;
        w.write_all(&(self.dim as u64).to_le_bytes())?;
        for &p in &self.present {
            w.write_all(&[p as u8])?;
        }
        for &v in &self.data {
            w.write_all(&v.to_le_bytes())?;
        }
        w.flush()
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Embedding> {
        use std::io::Read;
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != Self::MAGIC {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "not a dw2v embedding file",
            ));
        }
        r.read_exact(&mut b8)?;
        let vocab = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b8)?;
        let dim = u64::from_le_bytes(b8) as usize;
        let mut present_bytes = vec![0u8; vocab];
        r.read_exact(&mut present_bytes)?;
        let mut data_bytes = vec![0u8; vocab * dim * 4];
        r.read_exact(&mut data_bytes)?;
        Ok(Embedding {
            vocab,
            dim,
            data: data_bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
            present: present_bytes.into_iter().map(|b| b != 0).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let mut e = sample();
        e.present[2] = false;
        let path = std::env::temp_dir().join(format!("dw2v_emb_{}.bin", std::process::id()));
        e.save(&path).unwrap();
        let back = Embedding::load(&path).unwrap();
        assert_eq!(back.vocab, e.vocab);
        assert_eq!(back.dim, e.dim);
        assert_eq!(back.data, e.data);
        assert_eq!(back.present, e.present);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join(format!("dw2v_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"garbage").unwrap();
        assert!(Embedding::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    fn sample() -> Embedding {
        let mut e = Embedding::zeros(4, 2);
        e.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(1).copy_from_slice(&[2.0, 0.0]);
        e.row_mut(2).copy_from_slice(&[0.0, 1.0]);
        e.row_mut(3).copy_from_slice(&[-1.0, 0.0]);
        e
    }

    #[test]
    fn cosine_basics() {
        let e = sample();
        assert!((e.cosine(0, 1).unwrap() - 1.0).abs() < 1e-9);
        assert!(e.cosine(0, 2).unwrap().abs() < 1e-9);
        assert!((e.cosine(0, 3).unwrap() + 1.0).abs() < 1e-9);
    }

    #[test]
    fn absent_words_yield_none() {
        let mut e = sample();
        e.present[1] = false;
        assert!(e.cosine(0, 1).is_none());
        assert_eq!(e.present_count(), 3);
    }

    #[test]
    fn normalized_rows_unit_length() {
        let e = sample().normalized();
        for w in 0..4u32 {
            let n: f32 = e.row(w).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn nearest_orders_by_cosine_and_respects_exclusions() {
        let e = sample();
        let res = e.nearest(&[1.0, 0.1], 2, &[0]);
        assert_eq!(res[0].0, 1); // same direction as query, 0 excluded
        assert!(res[0].1 > res[1].1);
        assert!(!res.iter().any(|(w, _)| *w == 0));
    }

    #[test]
    fn nearest_skips_absent() {
        let mut e = sample();
        e.present[1] = false;
        let res = e.nearest(&[1.0, 0.0], 4, &[]);
        assert!(!res.iter().any(|(w, _)| *w == 1));
    }
}
