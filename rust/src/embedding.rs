//! The common embedding representation shared by trainers, the merge
//! phase and the evaluation harness.
//!
//! All sub-models live in the same global id space `0..V`; a sub-model
//! trained on a sub-corpus simply marks words it never (sufficiently) saw
//! as absent via the `present` mask — that sparsity is exactly what the
//! ALiR merge reconstructs (paper §3.3.2).
//!
//! Row reductions (cosine, norms, nearest-neighbour scans) run on the
//! vectorized `crate::kernels`; `nearest` additionally takes precomputed
//! row norms and a partial top-k selection so a V-row scan is O(V) work
//! and one pass, not O(V log V) and two norm passes per query.

use crate::kernels;

#[derive(Clone, Debug)]
pub struct Embedding {
    pub vocab: usize,
    pub dim: usize,
    /// row-major vocab × dim
    pub data: Vec<f32>,
    /// presence mask: false = this word is missing from this sub-model
    pub present: Vec<bool>,
}

impl Embedding {
    pub fn zeros(vocab: usize, dim: usize) -> Self {
        Self {
            vocab,
            dim,
            data: vec![0.0; vocab * dim],
            present: vec![true; vocab],
        }
    }

    pub fn from_rows(vocab: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), vocab * dim);
        Self {
            vocab,
            dim,
            data,
            present: vec![true; vocab],
        }
    }

    #[inline]
    pub fn row(&self, w: u32) -> &[f32] {
        &self.data[w as usize * self.dim..(w as usize + 1) * self.dim]
    }

    #[inline]
    pub fn row_mut(&mut self, w: u32) -> &mut [f32] {
        &mut self.data[w as usize * self.dim..(w as usize + 1) * self.dim]
    }

    pub fn is_present(&self, w: u32) -> bool {
        self.present[w as usize]
    }

    pub fn present_count(&self) -> usize {
        self.present.iter().filter(|&&p| p).count()
    }

    /// Cosine similarity; returns None if either word is absent.
    pub fn cosine(&self, a: u32, b: u32) -> Option<f64> {
        if !self.is_present(a) || !self.is_present(b) {
            return None;
        }
        let ra = self.row(a);
        let rb = self.row(b);
        let dot = kernels::dot_wide(ra, rb);
        let na = kernels::norm_sq_wide(ra);
        let nb = kernels::norm_sq_wide(rb);
        Some(dot / (na.sqrt() * nb.sqrt()).max(1e-12))
    }

    /// L2-normalized copy of the present rows (absent rows zeroed) — the
    /// usual preprocessing for analogy search.
    pub fn normalized(&self) -> Embedding {
        let mut out = self.clone();
        for w in 0..self.vocab as u32 {
            if !self.is_present(w) {
                out.row_mut(w).fill(0.0);
                continue;
            }
            let norm = kernels::norm_sq(self.row(w)).sqrt();
            if norm > 1e-12 {
                kernels::scale(out.row_mut(w), 1.0 / norm);
            }
        }
        out
    }

    /// Per-row L2 norms (0.0 for absent rows), accumulated in f64 like all
    /// eval-path scoring. Compute once and hand to
    /// [`Embedding::nearest_with_norms`] when scanning many queries — the
    /// analogy eval does exactly this.
    pub fn row_norms(&self) -> Vec<f64> {
        (0..self.vocab as u32)
            .map(|w| {
                if self.is_present(w) {
                    kernels::norm_sq_wide(self.row(w)).sqrt()
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Indices of the `k` nearest present rows to `query` by cosine,
    /// excluding `exclude`. Row norms are computed on the fly; for
    /// repeated queries use [`Embedding::nearest_with_norms`].
    pub fn nearest(&self, query: &[f32], k: usize, exclude: &[u32]) -> Vec<(u32, f64)> {
        self.nearest_with_norms(query, k, exclude, &self.row_norms())
    }

    /// [`Embedding::nearest`] with caller-precomputed `row_norms()`.
    ///
    /// One vectorized dot per candidate row, exclusion via binary search
    /// on a sorted copy of `exclude`, and an O(V) partial top-k
    /// (`select_nth_unstable_by`) instead of sorting the whole scan.
    ///
    /// Ordering is fully deterministic: score descending, ties broken by
    /// ascending word id. Equal-score rows (duplicate vectors, symmetric
    /// constructions) therefore always come back in the same order, which
    /// the serving layer's exact-vs-ANN recall tests rely on.
    pub fn nearest_with_norms(
        &self,
        query: &[f32],
        k: usize,
        exclude: &[u32],
        norms: &[f64],
    ) -> Vec<(u32, f64)> {
        debug_assert_eq!(norms.len(), self.vocab);
        if k == 0 {
            return Vec::new();
        }
        let qn = kernels::norm_sq_wide(query).sqrt();
        let mut excl = exclude.to_vec();
        excl.sort_unstable();
        let by_score_then_id = |a: &(u32, f64), b: &(u32, f64)| {
            b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(&b.0))
        };
        let mut scored: Vec<(u32, f64)> = (0..self.vocab as u32)
            .filter(|w| self.is_present(*w) && excl.binary_search(w).is_err())
            .map(|w| {
                let dot = kernels::dot_wide(self.row(w), query);
                let rn = norms[w as usize];
                (w, dot / (qn * rn).max(1e-12))
            })
            .collect();
        let k = k.min(scored.len());
        if k > 0 && k < scored.len() {
            scored.select_nth_unstable_by(k - 1, by_score_then_id);
            scored.truncate(k);
        }
        scored.sort_by(by_score_then_id);
        scored
    }
}

impl Embedding {
    const MAGIC: u32 = 0x6457_4532; // "dWE2"
    /// magic + vocab + dim header bytes preceding the presence bitmap.
    const HEADER_BYTES: u64 = 4 + 8 + 8;
    /// vocab + dim size fields at the front of the body.
    const BODY_HEADER_BYTES: u64 = 8 + 8;

    /// Serialize the shape-prefixed body shared by [`Self::save`] and the
    /// [`SubModelArtifact`] container: vocab u64 | dim u64 | present
    /// bytes | f32 rows (all little-endian).
    fn write_body<W: std::io::Write>(&self, w: &mut W) -> std::io::Result<()> {
        w.write_all(&(self.vocab as u64).to_le_bytes())?;
        w.write_all(&(self.dim as u64).to_le_bytes())?;
        for &p in &self.present {
            w.write_all(&[p as u8])?;
        }
        for &v in &self.data {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Deserialize a [`Self::write_body`] payload known (from the real
    /// file length) to span exactly `body_len` bytes. Every size claim is
    /// validated *before* any sized allocation: a corrupt header comes
    /// back as `InvalidData`, never an allocation abort.
    fn read_body<R: std::io::Read>(r: &mut R, body_len: u64) -> std::io::Result<Embedding> {
        let invalid =
            |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        if body_len < Self::BODY_HEADER_BYTES {
            return Err(invalid(format!(
                "embedding body is {body_len} bytes — shorter than its header"
            )));
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let vocab = u64::from_le_bytes(b8);
        r.read_exact(&mut b8)?;
        let dim = u64::from_le_bytes(b8);
        let expected = vocab
            .checked_mul(dim)
            .and_then(|vd| vd.checked_mul(4))
            .and_then(|data| data.checked_add(vocab))
            .and_then(|body| body.checked_add(Self::BODY_HEADER_BYTES))
            .ok_or_else(|| {
                invalid(format!("embedding header overflows: vocab={vocab} dim={dim}"))
            })?;
        if expected != body_len {
            return Err(invalid(format!(
                "embedding header (vocab={vocab}, dim={dim}) implies {expected} \
                 bytes but {body_len} are present"
            )));
        }
        let vocab = vocab as usize;
        let dim = dim as usize;
        let mut present_bytes = vec![0u8; vocab];
        r.read_exact(&mut present_bytes)?;
        let mut data_bytes = vec![0u8; vocab * dim * 4];
        r.read_exact(&mut data_bytes)?;
        Ok(Embedding {
            vocab,
            dim,
            data: data_bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
            present: present_bytes.into_iter().map(|b| b != 0).collect(),
        })
    }

    /// Persist as a simple binary: magic | vocab | dim | present bitmapish
    /// bytes | f32 rows.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(&Self::MAGIC.to_le_bytes())?;
        self.write_body(&mut w)?;
        w.flush()
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Embedding> {
        use std::io::Read;
        let invalid =
            |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let file = std::fs::File::open(path)?;
        let file_len = file.metadata()?.len();
        if file_len < Self::HEADER_BYTES {
            return Err(invalid("not a dw2v embedding file".to_string()));
        }
        let mut r = std::io::BufReader::new(file);
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != Self::MAGIC {
            return Err(invalid("not a dw2v embedding file".to_string()));
        }
        Self::read_body(&mut r, file_len - 4)
    }
}

/// Metadata carried by a [`SubModelArtifact`]: everything a coordinator
/// needs to decide whether a sub-model file belongs to the run it is
/// collecting (config identity) and to report on it (loss curve, pairs).
///
/// Serialized as a JSON object inside the artifact container. The `u64`
/// fields (seeds, pair counts) are encoded as **decimal strings**, not
/// JSON numbers — JSON numbers are f64 and silently lose precision above
/// 2^53, and derived trainer seeds use the full 64 bits.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// which sub-model (0-based) of the run this is
    pub submodel: usize,
    /// total sub-models the run's divider produces (100/r)
    pub num_submodels: usize,
    /// the experiment's root seed (config identity)
    pub root_seed: u64,
    /// the per-sub-model seed derived from it (what the trainer used)
    pub trainer_seed: u64,
    /// divide strategy name (`equal` | `random` | `shuffle`)
    pub strategy: String,
    /// sampling rate r%
    pub rate_percent: f64,
    /// epochs trained
    pub epochs: usize,
    /// (center, context) pairs actually dispatched
    pub pairs: u64,
    /// mean loss per finished epoch
    pub epoch_loss: Vec<f64>,
}

impl ArtifactMeta {
    fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, inum, num, obj, s};
        obj(vec![
            ("submodel", inum(self.submodel)),
            ("num_submodels", inum(self.num_submodels)),
            ("root_seed", s(&self.root_seed.to_string())),
            ("trainer_seed", s(&self.trainer_seed.to_string())),
            ("strategy", s(&self.strategy)),
            ("rate_percent", num(self.rate_percent)),
            ("epochs", inum(self.epochs)),
            ("pairs", s(&self.pairs.to_string())),
            (
                "epoch_loss",
                arr(self.epoch_loss.iter().map(|&l| num(l)).collect()),
            ),
        ])
    }

    fn from_json(j: &crate::util::json::Json) -> Result<Self, String> {
        let usize_field = |k: &str| {
            j.get(k)
                .as_usize()
                .ok_or_else(|| format!("artifact meta: missing/invalid '{k}'"))
        };
        let u64_field = |k: &str| {
            j.get(k)
                .as_str()
                .and_then(|v| v.parse::<u64>().ok())
                .ok_or_else(|| format!("artifact meta: missing/invalid '{k}'"))
        };
        let epoch_loss = j
            .get("epoch_loss")
            .as_arr()
            .ok_or("artifact meta: missing 'epoch_loss'")?
            .iter()
            .map(|v| v.as_f64().ok_or("artifact meta: non-numeric epoch loss"))
            .collect::<Result<Vec<f64>, _>>()?;
        Ok(Self {
            submodel: usize_field("submodel")?,
            num_submodels: usize_field("num_submodels")?,
            root_seed: u64_field("root_seed")?,
            trainer_seed: u64_field("trainer_seed")?,
            strategy: j
                .get("strategy")
                .as_str()
                .ok_or("artifact meta: missing 'strategy'")?
                .to_string(),
            rate_percent: j
                .get("rate_percent")
                .as_f64()
                .ok_or("artifact meta: missing 'rate_percent'")?,
            epochs: usize_field("epochs")?,
            pairs: u64_field("pairs")?,
            epoch_loss,
        })
    }
}

/// A trained sub-model as exchanged between a multi-process training
/// worker and its coordinator: the [`Embedding`] payload plus
/// [`ArtifactMeta`] in one versioned container.
///
/// ```text
/// artifact := MAGIC u32 | VERSION u32 | meta_len u32 | meta JSON bytes
///             | embedding body (vocab u64 | dim u64 | present | f32 rows)
/// ```
///
/// Like [`Embedding::load`], every header claim is validated against the
/// real file length before any sized allocation, so a truncated or
/// corrupt artifact (e.g. from a worker killed mid-write, although
/// workers additionally write-then-rename) is an `InvalidData` error the
/// coordinator treats as a failed worker — never a crash.
#[derive(Clone, Debug)]
pub struct SubModelArtifact {
    pub meta: ArtifactMeta,
    pub embedding: Embedding,
}

impl SubModelArtifact {
    const MAGIC: u32 = 0x6457_534D; // "dWSM"
    const VERSION: u32 = 1;
    /// magic + version + meta_len bytes preceding the metadata.
    const HEADER_BYTES: u64 = 4 + 4 + 4;

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let meta = self.meta.to_json().to_string();
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(&Self::MAGIC.to_le_bytes())?;
        w.write_all(&Self::VERSION.to_le_bytes())?;
        w.write_all(&(meta.len() as u32).to_le_bytes())?;
        w.write_all(meta.as_bytes())?;
        self.embedding.write_body(&mut w)?;
        w.flush()
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<SubModelArtifact> {
        use std::io::Read;
        let invalid =
            |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let file = std::fs::File::open(path)?;
        let file_len = file.metadata()?.len();
        if file_len < Self::HEADER_BYTES {
            return Err(invalid(format!(
                "sub-model artifact {} is {file_len} bytes — shorter than the header",
                path.display()
            )));
        }
        let mut r = std::io::BufReader::new(file);
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != Self::MAGIC {
            return Err(invalid(format!(
                "{} is not a dw2v sub-model artifact",
                path.display()
            )));
        }
        r.read_exact(&mut b4)?;
        let version = u32::from_le_bytes(b4);
        if version != Self::VERSION {
            return Err(invalid(format!(
                "unsupported sub-model artifact version {version} (this build reads {})",
                Self::VERSION
            )));
        }
        r.read_exact(&mut b4)?;
        let meta_len = u32::from_le_bytes(b4) as u64;
        if meta_len > file_len - Self::HEADER_BYTES {
            return Err(invalid(format!(
                "artifact metadata claims {meta_len} bytes but only {} follow",
                file_len - Self::HEADER_BYTES
            )));
        }
        let mut meta_bytes = vec![0u8; meta_len as usize];
        r.read_exact(&mut meta_bytes)?;
        let meta_text = std::str::from_utf8(&meta_bytes)
            .map_err(|_| invalid("artifact metadata is not UTF-8".to_string()))?;
        let meta_json = crate::util::json::Json::parse(meta_text)
            .map_err(|e| invalid(format!("artifact metadata: {e}")))?;
        let meta = ArtifactMeta::from_json(&meta_json).map_err(invalid)?;
        let body_len = file_len - Self::HEADER_BYTES - meta_len;
        let embedding = Embedding::read_body(&mut r, body_len)?;
        Ok(SubModelArtifact { meta, embedding })
    }
}

/// Metadata carried by a [`CheckpointArtifact`]: run identity (so a
/// respawned worker refuses a checkpoint from a different run), progress
/// (which epoch boundary this snapshot sits on), and the exact trainer
/// counters a resume must reinstate.
///
/// `u64` counters are decimal strings for the same 2^53 reason as
/// [`ArtifactMeta`]; the `f64` loss counters are plain JSON numbers —
/// the writer prints f64s shortest-round-trip, so they come back
/// bit-exact (the artifact roundtrip test pins this).
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointMeta {
    /// which sub-model (0-based) of the run this is
    pub submodel: usize,
    /// total sub-models the run's divider produces (100/r)
    pub num_submodels: usize,
    /// the experiment's root seed (config identity)
    pub root_seed: u64,
    /// the per-sub-model seed derived from it
    pub trainer_seed: u64,
    /// divide strategy name (`equal` | `random` | `shuffle`)
    pub strategy: String,
    /// sampling rate r%
    pub rate_percent: f64,
    /// total epochs the run will train
    pub epochs: usize,
    /// epochs completed at checkpoint time (resume starts at this epoch)
    pub epochs_done: usize,
    /// corpus fingerprint: total sentences in the shard dir
    pub total_sentences: usize,
    /// actual vocabulary size (= `seen_counts` length)
    pub vocab: usize,
    /// pairs handed to the device (drives the lr schedule position)
    pub dispatched_pairs: u64,
    /// pairs emitted by the batch builder (dispatched + pending; equal at
    /// an epoch boundary, where pending is 0)
    pub pairs_emitted: u64,
    /// sentences routed to this trainer so far
    pub sentences_received: u64,
    /// device dispatches so far
    pub dispatches: u64,
    /// exact f64 loss accumulator (the f32 metrics row rounds it)
    pub loss_sum: f64,
    /// exact f64 weighted-example accumulator
    pub examples: f64,
    /// exact f64 micro-step counter
    pub micro_steps: f64,
    /// mean loss per finished epoch
    pub epoch_loss: Vec<f64>,
}

impl CheckpointMeta {
    fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, inum, num, obj, s};
        obj(vec![
            ("submodel", inum(self.submodel)),
            ("num_submodels", inum(self.num_submodels)),
            ("root_seed", s(&self.root_seed.to_string())),
            ("trainer_seed", s(&self.trainer_seed.to_string())),
            ("strategy", s(&self.strategy)),
            ("rate_percent", num(self.rate_percent)),
            ("epochs", inum(self.epochs)),
            ("epochs_done", inum(self.epochs_done)),
            ("total_sentences", inum(self.total_sentences)),
            ("vocab", inum(self.vocab)),
            ("dispatched_pairs", s(&self.dispatched_pairs.to_string())),
            ("pairs_emitted", s(&self.pairs_emitted.to_string())),
            ("sentences_received", s(&self.sentences_received.to_string())),
            ("dispatches", s(&self.dispatches.to_string())),
            ("loss_sum", num(self.loss_sum)),
            ("examples", num(self.examples)),
            ("micro_steps", num(self.micro_steps)),
            (
                "epoch_loss",
                arr(self.epoch_loss.iter().map(|&l| num(l)).collect()),
            ),
        ])
    }

    fn from_json(j: &crate::util::json::Json) -> Result<Self, String> {
        let usize_field = |k: &str| {
            j.get(k)
                .as_usize()
                .ok_or_else(|| format!("checkpoint meta: missing/invalid '{k}'"))
        };
        let u64_field = |k: &str| {
            j.get(k)
                .as_str()
                .and_then(|v| v.parse::<u64>().ok())
                .ok_or_else(|| format!("checkpoint meta: missing/invalid '{k}'"))
        };
        let f64_field = |k: &str| {
            j.get(k)
                .as_f64()
                .ok_or_else(|| format!("checkpoint meta: missing/invalid '{k}'"))
        };
        let epoch_loss = j
            .get("epoch_loss")
            .as_arr()
            .ok_or("checkpoint meta: missing 'epoch_loss'")?
            .iter()
            .map(|v| v.as_f64().ok_or("checkpoint meta: non-numeric epoch loss"))
            .collect::<Result<Vec<f64>, _>>()?;
        Ok(Self {
            submodel: usize_field("submodel")?,
            num_submodels: usize_field("num_submodels")?,
            root_seed: u64_field("root_seed")?,
            trainer_seed: u64_field("trainer_seed")?,
            strategy: j
                .get("strategy")
                .as_str()
                .ok_or("checkpoint meta: missing 'strategy'")?
                .to_string(),
            rate_percent: f64_field("rate_percent")?,
            epochs: usize_field("epochs")?,
            epochs_done: usize_field("epochs_done")?,
            total_sentences: usize_field("total_sentences")?,
            vocab: usize_field("vocab")?,
            dispatched_pairs: u64_field("dispatched_pairs")?,
            pairs_emitted: u64_field("pairs_emitted")?,
            sentences_received: u64_field("sentences_received")?,
            dispatches: u64_field("dispatches")?,
            loss_sum: f64_field("loss_sum")?,
            examples: f64_field("examples")?,
            micro_steps: f64_field("micro_steps")?,
            epoch_loss,
        })
    }
}

/// An epoch-boundary training checkpoint: everything a respawned worker
/// needs to resume its sub-model mid-run and (on the native backend)
/// finish bitwise identical to an uninterrupted run.
///
/// ```text
/// checkpoint := MAGIC u32 | VERSION u32 | meta_len u32 | meta JSON bytes
///               | seen_counts u64 × meta.vocab
///               | packed trainer state as an embedding body
///                 (rows u64 | dim u64 | present | f32 rows)
/// ```
///
/// The packed payload is the trainer's full `[rows, dim]` device state
/// (W, C, pad and metrics rows), not a merged embedding — `present` is
/// all-true and carries no meaning here. Like the other containers,
/// every header claim is validated against the real file length before
/// any sized allocation; workers write-then-rename, so a torn file only
/// exists if the filesystem itself tore it — and still only costs a
/// from-scratch retrain, never a crash.
#[derive(Clone, Debug)]
pub struct CheckpointArtifact {
    pub meta: CheckpointMeta,
    /// per-word occurrence counters (`meta.vocab` long) feeding the
    /// min-count presence mask
    pub seen_counts: Vec<u64>,
    /// packed trainer state (`rows × dim`, present all-true)
    pub packed: Embedding,
}

impl CheckpointArtifact {
    const MAGIC: u32 = 0x6457_434B; // "dWCK"
    const VERSION: u32 = 1;
    /// magic + version + meta_len bytes preceding the metadata.
    const HEADER_BYTES: u64 = 4 + 4 + 4;

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        assert_eq!(
            self.seen_counts.len(),
            self.meta.vocab,
            "seen_counts length must equal meta.vocab"
        );
        let meta = self.meta.to_json().to_string();
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(&Self::MAGIC.to_le_bytes())?;
        w.write_all(&Self::VERSION.to_le_bytes())?;
        w.write_all(&(meta.len() as u32).to_le_bytes())?;
        w.write_all(meta.as_bytes())?;
        for &c in &self.seen_counts {
            w.write_all(&c.to_le_bytes())?;
        }
        self.packed.write_body(&mut w)?;
        w.flush()
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<CheckpointArtifact> {
        use std::io::Read;
        let invalid =
            |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let file = std::fs::File::open(path)?;
        let file_len = file.metadata()?.len();
        if file_len < Self::HEADER_BYTES {
            return Err(invalid(format!(
                "checkpoint {} is {file_len} bytes — shorter than the header",
                path.display()
            )));
        }
        let mut r = std::io::BufReader::new(file);
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != Self::MAGIC {
            return Err(invalid(format!(
                "{} is not a dw2v training checkpoint",
                path.display()
            )));
        }
        r.read_exact(&mut b4)?;
        let version = u32::from_le_bytes(b4);
        if version != Self::VERSION {
            return Err(invalid(format!(
                "unsupported checkpoint version {version} (this build reads {})",
                Self::VERSION
            )));
        }
        r.read_exact(&mut b4)?;
        let meta_len = u32::from_le_bytes(b4) as u64;
        if meta_len > file_len - Self::HEADER_BYTES {
            return Err(invalid(format!(
                "checkpoint metadata claims {meta_len} bytes but only {} follow",
                file_len - Self::HEADER_BYTES
            )));
        }
        let mut meta_bytes = vec![0u8; meta_len as usize];
        r.read_exact(&mut meta_bytes)?;
        let meta_text = std::str::from_utf8(&meta_bytes)
            .map_err(|_| invalid("checkpoint metadata is not UTF-8".to_string()))?;
        let meta_json = crate::util::json::Json::parse(meta_text)
            .map_err(|e| invalid(format!("checkpoint metadata: {e}")))?;
        let meta = CheckpointMeta::from_json(&meta_json).map_err(invalid)?;
        let after_meta = file_len - Self::HEADER_BYTES - meta_len;
        let seen_len = (meta.vocab as u64).checked_mul(8).ok_or_else(|| {
            invalid(format!("checkpoint vocab {} overflows", meta.vocab))
        })?;
        if seen_len > after_meta {
            return Err(invalid(format!(
                "checkpoint claims {} seen-count words ({seen_len} bytes) but \
                 only {after_meta} bytes follow the metadata",
                meta.vocab
            )));
        }
        let mut seen_bytes = vec![0u8; seen_len as usize];
        r.read_exact(&mut seen_bytes)?;
        let seen_counts: Vec<u64> = seen_bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let packed = Embedding::read_body(&mut r, after_meta - seen_len)?;
        Ok(CheckpointArtifact {
            meta,
            seen_counts,
            packed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let mut e = sample();
        e.present[2] = false;
        let path = std::env::temp_dir().join(format!("dw2v_emb_{}.bin", std::process::id()));
        e.save(&path).unwrap();
        let back = Embedding::load(&path).unwrap();
        assert_eq!(back.vocab, e.vocab);
        assert_eq!(back.dim, e.dim);
        assert_eq!(back.data, e.data);
        assert_eq!(back.present, e.present);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn save_load_roundtrip_property() {
        // randomized shapes and payloads, bitwise equality on every field —
        // the serving layer deserializes models saved by the training
        // pipeline, so the on-disk format must round-trip exactly
        let mut rng = crate::util::rng::Pcg64::new(0x5EED);
        for case in 0..12u32 {
            let vocab = 1 + rng.gen_range_usize(40);
            let dim = 1 + rng.gen_range_usize(24);
            let mut e = Embedding::zeros(vocab, dim);
            for v in e.data.iter_mut() {
                // mix magnitudes (incl. subnormal-ish and negative zero
                // territory) while staying NaN-free
                let raw = rng.gen_gauss() as f32;
                *v = match rng.gen_range(4) {
                    0 => raw * 1e-30,
                    1 => raw * 1e30,
                    2 => -0.0,
                    _ => raw,
                };
            }
            for p in e.present.iter_mut() {
                *p = rng.gen_bool(0.8);
            }
            let path = std::env::temp_dir().join(format!(
                "dw2v_prop_{}_{case}.bin",
                std::process::id()
            ));
            e.save(&path).unwrap();
            let back = Embedding::load(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert_eq!(back.vocab, e.vocab);
            assert_eq!(back.dim, e.dim);
            assert_eq!(back.present, e.present);
            assert_eq!(back.data.len(), e.data.len());
            for (i, (a, b)) in e.data.iter().zip(&back.data).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case {case}: f32 at {i} not bitwise equal: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn nearest_breaks_score_ties_by_ascending_id() {
        // rows 1, 3, 4 are identical → identical scores; the returned order
        // must be deterministic (ascending id) regardless of k or the
        // partial-selection pivot choices
        let mut e = Embedding::zeros(6, 2);
        e.row_mut(0).copy_from_slice(&[0.0, 1.0]);
        e.row_mut(1).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(2).copy_from_slice(&[-1.0, 0.0]);
        e.row_mut(3).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(4).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(5).copy_from_slice(&[0.5, 0.5]);
        let query = [1.0f32, 0.0];
        let full = e.nearest(&query, 6, &[]);
        assert_eq!(
            full.iter().map(|(w, _)| *w).collect::<Vec<_>>(),
            vec![1, 3, 4, 5, 0, 2]
        );
        // truncated k that cuts through the tie group still honors id order
        let top2 = e.nearest(&query, 2, &[]);
        assert_eq!(top2.iter().map(|(w, _)| *w).collect::<Vec<_>>(), vec![1, 3]);
        // and repeated runs agree exactly
        for _ in 0..5 {
            assert_eq!(e.nearest(&query, 4, &[]), e.nearest(&query, 4, &[]));
        }
    }

    fn sample_meta() -> ArtifactMeta {
        ArtifactMeta {
            submodel: 2,
            num_submodels: 4,
            // full-width u64s: JSON numbers would round these
            root_seed: u64::MAX - 12345,
            trainer_seed: 0xDEAD_BEEF_CAFE_F00D,
            strategy: "shuffle".to_string(),
            rate_percent: 25.0,
            epochs: 3,
            pairs: (1 << 60) + 7,
            epoch_loss: vec![0.693, 0.41, 0.385],
        }
    }

    #[test]
    fn artifact_roundtrip_is_exact() {
        let mut e = sample();
        e.present[1] = false;
        let art = SubModelArtifact {
            meta: sample_meta(),
            embedding: e,
        };
        let path = std::env::temp_dir().join(format!("dw2v_art_{}.dwsm", std::process::id()));
        art.save(&path).unwrap();
        let back = SubModelArtifact::load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(back.meta, art.meta, "meta incl. full-width u64 seeds");
        assert_eq!(back.embedding.vocab, art.embedding.vocab);
        assert_eq!(back.embedding.present, art.embedding.present);
        for (a, b) in art.embedding.data.iter().zip(&back.embedding.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in art.meta.epoch_loss.iter().zip(&back.meta.epoch_loss) {
            assert_eq!(a.to_bits(), b.to_bits(), "loss curve must survive JSON");
        }
    }

    #[test]
    fn artifact_rejects_corruption() {
        let art = SubModelArtifact {
            meta: sample_meta(),
            embedding: sample(),
        };
        let path =
            std::env::temp_dir().join(format!("dw2v_artbad_{}.dwsm", std::process::id()));
        art.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();

        let expect_invalid = |bytes: &[u8]| {
            std::fs::write(&path, bytes).unwrap();
            let err = SubModelArtifact::load(&path).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        };
        // truncations: inside the header, inside the metadata, inside the body
        expect_invalid(&full[..6]);
        expect_invalid(&full[..20]);
        expect_invalid(&full[..full.len() - 3]);
        // trailing junk
        let mut padded = full.clone();
        padded.extend_from_slice(&[0xEE; 5]);
        expect_invalid(&padded);
        // wrong version
        let mut vbad = full.clone();
        vbad[4] = 99;
        expect_invalid(&vbad);
        // a plain embedding file is not an artifact
        let epath =
            std::env::temp_dir().join(format!("dw2v_artemb_{}.bin", std::process::id()));
        art.embedding.save(&epath).unwrap();
        let err = SubModelArtifact::load(&epath).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::remove_file(&epath).unwrap();
        std::fs::remove_file(&path).unwrap();
    }

    fn sample_ckpt() -> CheckpointArtifact {
        CheckpointArtifact {
            meta: CheckpointMeta {
                submodel: 1,
                num_submodels: 4,
                root_seed: u64::MAX - 99,
                trainer_seed: 0xFEED_FACE_0123_4567,
                strategy: "shuffle".to_string(),
                rate_percent: 25.0,
                epochs: 5,
                epochs_done: 2,
                total_sentences: 1600,
                vocab: 4,
                dispatched_pairs: (1 << 61) + 3,
                pairs_emitted: (1 << 61) + 3,
                sentences_received: 12_345,
                dispatches: 678,
                // exactness matters: pick values f32 would round
                loss_sum: 1234.000000001,
                examples: 16_777_217.0,
                micro_steps: 1356.0,
                epoch_loss: vec![0.693, 0.41],
            },
            seen_counts: vec![7, 0, (1 << 55) + 1, 3],
            packed: sample(),
        }
    }

    #[test]
    fn checkpoint_roundtrip_is_exact() {
        let ck = sample_ckpt();
        let path =
            std::env::temp_dir().join(format!("dw2v_ckpt_{}.ckpt", std::process::id()));
        ck.save(&path).unwrap();
        let back = CheckpointArtifact::load(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(back.meta, ck.meta, "meta incl. full-width u64 counters");
        assert_eq!(
            back.meta.loss_sum.to_bits(),
            ck.meta.loss_sum.to_bits(),
            "f64 loss accumulator must survive JSON bit-exactly"
        );
        assert_eq!(back.meta.examples.to_bits(), ck.meta.examples.to_bits());
        assert_eq!(back.seen_counts, ck.seen_counts);
        assert_eq!(back.packed.vocab, ck.packed.vocab);
        assert_eq!(back.packed.present, ck.packed.present);
        for (a, b) in ck.packed.data.iter().zip(&back.packed.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ck.meta.epoch_loss.iter().zip(&back.meta.epoch_loss) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn checkpoint_rejects_corruption() {
        let ck = sample_ckpt();
        let path =
            std::env::temp_dir().join(format!("dw2v_ckbad_{}.ckpt", std::process::id()));
        ck.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();

        let expect_invalid = |bytes: &[u8]| {
            std::fs::write(&path, bytes).unwrap();
            let err = CheckpointArtifact::load(&path).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        };
        // truncations: header, metadata, seen counts, packed body
        expect_invalid(&full[..6]);
        expect_invalid(&full[..20]);
        expect_invalid(&full[..full.len() - 9]);
        expect_invalid(&full[..full.len() - 1]);
        // trailing junk
        let mut padded = full.clone();
        padded.extend_from_slice(&[0xEE; 5]);
        expect_invalid(&padded);
        // wrong version
        let mut vbad = full.clone();
        vbad[4] = 42;
        expect_invalid(&vbad);
        // a sub-model artifact is not a checkpoint (different magic)
        let art = SubModelArtifact {
            meta: sample_meta(),
            embedding: sample(),
        };
        let apath = std::env::temp_dir()
            .join(format!("dw2v_ckcross_{}.dwsm", std::process::id()));
        art.save(&apath).unwrap();
        let err = CheckpointArtifact::load(&apath).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::remove_file(&apath).unwrap();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join(format!("dw2v_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"garbage").unwrap();
        assert!(Embedding::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_corrupt_header_without_allocating() {
        // valid magic, then a vocab/dim pair claiming ~10^38 bytes: must be
        // InvalidData from the length check, not an allocation abort
        let path =
            std::env::temp_dir().join(format!("dw2v_hdr_{}.bin", std::process::id()));
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&Embedding::MAGIC.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // vocab
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // dim
        std::fs::write(&path, &bytes).unwrap();
        let err = Embedding::load(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_truncated_file() {
        let e = sample();
        let path =
            std::env::temp_dir().join(format!("dw2v_trunc_{}.bin", std::process::id()));
        e.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let err = Embedding::load(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // and a file with trailing junk is rejected too
        let mut padded = full.clone();
        padded.extend_from_slice(&[0u8; 3]);
        std::fs::write(&path, &padded).unwrap();
        let err = Embedding::load(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::remove_file(&path).unwrap();
    }

    fn sample() -> Embedding {
        let mut e = Embedding::zeros(4, 2);
        e.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(1).copy_from_slice(&[2.0, 0.0]);
        e.row_mut(2).copy_from_slice(&[0.0, 1.0]);
        e.row_mut(3).copy_from_slice(&[-1.0, 0.0]);
        e
    }

    #[test]
    fn cosine_basics() {
        let e = sample();
        assert!((e.cosine(0, 1).unwrap() - 1.0).abs() < 1e-9);
        assert!(e.cosine(0, 2).unwrap().abs() < 1e-9);
        assert!((e.cosine(0, 3).unwrap() + 1.0).abs() < 1e-9);
    }

    #[test]
    fn absent_words_yield_none() {
        let mut e = sample();
        e.present[1] = false;
        assert!(e.cosine(0, 1).is_none());
        assert_eq!(e.present_count(), 3);
    }

    #[test]
    fn normalized_rows_unit_length() {
        let e = sample().normalized();
        for w in 0..4u32 {
            let n: f32 = e.row(w).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn nearest_orders_by_cosine_and_respects_exclusions() {
        let e = sample();
        let res = e.nearest(&[1.0, 0.1], 2, &[0]);
        assert_eq!(res[0].0, 1); // same direction as query, 0 excluded
        assert!(res[0].1 > res[1].1);
        assert!(!res.iter().any(|(w, _)| *w == 0));
    }

    #[test]
    fn nearest_skips_absent() {
        let mut e = sample();
        e.present[1] = false;
        let res = e.nearest(&[1.0, 0.0], 4, &[]);
        assert!(!res.iter().any(|(w, _)| *w == 1));
    }

    #[test]
    fn nearest_with_norms_matches_fresh_computation() {
        // a larger random embedding: precomputed-norm path must agree with
        // the self-computing path on both order and scores
        let mut e = Embedding::zeros(50, 7);
        let mut rng = crate::util::rng::Pcg64::new(77);
        for w in 0..50u32 {
            for v in e.row_mut(w) {
                *v = rng.gen_gauss() as f32;
            }
        }
        e.present[13] = false;
        let norms = e.row_norms();
        let query: Vec<f32> = (0..7).map(|_| rng.gen_gauss() as f32).collect();
        let a = e.nearest(&query, 5, &[3, 40]);
        let b = e.nearest_with_norms(&query, 5, &[3, 40], &norms);
        assert_eq!(a.len(), 5);
        for ((wa, sa), (wb, sb)) in a.iter().zip(&b) {
            assert_eq!(wa, wb);
            assert!((sa - sb).abs() < 1e-12);
        }
        // top-k selection returns the same set as a full sort
        let full = {
            let mut all = e.nearest_with_norms(&query, 48, &[3, 40], &norms);
            all.truncate(5);
            all
        };
        assert_eq!(
            a.iter().map(|(w, _)| *w).collect::<Vec<_>>(),
            full.iter().map(|(w, _)| *w).collect::<Vec<_>>()
        );
        // k larger than the candidate set returns everything, ordered
        let everything = e.nearest(&query, 500, &[]);
        assert_eq!(everything.len(), 49); // 50 minus the absent row
        for pair in everything.windows(2) {
            assert!(pair[0].1 >= pair[1].1);
        }
        assert!(e.nearest(&query, 0, &[]).is_empty());
    }
}
