//! Analogy evaluation: 3CosAdd accuracy (Mikolov et al.), the protocol
//! behind the paper's Google/SemEval columns.
//!
//! For a question a : b :: c : ?, the prediction is
//! `argmax_w cos(w, b − a + c)` over present words excluding {a, b, c};
//! the question scores 1 iff the argmax is the gold d. Questions touching
//! an absent word are skipped and counted as OOV.

use crate::embedding::Embedding;
use crate::gen::benchmarks::AnalogyQuad;
use crate::kernels;
use crate::serve::index::AnnIndex;

#[derive(Clone, Debug)]
pub struct AnalogyResult {
    pub accuracy: f64,
    pub questions_used: usize,
    pub questions_skipped: usize,
    pub oov_words: usize,
}

/// Evaluate 3CosAdd accuracy of `quads` against an embedding (exact scan).
pub fn evaluate(emb: &Embedding, quads: &[AnalogyQuad]) -> AnalogyResult {
    let unit = emb.normalized();
    // one norm pass for the whole benchmark — every query reuses it
    // instead of recomputing V norms inside `nearest`
    let norms = unit.row_norms();
    evaluate_via(&unit, quads, |query, excl| {
        unit.nearest_with_norms(query, 1, excl, &norms)
            .first()
            .map(|(w, _)| *w)
    })
}

/// [`evaluate`] with the argmax served by an ANN index instead of the
/// exact scan — the approximate side of the exact-vs-ANN benchmark
/// comparison. `index` must be built over the same embedding; `ef_search
/// = 0` uses the index's configured default.
pub fn evaluate_indexed(
    emb: &Embedding,
    quads: &[AnalogyQuad],
    index: &AnnIndex,
    ef_search: usize,
) -> AnalogyResult {
    let unit = emb.normalized();
    evaluate_via(&unit, quads, |query, excl| {
        index
            .search(query, 1, ef_search, excl)
            .first()
            .map(|(w, _)| *w)
    })
}

/// The shared 3CosAdd protocol: assemble `b − a + c` over unit rows, ask
/// `top1` for the argmax (excluding the question words), score against d.
fn evaluate_via<F: FnMut(&[f32], &[u32]) -> Option<u32>>(
    unit: &Embedding,
    quads: &[AnalogyQuad],
    mut top1: F,
) -> AnalogyResult {
    let mut correct = 0usize;
    let mut used = 0usize;
    let mut skipped = 0usize;
    let mut oov = std::collections::HashSet::new();
    let dim = unit.dim;
    let mut query = vec![0.0f32; dim];
    for q in quads {
        let absent: Vec<u32> = [q.a, q.b, q.c, q.d]
            .into_iter()
            .filter(|&w| !unit.is_present(w))
            .collect();
        if !absent.is_empty() {
            oov.extend(absent);
            skipped += 1;
            continue;
        }
        let (a, b, c) = (unit.row(q.a), unit.row(q.b), unit.row(q.c));
        // query = b − a + c in two fused passes
        kernels::scaled_add(&mut query, b, a, -1.0);
        kernels::axpy(1.0, c, &mut query);
        used += 1;
        if top1(&query, &[q.a, q.b, q.c]) == Some(q.d) {
            correct += 1;
        }
    }
    AnalogyResult {
        accuracy: if used > 0 {
            correct as f64 / used as f64
        } else {
            0.0
        },
        questions_used: used,
        questions_skipped: skipped,
        oov_words: oov.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Embedding with perfect offset structure: word w = base(w%2) + group(w/2).
    fn offset_embedding() -> Embedding {
        let mut e = Embedding::zeros(8, 4);
        for w in 0..8u32 {
            let group = (w / 2) as usize;
            let sex = (w % 2) as f32; // the "relation" offset
            let mut v = [0.0f32; 4];
            v[group] = 1.0;
            v[3] += sex * 0.5;
            e.row_mut(w).copy_from_slice(&v);
        }
        e
    }

    #[test]
    fn perfect_offsets_score_full_accuracy() {
        let e = offset_embedding();
        // 0:1 :: 2:3, 2:3 :: 4:5, etc.
        let quads = vec![
            AnalogyQuad { a: 0, b: 1, c: 2, d: 3 },
            AnalogyQuad { a: 2, b: 3, c: 4, d: 5 },
            AnalogyQuad { a: 4, b: 5, c: 0, d: 1 },
        ];
        let r = evaluate(&e, &quads);
        assert_eq!(r.questions_used, 3);
        assert!(r.accuracy > 0.99, "accuracy={}", r.accuracy);
    }

    #[test]
    fn skips_questions_with_absent_words() {
        let mut e = offset_embedding();
        e.present[3] = false;
        let quads = vec![
            AnalogyQuad { a: 0, b: 1, c: 2, d: 3 }, // d absent
            AnalogyQuad { a: 2, b: 3, c: 4, d: 5 }, // b absent
            AnalogyQuad { a: 4, b: 5, c: 6, d: 7 }, // fine
        ];
        let r = evaluate(&e, &quads);
        assert_eq!(r.questions_used, 1);
        assert_eq!(r.questions_skipped, 2);
        assert_eq!(r.oov_words, 1);
    }

    #[test]
    fn excludes_question_words_from_candidates() {
        // degenerate embedding where c itself would otherwise win
        let mut e = Embedding::zeros(4, 2);
        e.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(1).copy_from_slice(&[1.0, 0.1]);
        e.row_mut(2).copy_from_slice(&[1.0, 0.05]);
        e.row_mut(3).copy_from_slice(&[1.0, 0.15]);
        let quads = vec![AnalogyQuad { a: 0, b: 1, c: 2, d: 3 }];
        let r = evaluate(&e, &quads);
        // whatever the winner, it cannot be a/b/c — with d the only other
        // word, accuracy must be 1
        assert_eq!(r.accuracy, 1.0);
    }

    #[test]
    fn indexed_evaluation_matches_exact_on_clean_structure() {
        let e = offset_embedding();
        let quads = vec![
            AnalogyQuad { a: 0, b: 1, c: 2, d: 3 },
            AnalogyQuad { a: 2, b: 3, c: 4, d: 5 },
            AnalogyQuad { a: 4, b: 5, c: 0, d: 1 },
        ];
        let exact = evaluate(&e, &quads);
        // tiny vocab → the index's brute-force fallback, so accuracy must
        // agree exactly with the scan
        let index = AnnIndex::build(&e.normalized(), Default::default());
        let approx = evaluate_indexed(&e, &quads, &index, 0);
        assert_eq!(exact.questions_used, approx.questions_used);
        assert!((exact.accuracy - approx.accuracy).abs() < 1e-12);
        assert!(approx.accuracy > 0.99);
    }

    #[test]
    fn random_embedding_scores_low() {
        let mut e = Embedding::zeros(50, 8);
        let mut rng = crate::util::rng::Pcg64::new(9);
        for w in 0..50u32 {
            for v in e.row_mut(w) {
                *v = rng.gen_gauss() as f32;
            }
        }
        let quads: Vec<AnalogyQuad> = (0..40)
            .map(|i| AnalogyQuad {
                a: i % 50,
                b: (i + 11) % 50,
                c: (i + 23) % 50,
                d: (i + 37) % 50,
            })
            .collect();
        let r = evaluate(&e, &quads);
        assert!(r.accuracy < 0.2, "random should be near chance: {}", r.accuracy);
    }
}
