//! Categorization evaluation: cluster the benchmark words' embeddings with
//! k-means (k = number of gold categories) and score cluster **purity**,
//! exactly the protocol behind the paper's AP/Battig columns.

use crate::embedding::Embedding;
use crate::gen::benchmarks::CatItem;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct CatResult {
    pub purity: f64,
    pub items_used: usize,
    pub oov_words: usize,
}

/// Standard k-means with k-means++-style farthest-first seeding on unit-
/// normalized vectors (cosine k-means).
pub fn kmeans(points: &[Vec<f32>], k: usize, seed: u64, iters: usize) -> Vec<usize> {
    let n = points.len();
    assert!(k >= 1);
    if n == 0 {
        return Vec::new();
    }
    let d = points[0].len();
    let mut rng = Pcg64::new_stream(seed, 0x6B6D); // "km"
    // unit-normalize input so euclidean kmeans ≈ cosine clustering
    let unit: Vec<Vec<f32>> = points
        .iter()
        .map(|p| {
            let norm: f32 = p.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 1e-12 {
                p.iter().map(|x| x / norm).collect()
            } else {
                p.clone()
            }
        })
        .collect();
    let dist2 = |a: &[f32], b: &[f32]| -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    };
    // k-means++ seeding
    let mut centers: Vec<Vec<f32>> = Vec::with_capacity(k);
    centers.push(unit[rng.gen_range_usize(n)].clone());
    while centers.len() < k.min(n) {
        let d2: Vec<f32> = unit
            .iter()
            .map(|p| {
                centers
                    .iter()
                    .map(|c| dist2(p, c))
                    .fold(f32::INFINITY, f32::min)
            })
            .collect();
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let mut pick = 0;
        if total > 0.0 {
            let mut u = rng.gen_f64() * total;
            for (i, &x) in d2.iter().enumerate() {
                u -= x as f64;
                if u <= 0.0 {
                    pick = i;
                    break;
                }
            }
        } else {
            pick = rng.gen_range_usize(n);
        }
        centers.push(unit[pick].clone());
    }
    while centers.len() < k {
        centers.push(vec![0.0; d]); // degenerate k > n case
    }
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        let mut changed = false;
        for (i, p) in unit.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    dist2(p, &centers[a])
                        .partial_cmp(&dist2(p, &centers[b]))
                        .unwrap()
                })
                .unwrap();
            if best != assign[i] {
                assign[i] = best;
                changed = true;
            }
        }
        let mut sums = vec![vec![0.0f32; d]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in unit.iter().enumerate() {
            counts[assign[i]] += 1;
            for (s, x) in sums[assign[i]].iter_mut().zip(p) {
                *s += x;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for s in sums[c].iter_mut() {
                    *s /= counts[c] as f32;
                }
                centers[c] = sums[c].clone();
            }
        }
        if !changed {
            break;
        }
    }
    assign
}

/// Purity: each cluster votes for its majority gold category;
/// purity = (Σ_cluster majority-count) / N.
pub fn purity(assign: &[usize], gold: &[usize], k: usize, num_categories: usize) -> f64 {
    assert_eq!(assign.len(), gold.len());
    if assign.is_empty() {
        return 0.0;
    }
    let mut table = vec![vec![0usize; num_categories]; k];
    for (&a, &g) in assign.iter().zip(gold) {
        table[a][g] += 1;
    }
    let correct: usize = table
        .iter()
        .map(|row| row.iter().copied().max().unwrap_or(0))
        .sum();
    correct as f64 / assign.len() as f64
}

/// Evaluate a categorization benchmark against an embedding.
pub fn evaluate(
    emb: &Embedding,
    items: &[CatItem],
    num_categories: usize,
    seed: u64,
) -> CatResult {
    let mut points = Vec::new();
    let mut gold = Vec::new();
    let mut oov = std::collections::HashSet::new();
    for it in items {
        if emb.is_present(it.word) {
            points.push(emb.row(it.word).to_vec());
            gold.push(it.category);
        } else {
            oov.insert(it.word);
        }
    }
    let assign = kmeans(&points, num_categories, seed, 50);
    CatResult {
        purity: purity(&assign, &gold, num_categories, num_categories),
        items_used: points.len(),
        oov_words: oov.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kmeans_separates_obvious_clusters() {
        // two tight blobs on orthogonal axes
        let mut points = Vec::new();
        for i in 0..20 {
            let e = 0.01 * i as f32;
            points.push(vec![1.0, e]);
            points.push(vec![e, 1.0]);
        }
        let assign = kmeans(&points, 2, 1, 50);
        // all even indices together, all odd together
        let a0 = assign[0];
        for i in (0..40).step_by(2) {
            assert_eq!(assign[i], a0);
        }
        assert_ne!(assign[1], a0);
    }

    #[test]
    fn purity_perfect_and_chance() {
        let assign = vec![0, 0, 1, 1];
        let gold = vec![1, 1, 0, 0];
        assert_eq!(purity(&assign, &gold, 2, 2), 1.0); // labels permuted is fine
        let mixed = vec![0, 1, 0, 1];
        assert_eq!(purity(&mixed, &gold, 2, 2), 0.5);
    }

    #[test]
    fn purity_empty() {
        assert_eq!(purity(&[], &[], 2, 2), 0.0);
    }

    #[test]
    fn evaluate_counts_oov() {
        let mut e = Embedding::zeros(6, 2);
        for w in 0..3u32 {
            e.row_mut(w).copy_from_slice(&[1.0, 0.0]);
        }
        for w in 3..6u32 {
            e.row_mut(w).copy_from_slice(&[0.0, 1.0]);
        }
        e.present[5] = false;
        let items: Vec<CatItem> = (0..6)
            .map(|w| CatItem {
                word: w,
                category: (w / 3) as usize,
            })
            .collect();
        let r = evaluate(&e, &items, 2, 7);
        assert_eq!(r.items_used, 5);
        assert_eq!(r.oov_words, 1);
        assert!(r.purity > 0.99, "purity={}", r.purity);
    }

    #[test]
    fn kmeans_handles_k_greater_than_n() {
        let points = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let assign = kmeans(&points, 5, 3, 10);
        assert_eq!(assign.len(), 2);
        for &a in &assign {
            assert!(a < 5);
        }
    }
}
