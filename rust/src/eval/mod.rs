//! Evaluation harness: similarity (Spearman ρ), categorization (k-means
//! purity) and analogy (3CosAdd accuracy) with the paper's OOV accounting,
//! plus the loader for the standard `questions-words.txt` analogy format
//! ([`questions`]) used when training on real ingested corpora.
pub mod analogy;
pub mod categorization;
pub mod questions;
pub mod report;
pub mod similarity;
