//! Evaluation harness: similarity (Spearman ρ), categorization (k-means
//! purity) and analogy (3CosAdd accuracy) with the paper's OOV accounting.
pub mod analogy;
pub mod categorization;
pub mod report;
pub mod similarity;
