//! Loader for the standard `questions-words.txt` analogy benchmark format
//! (Mikolov et al.), so models trained on real ingested corpora are scored
//! on real benchmarks instead of the synthetic gold suite.
//!
//! The format is line-oriented:
//!
//! ```text
//! : capital-common-countries
//! Athens Greece Baghdad Iraq
//! Athens Greece Bangkok Thailand
//! : gram1-adjective-to-adverb
//! amazing amazingly apparent apparently
//! ```
//!
//! Every `: name` line starts a section; every other non-empty line is one
//! `a : b :: c : d` question. Words are run through the **same
//! normalization the tokenizer applies to the corpus** (lowercasing,
//! U+2019 → `'`, punctuation stripped) before the vocabulary lookup, so
//! "Don’t" in a questions file matches the "don't" the ingest stored.
//! Questions with any out-of-vocabulary word are dropped at load time
//! (they could never be answered — the evaluator's own OOV accounting
//! covers words dropped later by sub-model presence masks).

use crate::gen::benchmarks::{AnalogyQuad, Benchmark, BenchmarkData, BenchmarkKind};
use crate::text::tokenize::tokenize;
use crate::text::vocab::Vocab;

/// One parsed questions-words file: a benchmark per non-empty section,
/// plus load accounting.
#[derive(Clone, Debug, Default)]
pub struct QuestionsWords {
    /// one analogy [`Benchmark`] per section that kept ≥ 1 question
    pub suite: Vec<Benchmark>,
    /// sections seen in the file (kept or not)
    pub sections: usize,
    /// well-formed questions seen
    pub total_questions: usize,
    /// questions dropped because a word is not in the vocabulary
    pub oov_questions: usize,
    /// lines that were neither a section header nor 4 words
    pub malformed_lines: usize,
}

impl QuestionsWords {
    pub fn kept_questions(&self) -> usize {
        self.total_questions - self.oov_questions
    }

    /// One-line human report.
    pub fn summary(&self) -> String {
        format!(
            "questions-words: {} sections, {}/{} questions in-vocab ({} malformed lines skipped)",
            self.sections,
            self.kept_questions(),
            self.total_questions,
            self.malformed_lines
        )
    }
}

/// Parse questions-words text against a frozen vocabulary.
pub fn parse_questions_words(text: &str, vocab: &Vocab) -> QuestionsWords {
    let mut out = QuestionsWords::default();
    let mut section = String::from("all");
    let mut quads: Vec<AnalogyQuad> = Vec::new();
    let flush = |name: &str, quads: &mut Vec<AnalogyQuad>, suite: &mut Vec<Benchmark>| {
        if quads.is_empty() {
            return;
        }
        suite.push(Benchmark {
            name: format!("qw-{name}"),
            kind: BenchmarkKind::Analogy,
            data: BenchmarkData::Analogy(std::mem::take(quads)),
        });
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix(':') {
            flush(&section, &mut quads, &mut out.suite);
            section = name.trim().to_string();
            out.sections += 1;
            continue;
        }
        let words: Vec<&str> = line.split_whitespace().collect();
        if words.len() != 4 {
            out.malformed_lines += 1;
            continue;
        }
        out.total_questions += 1;
        // tokenizer-identical normalization; a word that does not survive
        // as exactly one token could never appear in the vocab either
        let ids: Vec<Option<u32>> = words
            .iter()
            .map(|w| {
                let mut toks = tokenize(w);
                match toks.len() {
                    1 => vocab.id(&toks.pop().expect("len checked")),
                    _ => None,
                }
            })
            .collect();
        match (ids[0], ids[1], ids[2], ids[3]) {
            (Some(a), Some(b), Some(c), Some(d)) => quads.push(AnalogyQuad { a, b, c, d }),
            _ => out.oov_questions += 1,
        }
    }
    flush(&section, &mut quads, &mut out.suite);
    out
}

/// [`parse_questions_words`] from a file path.
pub fn load_questions_words(
    path: &std::path::Path,
    vocab: &Vocab,
) -> Result<QuestionsWords, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read questions file {}: {e}", path.display()))?;
    Ok(parse_questions_words(&text, vocab))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::vocab::VocabBuilder;

    fn vocab_of(words: &[&str]) -> Vocab {
        let mut b = VocabBuilder::new();
        for (i, w) in words.iter().enumerate() {
            // distinct counts keep id assignment unambiguous
            for _ in 0..(words.len() - i) {
                b.add_token(w);
            }
        }
        b.build(1, usize::MAX)
    }

    const SAMPLE: &str = "\
: capital-common
Athens Greece Oslo Norway
Athens Greece Paris France
: family
boy girl king queen
boy girl brother sister
";

    #[test]
    fn sections_become_benchmarks() {
        let v = vocab_of(&[
            "athens", "greece", "oslo", "norway", "paris", "france", "boy", "girl", "king",
            "queen", "brother", "sister",
        ]);
        let qw = parse_questions_words(SAMPLE, &v);
        assert_eq!(qw.sections, 2);
        assert_eq!(qw.total_questions, 4);
        assert_eq!(qw.oov_questions, 0);
        assert_eq!(qw.suite.len(), 2);
        assert_eq!(qw.suite[0].name, "qw-capital-common");
        assert_eq!(qw.suite[1].name, "qw-family");
        assert_eq!(qw.suite[0].len(), 2);
        // words map through lowercasing: "Athens" → id of "athens"
        let BenchmarkData::Analogy(quads) = &qw.suite[0].data else {
            panic!("expected analogy data")
        };
        assert_eq!(quads[0].a, v.id("athens").unwrap());
        assert_eq!(quads[0].d, v.id("norway").unwrap());
    }

    #[test]
    fn oov_questions_are_dropped_and_counted() {
        // no "paris"/"france": second capital question must drop
        let v = vocab_of(&[
            "athens", "greece", "oslo", "norway", "boy", "girl", "king", "queen", "brother",
            "sister",
        ]);
        let qw = parse_questions_words(SAMPLE, &v);
        assert_eq!(qw.total_questions, 4);
        assert_eq!(qw.oov_questions, 1);
        assert_eq!(qw.kept_questions(), 3);
        assert_eq!(qw.suite[0].len(), 1);
    }

    #[test]
    fn sections_with_no_surviving_questions_are_omitted() {
        let v = vocab_of(&["boy", "girl", "king", "queen", "brother", "sister"]);
        let qw = parse_questions_words(SAMPLE, &v);
        assert_eq!(qw.sections, 2);
        assert_eq!(qw.suite.len(), 1, "capital section is all-OOV");
        assert_eq!(qw.suite[0].name, "qw-family");
    }

    #[test]
    fn questions_before_any_header_and_malformed_lines() {
        let v = vocab_of(&["a", "b", "c", "d"]);
        let text = "a b c d\nnot enough words\na b c d e\n";
        let qw = parse_questions_words(text, &v);
        assert_eq!(qw.sections, 0);
        assert_eq!(qw.total_questions, 1);
        assert_eq!(qw.malformed_lines, 2);
        assert_eq!(qw.suite.len(), 1);
        assert_eq!(qw.suite[0].name, "qw-all");
    }

    #[test]
    fn words_get_tokenizer_normalization() {
        // vocab stores what the corpus tokenizer produced: "don't"
        let v = vocab_of(&["don't", "do", "can't", "cannot"]);
        // questions file typeset with curly apostrophes + mixed case
        let text = ": contractions\nDon\u{2019}t do Can\u{2019}t cannot\n";
        let qw = parse_questions_words(text, &v);
        assert_eq!(qw.total_questions, 1);
        assert_eq!(qw.oov_questions, 0, "curly apostrophes must normalize");
        let BenchmarkData::Analogy(quads) = &qw.suite[0].data else {
            panic!("expected analogy data")
        };
        assert_eq!(quads[0].a, v.id("don't").unwrap());
        assert_eq!(quads[0].c, v.id("can't").unwrap());
    }

    #[test]
    fn empty_input_is_empty() {
        let v = vocab_of(&["a"]);
        let qw = parse_questions_words("", &v);
        assert!(qw.suite.is_empty());
        assert_eq!(qw.total_questions, 0);
        assert!(qw.summary().contains("0 sections"));
    }
}
