//! Word-similarity evaluation: Spearman rank correlation between model
//! cosines and gold scores, with OOV accounting identical to the paper's
//! tables (pairs containing an absent word are skipped; the count of
//! absent benchmark words is reported in parentheses).

use crate::embedding::Embedding;
use crate::gen::benchmarks::SimPair;

/// Result of one similarity benchmark run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub spearman: f64,
    pub pairs_used: usize,
    pub pairs_skipped: usize,
    pub oov_words: usize,
}

/// Rank a slice (average ranks for ties), 1-based.
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Pearson correlation.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let (mut cov, mut vx, mut vy) = (0.0, 0.0, 0.0);
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx).powi(2);
        vy += (y - my).powi(2);
    }
    let denom = (vx * vy).sqrt();
    if denom < 1e-300 {
        0.0
    } else {
        cov / denom
    }
}

/// Spearman ρ = Pearson of the ranks.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Evaluate a similarity benchmark against an embedding.
pub fn evaluate(emb: &Embedding, pairs: &[SimPair]) -> SimResult {
    let mut gold = Vec::with_capacity(pairs.len());
    let mut model = Vec::with_capacity(pairs.len());
    let mut skipped = 0;
    let mut oov = std::collections::HashSet::new();
    for p in pairs {
        for w in [p.a, p.b] {
            if !emb.is_present(w) {
                oov.insert(w);
            }
        }
        match emb.cosine(p.a, p.b) {
            Some(cos) => {
                gold.push(p.gold);
                model.push(cos);
            }
            None => skipped += 1,
        }
    }
    SimResult {
        spearman: spearman(&gold, &model),
        pairs_used: gold.len(),
        pairs_skipped: skipped,
        oov_words: oov.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_with_ties() {
        assert_eq!(ranks(&[10.0, 20.0, 20.0, 30.0]), vec![1.0, 2.5, 2.5, 4.0]);
        assert_eq!(ranks(&[3.0, 1.0, 2.0]), vec![3.0, 1.0, 2.0]);
    }

    #[test]
    fn spearman_perfect_and_inverted() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((spearman(&xs, &[10.0, 20.0, 30.0, 40.0]) - 1.0).abs() < 1e-12);
        assert!((spearman(&xs, &[4.0, 3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        // monotone transform leaves spearman at 1
        assert!((spearman(&xs, &[1.0, 8.0, 27.0, 64.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_of_constant_is_zero() {
        assert_eq!(spearman(&[1.0, 2.0, 3.0], &[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn evaluate_skips_oov_and_counts() {
        let mut e = Embedding::zeros(4, 2);
        e.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(1).copy_from_slice(&[0.9, 0.1]);
        e.row_mut(2).copy_from_slice(&[0.0, 1.0]);
        e.present[3] = false;
        let pairs = vec![
            SimPair { a: 0, b: 1, gold: 0.9 },
            SimPair { a: 0, b: 2, gold: 0.1 },
            SimPair { a: 0, b: 3, gold: 0.5 }, // skipped: 3 absent
        ];
        let r = evaluate(&e, &pairs);
        assert_eq!(r.pairs_used, 2);
        assert_eq!(r.pairs_skipped, 1);
        assert_eq!(r.oov_words, 1);
        assert!(r.spearman > 0.99); // order matches gold
    }

    #[test]
    fn evaluate_detects_anticorrelation() {
        let mut e = Embedding::zeros(3, 2);
        e.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        e.row_mut(1).copy_from_slice(&[0.0, 1.0]); // dissimilar to 0
        e.row_mut(2).copy_from_slice(&[1.0, 0.05]); // similar to 0
        let pairs = vec![
            SimPair { a: 0, b: 1, gold: 0.9 }, // gold says similar, model says no
            SimPair { a: 0, b: 2, gold: 0.1 }, // gold says dissimilar, model says yes
        ];
        let r = evaluate(&e, &pairs);
        assert!(r.spearman < 0.0);
    }
}
