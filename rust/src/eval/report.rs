//! Benchmark-suite runner and table formatting.
//!
//! Runs every benchmark of a suite against an embedding and produces the
//! paper-style row: score per benchmark with the OOV word count in
//! parentheses, plus machine-readable JSON for the bench harnesses.

use super::{analogy, categorization, similarity};
use crate::embedding::Embedding;
use crate::gen::benchmarks::{Benchmark, BenchmarkData};
use crate::util::json::{arr, inum, num, obj, s, Json};

#[derive(Clone, Debug)]
pub struct BenchmarkScore {
    pub name: String,
    /// Spearman ρ / purity / accuracy depending on the benchmark kind
    pub score: f64,
    pub oov_words: usize,
    pub items_used: usize,
}

/// Evaluate a full suite; `seed` only affects k-means initialization.
pub fn evaluate_suite(emb: &Embedding, suite: &[Benchmark], seed: u64) -> Vec<BenchmarkScore> {
    suite
        .iter()
        .map(|b| match &b.data {
            BenchmarkData::Similarity(pairs) => {
                let r = similarity::evaluate(emb, pairs);
                BenchmarkScore {
                    name: b.name.clone(),
                    score: r.spearman,
                    oov_words: r.oov_words,
                    items_used: r.pairs_used,
                }
            }
            BenchmarkData::Categorization {
                items,
                num_categories,
            } => {
                let r = categorization::evaluate(emb, items, *num_categories, seed);
                BenchmarkScore {
                    name: b.name.clone(),
                    score: r.purity,
                    oov_words: r.oov_words,
                    items_used: r.items_used,
                }
            }
            BenchmarkData::Analogy(quads) => {
                let r = analogy::evaluate(emb, quads);
                BenchmarkScore {
                    name: b.name.clone(),
                    score: r.accuracy,
                    oov_words: r.oov_words,
                    items_used: r.questions_used,
                }
            }
        })
        .collect()
}

/// [`evaluate_suite`] with the analogy benchmarks' argmax served by an
/// ANN index ([`analogy::evaluate_indexed`]) instead of the exact scan —
/// similarity and categorization score pairwise/cluster-wise and have no
/// nearest-neighbor search to approximate, so they run identically.
/// Diffing this against [`evaluate_suite`] quantifies what approximate
/// search costs in benchmark accuracy at a given `ef_search`.
pub fn evaluate_suite_indexed(
    emb: &Embedding,
    suite: &[Benchmark],
    seed: u64,
    index: &crate::serve::index::AnnIndex,
    ef_search: usize,
) -> Vec<BenchmarkScore> {
    suite
        .iter()
        .map(|b| match &b.data {
            BenchmarkData::Analogy(quads) => {
                let r = analogy::evaluate_indexed(emb, quads, index, ef_search);
                BenchmarkScore {
                    name: b.name.clone(),
                    score: r.accuracy,
                    oov_words: r.oov_words,
                    items_used: r.questions_used,
                }
            }
            _ => evaluate_suite(emb, std::slice::from_ref(b), seed)
                .pop()
                .expect("one benchmark in, one score out"),
        })
        .collect()
}

/// Paper-style cell: "0.614 (12)".
pub fn format_cell(score: &BenchmarkScore) -> String {
    format!("{:.3} ({})", score.score, score.oov_words)
}

/// One formatted table row: label + a cell per benchmark.
pub fn format_row(label: &str, scores: &[BenchmarkScore]) -> String {
    let cells: Vec<String> = scores.iter().map(format_cell).collect();
    format!("{label:<28} {}", cells.join("  "))
}

/// Header line matching `format_row`'s layout.
pub fn format_header(scores: &[BenchmarkScore]) -> String {
    let cells: Vec<String> = scores
        .iter()
        .map(|sc| format!("{:<12}", sc.name))
        .collect();
    format!("{:<28} {}", "", cells.join(" "))
}

pub fn scores_to_json(label: &str, scores: &[BenchmarkScore]) -> Json {
    obj(vec![
        ("label", s(label)),
        (
            "scores",
            arr(scores
                .iter()
                .map(|sc| {
                    obj(vec![
                        ("benchmark", s(&sc.name)),
                        ("score", num(sc.score)),
                        ("oov", inum(sc.oov_words)),
                        ("used", inum(sc.items_used)),
                    ])
                })
                .collect()),
        ),
    ])
}

/// Mean score across benchmarks (used by Figure-3 missing-vocab curves).
pub fn mean_score(scores: &[BenchmarkScore]) -> f64 {
    if scores.is_empty() {
        return 0.0;
    }
    scores.iter().map(|s| s.score).sum::<f64>() / scores.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::corpus::{build_ground_truth, GeneratorConfig};
    use crate::gen::benchmarks::build_suite;

    fn ground_truth_embedding() -> (Embedding, Vec<Benchmark>) {
        // perfect model: embedding == ground truth vectors
        let cfg = GeneratorConfig {
            vocab: 300,
            clusters: 10,
            truth_dim: 8,
            ..Default::default()
        };
        let gt = build_ground_truth(&cfg, 3);
        let mut e = Embedding::zeros(300, 8);
        for w in 0..300u32 {
            let v = gt.vector(w);
            for (o, x) in e.row_mut(w).iter_mut().zip(v) {
                *o = x as f32;
            }
        }
        (e, build_suite(&gt, 3))
    }

    #[test]
    fn ground_truth_embedding_scores_high_everywhere() {
        let (e, suite) = ground_truth_embedding();
        let scores = evaluate_suite(&e, &suite, 1);
        assert_eq!(scores.len(), 8);
        for sc in &scores {
            assert_eq!(sc.oov_words, 0);
            match sc.name.as_str() {
                n if n.starts_with("sim") => {
                    assert!(sc.score > 0.95, "{n}: {}", sc.score)
                }
                // fine-grained purity is intrinsically capped well below 1
                // (paired clusters are geometrically close + identity noise);
                // the paper's own Battig numbers sit at ~0.45 (Table 2)
                n if n.starts_with("cat") => {
                    assert!(sc.score > 0.4, "{n}: {}", sc.score)
                }
                n if n.starts_with("ana") => {
                    assert!(sc.score > 0.6, "{n}: {}", sc.score)
                }
                other => panic!("unknown benchmark {other}"),
            }
        }
    }

    #[test]
    fn indexed_suite_tracks_exact_suite() {
        let (e, suite) = ground_truth_embedding();
        // 300 words > brute threshold → real graph search for the analogies
        let index = crate::serve::index::AnnIndex::build(&e, Default::default());
        let exact = evaluate_suite(&e, &suite, 1);
        let approx = evaluate_suite_indexed(&e, &suite, 1, &index, 0);
        assert_eq!(exact.len(), approx.len());
        for (a, b) in exact.iter().zip(&approx) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.oov_words, b.oov_words);
            if a.name.starts_with("ana") {
                // approximate argmax may miss the odd question
                assert!(
                    (a.score - b.score).abs() < 0.15,
                    "{}: exact {} vs indexed {}",
                    a.name,
                    a.score,
                    b.score
                );
            } else {
                // sim/cat paths are untouched by the index
                assert!((a.score - b.score).abs() < 1e-12, "{}", a.name);
            }
        }
    }

    #[test]
    fn random_embedding_scores_low() {
        let (_, suite) = ground_truth_embedding();
        let mut rng = crate::util::rng::Pcg64::new(5);
        let mut e = Embedding::zeros(300, 8);
        for w in 0..300u32 {
            for v in e.row_mut(w) {
                *v = rng.gen_gauss() as f32;
            }
        }
        let scores = evaluate_suite(&e, &suite, 1);
        for sc in &scores {
            if sc.name.starts_with("sim") {
                assert!(sc.score.abs() < 0.35, "{}: {}", sc.name, sc.score);
            }
            if sc.name.starts_with("ana") {
                assert!(sc.score < 0.1, "{}: {}", sc.name, sc.score);
            }
        }
    }

    #[test]
    fn formatting_matches_paper_style() {
        let sc = BenchmarkScore {
            name: "sim-men".into(),
            score: 0.6137,
            oov_words: 12,
            items_used: 500,
        };
        assert_eq!(format_cell(&sc), "0.614 (12)");
        let row = format_row("Shuffle 10%", &[sc.clone()]);
        assert!(row.starts_with("Shuffle 10%"));
        assert!(row.contains("0.614 (12)"));
        let header = format_header(&[sc]);
        assert!(header.contains("sim-men"));
    }

    #[test]
    fn json_report_shape() {
        let sc = BenchmarkScore {
            name: "x".into(),
            score: 0.5,
            oov_words: 1,
            items_used: 10,
        };
        let j = scores_to_json("row", &[sc]);
        assert_eq!(j.get("label").as_str(), Some("row"));
        assert_eq!(j.get("scores").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn mean_score_empty_and_filled() {
        assert_eq!(mean_score(&[]), 0.0);
        let scores = vec![
            BenchmarkScore { name: "a".into(), score: 0.4, oov_words: 0, items_used: 1 },
            BenchmarkScore { name: "b".into(), score: 0.6, oov_words: 0, items_used: 1 },
        ];
        assert!((mean_score(&scores) - 0.5).abs() < 1e-12);
    }
}
