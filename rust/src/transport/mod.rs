//! The coordinator↔worker transport layer.
//!
//! PR 5 made the persisted shard directory + the run directory the *only*
//! exchange medium between the coordinator and its train workers. This
//! module names that interface: three traits cover every exchange, a
//! filesystem implementation ([`fs::FsTransport`]) reproduces the
//! pre-refactor behavior byte for byte, and a TCP implementation
//! ([`tcp`] + [`server::ShardServer`]) puts the same interface on the
//! network so a worker can live on another host. The supervisor loop
//! (stall detection via beacon-byte change, retry/degrade/fail-fast,
//! survivor merge) is transport-indifferent — it talks only to these
//! traits.
//!
//! * [`ShardStore`] — the corpus side: the shard directory holding
//!   `shard_*.bin` + `vocab.tsv` and, for an overlapped ingest, the
//!   `shards.json` manifest ([`crate::text::feed::ShardManifest`]).
//!   Implementations materialize a **local** directory
//!   ([`ShardStore::local_dir`]) so the sentence-streaming readers
//!   (`ShardFileSource` / `ShardFeed`) run unchanged over either
//!   transport; the TCP store mirrors remote shards into a per-process
//!   cache, republishing the manifest only as shards land (preserving
//!   the feed invariant: a manifest row appears only after its shard is
//!   readable).
//! * [`ArtifactStore`] — the result side: atomic publish/collect of
//!   sub-model artifacts (`submodel_<s>.dwsm`) and epoch-boundary
//!   checkpoints (`submodel_<s>.ckpt`), plus run-dir preparation
//!   (stale-file sweep, `config.json`).
//! * [`ControlPlane`] — the liveness side: heartbeat beacons
//!   (`beacon_<s>.json`), worker registration, feed statistics
//!   (`feedstat_<s>.json`), one-shot fault markers
//!   (`fault_<s>_<action>.fired`) and per-role event journals
//!   (`events_<role>.jsonl`).
//!
//! # Run-dir layout (the contract every transport preserves)
//!
//! Shard directory (read-only to workers):
//!
//! | file | writer | meaning |
//! |---|---|---|
//! | `shard_<i>.bin` | ingest / gen-corpus | binary sentence shard `i` (dense `0..n`) |
//! | `vocab.tsv` | ingest / gen-corpus | `word<TAB>count` vocabulary |
//! | `shards.json` | overlapped ingest | manifest: published-shard rows + lr-schedule block |
//!
//! Run directory (`--out-dir`):
//!
//! | file | writer | meaning |
//! |---|---|---|
//! | `config.json` | coordinator | resolved experiment config for the run |
//! | `submodel_<s>.dwsm` | worker `s` | published sub-model artifact |
//! | `submodel_<s>.ckpt` | worker `s` | epoch-boundary checkpoint (deleted on success) |
//! | `beacon_<s>.json` | worker `s` | heartbeat; rewritten atomically, any byte change = liveness |
//! | `feedstat_<s>.json` | worker `s` | overlap feed wait statistics |
//! | `events_<role>.jsonl` | each process | append-only event journal |
//! | `fault_<s>_<action>.fired` | worker `s` | one-shot fault-injection marker |
//!
//! Every file is published atomically: write `<name>.tmp` (for beacons
//! `<name>.json.tmp`, checkpoints `<name>.ckpt.tmp`), then rename over
//! the final name. Readers therefore never observe a torn file; the
//! stale-file sweep removes both finals and temps from earlier runs.
//!
//! # TCP frame format (version 1)
//!
//! `dw2v shard-server` serves a shard dir + run dir over a small framed
//! protocol; `train-worker --connect HOST:PORT` is the client. All
//! integers on the wire are **big-endian**. A connection starts with a
//! handshake: the client sends the 4-byte magic `DW2V` followed by the
//! protocol version byte (`0x01`); the server echoes the same 5 bytes
//! back (or closes the connection on a magic/version mismatch). After
//! the handshake the client sends request frames and reads one reply per
//! request, strictly in order:
//!
//! ```text
//! request  := msg_type:u8  payload_len:u32  payload
//! payload  := header_len:u32  header:JSON  body:bytes
//! reply    := status:u8  body_len:u32  body:bytes
//! ```
//!
//! `payload_len` covers `header_len + header + body` and is capped at
//! [`frame::MAX_FRAME`] (1 GiB). The header is a JSON object; per the
//! crate-wide rule, **u64 values ride JSON as decimal strings** (f64
//! loses integer precision above 2^53), so e.g. a sub-model index is
//! `{"submodel":"3"}` and a shard index `{"shard":"12"}`. Reply status
//! is `0x00` OK (body = requested bytes), `0x01` error (body = UTF-8
//! message), `0x02` absent (the requested file does not exist — not an
//! error; e.g. no manifest yet, no checkpoint).
//!
//! Message types:
//!
//! | type | name | header | body → reply |
//! |---|---|---|---|
//! | `0x01` | `REGISTER` | `{"submodel"}` | — → OK |
//! | `0x02` | `GET_VOCAB` | `{}` | — → `vocab.tsv` bytes / absent |
//! | `0x03` | `GET_MANIFEST` | `{}` | — → `shards.json` bytes / absent |
//! | `0x04` | `GET_DIR_INFO` | `{}` | — → JSON `{"shards":["0","1",...]}` |
//! | `0x05` | `GET_SHARD` | `{"shard"}` | — → `shard_<i>.bin` bytes / absent |
//! | `0x06` | `PUT_BEACON` | `{"submodel"}` | beacon JSON → OK (mirrored to run dir) |
//! | `0x07` | `PUT_ARTIFACT` | `{"submodel"}` | `.dwsm` bytes → OK (atomic rename) |
//! | `0x08` | `PUT_CHECKPOINT` | `{"submodel"}` | `.ckpt` bytes → OK (atomic rename) |
//! | `0x09` | `GET_CHECKPOINT` | `{"submodel"}` | — → `.ckpt` bytes / absent |
//! | `0x0A` | `DEL_CHECKPOINT` | `{"submodel"}` | — → OK |
//! | `0x0B` | `PUT_FEEDSTAT` | `{"submodel"}` | feedstat JSON → OK |
//! | `0x0C` | `PUT_EVENT` | `{"role"}` | one journal line → OK (appended) |
//! | `0x0D` | `GET_MARKER` | `{"submodel","action"}` | — → OK if fired / absent |
//! | `0x0E` | `PUT_MARKER` | `{"submodel","action"}` | — → OK |
//!
//! The server **mirrors** everything a remote worker uploads (beacons,
//! artifacts, checkpoints, feedstats, journal events, fault markers)
//! into its `--out-dir` as ordinary run-dir files. That is what keeps
//! the rest of the system transport-indifferent: the supervisor polls
//! mirrored beacons and collects mirrored artifacts through the same
//! [`fs::FsTransport`] it uses for local fleets, and `dw2v status` /
//! `dw2v report` read a remote run exactly like a local one. A loopback
//! deployment therefore points the server and the coordinator at the
//! *same* `--out-dir`.

pub mod fs;
pub mod frame;
pub mod server;
pub mod tcp;

use crate::embedding::{CheckpointArtifact, SubModelArtifact};
use crate::obs::journal::Journal;
use crate::text::feed::ShardManifest;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// The corpus side of the interface: where a worker's sentences come
/// from. `local_dir` is always a real directory on this machine — the
/// shard readers (`ShardFileSource`, `ShardFeed`) stream from it
/// directly, so a remote store's job is to keep that directory fed.
pub trait ShardStore: Send + Sync {
    /// The materialized shard directory sentence streaming reads from.
    fn local_dir(&self) -> &Path;
    /// Contents of `vocab.tsv`.
    fn vocab_text(&self) -> Result<String, String>;
    /// Whether `vocab.tsv` exists (cheap pre-flight check).
    fn has_vocab(&self) -> bool;
    /// The `shards.json` manifest, if one has been published.
    fn manifest(&self) -> Result<Option<ShardManifest>, String>;
    /// Remove torn `*.tmp` shard/manifest files left by a dead ingest;
    /// returns how many were removed.
    fn sweep_torn(&self) -> Result<usize, String>;
    /// Create the shard dir and clear stale shards ahead of an
    /// overlapped ingest (coordinator-side; remote stores refuse).
    fn prepare_ingest_dir(&self) -> Result<(), String>;
    /// Drop any local mirror state (no-op for the filesystem store).
    fn cleanup(&self) {}
}

/// The result side: sub-model artifacts and checkpoints, plus run-dir
/// preparation. All publishes are atomic (write temp, rename).
pub trait ArtifactStore: Send + Sync {
    /// Create the run dir and sweep stale files from earlier runs;
    /// returns how many stale files were removed (coordinator-side).
    fn prepare_out_dir(&self) -> Result<usize, String>;
    /// Publish the resolved run config as `config.json`; returns the
    /// path it landed at (coordinator-side).
    fn write_config(&self, body: &str) -> Result<PathBuf, String>;
    /// Atomically publish sub-model `submodel`'s artifact. With
    /// `corrupt` the staged bytes are truncated to half first — the
    /// deterministic `corrupt-artifact` fault.
    fn publish_artifact(
        &self,
        submodel: usize,
        artifact: &SubModelArtifact,
        corrupt: bool,
    ) -> Result<(), String>;
    /// Load + identity-check sub-model `submodel`'s published artifact.
    fn collect_artifact(
        &self,
        submodel: usize,
        root_seed: u64,
        num_submodels: usize,
    ) -> Result<SubModelArtifact, String>;
    /// Best-effort removal of a rejected artifact so a respawn can't
    /// re-collect it.
    fn discard_artifact(&self, submodel: usize);
    /// Atomically publish an epoch-boundary checkpoint.
    fn save_checkpoint(&self, submodel: usize, ck: &CheckpointArtifact) -> Result<(), String>;
    /// Load the checkpoint if one exists: `None` = no checkpoint,
    /// `Some(Err)` = a checkpoint exists but cannot be read.
    fn load_checkpoint(&self, submodel: usize) -> Option<Result<CheckpointArtifact, String>>;
    /// Best-effort checkpoint removal (after success or rejection).
    fn remove_checkpoint(&self, submodel: usize);
    /// Human-readable location of the checkpoint, for log lines.
    fn checkpoint_desc(&self, submodel: usize) -> String;
}

/// The liveness side: heartbeats, registration, feed statistics, fault
/// markers and event journals.
pub trait ControlPlane: Send + Sync {
    /// Announce this worker to the coordinator side (no-op on fs).
    fn register(&self, submodel: usize) -> Result<(), String>;
    /// Publish a heartbeat beacon. Best-effort by design: a worker must
    /// never die because telemetry failed.
    fn publish_beacon(&self, submodel: usize, body: &str);
    /// Read the current beacon bytes, if any (coordinator-side; the
    /// supervisor treats ANY byte change as liveness).
    fn poll_beacon(&self, submodel: usize) -> Option<Vec<u8>>;
    /// Publish the overlap feed statistics file.
    fn publish_feedstat(&self, submodel: usize, body: &str) -> Result<(), String>;
    /// Whether the one-shot fault marker for `action` has fired.
    fn fault_marker_fired(&self, submodel: usize, action: &str) -> bool;
    /// Record the one-shot fault marker for `action` (best-effort).
    fn record_fault_marker(&self, submodel: usize, action: &str);
    /// Open this role's event journal.
    fn journal(&self, role: &str) -> Journal;
}

/// One transport: the three trait objects a run hands around. Cloning
/// shares the underlying implementation.
#[derive(Clone)]
pub struct Transport {
    pub shards: Arc<dyn ShardStore>,
    pub artifacts: Arc<dyn ArtifactStore>,
    pub control: Arc<dyn ControlPlane>,
}

impl Transport {
    /// Filesystem transport with coordinator-side artifact naming
    /// (`<out_dir>/submodel_<s>.dwsm`).
    pub fn fs(shard_dir: &Path, out_dir: &Path) -> Transport {
        fs::FsTransport::new(shard_dir, out_dir, None).into_transport()
    }

    /// Filesystem transport for one worker with an explicit artifact
    /// output path (`train-worker --out` accepts any path; the
    /// checkpoint sits next to it with extension `.ckpt`).
    pub fn fs_worker(shard_dir: &Path, artifact_out: &Path) -> Transport {
        let out_dir = artifact_out
            .parent()
            .map(Path::to_path_buf)
            .filter(|p| !p.as_os_str().is_empty())
            .unwrap_or_else(|| PathBuf::from("."));
        fs::FsTransport::new(shard_dir, &out_dir, Some(artifact_out.to_path_buf()))
            .into_transport()
    }

    /// TCP transport: connect to a `dw2v shard-server`, register, and
    /// start mirroring shards into a local cache directory.
    pub fn connect(addr: &str, submodel: usize, feed_mode: bool) -> Result<Transport, String> {
        tcp::connect(addr, submodel, feed_mode)
    }
}
