//! `dw2v shard-server`: the server half of the TCP transport.
//!
//! Serves one shard directory read-only (vocab, manifest, shard bytes)
//! and accepts worker uploads into one run directory. Every upload is
//! **mirrored as an ordinary run-dir file** with the same atomic
//! tmp+rename publication the local workers use — that mirroring is the
//! whole design: the supervisor, `dw2v status`, and `dw2v report` read a
//! remote fleet through the unchanged filesystem paths. A loopback
//! deployment points the server and the coordinator at the same
//! `--out-dir`.
//!
//! Concurrency model: thread per connection, strict request/reply per
//! thread. The server holds **no open file handles** between requests —
//! journal appends are open-append-close and beacons are per-request
//! tmp+rename. This matters because `prepare_run` sweeps stale
//! `events_*.jsonl`/beacon files from the run dir *after* the server has
//! started (loopback case): a held descriptor would keep writing into an
//! unlinked inode and the events would silently vanish from reports.

use super::frame::{self, Frame};
use crate::obs::journal::{journal_file_name, u64s, unix_ms};
use crate::text::corpus::Corpus;
use crate::transport::fs::{artifact_path, beacon_path, checkpoint_path, fault_marker_path};
use crate::util::json::{arr, obj, s, Json};
use crate::warnln;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};

/// A bound-but-not-yet-serving shard server. [`ShardServer::bind`] picks
/// the port (pass `:0` for an ephemeral one and read it back via
/// [`ShardServer::local_addr`]), then either [`ShardServer::run`] on the
/// current thread or [`ShardServer::spawn`] on a background one.
pub struct ShardServer {
    listener: TcpListener,
    shard_dir: PathBuf,
    out_dir: PathBuf,
}

impl ShardServer {
    /// Bind `addr` (e.g. `127.0.0.1:7311`, port 0 = ephemeral) and
    /// create the run dir uploads will be mirrored into.
    pub fn bind(addr: &str, shard_dir: &Path, out_dir: &Path) -> Result<ShardServer, String> {
        std::fs::create_dir_all(out_dir)
            .map_err(|e| format!("create {}: {e}", out_dir.display()))?;
        let listener =
            TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        Ok(ShardServer {
            listener,
            shard_dir: shard_dir.to_path_buf(),
            out_dir: out_dir.to_path_buf(),
        })
    }

    /// The address actually bound (resolves an ephemeral port).
    pub fn local_addr(&self) -> Result<SocketAddr, String> {
        self.listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))
    }

    /// Serve on a background thread; the handle lives until process
    /// exit (there is no drain/shutdown — kill the process).
    pub fn spawn(self) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || self.run())
    }

    /// Serve on the current thread, forever: accept, handshake, answer
    /// frames until the peer hangs up. A worker that is SIGKILLed simply
    /// appears as a clean-or-torn EOF on its connection — the server
    /// logs and moves on, exactly as fault-tolerant training requires.
    pub fn run(self) {
        for stream in self.listener.incoming() {
            match stream {
                Ok(stream) => {
                    let shard_dir = self.shard_dir.clone();
                    let out_dir = self.out_dir.clone();
                    std::thread::spawn(move || {
                        let peer = stream
                            .peer_addr()
                            .map(|a| a.to_string())
                            .unwrap_or_else(|_| "?".to_string());
                        let _ = stream.set_nodelay(true);
                        if let Err(e) = handle_conn(stream, &shard_dir, &out_dir) {
                            warnln!("shard-server: connection from {peer}: {e}");
                        }
                    });
                }
                Err(e) => warnln!("shard-server: accept: {e}"),
            }
        }
    }
}

/// One connection: handshake, then answer frames until clean EOF.
fn handle_conn(mut stream: TcpStream, shard_dir: &Path, out_dir: &Path) -> Result<(), String> {
    frame::server_handshake(&mut stream)?;
    loop {
        let frame = match frame::read_frame(&mut stream)? {
            Some(f) => f,
            // clean EOF between frames: the worker is done (or dead —
            // the supervisor's beacon watch owns that distinction)
            None => return Ok(()),
        };
        let (status, body) = match handle_frame(&frame, shard_dir, out_dir) {
            Ok(reply) => reply,
            Err(e) => (frame::REPLY_ERR, e.into_bytes()),
        };
        frame::write_reply(&mut stream, status, &body)?;
    }
}

type Reply = (u8, Vec<u8>);

const OK: Reply = (frame::REPLY_OK, Vec::new());

/// Dispatch one request. `Err` becomes an `ERR` reply with the message
/// as body — the client surfaces it verbatim.
fn handle_frame(frame: &Frame, shard_dir: &Path, out_dir: &Path) -> Result<Reply, String> {
    match frame.msg {
        frame::MSG_REGISTER => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            server_event(
                out_dir,
                "worker_registered",
                vec![("submodel", s(&submodel.to_string()))],
            );
            Ok(OK)
        }
        frame::MSG_GET_VOCAB => serve_file(&shard_dir.join("vocab.tsv")),
        frame::MSG_GET_MANIFEST => {
            serve_file(&shard_dir.join(crate::text::feed::MANIFEST_FILE))
        }
        frame::MSG_GET_DIR_INFO => {
            let entries = Corpus::shard_entries(shard_dir)
                .map_err(|e| format!("list {}: {e}", shard_dir.display()))?;
            let shards = arr(entries.iter().map(|(i, _)| s(&i.to_string())).collect());
            Ok((
                frame::REPLY_OK,
                obj(vec![("shards", shards)]).to_string().into_bytes(),
            ))
        }
        frame::MSG_GET_SHARD => {
            let idx = frame::header_usize(&frame.header, "shard")?;
            serve_file(&shard_dir.join(format!("shard_{idx}.bin")))
        }
        frame::MSG_PUT_BEACON => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            let path = beacon_path(out_dir, submodel);
            atomic_publish(&path.with_extension("json.tmp"), &path, &frame.body)?;
            Ok(OK)
        }
        frame::MSG_PUT_ARTIFACT => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            let path = artifact_path(out_dir, submodel);
            atomic_publish(&path.with_extension("tmp"), &path, &frame.body)?;
            server_event(
                out_dir,
                "artifact_received",
                vec![
                    ("submodel", s(&submodel.to_string())),
                    ("bytes", u64s(frame.body.len() as u64)),
                ],
            );
            Ok(OK)
        }
        frame::MSG_PUT_CHECKPOINT => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            let path = checkpoint_path(&artifact_path(out_dir, submodel));
            atomic_publish(&path.with_extension("ckpt.tmp"), &path, &frame.body)?;
            Ok(OK)
        }
        frame::MSG_GET_CHECKPOINT => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            serve_file(&checkpoint_path(&artifact_path(out_dir, submodel)))
        }
        frame::MSG_DEL_CHECKPOINT => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            let _ = std::fs::remove_file(checkpoint_path(&artifact_path(out_dir, submodel)));
            Ok(OK)
        }
        frame::MSG_PUT_FEEDSTAT => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            let path = out_dir.join(format!("feedstat_{submodel}.json"));
            atomic_publish(&path.with_extension("json.tmp"), &path, &frame.body)?;
            Ok(OK)
        }
        frame::MSG_PUT_EVENT => {
            let role = sanitized(&frame.header, "role")?;
            let line = std::str::from_utf8(&frame.body)
                .map_err(|e| format!("event line is not UTF-8: {e}"))?;
            if line.contains('\n') {
                return Err("event body must be a single journal line".to_string());
            }
            append_event_line(out_dir, &role, line)?;
            Ok(OK)
        }
        frame::MSG_GET_MARKER => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            let action = sanitized(&frame.header, "action")?;
            if fault_marker_path(out_dir, submodel, &action).exists() {
                Ok(OK)
            } else {
                Ok((frame::REPLY_ABSENT, Vec::new()))
            }
        }
        frame::MSG_PUT_MARKER => {
            let submodel = frame::header_usize(&frame.header, "submodel")?;
            let action = sanitized(&frame.header, "action")?;
            let path = fault_marker_path(out_dir, submodel, &action);
            std::fs::write(&path, b"fired\n")
                .map_err(|e| format!("write {}: {e}", path.display()))?;
            Ok(OK)
        }
        other => Err(format!("unknown message type {other:#04x}")),
    }
}

/// Serve a file's bytes, mapping "does not exist" to `ABSENT`.
fn serve_file(path: &Path) -> Result<Reply, String> {
    match std::fs::read(path) {
        Ok(bytes) => Ok((frame::REPLY_OK, bytes)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            Ok((frame::REPLY_ABSENT, Vec::new()))
        }
        Err(e) => Err(format!("read {}: {e}", path.display())),
    }
}

/// Mirror uploaded bytes with the run-dir publication idiom: write the
/// temp name, rename over the final one.
fn atomic_publish(tmp: &Path, path: &Path, bytes: &[u8]) -> Result<(), String> {
    std::fs::write(tmp, bytes).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(tmp, path).map_err(|e| format!("publish {}: {e}", path.display()))
}

/// A header field that becomes part of a file name (journal role, fault
/// action). Anything beyond `[A-Za-z0-9_]` is rejected — a remote peer
/// must not be able to point an append or a marker write outside the
/// run dir.
fn sanitized(header: &Json, key: &str) -> Result<String, String> {
    let raw = frame::header_str(header, key)?;
    if raw.is_empty()
        || raw.len() > 64
        || !raw.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
    {
        return Err(format!(
            "header field '{key}' must be 1-64 chars of [A-Za-z0-9_], got {raw:?}"
        ));
    }
    Ok(raw.to_string())
}

/// Append one pre-built journal line for `role`: open-append-close, no
/// held descriptor (see the module doc for why).
fn append_event_line(out_dir: &Path, role: &str, line: &str) -> Result<(), String> {
    let path = out_dir.join(journal_file_name(role));
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .map_err(|e| format!("open {}: {e}", path.display()))?;
    f.write_all(format!("{line}\n").as_bytes())
        .map_err(|e| format!("append {}: {e}", path.display()))
}

/// The server's own telemetry (registrations, artifact receipts) rides
/// role `server` in the same journal format — reporting tools ignore
/// kinds they don't know, so this is pure additional signal.
fn server_event(out_dir: &Path, kind: &str, fields: Vec<(&str, Json)>) {
    let mut all = vec![
        ("unix_ms", u64s(unix_ms())),
        ("role", s("server")),
        ("kind", s(kind)),
    ];
    all.extend(fields);
    let _ = append_event_line(out_dir, "server", &obj(all).to_string());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::journal::read_journal;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dw2v_srv_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn req(
        stream: &mut TcpStream,
        msg: u8,
        header: Json,
        body: &[u8],
    ) -> (u8, Vec<u8>) {
        frame::write_frame(stream, msg, &header, body).unwrap();
        frame::read_reply(stream).unwrap()
    }

    #[test]
    fn loopback_roundtrip_serves_and_mirrors() {
        let shard_dir = tmpdir("shards");
        let out_dir = tmpdir("run");
        std::fs::write(shard_dir.join("vocab.tsv"), b"the\t10\n").unwrap();
        std::fs::write(shard_dir.join("shard_0.bin"), b"shardbytes").unwrap();

        let server = ShardServer::bind("127.0.0.1:0", &shard_dir, &out_dir).unwrap();
        let addr = server.local_addr().unwrap();
        let _handle = server.spawn();

        let mut c = TcpStream::connect(addr).unwrap();
        frame::client_handshake(&mut c).unwrap();

        let sub = obj(vec![("submodel", s("1"))]);
        assert_eq!(req(&mut c, frame::MSG_REGISTER, sub.clone(), b"").0, frame::REPLY_OK);

        let (status, vocab) = req(&mut c, frame::MSG_GET_VOCAB, obj(vec![]), b"");
        assert_eq!(status, frame::REPLY_OK);
        assert_eq!(vocab, b"the\t10\n");

        // no manifest was published — absent, not an error
        assert_eq!(
            req(&mut c, frame::MSG_GET_MANIFEST, obj(vec![]), b"").0,
            frame::REPLY_ABSENT
        );

        let (status, info) = req(&mut c, frame::MSG_GET_DIR_INFO, obj(vec![]), b"");
        assert_eq!(status, frame::REPLY_OK);
        let info = Json::parse(std::str::from_utf8(&info).unwrap()).unwrap();
        assert_eq!(info.get("shards").as_arr().unwrap().len(), 1);

        let (status, bytes) = req(
            &mut c,
            frame::MSG_GET_SHARD,
            obj(vec![("shard", s("0"))]),
            b"",
        );
        assert_eq!(status, frame::REPLY_OK);
        assert_eq!(bytes, b"shardbytes");
        assert_eq!(
            req(&mut c, frame::MSG_GET_SHARD, obj(vec![("shard", s("7"))]), b"").0,
            frame::REPLY_ABSENT
        );

        // uploads land as ordinary run-dir files
        assert_eq!(
            req(&mut c, frame::MSG_PUT_BEACON, sub.clone(), b"{\"seq\":\"1\"}").0,
            frame::REPLY_OK
        );
        assert_eq!(
            std::fs::read(out_dir.join("beacon_1.json")).unwrap(),
            b"{\"seq\":\"1\"}"
        );
        assert_eq!(
            req(&mut c, frame::MSG_PUT_ARTIFACT, sub.clone(), b"notarealartifact").0,
            frame::REPLY_OK
        );
        assert_eq!(
            std::fs::read(out_dir.join("submodel_1.dwsm")).unwrap(),
            b"notarealartifact"
        );

        // checkpoint lifecycle: put, get back, delete, absent
        assert_eq!(
            req(&mut c, frame::MSG_PUT_CHECKPOINT, sub.clone(), b"ckptbytes").0,
            frame::REPLY_OK
        );
        let (status, ck) = req(&mut c, frame::MSG_GET_CHECKPOINT, sub.clone(), b"");
        assert_eq!((status, ck.as_slice()), (frame::REPLY_OK, b"ckptbytes".as_slice()));
        assert_eq!(req(&mut c, frame::MSG_DEL_CHECKPOINT, sub.clone(), b"").0, frame::REPLY_OK);
        assert_eq!(
            req(&mut c, frame::MSG_GET_CHECKPOINT, sub.clone(), b"").0,
            frame::REPLY_ABSENT
        );

        // one-shot fault markers
        let marker = obj(vec![("submodel", s("1")), ("action", s("crash"))]);
        assert_eq!(req(&mut c, frame::MSG_GET_MARKER, marker.clone(), b"").0, frame::REPLY_ABSENT);
        assert_eq!(req(&mut c, frame::MSG_PUT_MARKER, marker.clone(), b"").0, frame::REPLY_OK);
        assert_eq!(req(&mut c, frame::MSG_GET_MARKER, marker, b"").0, frame::REPLY_OK);
        assert!(out_dir.join("fault_1_crash.fired").exists());

        // relayed journal events append to the role's jsonl file
        let line = r#"{"unix_ms":"1","role":"worker_1","kind":"worker_start"}"#;
        assert_eq!(
            req(
                &mut c,
                frame::MSG_PUT_EVENT,
                obj(vec![("role", s("worker_1"))]),
                line.as_bytes()
            )
            .0,
            frame::REPLY_OK
        );
        let events = read_journal(&out_dir.join(journal_file_name("worker_1"))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("kind").as_str(), Some("worker_start"));

        // a path-traversal role is refused
        let (status, err) = req(
            &mut c,
            frame::MSG_PUT_EVENT,
            obj(vec![("role", s("../evil"))]),
            b"{}",
        );
        assert_eq!(status, frame::REPLY_ERR);
        assert!(String::from_utf8_lossy(&err).contains("A-Za-z0-9_"));

        // server telemetry recorded the registration and the artifact
        let server_events = read_journal(&out_dir.join(journal_file_name("server"))).unwrap();
        let kinds: Vec<_> = server_events
            .iter()
            .filter_map(|e| e.get("kind").as_str().map(str::to_string))
            .collect();
        assert!(kinds.contains(&"worker_registered".to_string()));
        assert!(kinds.contains(&"artifact_received".to_string()));

        let _ = std::fs::remove_dir_all(&shard_dir);
        let _ = std::fs::remove_dir_all(&out_dir);
    }
}
