//! Wire framing for the DW2V transport protocol, version 1.
//!
//! The byte-level contract lives in the [`super`] module doc; this file
//! is the only place that reads or writes it. Everything here is generic
//! over `Read`/`Write` so the unit tests can exercise the exact
//! serialization against in-memory buffers without opening a socket.
//!
//! Framing errors are all `String`s naming the field that went wrong —
//! on the server they travel back to the client inside an `ERR` reply,
//! on the client they surface as worker-fatal transport errors.

use crate::util::json::Json;
use std::io::{Read, Write};

/// First four bytes of every connection, both directions.
pub const MAGIC: [u8; 4] = *b"DW2V";
/// Protocol version byte sent right after [`MAGIC`].
pub const VERSION: u8 = 0x01;
/// Upper bound on any payload or reply body. A frame claiming more is a
/// protocol violation (or a corrupted length prefix) — reject it before
/// allocating.
pub const MAX_FRAME: usize = 1 << 30;

pub const MSG_REGISTER: u8 = 0x01;
pub const MSG_GET_VOCAB: u8 = 0x02;
pub const MSG_GET_MANIFEST: u8 = 0x03;
pub const MSG_GET_DIR_INFO: u8 = 0x04;
pub const MSG_GET_SHARD: u8 = 0x05;
pub const MSG_PUT_BEACON: u8 = 0x06;
pub const MSG_PUT_ARTIFACT: u8 = 0x07;
pub const MSG_PUT_CHECKPOINT: u8 = 0x08;
pub const MSG_GET_CHECKPOINT: u8 = 0x09;
pub const MSG_DEL_CHECKPOINT: u8 = 0x0A;
pub const MSG_PUT_FEEDSTAT: u8 = 0x0B;
pub const MSG_PUT_EVENT: u8 = 0x0C;
pub const MSG_GET_MARKER: u8 = 0x0D;
pub const MSG_PUT_MARKER: u8 = 0x0E;

pub const REPLY_OK: u8 = 0x00;
pub const REPLY_ERR: u8 = 0x01;
pub const REPLY_ABSENT: u8 = 0x02;

/// One decoded request: message type, JSON header, raw body bytes.
pub struct Frame {
    pub msg: u8,
    pub header: Json,
    pub body: Vec<u8>,
}

/// Client side of the handshake: send magic + version, require the
/// server to echo the same five bytes back.
pub fn client_handshake<S: Read + Write>(s: &mut S) -> Result<(), String> {
    let mut hello = [0u8; 5];
    hello[..4].copy_from_slice(&MAGIC);
    hello[4] = VERSION;
    s.write_all(&hello)
        .map_err(|e| format!("handshake send: {e}"))?;
    s.flush().map_err(|e| format!("handshake flush: {e}"))?;
    let mut echo = [0u8; 5];
    s.read_exact(&mut echo)
        .map_err(|e| format!("handshake read: {e}"))?;
    if echo != hello {
        return Err(format!(
            "handshake mismatch: peer answered {:02x?}, not DW2V v{VERSION} — \
             is that really a dw2v shard-server?",
            echo
        ));
    }
    Ok(())
}

/// Server side of the handshake: require magic + version, echo them.
pub fn server_handshake<S: Read + Write>(s: &mut S) -> Result<(), String> {
    let mut hello = [0u8; 5];
    s.read_exact(&mut hello)
        .map_err(|e| format!("handshake read: {e}"))?;
    if hello[..4] != MAGIC {
        return Err(format!("bad magic {:02x?}: not a DW2V client", &hello[..4]));
    }
    if hello[4] != VERSION {
        return Err(format!(
            "protocol version {} not supported (this server speaks v{VERSION})",
            hello[4]
        ));
    }
    s.write_all(&hello)
        .map_err(|e| format!("handshake echo: {e}"))?;
    s.flush().map_err(|e| format!("handshake flush: {e}"))?;
    Ok(())
}

/// Serialize one request frame: `msg` + payload length + payload, where
/// the payload is the length-prefixed compact-JSON header followed by
/// the raw body.
pub fn write_frame<W: Write>(w: &mut W, msg: u8, header: &Json, body: &[u8]) -> Result<(), String> {
    let header_bytes = header.to_string().into_bytes();
    let payload_len = 4 + header_bytes.len() + body.len();
    if payload_len > MAX_FRAME {
        return Err(format!("frame of {payload_len} bytes exceeds MAX_FRAME ({MAX_FRAME})"));
    }
    w.write_all(&[msg]).map_err(|e| format!("send frame type: {e}"))?;
    w.write_all(&(payload_len as u32).to_be_bytes())
        .map_err(|e| format!("send frame length: {e}"))?;
    w.write_all(&(header_bytes.len() as u32).to_be_bytes())
        .map_err(|e| format!("send header length: {e}"))?;
    w.write_all(&header_bytes).map_err(|e| format!("send header: {e}"))?;
    w.write_all(body).map_err(|e| format!("send body: {e}"))?;
    w.flush().map_err(|e| format!("flush frame: {e}"))?;
    Ok(())
}

/// Read one request frame. `Ok(None)` means the peer closed the
/// connection cleanly *between* frames — for the server that is the
/// normal end of a worker session (including one that was SIGKILLed),
/// not an error. EOF anywhere inside a frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, String> {
    let mut msg = [0u8; 1];
    loop {
        match r.read(&mut msg) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(format!("read frame type: {e}")),
        }
    }
    let payload_len = read_u32(r, "payload length")? as usize;
    if payload_len > MAX_FRAME {
        return Err(format!("frame of {payload_len} bytes exceeds MAX_FRAME ({MAX_FRAME})"));
    }
    if payload_len < 4 {
        return Err(format!("payload of {payload_len} bytes cannot hold a header length"));
    }
    let payload = read_exact_vec(r, payload_len, "payload")?;
    let header_len = u32::from_be_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    if 4 + header_len > payload.len() {
        return Err(format!(
            "header of {header_len} bytes overruns the {payload_len}-byte payload"
        ));
    }
    let header_text = std::str::from_utf8(&payload[4..4 + header_len])
        .map_err(|e| format!("header is not UTF-8: {e}"))?;
    let header = Json::parse(header_text).map_err(|e| format!("parse header: {e}"))?;
    let body = payload[4 + header_len..].to_vec();
    Ok(Some(Frame { msg: msg[0], header, body }))
}

/// Serialize one reply: status byte + body length + body.
pub fn write_reply<W: Write>(w: &mut W, status: u8, body: &[u8]) -> Result<(), String> {
    if body.len() > MAX_FRAME {
        return Err(format!("reply of {} bytes exceeds MAX_FRAME ({MAX_FRAME})", body.len()));
    }
    w.write_all(&[status]).map_err(|e| format!("send reply status: {e}"))?;
    w.write_all(&(body.len() as u32).to_be_bytes())
        .map_err(|e| format!("send reply length: {e}"))?;
    w.write_all(body).map_err(|e| format!("send reply body: {e}"))?;
    w.flush().map_err(|e| format!("flush reply: {e}"))?;
    Ok(())
}

/// Read one reply. Unlike [`read_frame`], EOF here is always an error —
/// a client only reads a reply after sending a request, so the server
/// hanging up mid-exchange is a failure to report.
pub fn read_reply<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>), String> {
    let mut status = [0u8; 1];
    r.read_exact(&mut status)
        .map_err(|e| format!("read reply status: {e}"))?;
    let body_len = read_u32(r, "reply length")? as usize;
    if body_len > MAX_FRAME {
        return Err(format!("reply of {body_len} bytes exceeds MAX_FRAME ({MAX_FRAME})"));
    }
    let body = read_exact_vec(r, body_len, "reply body")?;
    Ok((status[0], body))
}

/// Require a string-valued header field (the protocol carries every
/// integer as a decimal string — see the module doc's u64 rule).
pub fn header_str<'a>(header: &'a Json, key: &str) -> Result<&'a str, String> {
    header
        .get(key)
        .as_str()
        .ok_or_else(|| format!("header field '{key}' missing or not a string"))
}

/// Require a header field holding a decimal integer as a string.
pub fn header_usize(header: &Json, key: &str) -> Result<usize, String> {
    let raw = header_str(header, key)?;
    raw.parse::<usize>()
        .map_err(|_| format!("header field '{key}' is '{raw}', not a whole number"))
}

fn read_u32<R: Read>(r: &mut R, what: &str) -> Result<u32, String> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).map_err(|e| format!("read {what}: {e}"))?;
    Ok(u32::from_be_bytes(b))
}

fn read_exact_vec<R: Read>(r: &mut R, len: usize, what: &str) -> Result<Vec<u8>, String> {
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).map_err(|e| format!("read {what}: {e}"))?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{obj, s};
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip_preserves_type_header_and_body() {
        let mut wire = Vec::new();
        let header = obj(vec![("submodel", s("3")), ("shard", s("12"))]);
        let body = vec![0u8, 1, 2, 254, 255];
        write_frame(&mut wire, MSG_GET_SHARD, &header, &body).unwrap();
        let frame = read_frame(&mut Cursor::new(&wire)).unwrap().expect("one frame");
        assert_eq!(frame.msg, MSG_GET_SHARD);
        assert_eq!(header_usize(&frame.header, "submodel").unwrap(), 3);
        assert_eq!(header_usize(&frame.header, "shard").unwrap(), 12);
        assert_eq!(frame.body, body);
    }

    #[test]
    fn clean_eof_before_a_frame_is_none_not_an_error() {
        assert!(read_frame(&mut Cursor::new(Vec::<u8>::new())).unwrap().is_none());
    }

    #[test]
    fn eof_inside_a_frame_is_an_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, MSG_REGISTER, &obj(vec![("submodel", s("0"))]), b"").unwrap();
        wire.truncate(wire.len() - 1);
        let err = read_frame(&mut Cursor::new(&wire)).unwrap_err();
        assert!(err.contains("payload"), "unexpected error: {err}");
    }

    #[test]
    fn oversize_length_prefix_is_rejected_before_allocation() {
        // type byte + a 4-byte length claiming 2 GiB
        let mut wire = vec![MSG_GET_VOCAB];
        wire.extend_from_slice(&(2u32 << 30).to_be_bytes());
        let err = read_frame(&mut Cursor::new(&wire)).unwrap_err();
        assert!(err.contains("MAX_FRAME"), "unexpected error: {err}");
    }

    #[test]
    fn header_overrunning_payload_is_rejected() {
        // payload_len = 4, header_len claims 100
        let mut wire = vec![MSG_GET_VOCAB];
        wire.extend_from_slice(&4u32.to_be_bytes());
        wire.extend_from_slice(&100u32.to_be_bytes());
        let err = read_frame(&mut Cursor::new(&wire)).unwrap_err();
        assert!(err.contains("overruns"), "unexpected error: {err}");
    }

    #[test]
    fn reply_roundtrip_and_status_codes() {
        for (status, body) in [
            (REPLY_OK, b"payload".to_vec()),
            (REPLY_ERR, b"no such shard".to_vec()),
            (REPLY_ABSENT, Vec::new()),
        ] {
            let mut wire = Vec::new();
            write_reply(&mut wire, status, &body).unwrap();
            let (got_status, got_body) = read_reply(&mut Cursor::new(&wire)).unwrap();
            assert_eq!(got_status, status);
            assert_eq!(got_body, body);
        }
    }

    #[test]
    fn handshake_roundtrip_and_rejections() {
        struct Duplex {
            incoming: Cursor<Vec<u8>>,
            outgoing: Vec<u8>,
        }
        impl std::io::Read for Duplex {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                self.incoming.read(buf)
            }
        }
        impl std::io::Write for Duplex {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.outgoing.extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }

        // server accepts a well-formed hello and echoes it
        let mut srv = Duplex {
            incoming: Cursor::new(vec![b'D', b'W', b'2', b'V', VERSION]),
            outgoing: Vec::new(),
        };
        server_handshake(&mut srv).unwrap();
        assert_eq!(srv.outgoing, vec![b'D', b'W', b'2', b'V', VERSION]);

        // client accepts the echo
        let mut cli = Duplex {
            incoming: Cursor::new(vec![b'D', b'W', b'2', b'V', VERSION]),
            outgoing: Vec::new(),
        };
        client_handshake(&mut cli).unwrap();

        // wrong magic and wrong version are both rejected by the server
        let mut bad_magic = Duplex {
            incoming: Cursor::new(vec![b'H', b'T', b'T', b'P', VERSION]),
            outgoing: Vec::new(),
        };
        assert!(server_handshake(&mut bad_magic).unwrap_err().contains("magic"));
        let mut bad_version = Duplex {
            incoming: Cursor::new(vec![b'D', b'W', b'2', b'V', 9]),
            outgoing: Vec::new(),
        };
        assert!(server_handshake(&mut bad_version).unwrap_err().contains("version"));

        // a client talking to something that answers garbage bails out
        let mut cli_bad = Duplex {
            incoming: Cursor::new(vec![0, 1, 2, 3, 4]),
            outgoing: Vec::new(),
        };
        assert!(client_handshake(&mut cli_bad).unwrap_err().contains("mismatch"));
    }
}
