//! [`FsTransport`] — the filesystem transport, extracted mechanically
//! from the PR-5/PR-6 coordinator so its behavior (paths, temp-file
//! names, error messages, publication order) is byte-for-byte what the
//! pre-transport code did. Both sides of a local run share it: the
//! coordinator polls beacons and collects artifacts from `out_dir`, a
//! worker publishes into the same directory. It is also the server side
//! of a TCP deployment — [`super::server::ShardServer`] mirrors remote
//! uploads into the run dir these same helpers manage.

use super::{ArtifactStore, ControlPlane, ShardStore, Transport};
use crate::embedding::{CheckpointArtifact, SubModelArtifact};
use crate::info;
use crate::obs::journal::Journal;
use crate::text::feed::{self, ShardManifest};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Beacon file a worker publishes for `submodel` inside the artifact
/// dir.
pub fn beacon_path(out_dir: &Path, submodel: usize) -> PathBuf {
    out_dir.join(format!("beacon_{submodel}.json"))
}

/// Coordinator-side artifact naming: `submodel_<s>.dwsm` in the run dir.
pub fn artifact_path(out_dir: &Path, submodel: usize) -> PathBuf {
    out_dir.join(format!("submodel_{submodel}.dwsm"))
}

/// Where a worker keeps its epoch-boundary checkpoint, derived from the
/// artifact path: `submodel_3.dwsm` → `submodel_3.ckpt`.
pub fn checkpoint_path(out: &Path) -> PathBuf {
    out.with_extension("ckpt")
}

/// One-shot fault-injection marker for `(submodel, action)` — e.g.
/// `fault_1_crash.fired`.
pub fn fault_marker_path(out_dir: &Path, submodel: usize, action: &str) -> PathBuf {
    out_dir.join(format!("fault_{submodel}_{action}.fired"))
}

/// Is `name` output of a previous run in the same artifact dir — a
/// sub-model artifact/checkpoint/temp file, a worker beacon, a feed-mode
/// statistics file, an event journal, a rendered run report, or a
/// fault-injection marker?
fn is_stale_run_file(name: &str) -> bool {
    let sub = name.starts_with("submodel_")
        && (name.ends_with(".dwsm") || name.ends_with(".ckpt") || name.ends_with(".tmp"));
    let beacon = name.starts_with("beacon_")
        && (name.ends_with(".json") || name.ends_with(".tmp"));
    let feedstat = name.starts_with("feedstat_")
        && (name.ends_with(".json") || name.ends_with(".tmp"));
    let journal = name.starts_with("events_") && name.ends_with(".jsonl");
    let report = name == crate::obs::report::REPORT_FILE
        || name == crate::obs::report::REPORT_HTML_FILE;
    sub || beacon || feedstat || journal || report || name.starts_with("fault_")
}

/// Delete leftovers of a previous run from `out_dir` (artifacts,
/// checkpoints, temp files, beacons, fault markers) so a worker that dies
/// before publishing can never let an older run's file masquerade as this
/// run's output — and a fresh run never "resumes" an unrelated
/// checkpoint. Returns how many files were removed.
pub fn clean_artifact_dir(out_dir: &Path) -> Result<usize, String> {
    let entries = match std::fs::read_dir(out_dir) {
        Ok(e) => e,
        // nothing to clean if the dir doesn't exist yet
        Err(_) => return Ok(0),
    };
    let mut removed = 0usize;
    for entry in entries.flatten() {
        if let Some(name) = entry.file_name().to_str() {
            if is_stale_run_file(name) {
                std::fs::remove_file(entry.path())
                    .map_err(|e| format!("remove stale {}: {e}", entry.path().display()))?;
                removed += 1;
            }
        }
    }
    Ok(removed)
}

/// Remove torn shard spills (`shard_*.bin.tmp`) and a torn manifest temp
/// left behind by an ingest that died mid-publish. Readers already skip
/// `.tmp` files, so these are harmless to correctness — but left alone a
/// dead run's debris would sit next to real data forever. Never called
/// in feed mode: there the `.tmp` files belong to the live ingest.
fn sweep_torn_shard_files(shard_dir: &Path) -> Result<usize, String> {
    let entries = match std::fs::read_dir(shard_dir) {
        Ok(e) => e,
        Err(_) => return Ok(0),
    };
    let mut removed = 0usize;
    for entry in entries.flatten() {
        if let Some(name) = entry.file_name().to_str() {
            let torn_shard = name.starts_with("shard_") && name.ends_with(".bin.tmp");
            if torn_shard || name == feed::MANIFEST_TMP_FILE {
                std::fs::remove_file(entry.path())
                    .map_err(|e| format!("remove torn {}: {e}", entry.path().display()))?;
                removed += 1;
            }
        }
    }
    Ok(removed)
}

/// Load and validate the artifact a cleanly-exited worker should have
/// published. Every error is attributed to the sub-model it belongs to —
/// a truncated or corrupt file names its worker instead of surfacing as
/// a bare parse error.
pub fn collect_artifact(
    out: &Path,
    submodel: usize,
    root_seed: u64,
    num_submodels: usize,
) -> Result<SubModelArtifact, String> {
    let a = SubModelArtifact::load(out).map_err(|e| {
        format!(
            "sub-model {submodel}: artifact {} rejected: {e}",
            out.display()
        )
    })?;
    if a.meta.submodel != submodel
        || a.meta.root_seed != root_seed
        || a.meta.num_submodels != num_submodels
    {
        return Err(format!(
            "sub-model {submodel}: artifact {} belongs to a different run \
             (submodel {} of {}, root seed {})",
            out.display(),
            a.meta.submodel,
            a.meta.num_submodels,
            a.meta.root_seed
        ));
    }
    Ok(a)
}

/// The filesystem transport: a shard dir to read from and a run dir to
/// publish into. `artifact_override` pins the worker's own artifact to
/// an explicit path (`train-worker --out` accepts any path); without it
/// artifacts follow the coordinator naming [`artifact_path`].
pub struct FsTransport {
    shard_dir: PathBuf,
    out_dir: PathBuf,
    artifact_override: Option<PathBuf>,
}

impl FsTransport {
    pub fn new(shard_dir: &Path, out_dir: &Path, artifact_override: Option<PathBuf>) -> Self {
        Self {
            shard_dir: shard_dir.to_path_buf(),
            out_dir: out_dir.to_path_buf(),
            artifact_override,
        }
    }

    /// Wrap one shared instance as all three trait objects.
    pub fn into_transport(self) -> Transport {
        let me = Arc::new(self);
        Transport {
            shards: Arc::clone(&me) as Arc<dyn ShardStore>,
            artifacts: Arc::clone(&me) as Arc<dyn ArtifactStore>,
            control: me as Arc<dyn ControlPlane>,
        }
    }

    fn artifact(&self, submodel: usize) -> PathBuf {
        match &self.artifact_override {
            Some(p) => p.clone(),
            None => artifact_path(&self.out_dir, submodel),
        }
    }

    fn checkpoint(&self, submodel: usize) -> PathBuf {
        checkpoint_path(&self.artifact(submodel))
    }
}

impl ShardStore for FsTransport {
    fn local_dir(&self) -> &Path {
        &self.shard_dir
    }

    fn vocab_text(&self) -> Result<String, String> {
        let vocab_path = self.shard_dir.join("vocab.tsv");
        std::fs::read_to_string(&vocab_path)
            .map_err(|e| format!("read {}: {e}", vocab_path.display()))
    }

    fn has_vocab(&self) -> bool {
        self.shard_dir.join("vocab.tsv").is_file()
    }

    fn manifest(&self) -> Result<Option<ShardManifest>, String> {
        ShardManifest::load(&self.shard_dir)
    }

    fn sweep_torn(&self) -> Result<usize, String> {
        sweep_torn_shard_files(&self.shard_dir)
    }

    fn prepare_ingest_dir(&self) -> Result<(), String> {
        std::fs::create_dir_all(&self.shard_dir)
            .map_err(|e| format!("create {}: {e}", self.shard_dir.display()))?;
        crate::text::corpus::remove_stale_shards(&self.shard_dir)
            .map_err(|e| format!("clear stale shards in {}: {e}", self.shard_dir.display()))
    }
}

impl ArtifactStore for FsTransport {
    fn prepare_out_dir(&self) -> Result<usize, String> {
        std::fs::create_dir_all(&self.out_dir)
            .map_err(|e| format!("create {}: {e}", self.out_dir.display()))?;
        clean_artifact_dir(&self.out_dir)
    }

    fn write_config(&self, body: &str) -> Result<PathBuf, String> {
        let config_path = self.out_dir.join("config.json");
        std::fs::write(&config_path, body)
            .map_err(|e| format!("write {}: {e}", config_path.display()))?;
        Ok(config_path)
    }

    fn publish_artifact(
        &self,
        submodel: usize,
        artifact: &SubModelArtifact,
        corrupt: bool,
    ) -> Result<(), String> {
        // write-then-rename: the coordinator must never observe a partial
        // artifact, even if this process dies mid-save
        let out = self.artifact(submodel);
        let tmp = out.with_extension("tmp");
        artifact
            .save(&tmp)
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        if corrupt {
            // fault injection: tear the temp file *before* the publishing
            // rename and still exit 0 — only the coordinator's artifact
            // validation can catch this failure mode
            let len = std::fs::metadata(&tmp)
                .map_err(|e| format!("stat {}: {e}", tmp.display()))?
                .len();
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(&tmp)
                .map_err(|e| format!("reopen {}: {e}", tmp.display()))?;
            f.set_len(len / 2)
                .map_err(|e| format!("truncate {}: {e}", tmp.display()))?;
            info!(
                "fault injection: worker {} truncating its artifact to {} bytes",
                submodel,
                len / 2
            );
        }
        std::fs::rename(&tmp, &out)
            .map_err(|e| format!("publish {}: {e}", out.display()))?;
        Ok(())
    }

    fn collect_artifact(
        &self,
        submodel: usize,
        root_seed: u64,
        num_submodels: usize,
    ) -> Result<SubModelArtifact, String> {
        collect_artifact(&self.artifact(submodel), submodel, root_seed, num_submodels)
    }

    fn discard_artifact(&self, submodel: usize) {
        // a rejected artifact must not linger: a retried worker
        // republishes, a degraded one must leave nothing collectible
        let _ = std::fs::remove_file(self.artifact(submodel));
    }

    fn save_checkpoint(&self, submodel: usize, ck: &CheckpointArtifact) -> Result<(), String> {
        let path = self.checkpoint(submodel);
        let tmp = path.with_extension("ckpt.tmp");
        ck.save(&tmp)
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("publish {}: {e}", path.display()))?;
        Ok(())
    }

    fn load_checkpoint(&self, submodel: usize) -> Option<Result<CheckpointArtifact, String>> {
        let path = self.checkpoint(submodel);
        if !path.is_file() {
            return None;
        }
        Some(CheckpointArtifact::load(&path).map_err(|e| e.to_string()))
    }

    fn remove_checkpoint(&self, submodel: usize) {
        let _ = std::fs::remove_file(self.checkpoint(submodel));
    }

    fn checkpoint_desc(&self, submodel: usize) -> String {
        self.checkpoint(submodel).display().to_string()
    }
}

impl ControlPlane for FsTransport {
    fn register(&self, _submodel: usize) -> Result<(), String> {
        Ok(())
    }

    fn publish_beacon(&self, submodel: usize, body: &str) {
        // best-effort: a failed beacon write must never fail training —
        // the worst case is the supervisor calling a stall and respawning
        let path = beacon_path(&self.out_dir, submodel);
        let tmp = path.with_extension("json.tmp");
        if std::fs::write(&tmp, body).is_ok() {
            let _ = std::fs::rename(&tmp, &path);
        }
    }

    fn poll_beacon(&self, submodel: usize) -> Option<Vec<u8>> {
        std::fs::read(beacon_path(&self.out_dir, submodel)).ok()
    }

    fn publish_feedstat(&self, submodel: usize, body: &str) -> Result<(), String> {
        let path = self.out_dir.join(format!("feedstat_{submodel}.json"));
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, body).map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("publish {}: {e}", path.display()))?;
        Ok(())
    }

    fn fault_marker_fired(&self, submodel: usize, action: &str) -> bool {
        fault_marker_path(&self.out_dir, submodel, action).exists()
    }

    fn record_fault_marker(&self, submodel: usize, action: &str) {
        let _ = std::fs::write(
            fault_marker_path(&self.out_dir, submodel, action),
            b"fired\n",
        );
    }

    fn journal(&self, role: &str) -> Journal {
        Journal::open(&self.out_dir, role)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torn_shard_tmp_files_are_swept() {
        let dir = std::env::temp_dir().join(format!("dw2v_torn_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for name in [
            "shard_0.bin",
            "shard_1.bin.tmp",
            "shards.json.tmp",
            "shards.json",
            "vocab.tsv",
        ] {
            std::fs::write(dir.join(name), b"x").unwrap();
        }
        assert_eq!(sweep_torn_shard_files(&dir).unwrap(), 2);
        assert!(dir.join("shard_0.bin").exists(), "real shards must survive");
        assert!(dir.join("shards.json").exists(), "the manifest must survive");
        assert!(dir.join("vocab.tsv").exists());
        assert!(!dir.join("shard_1.bin.tmp").exists());
        assert!(!dir.join("shards.json.tmp").exists());
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(sweep_torn_shard_files(&dir).unwrap(), 0);
    }

    #[test]
    fn stale_run_files_are_recognized() {
        for stale in [
            "submodel_0.dwsm",
            "submodel_12.ckpt",
            "submodel_3.tmp",
            "submodel_3.ckpt.tmp",
            "beacon_0.json",
            "beacon_7.json.tmp",
            "feedstat_2.json",
            "feedstat_2.json.tmp",
            "fault_1_crash.fired",
            "events_coordinator.jsonl",
            "events_worker_3.jsonl",
            "run_report.json",
            "run_report.html",
        ] {
            assert!(is_stale_run_file(stale), "should be stale: {stale}");
        }
        for keep in [
            "config.json",
            "vocab.tsv",
            "shard_0.bin",
            "merged.bin",
            "submodel_notes.txt",
            "beacon_0.log",
            "events_notes.txt",
        ] {
            assert!(!is_stale_run_file(keep), "should be kept: {keep}");
        }
    }

    #[test]
    fn clean_artifact_dir_sweeps_only_run_files() {
        let dir = std::env::temp_dir().join(format!("dw2v_clean_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for name in [
            "submodel_0.dwsm",
            "submodel_1.ckpt",
            "beacon_0.json",
            "fault_0_crash.fired",
            "config.json",
            "keepme.txt",
        ] {
            std::fs::write(dir.join(name), b"x").unwrap();
        }
        let removed = clean_artifact_dir(&dir).unwrap();
        assert_eq!(removed, 4);
        assert!(dir.join("config.json").exists());
        assert!(dir.join("keepme.txt").exists());
        assert!(!dir.join("submodel_0.dwsm").exists());
        assert!(!dir.join("submodel_1.ckpt").exists());
        assert!(!dir.join("beacon_0.json").exists());
        // a missing dir is not an error — there is nothing to clean
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(clean_artifact_dir(&dir).unwrap(), 0);
    }

    #[test]
    fn checkpoint_path_swaps_the_extension() {
        assert_eq!(
            checkpoint_path(Path::new("/x/submodel_3.dwsm")),
            PathBuf::from("/x/submodel_3.ckpt")
        );
    }

    #[test]
    fn fs_worker_transport_respects_the_artifact_override() {
        let t = FsTransport::new(
            Path::new("/shards"),
            Path::new("/run"),
            Some(PathBuf::from("/elsewhere/nope.dwsm")),
        );
        assert_eq!(t.artifact(3), PathBuf::from("/elsewhere/nope.dwsm"));
        assert_eq!(t.checkpoint(3), PathBuf::from("/elsewhere/nope.ckpt"));
        let c = FsTransport::new(Path::new("/shards"), Path::new("/run"), None);
        assert_eq!(c.artifact(3), PathBuf::from("/run/submodel_3.dwsm"));
        assert_eq!(c.checkpoint(3), PathBuf::from("/run/submodel_3.ckpt"));
    }
}
