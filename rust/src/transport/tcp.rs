//! The worker side of the TCP transport: [`connect`] dials a
//! `dw2v shard-server`, registers, and returns a [`super::Transport`]
//! whose three stores speak the frame protocol from [`super::frame`].
//!
//! The central trick is a **double mirror** keeping both ends of the
//! system transport-indifferent:
//!
//! * the client materializes remote shards into a private local cache
//!   directory, so the sentence-streaming readers (`ShardFileSource`,
//!   `ShardFeed`) run over TCP completely unchanged — in snapshot mode
//!   the cache is filled synchronously before training starts, in feed
//!   mode a background thread follows the server's manifest and
//!   republishes a truncated local copy as shards land (a local manifest
//!   row appears only once its shard bytes are readable, preserving the
//!   feed invariant);
//! * the server mirrors every upload (beacons, artifacts, checkpoints,
//!   feedstats, journal events, fault markers) into its run dir, so the
//!   supervisor and `dw2v status`/`report` read a remote fleet exactly
//!   like a local one.
//!
//! Requests are strictly serialized per connection (one `Mutex` around
//! the stream); the mirror thread uses its own connection so shard
//! downloads never block heartbeats.

use super::frame;
use super::{ArtifactStore, ControlPlane, ShardStore, Transport};
use crate::embedding::{CheckpointArtifact, SubModelArtifact};
use crate::info;
use crate::obs::journal::Journal;
use crate::text::feed::ShardManifest;
use crate::util::json::{obj, s, Json};
use crate::warnln;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// How often the feed-mode mirror polls the server's manifest. Cheap (an
/// empty-body request against a loopback/LAN server) and well under the
/// feed's own poll cadence, so the mirror is never the bottleneck.
const MIRROR_POLL_MS: u64 = 25;
/// How long the mirror waits for `vocab.tsv` to appear server-side in
/// feed mode before giving up — matches the feed's own no-progress
/// deadline.
const VOCAB_WAIT_SECS: u64 = 300;

/// One framed-protocol connection. All requests are serialized: the
/// protocol is strict request/reply, so the stream lock *is* the
/// ordering.
struct TcpClient {
    stream: Mutex<TcpStream>,
}

impl TcpClient {
    fn connect(addr: &str) -> Result<TcpClient, String> {
        let mut stream =
            TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        // every request is a full frame; latency matters more than batching
        let _ = stream.set_nodelay(true);
        frame::client_handshake(&mut stream).map_err(|e| format!("{addr}: {e}"))?;
        Ok(TcpClient {
            stream: Mutex::new(stream),
        })
    }

    fn request(&self, msg: u8, header: &Json, body: &[u8]) -> Result<(u8, Vec<u8>), String> {
        let mut stream = self
            .stream
            .lock()
            .map_err(|_| "transport connection poisoned".to_string())?;
        frame::write_frame(&mut *stream, msg, header, body)?;
        frame::read_reply(&mut *stream)
    }

    /// A request that must succeed: ERR and ABSENT both become errors.
    fn ok(&self, msg: u8, header: &Json, body: &[u8]) -> Result<Vec<u8>, String> {
        match self.ok_or_absent(msg, header, body)? {
            Some(bytes) => Ok(bytes),
            None => Err("server answered ABSENT for a required file".to_string()),
        }
    }

    /// A request where ABSENT is a legitimate answer (`None`).
    fn ok_or_absent(
        &self,
        msg: u8,
        header: &Json,
        body: &[u8],
    ) -> Result<Option<Vec<u8>>, String> {
        match self.request(msg, header, body)? {
            (frame::REPLY_OK, bytes) => Ok(Some(bytes)),
            (frame::REPLY_ABSENT, _) => Ok(None),
            (frame::REPLY_ERR, bytes) => Err(String::from_utf8_lossy(&bytes).into_owned()),
            (status, _) => Err(format!("unknown reply status {status:#04x}")),
        }
    }
}

fn submodel_header(submodel: usize) -> Json {
    obj(vec![("submodel", s(&submodel.to_string()))])
}

/// Dial `addr`, register as `submodel`, and build the transport. In
/// snapshot mode (`feed_mode == false`) the whole corpus is fetched
/// before this returns; in feed mode a mirror thread keeps the cache
/// growing and this returns as soon as registration succeeds.
pub fn connect(addr: &str, submodel: usize, feed_mode: bool) -> Result<Transport, String> {
    let client = Arc::new(TcpClient::connect(addr)?);
    client
        .ok(frame::MSG_REGISTER, &submodel_header(submodel), b"")
        .map_err(|e| format!("register with {addr}: {e}"))?;

    // one cache per (process, submodel): workers are separate processes,
    // and a respawned worker gets a fresh pid — no cross-run reuse
    let cache = std::env::temp_dir().join(format!(
        "dw2v_tcp_cache_{}_{submodel}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&cache);
    std::fs::create_dir_all(&cache).map_err(|e| format!("create {}: {e}", cache.display()))?;

    if feed_mode {
        // the mirror owns vocab + shards + manifest; it must cache the
        // vocab before the first manifest publish, because a worker
        // treats "manifest present" as "corpus readable"
        let mirror_addr = addr.to_string();
        let mirror_cache = cache.clone();
        std::thread::spawn(move || {
            if let Err(e) = run_mirror(&mirror_addr, &mirror_cache) {
                // the worker surfaces this as a feed no-progress timeout;
                // the real cause goes to stderr
                warnln!("shard mirror for {} died: {e}", mirror_cache.display());
            }
        });
    } else {
        snapshot_sync(&client, addr, &cache)?;
    }

    Ok(Transport {
        shards: Arc::new(TcpShards {
            cache: cache.clone(),
        }),
        artifacts: Arc::new(TcpArtifacts {
            client: Arc::clone(&client),
            addr: addr.to_string(),
            cache: cache.clone(),
        }),
        control: Arc::new(TcpControl {
            client,
            addr: addr.to_string(),
        }),
    })
}

/// Snapshot mode: fetch the finished corpus in one pass — vocab, every
/// shard the server lists, and the manifest verbatim if one exists.
fn snapshot_sync(client: &TcpClient, addr: &str, cache: &Path) -> Result<(), String> {
    let vocab = client
        .ok_or_absent(frame::MSG_GET_VOCAB, &obj(vec![]), b"")?
        .ok_or_else(|| {
            format!("{addr} has no vocab.tsv — persist a corpus next to the shard-server first")
        })?;
    let vocab_path = cache.join("vocab.tsv");
    std::fs::write(&vocab_path, vocab)
        .map_err(|e| format!("write {}: {e}", vocab_path.display()))?;

    let info_bytes = client.ok(frame::MSG_GET_DIR_INFO, &obj(vec![]), b"")?;
    let info_text = String::from_utf8(info_bytes)
        .map_err(|e| format!("{addr}: dir info is not UTF-8: {e}"))?;
    let info = Json::parse(&info_text).map_err(|e| format!("{addr}: parse dir info: {e}"))?;
    let shards = info
        .get("shards")
        .as_arr()
        .ok_or_else(|| format!("{addr}: dir info lacks a shards list"))?;
    info!(
        "transport: mirroring {} shards from {addr} into {}",
        shards.len(),
        cache.display()
    );
    for entry in shards {
        let idx = entry
            .as_str()
            .and_then(|t| t.parse::<usize>().ok())
            .ok_or_else(|| format!("{addr}: bad shard index {entry} in dir info"))?;
        fetch_shard(client, cache, idx)?;
    }

    // mirror the manifest bytes verbatim: a snapshot worker must see
    // exactly the schedule block the ingest published
    if let Some(manifest) =
        client.ok_or_absent(frame::MSG_GET_MANIFEST, &obj(vec![]), b"")?
    {
        let tmp = cache.join(crate::text::feed::MANIFEST_TMP_FILE);
        let path = cache.join(crate::text::feed::MANIFEST_FILE);
        std::fs::write(&tmp, manifest).map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("publish {}: {e}", path.display()))?;
    }
    Ok(())
}

/// Download shard `idx` into the cache, atomically (tmp + rename) so a
/// concurrent reader never sees a torn shard.
fn fetch_shard(client: &TcpClient, cache: &Path, idx: usize) -> Result<(), String> {
    let bytes = client.ok(
        frame::MSG_GET_SHARD,
        &obj(vec![("shard", s(&idx.to_string()))]),
        b"",
    )?;
    let tmp = cache.join(format!("shard_{idx}.bin.tmp"));
    let path = cache.join(format!("shard_{idx}.bin"));
    std::fs::write(&tmp, bytes).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &path).map_err(|e| format!("publish {}: {e}", path.display()))?;
    Ok(())
}

/// The truncated local view of the server's manifest after `fetched`
/// shards have landed in the cache: same tokens and schedule block
/// (workers need the schedule before the first shard), but only the rows
/// whose shards are locally readable, and `complete` only once every
/// remote row is mirrored.
fn local_manifest(remote: &ShardManifest, fetched: usize) -> ShardManifest {
    ShardManifest {
        complete: remote.complete && fetched == remote.num_shards(),
        shard_sentences: remote.shard_sentences[..fetched].to_vec(),
        tokens: remote.tokens,
        schedule: remote.schedule.clone(),
    }
}

/// Feed-mode mirror loop: wait for the server-side vocab, then follow
/// the remote manifest, fetching each new shard and republishing the
/// truncated local manifest after it lands. Runs on its own connection
/// and thread; returns once the mirrored corpus is complete.
fn run_mirror(addr: &str, cache: &Path) -> Result<(), String> {
    let client = TcpClient::connect(addr)?;
    let poll = std::time::Duration::from_millis(MIRROR_POLL_MS);

    // the ingest publishes vocab.tsv before the schedule block, so this
    // wait ends as soon as the remote ingest has frozen its vocabulary
    let vocab_wait = std::time::Instant::now();
    let vocab = loop {
        if let Some(bytes) = client.ok_or_absent(frame::MSG_GET_VOCAB, &obj(vec![]), b"")? {
            break bytes;
        }
        if vocab_wait.elapsed().as_secs() >= VOCAB_WAIT_SECS {
            return Err(format!(
                "{addr} published no vocab.tsv within {VOCAB_WAIT_SECS}s — is the ingest dead?"
            ));
        }
        std::thread::sleep(poll);
    };
    let vocab_path = cache.join("vocab.tsv");
    std::fs::write(&vocab_path, vocab)
        .map_err(|e| format!("write {}: {e}", vocab_path.display()))?;

    let mut fetched = 0usize;
    let mut published_rows: Option<(usize, bool)> = None;
    loop {
        let remote = match client.ok_or_absent(frame::MSG_GET_MANIFEST, &obj(vec![]), b"")? {
            Some(bytes) => {
                let text = String::from_utf8(bytes)
                    .map_err(|e| format!("{addr}: manifest is not UTF-8: {e}"))?;
                let v = Json::parse(&text).map_err(|e| format!("{addr}: parse manifest: {e}"))?;
                ShardManifest::from_json(&v).map_err(|e| format!("{addr}: {e}"))?
            }
            None => {
                std::thread::sleep(poll);
                continue;
            }
        };
        while fetched < remote.num_shards() {
            fetch_shard(&client, cache, fetched)?;
            fetched += 1;
            // republish after every shard so the feed wakes promptly
            local_manifest(&remote, fetched).publish(cache)?;
            published_rows = Some((fetched, remote.complete));
        }
        // republish when only the complete flag moved (no new shards)
        let now = (fetched, remote.complete && fetched == remote.num_shards());
        if published_rows != Some(now) {
            local_manifest(&remote, fetched).publish(cache)?;
            published_rows = Some(now);
        }
        if remote.complete && fetched == remote.num_shards() {
            info!(
                "transport: mirror complete — {fetched} shards in {}",
                cache.display()
            );
            return Ok(());
        }
        std::thread::sleep(poll);
    }
}

/// [`ShardStore`] over the local mirror cache. Reads never touch the
/// network — the snapshot sync or the mirror thread already did.
struct TcpShards {
    cache: PathBuf,
}

impl ShardStore for TcpShards {
    fn local_dir(&self) -> &Path {
        &self.cache
    }

    fn vocab_text(&self) -> Result<String, String> {
        let vocab_path = self.cache.join("vocab.tsv");
        std::fs::read_to_string(&vocab_path)
            .map_err(|e| format!("read {}: {e}", vocab_path.display()))
    }

    fn has_vocab(&self) -> bool {
        self.cache.join("vocab.tsv").is_file()
    }

    fn manifest(&self) -> Result<Option<ShardManifest>, String> {
        ShardManifest::load(&self.cache)
    }

    fn sweep_torn(&self) -> Result<usize, String> {
        // the cache is created fresh per process — nothing stale to sweep
        Ok(0)
    }

    fn prepare_ingest_dir(&self) -> Result<(), String> {
        Err("a TCP transport cannot host an ingest — run the ingest next to the shard-server"
            .to_string())
    }

    fn cleanup(&self) {
        let _ = std::fs::remove_dir_all(&self.cache);
    }
}

/// [`ArtifactStore`] that uploads instead of renaming: artifacts and
/// checkpoints are staged in the cache (so the `corrupt-artifact` fault
/// can tear real bytes), then shipped whole; the server does the atomic
/// rename into its run dir.
struct TcpArtifacts {
    client: Arc<TcpClient>,
    addr: String,
    cache: PathBuf,
}

impl ArtifactStore for TcpArtifacts {
    fn prepare_out_dir(&self) -> Result<usize, String> {
        Err("run-dir preparation is coordinator-side — not available over a worker connection"
            .to_string())
    }

    fn write_config(&self, _body: &str) -> Result<PathBuf, String> {
        Err("config publication is coordinator-side — not available over a worker connection"
            .to_string())
    }

    fn publish_artifact(
        &self,
        submodel: usize,
        artifact: &SubModelArtifact,
        corrupt: bool,
    ) -> Result<(), String> {
        let staged = self.cache.join(format!("submodel_{submodel}.dwsm.up"));
        artifact
            .save(&staged)
            .map_err(|e| format!("write {}: {e}", staged.display()))?;
        if corrupt {
            // same deterministic fault as the filesystem path: tear the
            // staged bytes, upload the torn file, exit 0 — only the
            // coordinator's artifact validation can catch it
            let len = std::fs::metadata(&staged)
                .map_err(|e| format!("stat {}: {e}", staged.display()))?
                .len();
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(&staged)
                .map_err(|e| format!("reopen {}: {e}", staged.display()))?;
            f.set_len(len / 2)
                .map_err(|e| format!("truncate {}: {e}", staged.display()))?;
            info!(
                "fault injection: worker {} truncating its artifact to {} bytes",
                submodel,
                len / 2
            );
        }
        let bytes = std::fs::read(&staged)
            .map_err(|e| format!("read {}: {e}", staged.display()))?;
        self.client
            .ok(frame::MSG_PUT_ARTIFACT, &submodel_header(submodel), &bytes)
            .map_err(|e| format!("upload artifact to {}: {e}", self.addr))?;
        let _ = std::fs::remove_file(&staged);
        Ok(())
    }

    fn collect_artifact(
        &self,
        _submodel: usize,
        _root_seed: u64,
        _num_submodels: usize,
    ) -> Result<SubModelArtifact, String> {
        Err("artifact collection is coordinator-side — not available over a worker connection"
            .to_string())
    }

    fn discard_artifact(&self, _submodel: usize) {}

    fn save_checkpoint(&self, submodel: usize, ck: &CheckpointArtifact) -> Result<(), String> {
        let staged = self.cache.join(format!("submodel_{submodel}.ckpt.up"));
        ck.save(&staged)
            .map_err(|e| format!("write {}: {e}", staged.display()))?;
        let bytes = std::fs::read(&staged)
            .map_err(|e| format!("read {}: {e}", staged.display()))?;
        self.client
            .ok(frame::MSG_PUT_CHECKPOINT, &submodel_header(submodel), &bytes)
            .map_err(|e| format!("upload checkpoint to {}: {e}", self.addr))?;
        let _ = std::fs::remove_file(&staged);
        Ok(())
    }

    fn load_checkpoint(&self, submodel: usize) -> Option<Result<CheckpointArtifact, String>> {
        let fetched = self
            .client
            .ok_or_absent(frame::MSG_GET_CHECKPOINT, &submodel_header(submodel), b"");
        let bytes = match fetched {
            Ok(Some(bytes)) => bytes,
            Ok(None) => return None,
            Err(e) => return Some(Err(format!("fetch checkpoint from {}: {e}", self.addr))),
        };
        // CheckpointArtifact::load wants a file, so land the bytes first
        let staged = self.cache.join(format!("submodel_{submodel}.ckpt"));
        if let Err(e) = std::fs::write(&staged, &bytes) {
            return Some(Err(format!("write {}: {e}", staged.display())));
        }
        Some(CheckpointArtifact::load(&staged).map_err(|e| e.to_string()))
    }

    fn remove_checkpoint(&self, submodel: usize) {
        let _ = self
            .client
            .ok(frame::MSG_DEL_CHECKPOINT, &submodel_header(submodel), b"");
        let _ = std::fs::remove_file(self.cache.join(format!("submodel_{submodel}.ckpt")));
    }

    fn checkpoint_desc(&self, submodel: usize) -> String {
        format!("submodel_{submodel}.ckpt on {}", self.addr)
    }
}

/// [`ControlPlane`] over the control connection. Everything a worker
/// sends here is mirrored by the server into its run dir, which is how
/// the supervisor and `dw2v status`/`report` observe remote workers.
struct TcpControl {
    client: Arc<TcpClient>,
    addr: String,
}

impl TcpControl {
    fn marker_header(submodel: usize, action: &str) -> Json {
        obj(vec![
            ("submodel", s(&submodel.to_string())),
            ("action", s(action)),
        ])
    }
}

impl ControlPlane for TcpControl {
    fn register(&self, submodel: usize) -> Result<(), String> {
        self.client
            .ok(frame::MSG_REGISTER, &submodel_header(submodel), b"")
            .map(|_| ())
            .map_err(|e| format!("register with {}: {e}", self.addr))
    }

    fn publish_beacon(&self, submodel: usize, body: &str) {
        // best-effort, like the filesystem beacon: a dropped heartbeat
        // must never kill training — worst case the supervisor respawns
        let _ = self.client.ok(
            frame::MSG_PUT_BEACON,
            &submodel_header(submodel),
            body.as_bytes(),
        );
    }

    fn poll_beacon(&self, _submodel: usize) -> Option<Vec<u8>> {
        // coordinator-side: the supervisor polls the server's mirrored
        // beacon files through its own FsTransport
        None
    }

    fn publish_feedstat(&self, submodel: usize, body: &str) -> Result<(), String> {
        self.client
            .ok(
                frame::MSG_PUT_FEEDSTAT,
                &submodel_header(submodel),
                body.as_bytes(),
            )
            .map(|_| ())
            .map_err(|e| format!("publish feedstat to {}: {e}", self.addr))
    }

    fn fault_marker_fired(&self, submodel: usize, action: &str) -> bool {
        // on error, claim "not fired": a one-shot fault firing twice in a
        // degraded-network corner beats it never firing in tests
        matches!(
            self.client.ok_or_absent(
                frame::MSG_GET_MARKER,
                &Self::marker_header(submodel, action),
                b"",
            ),
            Ok(Some(_))
        )
    }

    fn record_fault_marker(&self, submodel: usize, action: &str) {
        let _ = self.client.ok(
            frame::MSG_PUT_MARKER,
            &Self::marker_header(submodel, action),
            b"",
        );
    }

    fn journal(&self, role: &str) -> Journal {
        let client = Arc::clone(&self.client);
        let header = obj(vec![("role", s(role))]);
        Journal::with_sender(role, move |line| {
            // journals are best-effort telemetry on every transport
            let _ = client.ok(frame::MSG_PUT_EVENT, &header, line.as_bytes());
        })
    }
}
