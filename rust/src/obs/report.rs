//! Cross-run aggregation: turn a run directory's telemetry files
//! (journals + beacons + feedstats + config) into `run_report.json`, a
//! self-contained HTML render, and the live `dw2v status` table.
//!
//! Everything here is read-side: it never writes into the files the run
//! itself owns, and it tolerates a run that is still in flight (partial
//! journals, missing beacons, torn final lines).

use super::journal::{self, json_u64};
use crate::util::json::{arr, inum, num, obj, s, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// File name of the JSON report inside a run directory.
pub const REPORT_FILE: &str = "run_report.json";
/// File name of the HTML render next to it.
pub const REPORT_HTML_FILE: &str = "run_report.html";

/// Read every `beacon_<s>.json` in `dir`, sorted by sub-model. A beacon
/// that fails to parse is skipped (it is being rewritten right now —
/// the writer's tmp+rename makes that window tiny but real on NFS-ish
/// filesystems; the next refresh will see it).
pub fn read_beacons(dir: &Path) -> Vec<Json> {
    let mut out: Vec<(u64, Json)> = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return Vec::new();
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        let is_beacon = name.starts_with("beacon_") && name.ends_with(".json");
        if !is_beacon {
            continue;
        }
        if let Ok(text) = std::fs::read_to_string(entry.path()) {
            if let Ok(v) = Json::parse(&text) {
                let sub = v.get("submodel").as_f64().unwrap_or(-1.0) as u64;
                out.push((sub, v));
            }
        }
    }
    out.sort_by_key(|(sub, _)| *sub);
    out.into_iter().map(|(_, v)| v).collect()
}

fn read_feedstats(dir: &Path) -> Vec<Json> {
    let mut out: Vec<(u64, Json)> = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return Vec::new();
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !(name.starts_with("feedstat_") && name.ends_with(".json")) {
            continue;
        }
        if let Ok(text) = std::fs::read_to_string(entry.path()) {
            if let Ok(v) = Json::parse(&text) {
                let sub = v.get("submodel").as_f64().unwrap_or(-1.0) as u64;
                out.push((sub, v));
            }
        }
    }
    out.sort_by_key(|(sub, _)| *sub);
    out.into_iter().map(|(_, v)| v).collect()
}

/// Per-worker rollup accumulated from the journals + beacons.
#[derive(Default)]
struct WorkerRollup {
    spawns: u64,
    respawns: u64,
    crashes: u64,
    stalls: u64,
    completed: bool,
    failed: Option<String>,
    epochs: Vec<Json>,
    checkpoint_secs: f64,
    last_phase: String,
}

fn event_submodel(ev: &Json) -> Option<usize> {
    ev.get("submodel").as_usize()
}

/// Aggregate `run_dir` into the report JSON. Works on finished and
/// in-flight runs alike; a directory with no telemetry at all is an
/// error (wrong directory beats an empty report).
pub fn build_report(run_dir: &Path) -> Result<Json, String> {
    let mut journals = journal::list_journals(run_dir);
    let beacons = read_beacons(run_dir);
    if journals.is_empty() && beacons.is_empty() {
        return Err(format!(
            "{} holds no events_*.jsonl and no beacon_*.json — not a run directory?",
            run_dir.display()
        ));
    }
    // the ingest + overlap journals live in the shard dir; with the
    // default `--out-dir <shard-dir>/submodels` layout that is the
    // parent, so an overlapped run's report covers those phases too
    for role in ["ingest", "overlap"] {
        if journals.iter().any(|(r, _)| r == role) {
            continue;
        }
        if let Some(parent) = run_dir.parent() {
            let p = parent.join(journal::journal_file_name(role));
            if p.is_file() {
                journals.push((role.to_string(), p));
            }
        }
    }

    // replay every journal into one time-ordered event stream
    let mut all_events: Vec<Json> = Vec::new();
    for (_role, path) in &journals {
        all_events.extend(read_journal_lenient(path)?);
    }
    all_events.sort_by_key(|ev| json_u64(ev.get("unix_ms")).unwrap_or(0));

    let mut workers: BTreeMap<usize, WorkerRollup> = BTreeMap::new();
    let mut phases: BTreeMap<String, f64> = BTreeMap::new();
    let mut pairs_curve: Vec<Json> = Vec::new();
    let mut ingest_summary = Json::Null;
    let mut shard_publications = 0u64;
    for ev in &all_events {
        let kind = ev.get("kind").as_str().unwrap_or("");
        let secs = ev.get("secs").as_f64().unwrap_or(0.0);
        match kind {
            "worker_spawn" => {
                if let Some(sub) = event_submodel(ev) {
                    workers.entry(sub).or_default().spawns += 1;
                }
            }
            "worker_respawn" => {
                if let Some(sub) = event_submodel(ev) {
                    workers.entry(sub).or_default().respawns += 1;
                }
            }
            "worker_crash" => {
                if let Some(sub) = event_submodel(ev) {
                    workers.entry(sub).or_default().crashes += 1;
                }
            }
            "stall_detected" => {
                if let Some(sub) = event_submodel(ev) {
                    workers.entry(sub).or_default().stalls += 1;
                }
            }
            "worker_failed" => {
                if let Some(sub) = event_submodel(ev) {
                    workers.entry(sub).or_default().failed =
                        Some(ev.get("why").as_str().unwrap_or("?").to_string());
                }
            }
            "worker_exit" | "worker_done" => {
                if let Some(sub) = event_submodel(ev) {
                    workers.entry(sub).or_default().completed = true;
                }
            }
            "epoch_done" => {
                if let Some(sub) = event_submodel(ev) {
                    let w = workers.entry(sub).or_default();
                    w.epochs.push(ev.clone());
                    pairs_curve.push(obj(vec![
                        ("submodel", inum(sub)),
                        ("epoch", ev.get("epoch").clone()),
                        ("pairs_per_s", ev.get("pairs_per_s").clone()),
                        ("unix_ms", ev.get("unix_ms").clone()),
                    ]));
                }
            }
            "checkpoint_written" => {
                if let Some(sub) = event_submodel(ev) {
                    workers.entry(sub).or_default().checkpoint_secs += secs;
                }
            }
            "fleet_done" => {
                phases.insert("train_secs".to_string(), secs);
            }
            "merge_done" => {
                phases.insert("merge_secs".to_string(), secs);
            }
            "eval_done" => {
                phases.insert("eval_secs".to_string(), secs);
            }
            "pass1_done" => {
                phases.insert("ingest_pass1_secs".to_string(), secs);
            }
            "schedule_done" => {
                phases.insert("ingest_schedule_secs".to_string(), secs);
            }
            "pass2_done" => {
                phases.insert("ingest_pass2_secs".to_string(), secs);
            }
            "shard_published" => shard_publications += 1,
            "ingest_done" => ingest_summary = ev.clone(),
            _ => {}
        }
    }

    // beacons carry the freshest phase per worker (the "now" view)
    for b in &beacons {
        if let Some(sub) = b.get("submodel").as_usize() {
            let w = workers.entry(sub).or_default();
            w.last_phase = b.get("phase").as_str().unwrap_or("?").to_string();
            if w.last_phase == "done" {
                w.completed = true;
            }
        }
    }

    let worker_rows: Vec<Json> = workers
        .iter()
        .map(|(sub, w)| {
            let mut fields = vec![
                ("submodel", inum(*sub)),
                ("spawns", inum(w.spawns)),
                ("respawns", inum(w.respawns)),
                ("crashes", inum(w.crashes)),
                ("stalls", inum(w.stalls)),
                ("completed", Json::Bool(w.completed)),
                ("checkpoint_secs", num(w.checkpoint_secs)),
                ("last_phase", s(&w.last_phase)),
                ("epochs", arr(w.epochs.clone())),
            ];
            if let Some(why) = &w.failed {
                fields.push(("failed", s(why)));
            }
            obj(fields)
        })
        .collect();

    let config = std::fs::read_to_string(run_dir.join("config.json"))
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .unwrap_or(Json::Null);

    let phase_rows = Json::Obj(
        phases
            .iter()
            .map(|(k, v)| (k.clone(), num(*v)))
            .collect::<BTreeMap<_, _>>(),
    );

    let mut ingest_fields = BTreeMap::new();
    ingest_fields.insert("shard_publications".to_string(), inum(shard_publications));
    ingest_fields.insert("summary".to_string(), ingest_summary);
    Ok(obj(vec![
        ("run_dir", s(&run_dir.display().to_string())),
        ("generated_unix_ms", journal::u64s(journal::unix_ms())),
        ("config", config),
        ("phases", phase_rows),
        ("workers", arr(worker_rows)),
        ("pairs_per_s", arr(pairs_curve)),
        ("ingest", Json::Obj(ingest_fields)),
        ("feedstats", arr(read_feedstats(run_dir))),
        ("beacons", arr(beacons)),
        ("timeline", arr(all_events)),
    ]))
}

/// Read a journal for reporting: a mid-file parse error in one journal
/// degrades to an error naming the file (the caller surfaces it), but a
/// *missing* journal is fine — in-flight runs grow them over time.
fn read_journal_lenient(path: &Path) -> Result<Vec<Json>, String> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    journal::read_journal(path)
}

/// Build the report and write `run_report.json` + `run_report.html`
/// into `run_dir` (atomically, tmp + rename). Returns the JSON path.
pub fn write_report(run_dir: &Path) -> Result<PathBuf, String> {
    let report = build_report(run_dir)?;
    let path = run_dir.join(REPORT_FILE);
    let tmp = run_dir.join(format!("{REPORT_FILE}.tmp"));
    std::fs::write(&tmp, report.to_string_pretty())
        .map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &path).map_err(|e| format!("publish {}: {e}", path.display()))?;
    let html_path = run_dir.join(REPORT_HTML_FILE);
    let html_tmp = run_dir.join(format!("{REPORT_HTML_FILE}.tmp"));
    std::fs::write(&html_tmp, render_html(&report))
        .map_err(|e| format!("write {}: {e}", html_tmp.display()))?;
    std::fs::rename(&html_tmp, &html_path)
        .map_err(|e| format!("publish {}: {e}", html_path.display()))?;
    Ok(path)
}

fn esc(text: &str) -> String {
    text.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// A self-contained HTML render of the report: inline CSS, no scripts,
/// no external assets — openable from any file browser.
pub fn render_html(report: &Json) -> String {
    let mut h = String::new();
    h.push_str(
        "<!doctype html><html><head><meta charset=\"utf-8\">\
         <title>dw2v run report</title><style>\
         body{font-family:monospace;margin:2em;background:#fafafa;color:#222}\
         table{border-collapse:collapse;margin:1em 0}\
         th,td{border:1px solid #bbb;padding:4px 10px;text-align:left}\
         th{background:#eee}h2{margin-top:1.5em}\
         .bad{color:#a00;font-weight:bold}.ok{color:#070}\
         </style></head><body>",
    );
    h.push_str(&format!(
        "<h1>dw2v run report</h1><p>run dir: <code>{}</code></p>",
        esc(report.get("run_dir").as_str().unwrap_or("?"))
    ));

    h.push_str("<h2>Phase wallclock</h2><table><tr><th>phase</th><th>seconds</th></tr>");
    if let Some(phases) = report.get("phases").as_obj() {
        for (k, v) in phases {
            h.push_str(&format!(
                "<tr><td>{}</td><td>{:.3}</td></tr>",
                esc(k),
                v.as_f64().unwrap_or(0.0)
            ));
        }
    }
    h.push_str("</table>");

    h.push_str(
        "<h2>Workers</h2><table><tr><th>sub-model</th><th>spawns</th><th>respawns</th>\
         <th>crashes</th><th>stalls</th><th>checkpoint s</th><th>state</th></tr>",
    );
    for w in report.get("workers").as_arr().unwrap_or(&[]) {
        let completed = w.get("completed").as_bool().unwrap_or(false);
        let state = if let Some(why) = w.get("failed").as_str() {
            format!("<span class=\"bad\">failed: {}</span>", esc(why))
        } else if completed {
            "<span class=\"ok\">completed</span>".to_string()
        } else {
            esc(w.get("last_phase").as_str().unwrap_or("running"))
        };
        h.push_str(&format!(
            "<tr><td>{}</td><td>{}</td><td>{}</td><td>{}</td><td>{}</td><td>{:.3}</td><td>{}</td></tr>",
            w.get("submodel").as_f64().unwrap_or(-1.0) as i64,
            w.get("spawns").as_f64().unwrap_or(0.0) as u64,
            w.get("respawns").as_f64().unwrap_or(0.0) as u64,
            w.get("crashes").as_f64().unwrap_or(0.0) as u64,
            w.get("stalls").as_f64().unwrap_or(0.0) as u64,
            w.get("checkpoint_secs").as_f64().unwrap_or(0.0),
            state
        ));
    }
    h.push_str("</table>");

    h.push_str(
        "<h2>Throughput (pairs/s per epoch)</h2><table>\
         <tr><th>sub-model</th><th>epoch</th><th>pairs/s</th></tr>",
    );
    for p in report.get("pairs_per_s").as_arr().unwrap_or(&[]) {
        h.push_str(&format!(
            "<tr><td>{}</td><td>{}</td><td>{:.0}</td></tr>",
            p.get("submodel").as_f64().unwrap_or(-1.0) as i64,
            p.get("epoch").as_f64().unwrap_or(-1.0) as i64,
            p.get("pairs_per_s").as_f64().unwrap_or(0.0)
        ));
    }
    h.push_str("</table>");

    h.push_str(
        "<h2>Timeline</h2><table><tr><th>unix ms</th><th>role</th><th>kind</th>\
         <th>sub-model</th><th>secs</th></tr>",
    );
    for ev in report.get("timeline").as_arr().unwrap_or(&[]) {
        let sub = ev
            .get("submodel")
            .as_usize()
            .map(|v| v.to_string())
            .unwrap_or_default();
        let secs = ev
            .get("secs")
            .as_f64()
            .map(|v| format!("{v:.3}"))
            .unwrap_or_default();
        h.push_str(&format!(
            "<tr><td>{}</td><td>{}</td><td>{}</td><td>{}</td><td>{}</td></tr>",
            json_u64(ev.get("unix_ms")).unwrap_or(0),
            esc(ev.get("role").as_str().unwrap_or("?")),
            esc(ev.get("kind").as_str().unwrap_or("?")),
            sub,
            secs
        ));
    }
    h.push_str("</table></body></html>");
    h
}

/// One refresh of the live `dw2v status` view: a per-worker progress
/// table from the beacons in `run_dir`, plus the shard manifest (looked
/// up in `run_dir`, then its parent — `--out-dir` defaults to
/// `<shard-dir>/submodels`). `prev` carries `(pairs, unix_ms)` per
/// sub-model from the previous refresh so a rate can be derived.
/// Returns `(rendered table, all workers done)`.
pub fn render_status(
    run_dir: &Path,
    prev: &mut BTreeMap<usize, (u64, u64)>,
) -> Result<(String, bool), String> {
    let beacons = read_beacons(run_dir);
    if beacons.is_empty() {
        return Err(format!(
            "no beacon_*.json in {} — nothing to watch (yet?)",
            run_dir.display()
        ));
    }
    let manifest = crate::text::feed::ShardManifest::load(run_dir)
        .ok()
        .flatten()
        .or_else(|| {
            run_dir
                .parent()
                .and_then(|p| crate::text::feed::ShardManifest::load(p).ok().flatten())
        });

    let now = journal::unix_ms();
    let mut out = String::new();
    out.push_str(&format!("run: {}", run_dir.display()));
    if let Some(man) = &manifest {
        out.push_str(&format!(
            "   shards: {}{}",
            man.num_shards(),
            if man.complete { " (complete)" } else { " (growing)" }
        ));
    }
    out.push('\n');
    out.push_str(&format!(
        "{:>3}  {:<10} {:>6} {:>12} {:>14} {:>12} {:>8}\n",
        "s", "phase", "epoch", "sentences", "pairs", "pairs/s", "age"
    ));
    let mut all_done = true;
    for b in &beacons {
        let sub = b.get("submodel").as_usize().unwrap_or(usize::MAX);
        let phase = b.get("phase").as_str().unwrap_or("?");
        if phase != "done" {
            all_done = false;
        }
        let pairs = json_u64(b.get("pairs")).unwrap_or(0);
        let ms = json_u64(b.get("unix_ms")).unwrap_or(0);
        let rate = match prev.get(&sub) {
            Some(&(p0, t0)) if ms > t0 && pairs >= p0 => {
                format!("{:.0}", (pairs - p0) as f64 / ((ms - t0) as f64 / 1e3))
            }
            _ => "-".to_string(),
        };
        prev.insert(sub, (pairs, ms));
        let age_s = now.saturating_sub(ms) as f64 / 1e3;
        out.push_str(&format!(
            "{:>3}  {:<10} {:>6} {:>12} {:>14} {:>12} {:>7.1}s\n",
            sub,
            phase,
            b.get("epoch").as_f64().unwrap_or(0.0) as u64,
            json_u64(b.get("sentences")).unwrap_or(0),
            pairs,
            rate,
            age_s
        ));
    }
    Ok((out, all_done))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::journal::{u64s, Journal};

    fn tmpdir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("dw2v_report_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fake_beacon(dir: &Path, sub: usize, phase: &str, pairs: u64) {
        let b = obj(vec![
            ("submodel", num(sub as f64)),
            ("phase", s(phase)),
            ("epoch", num(1.0)),
            ("sentences", u64s(10)),
            ("pairs", u64s(pairs)),
            ("seq", u64s(3)),
            ("unix_ms", u64s(journal::unix_ms())),
        ]);
        std::fs::write(dir.join(format!("beacon_{sub}.json")), b.to_string_pretty()).unwrap();
    }

    /// Synthesize the journals a crash→respawn run leaves behind and
    /// check the report's worker timeline shows the failure + recovery.
    #[test]
    fn report_rolls_up_a_crash_and_respawn() {
        let dir = tmpdir("crash");
        let coord = Journal::open(&dir, "coordinator");
        coord.event("run_start", vec![("submodels", num(2.0))]);
        coord.event("worker_spawn", vec![("submodel", num(0.0))]);
        coord.event("worker_spawn", vec![("submodel", num(1.0))]);
        coord.event(
            "worker_crash",
            vec![("submodel", num(1.0)), ("why", s("exit code 102"))],
        );
        coord.event(
            "worker_respawn",
            vec![("submodel", num(1.0)), ("attempt", num(1.0)), ("backoff_ms", num(50.0))],
        );
        coord.event("worker_exit", vec![("submodel", num(0.0)), ("secs", num(1.5))]);
        coord.event("worker_exit", vec![("submodel", num(1.0)), ("secs", num(2.5))]);
        coord.event("fleet_done", vec![("secs", num(3.0))]);
        coord.event("merge_done", vec![("secs", num(0.2))]);
        coord.event("eval_done", vec![("secs", num(0.1))]);
        let w1 = Journal::open(&dir, "worker_1");
        w1.event(
            "epoch_done",
            vec![
                ("submodel", num(1.0)),
                ("epoch", num(0.0)),
                ("secs", num(1.0)),
                ("pairs", u64s(5000)),
                ("pairs_per_s", num(5000.0)),
            ],
        );
        fake_beacon(&dir, 0, "done", 9999);
        fake_beacon(&dir, 1, "done", 9999);

        let path = write_report(&dir).unwrap();
        let report = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let workers = report.get("workers").as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        let victim = &workers[1];
        assert_eq!(victim.get("crashes").as_f64(), Some(1.0));
        assert_eq!(victim.get("respawns").as_f64(), Some(1.0));
        assert_eq!(victim.get("completed").as_bool(), Some(true));
        assert_eq!(workers[0].get("crashes").as_f64(), Some(0.0));
        assert_eq!(report.get("phases").get("train_secs").as_f64(), Some(3.0));
        assert_eq!(report.get("phases").get("merge_secs").as_f64(), Some(0.2));
        let curve = report.get("pairs_per_s").as_arr().unwrap();
        assert_eq!(curve.len(), 1);
        assert_eq!(curve[0].get("pairs_per_s").as_f64(), Some(5000.0));

        // the HTML render is self-contained and mentions the crash
        let html = std::fs::read_to_string(dir.join(REPORT_HTML_FILE)).unwrap();
        assert!(html.contains("worker_crash"));
        assert!(html.contains("completed"));
        assert!(!dir.join(format!("{REPORT_FILE}.tmp")).exists(), "publication is atomic");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dir_is_an_error_not_an_empty_report() {
        let dir = tmpdir("empty");
        let err = build_report(&dir).unwrap_err();
        assert!(err.contains("not a run directory"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn status_renders_rates_from_consecutive_refreshes() {
        let dir = tmpdir("status");
        fake_beacon(&dir, 0, "train", 1000);
        let mut prev = BTreeMap::new();
        let (first, done) = render_status(&dir, &mut prev).unwrap();
        assert!(first.contains("train"));
        assert!(!done);
        // second refresh with more pairs and a later stamp → a rate
        std::thread::sleep(std::time::Duration::from_millis(20));
        fake_beacon(&dir, 0, "done", 3000);
        let (second, done) = render_status(&dir, &mut prev).unwrap();
        assert!(done, "all beacons at phase done");
        assert!(second.contains("done"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
