//! Per-process append-only event journal (`events_<role>.jsonl`).
//!
//! One journal file per process, one JSON object per line. Appends go
//! through a single `write(2)` on an `O_APPEND` descriptor, which is
//! atomic for sane line lengths on every filesystem we care about: lines
//! from concurrent writers (there are none today — the file is
//! per-process — but the contract is cheap) never interleave, and a
//! crash mid-append can tear at most the **final** line. The reader
//! ([`read_journal`]) therefore drops a malformed final line silently
//! and treats a malformed line anywhere else as corruption.
//!
//! Journals are telemetry, not ledgers: every write is best-effort, and
//! a journal that cannot be opened degrades to a no-op writer with one
//! warning rather than failing the run it was supposed to observe.

use crate::util::json::{obj, s, Json};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// File name of the journal for `role` inside a run directory.
pub fn journal_file_name(role: &str) -> String {
    format!("events_{role}.jsonl")
}

/// Milliseconds since the unix epoch — the timestamp every event carries.
pub fn unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

// The decimal-string u64 encoding now lives with the rest of the JSON
// helpers; re-exported here because journal events were its first home
// and every caller imports it from this module.
pub use crate::util::json::{json_u64, u64s};

/// Where a journal's lines go. Local processes append to a file; a
/// remote worker hands each finished line to a sender closure (the TCP
/// transport ships it to the server, which appends it to the run dir so
/// reports see one fleet regardless of where workers ran).
enum Sink {
    /// drops every event (open failed, or `Journal::disabled()`)
    Disabled,
    /// append to an `O_APPEND` file — the local case
    File(Mutex<std::fs::File>),
    /// hand the finished line (no trailing newline) to a transport
    Sender(Box<dyn Fn(&str) + Send + Sync>),
}

/// An append-only JSONL event writer for one process. Cheap to clone
/// into worker closures is a non-goal — open once, share by reference.
pub struct Journal {
    role: String,
    sink: Sink,
}

impl Journal {
    /// Open (create + append) `dir/events_<role>.jsonl`. Never fails:
    /// an unopenable journal becomes a no-op writer with one warning —
    /// telemetry must not take down the run it observes.
    pub fn open(dir: &Path, role: &str) -> Journal {
        let path = dir.join(journal_file_name(role));
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path);
        match file {
            Ok(f) => Journal {
                role: role.to_string(),
                sink: Sink::File(Mutex::new(f)),
            },
            Err(e) => {
                eprintln!(
                    "warn: journal {} did not open ({e}) — events will be dropped",
                    path.display()
                );
                Journal::disabled_as(role)
            }
        }
    }

    /// A journal that forwards each event line to `send` instead of a
    /// local file. The closure owns delivery (and its failure policy —
    /// journals are best-effort, so swallowing errors there is fine).
    pub fn with_sender(role: &str, send: impl Fn(&str) + Send + Sync + 'static) -> Journal {
        Journal {
            role: role.to_string(),
            sink: Sink::Sender(Box::new(send)),
        }
    }

    /// A journal that drops every event (for paths with no run dir).
    pub fn disabled() -> Journal {
        Journal::disabled_as("disabled")
    }

    fn disabled_as(role: &str) -> Journal {
        Journal {
            role: role.to_string(),
            sink: Sink::Disabled,
        }
    }

    pub fn is_enabled(&self) -> bool {
        !matches!(self.sink, Sink::Disabled)
    }

    /// Append one event: `{"unix_ms": "...", "role": ..., "kind": ...,
    /// ...fields}` as a single line, single write. Best-effort.
    pub fn event(&self, kind: &str, fields: Vec<(&str, Json)>) {
        if let Sink::Disabled = self.sink {
            return;
        }
        let mut all = vec![
            ("unix_ms", u64s(unix_ms())),
            ("role", s(&self.role)),
            ("kind", s(kind)),
        ];
        all.extend(fields);
        let line = obj(all).to_string();
        match &self.sink {
            Sink::Disabled => {}
            Sink::File(file) => {
                if let Ok(mut f) = file.lock() {
                    let _ = f.write_all(format!("{line}\n").as_bytes());
                }
            }
            Sink::Sender(send) => send(&line),
        }
    }
}

/// Open `dir/events_<role>.jsonl` fresh: delete last run's file first,
/// then open. For journals that live outside the run dir (e.g. the
/// overlap driver's, which `prepare_run`'s stale sweep never touches) —
/// a new run must replace the old trace, not append to it.
pub fn fresh_journal(dir: &Path, role: &str) -> Journal {
    let _ = std::fs::remove_file(dir.join(journal_file_name(role)));
    Journal::open(dir, role)
}

/// Parse a journal file. A line that fails to parse is tolerated **only
/// as the final line** (the torn-write crash case); anywhere else it is
/// an error naming the line, because `O_APPEND` single-write lines
/// cannot tear mid-file and garbage there means real corruption.
pub fn read_journal(path: &Path) -> Result<Vec<Json>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut events = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        match Json::parse(line) {
            Ok(v) => events.push(v),
            Err(e) if i + 1 == lines.len() => {
                eprintln!(
                    "warn: {} line {}: dropping torn final line ({e})",
                    path.display(),
                    i + 1
                );
            }
            Err(e) => {
                return Err(format!(
                    "{} line {}: malformed mid-file event ({e}) — journal corrupt",
                    path.display(),
                    i + 1
                ));
            }
        }
    }
    Ok(events)
}

/// Enumerate the journals in a run directory: `(role, path)` for every
/// `events_<role>.jsonl`, sorted by role for deterministic reports.
pub fn list_journals(dir: &Path) -> Vec<(String, PathBuf)> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(role) = name
            .strip_prefix("events_")
            .and_then(|r| r.strip_suffix(".jsonl"))
        {
            out.push((role.to_string(), entry.path()));
        }
    }
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    fn tmpdir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("dw2v_journal_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn events_round_trip_with_timestamps_and_role() {
        let dir = tmpdir("roundtrip");
        let j = Journal::open(&dir, "worker_3");
        assert!(j.is_enabled());
        j.event("epoch_done", vec![("epoch", num(1.0)), ("pairs", u64s(1 << 60))]);
        j.event("worker_done", vec![]);
        let events = read_journal(&dir.join(journal_file_name("worker_3"))).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("kind").as_str(), Some("epoch_done"));
        assert_eq!(events[0].get("role").as_str(), Some("worker_3"));
        // u64 counters survive above 2^53 via the string encoding
        assert_eq!(json_u64(events[0].get("pairs")), Some(1 << 60));
        assert!(json_u64(events[0].get("unix_ms")).unwrap() > 0);
        assert_eq!(events[1].get("kind").as_str(), Some("worker_done"));
        let listed = list_journals(&dir);
        assert_eq!(listed.len(), 1);
        assert_eq!(listed[0].0, "worker_3");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_final_line_is_dropped_but_midfile_garbage_is_an_error() {
        let dir = tmpdir("torn");
        let path = dir.join(journal_file_name("coordinator"));
        let j = Journal::open(&dir, "coordinator");
        j.event("a", vec![]);
        j.event("b", vec![]);
        // crash mid-append: the final line is a torn prefix
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"unix_ms\": \"12");
        std::fs::write(&path, &text).unwrap();
        let events = read_journal(&path).unwrap();
        assert_eq!(events.len(), 2, "torn final line must be dropped");
        assert_eq!(events[1].get("kind").as_str(), Some("b"));

        // the same garbage mid-file is corruption, not a crash artifact
        let bad = "{\"k\": tor\n".to_string() + &text;
        std::fs::write(&path, bad).unwrap();
        let err = read_journal(&path).unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("corrupt"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sender_journal_forwards_complete_lines() {
        let seen = std::sync::Arc::new(Mutex::new(Vec::<String>::new()));
        let sink = std::sync::Arc::clone(&seen);
        let j = Journal::with_sender("worker_1", move |line| {
            sink.lock().unwrap().push(line.to_string());
        });
        assert!(j.is_enabled());
        j.event("epoch_done", vec![("pairs", u64s(1 << 60))]);
        let lines = seen.lock().unwrap();
        assert_eq!(lines.len(), 1);
        let parsed = Json::parse(&lines[0]).unwrap();
        assert_eq!(parsed.get("kind").as_str(), Some("epoch_done"));
        assert_eq!(parsed.get("role").as_str(), Some("worker_1"));
        assert_eq!(json_u64(parsed.get("pairs")), Some(1 << 60));
        assert!(!lines[0].ends_with('\n'), "sender lines carry no newline");
    }

    #[test]
    fn fresh_journal_replaces_the_previous_file() {
        let dir = tmpdir("fresh");
        let old = Journal::open(&dir, "overlap");
        old.event("stale", vec![]);
        drop(old);
        let j = fresh_journal(&dir, "overlap");
        j.event("new_run", vec![]);
        let events = read_journal(&dir.join(journal_file_name("overlap"))).unwrap();
        assert_eq!(events.len(), 1, "the stale event must be gone");
        assert_eq!(events[0].get("kind").as_str(), Some("new_run"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn disabled_journal_drops_events_silently() {
        let j = Journal::disabled();
        assert!(!j.is_enabled());
        j.event("ignored", vec![("x", num(1.0))]); // must not panic
    }

    #[test]
    fn empty_and_absent_journals() {
        let dir = tmpdir("empty");
        let path = dir.join(journal_file_name("x"));
        assert!(read_journal(&path).is_err(), "absent file is an error");
        std::fs::write(&path, "").unwrap();
        assert_eq!(read_journal(&path).unwrap().len(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
