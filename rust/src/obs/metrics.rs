//! Lock-free metrics registry: named counters, gauges, and fixed-bucket
//! latency histograms.
//!
//! Registration (name → handle) is the only locked path; the handles
//! are `Arc`s whose updates are plain relaxed atomics, so hot loops
//! resolve their instruments once and then pay one atomic op per
//! update. The SGNS inner loop goes one cheaper still: it batches
//! through [`LocalCounter`] (the PR-1 thread-local-flush pattern, same
//! cadence as [`crate::sgns::hogwild::COUNTER_FLUSH`]) so the global
//! counter sees one `fetch_add` per ten thousand pairs.
//!
//! The whole registry can be switched off at runtime
//! ([`Registry::set_enabled`]); hot paths check [`Registry::enabled`]
//! (one relaxed load) before touching their instruments, which is what
//! lets the bench harness price instrumentation against a clean run.

use crate::util::json::{num, s, Json};
use crate::util::sync::{AtomicBool, AtomicU64, Ordering};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonically increasing u64.
pub struct Counter(AtomicU64);

// manual impl: loom's atomics provide no `Default`
impl Default for Counter {
    fn default() -> Self {
        Counter(AtomicU64::new(0))
    }
}

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins f64 (stored as bits in an AtomicU64).
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Self {
        Gauge(AtomicU64::new(0))
    }
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Histogram bucket upper bounds in seconds: a 1–2–5 ladder from 1 µs
/// to 10 s. Fixed buckets keep `observe` allocation-free and make
/// percentiles a cumulative scan; the price is bucket-granularity
/// answers (a percentile is reported as its bucket's upper bound).
pub const BUCKET_BOUNDS: [f64; 24] = [
    1e-6, 2e-6, 5e-6, 1e-5, 2e-5, 5e-5, 1e-4, 2e-4, 5e-4, 1e-3, 2e-3, 5e-3, 1e-2, 2e-2, 5e-2,
    1e-1, 2e-1, 5e-1, 1.0, 2.0, 5.0, 1e1, 2e1, 5e1,
];

/// A fixed-bucket latency histogram. One `fetch_add` per observation
/// (plus one for the running sum).
pub struct Histogram {
    counts: Vec<AtomicU64>, // one per bound, plus a final overflow bucket
    total: AtomicU64,
    sum_micros: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            counts: (0..=BUCKET_BOUNDS.len()).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, secs: f64) {
        let idx = BUCKET_BOUNDS
            .iter()
            .position(|&b| secs <= b)
            .unwrap_or(BUCKET_BOUNDS.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        let micros = (secs.max(0.0) * 1e6) as u64;
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        Some(self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6 / n as f64)
    }

    /// The upper bound of the bucket holding the `p`-th percentile
    /// observation (`p` in `[0, 1]`). `None` when empty; a single
    /// sample answers every percentile with its own bucket's bound.
    /// Overflow observations report the last finite bound.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let rank = ((p.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= rank {
                return Some(*BUCKET_BOUNDS.get(i).unwrap_or(BUCKET_BOUNDS.last().unwrap()));
            }
        }
        Some(*BUCKET_BOUNDS.last().unwrap())
    }
}

/// The registry: named instruments, lock-free after registration.
pub struct Registry {
    enabled: AtomicBool,
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self {
            enabled: AtomicBool::new(true),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Registry {
    /// Runtime kill switch. Hot paths check [`Registry::enabled`]
    /// before updating their (pre-resolved) instruments.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Resolve (or create) the named counter. Locked — call once
    /// outside the hot loop and keep the `Arc`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.histograms
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// A JSON snapshot of every instrument: counters as decimal
    /// strings (u64-precision convention), gauges as numbers,
    /// histograms as `{count, mean_secs, p50_secs, p99_secs}`. This is
    /// what gets embedded in journal rows and beacons.
    pub fn snapshot(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), s(&c.get().to_string())))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, g)| (k.clone(), num(g.get())))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| {
                let mut fields = BTreeMap::new();
                fields.insert("count".to_string(), s(&h.count().to_string()));
                if let Some(m) = h.mean_secs() {
                    fields.insert("mean_secs".to_string(), num(m));
                }
                if let Some(p) = h.percentile(0.50) {
                    fields.insert("p50_secs".to_string(), num(p));
                }
                if let Some(p) = h.percentile(0.99) {
                    fields.insert("p99_secs".to_string(), num(p));
                }
                (k.clone(), Json::Obj(fields))
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("counters".to_string(), Json::Obj(counters));
        top.insert("gauges".to_string(), Json::Obj(gauges));
        top.insert("histograms".to_string(), Json::Obj(histograms));
        Json::Obj(top)
    }
}

/// The process-wide registry every subsystem reports into.
pub fn global() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::default)
}

/// A thread-local batching wrapper over a shared [`Counter`] — the
/// PR-1 flush pattern: accumulate locally, `fetch_add` once per
/// `flush_every` increments (and on drop), so N threads hammering one
/// counter contend once per batch instead of once per event.
pub struct LocalCounter {
    target: Arc<Counter>,
    pending: u64,
    flush_every: u64,
}

impl LocalCounter {
    pub fn new(target: Arc<Counter>, flush_every: u64) -> Self {
        Self {
            target,
            pending: 0,
            flush_every: flush_every.max(1),
        }
    }

    pub fn add(&mut self, n: u64) {
        self.pending += n;
        if self.pending >= self.flush_every {
            self.flush();
        }
    }

    pub fn flush(&mut self) {
        if self.pending > 0 {
            self.target.add(self.pending);
            self.pending = 0;
        }
    }
}

impl Drop for LocalCounter {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_totals_are_exact_under_a_thread_pool() {
        let reg = Registry::default();
        // miri executes every interleaving step interpreted — keep the
        // schedule space meaningful but the instruction count sane
        #[cfg(miri)]
        let (per_thread, threads) = (200u64, 4);
        #[cfg(not(miri))]
        let (per_thread, threads) = (10_000u64, 8);
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let c = reg.counter("pool_total");
                std::thread::spawn(move || {
                    let mut local = LocalCounter::new(c, 64);
                    for _ in 0..per_thread {
                        local.add(1);
                    }
                    // drop flushes the remainder
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.counter("pool_total").get(), per_thread * threads);
    }

    #[test]
    fn histogram_percentile_edge_cases() {
        let h = Histogram::default();
        // empty: no percentile, no mean
        assert_eq!(h.percentile(0.5), None);
        assert_eq!(h.mean_secs(), None);
        assert_eq!(h.count(), 0);

        // single sample: every percentile is that sample's bucket bound
        h.observe(3e-3);
        assert_eq!(h.count(), 1);
        let p50 = h.percentile(0.50).unwrap();
        let p99 = h.percentile(0.99).unwrap();
        assert_eq!(p50, p99, "one sample answers every percentile alike");
        assert!(p50 >= 3e-3 && p50 <= 1e-2, "bucket bound brackets the sample: {p50}");

        // overflow lands in the last bucket and reports the last bound
        let h2 = Histogram::default();
        h2.observe(1e9);
        assert_eq!(h2.percentile(0.5), Some(*BUCKET_BOUNDS.last().unwrap()));
    }

    #[test]
    fn percentiles_split_a_bimodal_distribution() {
        let h = Histogram::default();
        for _ in 0..98 {
            h.observe(1.5e-6); // → 2 µs bucket
        }
        for _ in 0..2 {
            h.observe(0.3); // → 0.5 s bucket
        }
        assert_eq!(h.percentile(0.50), Some(2e-6));
        assert_eq!(h.percentile(0.99), Some(5e-1));
        let mean = h.mean_secs().unwrap();
        assert!(mean > 1e-3 && mean < 1e-2, "mean pulled up by the tail: {mean}");
    }

    #[test]
    fn snapshot_serializes_all_instrument_kinds() {
        let reg = Registry::default();
        reg.counter("big").add((1u64 << 60) + 1);
        reg.gauge("ratio").set(0.75);
        reg.histogram("lat").observe(2e-4);
        let snap = reg.snapshot();
        assert_eq!(
            snap.get("counters").get("big").as_str(),
            Some(((1u64 << 60) + 1).to_string().as_str()),
            "u64 counters must not round-trip through f64"
        );
        assert_eq!(snap.get("gauges").get("ratio").as_f64(), Some(0.75));
        assert_eq!(snap.get("histograms").get("lat").get("count").as_str(), Some("1"));
        assert!(snap.get("histograms").get("lat").get("p99_secs").as_f64().is_some());
        // and the snapshot survives the repo's own JSON round trip
        let back = Json::parse(&snap.to_string_pretty()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn disable_is_a_runtime_toggle() {
        let reg = Registry::default();
        assert!(reg.enabled());
        reg.set_enabled(false);
        assert!(!reg.enabled());
        reg.set_enabled(true);
        assert!(reg.enabled());
    }
}

/// Loom models (run by the CI loom job with `RUSTFLAGS="--cfg loom"`).
///
/// Instruments are resolved **before** any modeled thread spawns so the
/// registry's `std::sync::Mutex` (invisible to loom) never sits inside a
/// modeled interleaving — the models exercise exactly the lock-free part
/// of the protocol: relaxed counter updates and the enabled kill switch.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;

    #[test]
    fn local_counter_flush_is_exact_under_the_kill_switch() {
        loom::model(|| {
            let reg = Arc::new(Registry::default());
            let target = reg.counter("pairs"); // resolved pre-spawn (Mutex)
            let worker_target = Arc::clone(&target);
            let worker = loom::thread::spawn(move || {
                let mut local = LocalCounter::new(worker_target, 2);
                local.add(1);
                local.add(1); // hits flush_every → one fetch_add
                local.add(1); // remainder flushes on drop
            });
            // the kill switch flips concurrently with the flushes; it
            // gates *future* instrument updates, it must never corrupt
            // or lose an in-flight flush
            reg.set_enabled(false);
            let _ = reg.enabled();
            worker.join().unwrap();
            assert_eq!(target.get(), 3, "no flush may be lost or doubled");
            assert!(!reg.enabled());
        });
    }

    #[test]
    fn concurrent_counters_and_gauge_writes_are_race_free() {
        loom::model(|| {
            let reg = Arc::new(Registry::default());
            let c = reg.counter("n");
            let g = reg.gauge("ratio");
            let (c2, g2) = (Arc::clone(&c), Arc::clone(&g));
            let t = loom::thread::spawn(move || {
                c2.add(2);
                g2.set(0.5);
            });
            c.add(1);
            let _ = g.get(); // torn-free by construction: bits in one atomic
            t.join().unwrap();
            assert_eq!(c.get(), 3);
            assert_eq!(g.get(), 0.5);
        });
    }
}
