//! Run-wide observability: event journals, a metrics registry, and the
//! cross-run report/status tooling built on top of them.
//!
//! The paper's zero-synchronization design means the only window into a
//! running fleet is what the processes write to disk. This module makes
//! that window load-bearing: every phase of the pipeline appends typed
//! events to a per-process journal, hot paths feed a lock-free metrics
//! registry, and two CLI verbs (`dw2v status`, `dw2v report`) turn the
//! files into a live progress table and a post-hoc `run_report.json`.
//!
//! ## The on-disk contract
//!
//! A *run directory* (the `--out-dir` of a multi-process run, or the
//! shard directory of an ingest) accumulates three kinds of telemetry
//! files, all safe to read while the run is still writing them:
//!
//! * **`events_<role>.jsonl`** — one append-only journal per process
//!   ([`journal::Journal`]). `<role>` identifies the writer:
//!   `coordinator`, `worker_<s>`, `ingest`, `overlap`. Each line is one
//!   self-contained JSON object `{"unix_ms": "...", "role": "...",
//!   "kind": "...", ...}` written with a **single `write(2)` on an
//!   `O_APPEND` descriptor**, so concurrent appends never interleave
//!   within a line and a crash can tear at most the final line. Readers
//!   therefore tolerate a torn *final* line (the crash case) but treat
//!   a malformed line anywhere else as real corruption. u64 counters
//!   ride as decimal strings, the repo-wide convention for values that
//!   would lose precision as f64 above 2^53.
//! * **`beacon_<s>.json`** — the liveness/progress heartbeat each
//!   training worker rewrites atomically (tmp + rename) every beacon
//!   interval; see [`crate::coordinator::supervisor`] for the field
//!   contract. Journals are the *history*, beacons are the *now* —
//!   `dw2v status` tails beacons, `dw2v report` replays journals.
//! * **`run_report.json`** / **`run_report.html`** — the aggregate
//!   [`report::write_report`] produces: per-phase wallclock, a
//!   per-worker timeline (spawns, crashes, stalls, respawns,
//!   completion), pairs/s curves, ingest throughput.
//!
//! Telemetry must never take down the run it observes: a journal that
//! fails to open degrades to a no-op writer (with one warning), and all
//! appends are best-effort.
//!
//! ## Metrics
//!
//! [`metrics::Registry`] holds named counters, gauges, and fixed-bucket
//! latency histograms behind plain atomics. Registration (name lookup)
//! is the only locked path; handles are `Arc`s the hot path updates
//! lock-free. The SGNS inner loop pays one atomic add per
//! [`crate::sgns::hogwild::COUNTER_FLUSH`] pairs — the PR-1
//! thread-local-flush pattern — and the whole registry can be switched
//! off at runtime ([`metrics::Registry::set_enabled`]) so the bench
//! harness can price instrumentation against a clean run.

pub mod journal;
pub mod metrics;
pub mod report;
