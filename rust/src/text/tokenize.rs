//! Tokenization and sentence splitting.
//!
//! The paper pre-processes Wikipedia/Web by removing non-textual elements,
//! sentence splitting and tokenization. This module provides the same
//! pipeline for raw-text ingestion: unicode-aware lowercasing, alphanumeric
//! token extraction, and sentence segmentation on terminal punctuation.

/// Split raw text into sentences on `.`, `!`, `?` and newlines, skipping
/// empties.
pub fn split_sentences(text: &str) -> Vec<&str> {
    text.split(|c| matches!(c, '.' | '!' | '?' | '\n'))
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect()
}

/// Tokenize one sentence: lowercase alphanumeric runs; apostrophes are kept
/// inside words ("don't"), every other character is a separator. The
/// unicode right single quotation mark (U+2019, what most real corpora use
/// for contractions) is normalized to the ASCII apostrophe so "don’t" and
/// "don't" map to the same vocabulary entry.
pub fn tokenize(sentence: &str) -> Vec<String> {
    let mut tokens = Vec::new();
    let mut current = String::new();
    for ch in sentence.chars() {
        let ch = if ch == '\u{2019}' { '\'' } else { ch };
        if ch.is_alphanumeric() || (ch == '\'' && !current.is_empty()) {
            for lc in ch.to_lowercase() {
                current.push(lc);
            }
        } else if !current.is_empty() {
            tokens.push(std::mem::take(&mut current).trim_end_matches('\'').to_string());
        }
    }
    if !current.is_empty() {
        tokens.push(current.trim_end_matches('\'').to_string());
    }
    tokens.retain(|t| !t.is_empty());
    tokens
}

/// Full pipeline: raw text → tokenized sentences.
pub fn sentences_of(text: &str) -> Vec<Vec<String>> {
    split_sentences(text)
        .into_iter()
        .map(tokenize)
        .filter(|s| !s.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_on_terminal_punctuation() {
        let s = split_sentences("Hello world. How are you? Fine!\nGreat");
        assert_eq!(s, vec!["Hello world", "How are you", "Fine", "Great"]);
    }

    #[test]
    fn tokenize_lowercases_and_strips_punct() {
        assert_eq!(
            tokenize("The Quick, Brown FOX!"),
            vec!["the", "quick", "brown", "fox"]
        );
    }

    #[test]
    fn keeps_interior_apostrophes() {
        assert_eq!(tokenize("Don't stop"), vec!["don't", "stop"]);
        // leading/trailing apostrophes are separators/stripped
        assert_eq!(tokenize("'quoted' word'"), vec!["quoted", "word"]);
    }

    #[test]
    fn numbers_survive() {
        assert_eq!(tokenize("in 1984 there were 2 pigs"), vec![
            "in", "1984", "there", "were", "2", "pigs"
        ]);
    }

    #[test]
    fn unicode_lowercase() {
        assert_eq!(tokenize("Überraschung CAFÉ"), vec!["überraschung", "café"]);
    }

    #[test]
    fn full_pipeline_skips_empty_sentences() {
        let out = sentences_of("First one. ... Second two.");
        assert_eq!(out, vec![vec!["first", "one"], vec!["second", "two"]]);
    }

    #[test]
    fn empty_input() {
        assert!(sentences_of("").is_empty());
        assert!(tokenize("!!!").is_empty());
    }

    #[test]
    fn crlf_line_endings_are_separators() {
        // \r must neither join tokens nor survive inside one
        let out = sentences_of("first line\r\nsecond line\r\n");
        assert_eq!(out, vec![vec!["first", "line"], vec!["second", "line"]]);
        assert_eq!(tokenize("a\rb"), vec!["a", "b"]);
    }

    #[test]
    fn unicode_apostrophe_normalizes_to_ascii() {
        // U+2019 (‘don’t’ as typeset in real corpora) == ASCII don't
        assert_eq!(tokenize("Don\u{2019}t stop"), vec!["don't", "stop"]);
        assert_eq!(tokenize("Don\u{2019}t"), tokenize("Don't"));
        // leading/trailing curly quotes are stripped like ASCII ones
        assert_eq!(tokenize("\u{2019}quoted\u{2019}"), vec!["quoted"]);
    }

    #[test]
    fn multi_char_lowercasing_is_kept_whole() {
        // 'İ' (U+0130) lowercases to the two-scalar "i\u{307}" — the token
        // must carry both, not truncate to a single char
        assert_eq!(tokenize("İstanbul"), vec!["i\u{307}stanbul"]);
        // 'ẞ' lowercases to 'ß' (1:1 but non-ASCII)
        assert_eq!(tokenize("GROẞ"), vec!["groß"]);
    }

    #[test]
    fn very_long_lines_tokenize_without_truncation() {
        let line = "word ".repeat(100_000);
        let toks = tokenize(&line);
        assert_eq!(toks.len(), 100_000);
        assert!(toks.iter().all(|t| t == "word"));
    }
}
