//! Streaming raw-text ingestion: the paper's preprocess → HDFS-shards
//! step, scaled to one node.
//!
//! Two memory-bounded passes over the input file:
//!
//! 1. **count** — the file is read in whole-line chunks of roughly
//!    [`IngestConfig::chunk_bytes`]; each chunk is fanned out over
//!    [`crate::exec::pool`] workers that tokenize and accumulate
//!    [`VocabBuilder`] partial counts (the mapper-side partials of
//!    Ordentlich et al.'s distributed vocab count), merged via
//!    [`VocabBuilder::merge`] and frozen with `min_count`/`max_vocab`;
//! 2. **encode** — the file is re-streamed, chunks are tokenized and
//!    id-encoded against the frozen [`Vocab`] in parallel (OOV tokens
//!    dropped and counted), and finished sentences are spilled to the
//!    binary [`Corpus`] shard format every [`IngestConfig::shard_tokens`]
//!    tokens.
//!
//! Peak memory is one chunk of raw text + one shard of encoded ids — the
//! corpus itself never lives in memory, so a multi-GB text file ingests in
//! a bounded footprint. The resulting `shard_*.bin` + `vocab.tsv` layout
//! is exactly what [`Corpus::read_sharded`] / the training pipeline
//! consume (paper: HDFS splits → mappers).
//!
//! ## Shard publication and the overlap protocol
//!
//! Pass 2 publishes every spilled shard **atomically** — write
//! `shard_<i>.bin.tmp`, rename to `shard_<i>.bin` — and after each rename
//! atomically rewrites the [`super::feed::ShardManifest`] (`shards.json`:
//! shards so far, per-shard sentence counts, token total, `complete`
//! written last). A concurrent reader therefore never observes a
//! half-written shard, and can distinguish "shard 7 not written yet" from
//! "shard 7 missing". The manifest file format lives in [`super::feed`].
//!
//! [`ingest_file_overlapped`] additionally runs a **schedule pass**
//! between the vocabulary freeze and pass 2: it re-streams the encoded
//! sentence stream through a [`PairEstimator`] (no shard writes) and
//! publishes `{total_sentences, per_epoch_pairs}` in the manifest's
//! `schedule` block *before the first shard exists*. Because that
//! estimator is a plain sequential f64 sum in sentence order, the
//! published value is bitwise identical to what a training worker would
//! compute by streaming the finished shards — which is what lets workers
//! start their first gradient on `shard_0.bin` while ingest is still
//! writing later shards, yet finish bitwise identical to a back-to-back
//! run.

use super::corpus::Corpus;
use super::feed::{ScheduleBlock, ShardManifest};
use super::tokenize::{split_sentences, tokenize};
use super::vocab::{Vocab, VocabBuilder};
use crate::exec::pool::parallel_map;
use crate::obs::journal::{self, u64s, Journal};
use crate::sgns::config::SgnsConfig;
use crate::sgns::schedule::PairEstimator;
use crate::util::json;
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Knobs for one ingestion run.
#[derive(Clone, Debug)]
pub struct IngestConfig {
    /// drop words seen fewer than this many times
    pub min_count: u64,
    /// keep at most this many of the most frequent words
    pub max_vocab: usize,
    /// tokenizer worker threads per chunk
    pub workers: usize,
    /// target raw-text bytes per streamed chunk (whole lines; a single
    /// line longer than this is still read intact)
    pub chunk_bytes: usize,
    /// target encoded tokens per output shard file
    pub shard_tokens: u64,
}

impl Default for IngestConfig {
    fn default() -> Self {
        Self {
            min_count: 5,
            max_vocab: 1_000_000,
            workers: 4,
            chunk_bytes: 4 << 20,
            shard_tokens: 2_000_000,
        }
    }
}

/// What one ingestion run saw and produced.
#[derive(Clone, Debug, Default)]
pub struct IngestStats {
    /// raw input size in bytes
    pub bytes: u64,
    pub lines: u64,
    /// non-empty tokenized sentences seen
    pub sentences: u64,
    /// sentences with at least one in-vocab token (what the shards hold)
    pub written_sentences: u64,
    /// all tokens produced by the tokenizer
    pub raw_tokens: u64,
    /// tokens encoded into shards (in-vocab)
    pub kept_tokens: u64,
    /// tokens dropped as out-of-vocabulary (`min_count`/`max_vocab`)
    pub oov_tokens: u64,
    pub vocab_size: usize,
    pub shards: usize,
    pub pass1_secs: f64,
    /// overlap mode only: wall clock of the schedule pass (else 0)
    pub schedule_secs: f64,
    pub pass2_secs: f64,
}

impl IngestStats {
    /// Fraction of tokenized tokens dropped as OOV.
    pub fn oov_rate(&self) -> f64 {
        self.oov_tokens as f64 / self.raw_tokens.max(1) as f64
    }

    /// End-to-end ingest throughput: file bytes over every pass's wall
    /// clock (including the overlap-mode schedule pass, when run).
    pub fn bytes_per_sec(&self) -> f64 {
        self.bytes as f64
            / (self.pass1_secs + self.schedule_secs + self.pass2_secs).max(1e-9)
    }

    /// One-line human report.
    pub fn summary(&self) -> String {
        format!(
            "ingest: {} lines / {} sentences / {} tokens ({} kept, {:.2}% OOV) \
             -> vocab {} / {} shards, {:.1} MB in {:.2}s+{:.2}s ({:.1} MB/s)",
            self.lines,
            self.sentences,
            self.raw_tokens,
            self.kept_tokens,
            100.0 * self.oov_rate(),
            self.vocab_size,
            self.shards,
            self.bytes as f64 / 1e6,
            self.pass1_secs,
            self.pass2_secs,
            self.bytes_per_sec() / 1e6
        )
    }
}

/// Result of [`ingest_file`]: the frozen vocabulary, the shard files
/// written (plus `vocab.tsv` beside them), and the run report.
#[derive(Clone, Debug)]
pub struct IngestOutput {
    pub vocab: Vocab,
    pub shard_paths: Vec<PathBuf>,
    pub stats: IngestStats,
}

/// Reads whole lines until roughly `chunk_bytes` accumulate. Trailing
/// `\n`/`\r\n` are stripped so downstream tokenization sees clean lines.
struct ChunkReader {
    reader: BufReader<File>,
    chunk_bytes: usize,
}

impl ChunkReader {
    fn open(path: &Path, chunk_bytes: usize) -> std::io::Result<Self> {
        Ok(Self {
            reader: BufReader::new(File::open(path)?),
            chunk_bytes: chunk_bytes.max(1),
        })
    }

    /// Next chunk of lines, or `None` at EOF.
    fn next_chunk(&mut self) -> std::io::Result<Option<Vec<String>>> {
        let mut lines = Vec::new();
        let mut budget = 0usize;
        let mut buf = String::new();
        while budget < self.chunk_bytes {
            buf.clear();
            let n = self.reader.read_line(&mut buf)?;
            if n == 0 {
                break;
            }
            budget += n;
            while buf.ends_with('\n') || buf.ends_with('\r') {
                buf.pop();
            }
            lines.push(std::mem::take(&mut buf));
        }
        Ok(if lines.is_empty() { None } else { Some(lines) })
    }
}

/// Split `lines` into up to `workers` contiguous slices for fork-join
/// tokenization.
fn line_slices(lines: &[String], workers: usize) -> Vec<&[String]> {
    let per = lines.len().div_ceil(workers.max(1)).max(1);
    lines.chunks(per).collect()
}

/// Pass 1: stream the file and build the frequency-ranked vocabulary from
/// parallel partial counts. Returns the frozen vocab plus (bytes, lines)
/// seen.
pub fn count_vocab(path: &Path, cfg: &IngestConfig) -> Result<(Vocab, u64, u64), String> {
    let ctx = |e: std::io::Error| format!("ingest pass 1 ({}): {e}", path.display());
    let bytes = std::fs::metadata(path).map_err(ctx)?.len();
    let mut reader = ChunkReader::open(path, cfg.chunk_bytes).map_err(ctx)?;
    let mut builder = VocabBuilder::new();
    let mut lines = 0u64;
    while let Some(chunk) = reader.next_chunk().map_err(ctx)? {
        lines += chunk.len() as u64;
        let partials = parallel_map(&line_slices(&chunk, cfg.workers), cfg.workers, |slice| {
            let mut b = VocabBuilder::new();
            for line in slice.iter() {
                for sentence in split_sentences(line) {
                    for token in tokenize(sentence) {
                        b.add_token(&token);
                    }
                }
            }
            b
        });
        for p in partials {
            builder.merge(p);
        }
    }
    Ok((builder.build(cfg.min_count, cfg.max_vocab), bytes, lines))
}

/// Per-slice pass-2 result: encoded sentences + token accounting.
struct EncodedSlice {
    sentences: Vec<Vec<u32>>,
    tokenized_sentences: u64,
    raw_tokens: u64,
    oov_tokens: u64,
}

/// Pass 2 driver: re-stream `input`, tokenize + id-encode chunks in
/// parallel, feed every surviving sentence (in input order) to `sink`,
/// accumulating the token accounting into `stats`.
fn encode_stream(
    input: &Path,
    cfg: &IngestConfig,
    vocab: &Vocab,
    stats: &mut IngestStats,
    mut sink: impl FnMut(Vec<u32>) -> Result<(), String>,
) -> Result<(), String> {
    let ctx = |e: std::io::Error| format!("ingest pass 2 ({}): {e}", input.display());
    let mut reader = ChunkReader::open(input, cfg.chunk_bytes).map_err(ctx)?;
    while let Some(chunk) = reader.next_chunk().map_err(ctx)? {
        let encoded = parallel_map(&line_slices(&chunk, cfg.workers), cfg.workers, |slice| {
            let mut out = EncodedSlice {
                sentences: Vec::new(),
                tokenized_sentences: 0,
                raw_tokens: 0,
                oov_tokens: 0,
            };
            for line in slice.iter() {
                for sentence in split_sentences(line) {
                    let tokens = tokenize(sentence);
                    if tokens.is_empty() {
                        continue;
                    }
                    out.tokenized_sentences += 1;
                    out.raw_tokens += tokens.len() as u64;
                    let ids = vocab.encode(&tokens);
                    out.oov_tokens += (tokens.len() - ids.len()) as u64;
                    if !ids.is_empty() {
                        out.sentences.push(ids);
                    }
                }
            }
            out
        });
        for enc in encoded {
            stats.sentences += enc.tokenized_sentences;
            stats.raw_tokens += enc.raw_tokens;
            stats.oov_tokens += enc.oov_tokens;
            for s in enc.sentences {
                stats.kept_tokens += s.len() as u64;
                stats.written_sentences += 1;
                sink(s)?;
            }
        }
    }
    Ok(())
}

/// Knobs for [`ingest_file_overlapped`]: the SGNS parameters the schedule
/// pass must match (they change the expected-pair sum) plus a test hook.
#[derive(Clone, Debug)]
pub struct OverlapOptions {
    /// SGNS max window the training run will use
    pub window: usize,
    /// SGNS frequent-word subsampling threshold the training run will use
    pub subsample_t: f64,
    /// test hook: sleep this long before publishing each shard, so e2e
    /// tests can prove workers really trained while shards were still
    /// being written (zero in production)
    pub shard_delay: Duration,
}

impl OverlapOptions {
    pub fn new(window: usize, subsample_t: f64) -> Self {
        Self {
            window,
            subsample_t,
            shard_delay: Duration::ZERO,
        }
    }
}

/// Full two-pass ingestion of a raw text file into `out_dir`: writes
/// `shard_0.bin … shard_{n-1}.bin` (the [`Corpus`] binary format, readable
/// with [`Corpus::read_sharded`]) and a `vocab.tsv` beside them, each
/// shard published atomically with the manifest updated after every
/// rename (see the module docs). Stale `shard_*.bin` files — plus `.tmp`
/// debris and any previous manifest — are removed first — `read_sharded`
/// globs the whole directory, so leftovers encoded against an older vocab
/// would otherwise corrupt the corpus.
///
/// Sentences that lose every token to the vocabulary filter are dropped;
/// everything else is preserved in order, so the concatenated decoded
/// shard stream equals the tokenized input filtered to in-vocab words.
pub fn ingest_file(
    input: &Path,
    out_dir: &Path,
    cfg: &IngestConfig,
) -> Result<IngestOutput, String> {
    ingest_file_impl(input, out_dir, cfg, None, None)
}

/// [`ingest_file`] that additionally tees every encoded sentence into an
/// in-memory [`Corpus`], for callers that persist the shard layout and
/// train immediately — avoids reading back from disk what pass 2 just
/// wrote.
pub fn ingest_file_and_load(
    input: &Path,
    out_dir: &Path,
    cfg: &IngestConfig,
) -> Result<(IngestOutput, Corpus), String> {
    let mut corpus = Corpus::default();
    let out = ingest_file_impl(input, out_dir, cfg, Some(&mut corpus), None)?;
    Ok((out, corpus))
}

/// [`ingest_file`] for ingest/training overlap: runs the extra schedule
/// pass after the vocabulary freeze and publishes its result in the
/// manifest's `schedule` block **before** pass 2 writes any shard, so
/// training workers following the directory via
/// [`super::feed::ShardFeed`] can start the moment `shard_0.bin` lands.
pub fn ingest_file_overlapped(
    input: &Path,
    out_dir: &Path,
    cfg: &IngestConfig,
    overlap: &OverlapOptions,
) -> Result<IngestOutput, String> {
    ingest_file_impl(input, out_dir, cfg, None, Some(overlap))
}

fn ingest_file_impl(
    input: &Path,
    out_dir: &Path,
    cfg: &IngestConfig,
    mut tee: Option<&mut Corpus>,
    overlap: Option<&OverlapOptions>,
) -> Result<IngestOutput, String> {
    let mut stats = IngestStats::default();

    let t1 = std::time::Instant::now();
    let (vocab, bytes, lines) = count_vocab(input, cfg)?;
    stats.pass1_secs = t1.elapsed().as_secs_f64();
    stats.bytes = bytes;
    stats.lines = lines;
    stats.vocab_size = vocab.len();

    std::fs::create_dir_all(out_dir).map_err(|e| format!("create {}: {e}", out_dir.display()))?;
    super::corpus::remove_stale_shards(out_dir)
        .map_err(|e| format!("clear stale shards in {}: {e}", out_dir.display()))?;
    // fresh journal per ingest: a shard dir is wholly replaced by a
    // re-ingest, so a previous run's events must not splice into this one
    let _ = std::fs::remove_file(out_dir.join(journal::journal_file_name("ingest")));
    let jrn = Journal::open(out_dir, "ingest");
    jrn.event(
        "pass1_done",
        vec![
            ("secs", json::num(stats.pass1_secs)),
            (
                "mb_per_s",
                json::num(stats.bytes as f64 / 1e6 / stats.pass1_secs.max(1e-9)),
            ),
            ("lines", u64s(stats.lines)),
            ("vocab", json::inum(stats.vocab_size)),
        ],
    );
    // vocab.tsv is fully known after pass 1 — write it before any shard
    // so a mid-pass-2 failure can never leave new shards paired with a
    // previous run's vocabulary
    std::fs::write(out_dir.join("vocab.tsv"), vocab.to_tsv())
        .map_err(|e| format!("write vocab.tsv: {e}"))?;

    let mut manifest = ShardManifest::default();
    if let Some(ov) = overlap {
        // schedule pass: same encode path as pass 2 (identical sentence
        // stream), but the sink is a PairEstimator instead of a shard
        // writer — published before the first shard so workers can start
        let ts = std::time::Instant::now();
        let mut scfg = SgnsConfig::default();
        scfg.window = ov.window;
        scfg.subsample_t = ov.subsample_t;
        let mut est = PairEstimator::new(&vocab, &scfg);
        let mut total_sentences = 0u64;
        let mut sched_stats = IngestStats::default();
        encode_stream(input, cfg, &vocab, &mut sched_stats, |s| {
            est.add_sentence(&s);
            total_sentences += 1;
            Ok(())
        })?;
        manifest.schedule = Some(ScheduleBlock {
            total_sentences,
            per_epoch_pairs: est.per_epoch(),
            window: ov.window,
            subsample_t: ov.subsample_t,
        });
        manifest.publish(out_dir)?;
        stats.schedule_secs = ts.elapsed().as_secs_f64();
        jrn.event(
            "schedule_done",
            vec![
                ("secs", json::num(stats.schedule_secs)),
                ("sentences", u64s(total_sentences)),
            ],
        );
    }

    let t2 = std::time::Instant::now();
    let delay = overlap.map(|ov| ov.shard_delay).unwrap_or(Duration::ZERO);
    let mut pending = Corpus::default();
    let mut pending_tokens = 0u64;
    let mut shard_paths: Vec<PathBuf> = Vec::new();

    /// Publish the pending buffer as the next shard (tmp → rename, then
    /// manifest row); sentences then move into the tee corpus (no
    /// per-sentence clone) or are dropped.
    fn flush_shard(
        out_dir: &Path,
        pending: &mut Corpus,
        pending_tokens: &mut u64,
        shard_paths: &mut Vec<PathBuf>,
        tee: &mut Option<&mut Corpus>,
        manifest: &mut ShardManifest,
        delay: Duration,
        jrn: &Journal,
    ) -> Result<(), String> {
        if pending.is_empty() {
            return Ok(());
        }
        if !delay.is_zero() {
            std::thread::sleep(delay);
        }
        let idx = shard_paths.len();
        let path = out_dir.join(format!("shard_{idx}.bin"));
        let tmp = out_dir.join(format!("shard_{idx}.bin.tmp"));
        pending
            .write_shard(&tmp)
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("publish {}: {e}", path.display()))?;
        // manifest row strictly after the rename: a listed shard is a
        // readable shard (the ordering ShardFeed relies on)
        manifest.shard_sentences.push(pending.len() as u64);
        manifest.tokens += pending.total_tokens();
        manifest.publish(out_dir)?;
        jrn.event(
            "shard_published",
            vec![
                ("shard", json::inum(idx)),
                ("sentences", u64s(pending.len() as u64)),
            ],
        );
        shard_paths.push(path);
        match tee.as_deref_mut() {
            Some(corpus) => corpus.sentences.append(&mut pending.sentences),
            None => pending.sentences.clear(),
        }
        *pending_tokens = 0;
        Ok(())
    }

    encode_stream(input, cfg, &vocab, &mut stats, |s| {
        pending_tokens += s.len() as u64;
        pending.sentences.push(s);
        if pending_tokens >= cfg.shard_tokens {
            flush_shard(
                out_dir,
                &mut pending,
                &mut pending_tokens,
                &mut shard_paths,
                &mut tee,
                &mut manifest,
                delay,
                &jrn,
            )?;
        }
        Ok(())
    })?;
    flush_shard(
        out_dir,
        &mut pending,
        &mut pending_tokens,
        &mut shard_paths,
        &mut tee,
        &mut manifest,
        delay,
        &jrn,
    )?;
    if let Some(sched) = &manifest.schedule {
        // the schedule pass and pass 2 walked the identical deterministic
        // stream; a disagreement means the input changed mid-ingest
        if sched.total_sentences != stats.written_sentences {
            return Err(format!(
                "ingest ({}): schedule pass saw {} sentences but pass 2 wrote {} — \
                 input file changed during ingest?",
                input.display(),
                sched.total_sentences,
                stats.written_sentences
            ));
        }
    }
    manifest.complete = true;
    manifest.publish(out_dir)?;
    stats.pass2_secs = t2.elapsed().as_secs_f64();
    stats.shards = shard_paths.len();
    jrn.event(
        "pass2_done",
        vec![
            ("secs", json::num(stats.pass2_secs)),
            ("shards", json::inum(stats.shards)),
            ("sentences", u64s(stats.written_sentences)),
        ],
    );
    jrn.event(
        "ingest_done",
        vec![
            (
                "secs",
                json::num(stats.pass1_secs + stats.schedule_secs + stats.pass2_secs),
            ),
            ("mb_per_s", json::num(stats.bytes_per_sec() / 1e6)),
            ("kept_tokens", u64s(stats.kept_tokens)),
            ("oov_tokens", u64s(stats.oov_tokens)),
        ],
    );

    Ok(IngestOutput {
        vocab,
        shard_paths,
        stats,
    })
}

/// In-memory variant of [`ingest_file`]: same two streaming passes, but
/// pass 2 accumulates the id-encoded corpus directly (≈4 bytes/token —
/// the same memory training needs resident anyway) instead of spilling
/// shards and reading them back. Used by the default CLI `--text` path
/// when no `--shard-dir` persistence was requested.
pub fn ingest_to_corpus(
    input: &Path,
    cfg: &IngestConfig,
) -> Result<(Vocab, Corpus, IngestStats), String> {
    let mut stats = IngestStats::default();

    let t1 = std::time::Instant::now();
    let (vocab, bytes, lines) = count_vocab(input, cfg)?;
    stats.pass1_secs = t1.elapsed().as_secs_f64();
    stats.bytes = bytes;
    stats.lines = lines;
    stats.vocab_size = vocab.len();

    let t2 = std::time::Instant::now();
    let mut corpus = Corpus::default();
    encode_stream(input, cfg, &vocab, &mut stats, |s| {
        corpus.sentences.push(s);
        Ok(())
    })?;
    stats.pass2_secs = t2.elapsed().as_secs_f64();
    Ok((vocab, corpus, stats))
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "dw2v_ingest_test_{name}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_input(dir: &Path, text: &str) -> PathBuf {
        let path = dir.join("input.txt");
        std::fs::write(&path, text).unwrap();
        path
    }

    fn small_cfg() -> IngestConfig {
        IngestConfig {
            min_count: 1,
            max_vocab: usize::MAX,
            workers: 2,
            chunk_bytes: 64, // force many chunks even on tiny inputs
            shard_tokens: 16,
        }
    }

    /// Reference stream: tokenize the whole text in memory, filter to the
    /// given vocab, decode ids back to words.
    fn reference_stream(text: &str, vocab: &Vocab) -> Vec<String> {
        crate::text::tokenize::sentences_of(text)
            .into_iter()
            .flatten()
            .filter(|t| vocab.id(t).is_some())
            .collect()
    }

    fn decoded_stream(dir: &Path, vocab: &Vocab) -> Vec<String> {
        Corpus::read_sharded(dir)
            .unwrap()
            .sentences
            .iter()
            .flatten()
            .map(|&id| vocab.word(id).to_string())
            .collect()
    }

    #[test]
    fn ingest_counts_and_encodes_a_simple_file() {
        let dir = tmpdir("simple");
        let input = write_input(
            &dir,
            "the cat sat on the mat. The dog sat too!\nthe end\n",
        );
        let out = ingest_file(&input, &dir.join("shards"), &small_cfg()).unwrap();
        assert_eq!(out.stats.lines, 2);
        assert_eq!(out.stats.sentences, 3);
        assert_eq!(out.stats.raw_tokens, 12);
        assert_eq!(out.stats.oov_tokens, 0);
        assert_eq!(out.stats.kept_tokens, 12);
        // "the" counted across sentences and cases
        let v = &out.vocab;
        assert_eq!(v.count(v.id("the").unwrap()), 4);
        assert_eq!(v.id("The"), None, "vocabulary is lowercased");
        // most frequent word gets id 0
        assert_eq!(v.word(0), "the");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn min_count_drops_mass_into_oov() {
        let dir = tmpdir("oov");
        let input = write_input(&dir, "a a a a b b c\na b a\n");
        let mut cfg = small_cfg();
        cfg.min_count = 2; // drops the singleton c
        let out = ingest_file(&input, &dir.join("shards"), &cfg).unwrap();
        assert_eq!(out.vocab.len(), 2);
        assert_eq!(out.stats.oov_tokens, 1);
        assert_eq!(out.stats.kept_tokens, 9);
        assert!((out.stats.oov_rate() - 1.0 / 10.0).abs() < 1e-12);
        // the vocab's own accounting must agree with the stream's
        assert_eq!(out.vocab.total_tokens(), out.stats.raw_tokens);
        assert_eq!(out.vocab.retained_tokens(), out.stats.kept_tokens);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shards_split_at_token_budget_and_concatenate_in_order() {
        let dir = tmpdir("shards");
        let mut text = String::new();
        for i in 0..40 {
            text.push_str(&format!("w{} w{} w{}\n", i % 7, (i + 1) % 7, (i + 2) % 7));
        }
        let out = ingest_file(&input_of(&dir, &text), &dir.join("shards"), &small_cfg()).unwrap();
        // 120 tokens at ≤16+sentence per shard → several shards
        assert!(out.stats.shards >= 5, "got {} shards", out.stats.shards);
        assert_eq!(out.shard_paths.len(), out.stats.shards);
        assert_eq!(
            decoded_stream(&dir.join("shards"), &out.vocab),
            reference_stream(&text, &out.vocab)
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn input_of(dir: &Path, text: &str) -> PathBuf {
        write_input(dir, text)
    }

    #[test]
    fn empty_file_yields_empty_everything() {
        let dir = tmpdir("empty");
        let input = write_input(&dir, "");
        let out = ingest_file(&input, &dir.join("shards"), &small_cfg()).unwrap();
        assert_eq!(out.vocab.len(), 0);
        assert_eq!(out.stats.raw_tokens, 0);
        assert_eq!(out.stats.shards, 0);
        assert!(Corpus::read_sharded(&dir.join("shards")).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn punctuation_only_file_yields_no_tokens() {
        let dir = tmpdir("punct");
        let input = write_input(&dir, "... !!! ???\n\n---\n");
        let out = ingest_file(&input, &dir.join("shards"), &small_cfg()).unwrap();
        assert_eq!(out.vocab.len(), 0);
        assert_eq!(out.stats.sentences, 0);
        assert_eq!(out.stats.shards, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crlf_and_unicode_inputs_round_trip() {
        let dir = tmpdir("crlf");
        let text = "Don\u{2019}t stop.\r\nÜberraschung CAFÉ!\r\nİstanbul 2024\r\n";
        let input = write_input(&dir, text);
        let out = ingest_file(&input, &dir.join("shards"), &small_cfg()).unwrap();
        assert_eq!(out.stats.lines, 3);
        let v = &out.vocab;
        for w in ["don't", "stop", "überraschung", "café", "i\u{307}stanbul", "2024"] {
            assert!(v.id(w).is_some(), "missing token {w:?}");
        }
        assert_eq!(
            decoded_stream(&dir.join("shards"), v),
            reference_stream(text, v)
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn line_longer_than_chunk_budget_is_read_intact() {
        let dir = tmpdir("longline");
        // one line several times the 64-byte chunk budget
        let text = format!("{}\nshort tail\n", "alpha beta ".repeat(500));
        let input = write_input(&dir, &text);
        let out = ingest_file(&input, &dir.join("shards"), &small_cfg()).unwrap();
        assert_eq!(out.stats.lines, 2);
        assert_eq!(out.stats.raw_tokens, 1002);
        let v = &out.vocab;
        assert_eq!(v.count(v.id("alpha").unwrap()), 500);
        assert_eq!(
            decoded_stream(&dir.join("shards"), v),
            reference_stream(&text, v)
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn worker_count_does_not_change_the_output() {
        let dir = tmpdir("workers");
        let mut rng = Pcg64::new(0xD0C);
        let mut text = String::new();
        for _ in 0..300 {
            let len = 1 + rng.gen_range_usize(12);
            for _ in 0..len {
                text.push_str(&format!("w{} ", rng.gen_range(40)));
            }
            text.push('\n');
        }
        let input = write_input(&dir, &text);
        let mut outputs = Vec::new();
        for workers in [1usize, 4] {
            let mut cfg = small_cfg();
            cfg.workers = workers;
            cfg.chunk_bytes = 256;
            let shard_dir = dir.join(format!("shards_{workers}"));
            let out = ingest_file(&input, &shard_dir, &cfg).unwrap();
            outputs.push((
                out.vocab.to_tsv(),
                Corpus::read_sharded(&shard_dir).unwrap(),
                out.stats.kept_tokens,
            ));
        }
        assert_eq!(outputs[0].0, outputs[1].0, "vocab must be deterministic");
        assert_eq!(outputs[0].1, outputs[1].1, "corpus must be deterministic");
        assert_eq!(outputs[0].2, outputs[1].2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Property: for random text over a mixed alphabet (words, digits,
    /// punctuation, unicode, CRLF, blank lines), ingest → shards →
    /// read_shard → decode preserves the tokenized in-vocab stream
    /// exactly, and the token accounting balances.
    #[test]
    fn ingest_round_trip_property() {
        let mut rng = Pcg64::new(0x1261);
        let words = [
            "alpha", "beta", "Gamma", "DELTA", "don't", "café", "x9", "42", "σίγμα",
        ];
        let seps = [" ", "  ", ", ", ". ", "! ", "\n", "\r\n", " — ", "\n\n"];
        for case in 0..10 {
            let dir = tmpdir(&format!("prop{case}"));
            let mut text = String::new();
            let n = 50 + rng.gen_range_usize(400);
            for _ in 0..n {
                text.push_str(words[rng.gen_range_usize(words.len())]);
                text.push_str(seps[rng.gen_range_usize(seps.len())]);
            }
            let input = write_input(&dir, &text);
            let mut cfg = small_cfg();
            cfg.min_count = 1 + rng.gen_range(2); // sometimes drop rare words
            cfg.chunk_bytes = 32 + rng.gen_range_usize(200);
            cfg.shard_tokens = 8 + rng.gen_range(64);
            let out = ingest_file(&input, &dir.join("shards"), &cfg).unwrap();
            assert_eq!(
                decoded_stream(&dir.join("shards"), &out.vocab),
                reference_stream(&text, &out.vocab),
                "case {case} failed round trip"
            );
            assert_eq!(
                out.stats.kept_tokens + out.stats.oov_tokens,
                out.stats.raw_tokens,
                "case {case} token accounting"
            );
            assert_eq!(out.vocab.total_tokens(), out.stats.raw_tokens);
            assert_eq!(out.vocab.retained_tokens(), out.stats.kept_tokens);
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }

    /// Re-ingesting into the same directory must not leave shards from a
    /// previous (larger) run behind — `read_sharded` globs the directory,
    /// so stale files would splice an old corpus (with old ids) into the
    /// new one.
    #[test]
    fn reingest_removes_stale_shards() {
        let dir = tmpdir("stale");
        let shards = dir.join("shards");
        let big: String = (0..30)
            .map(|i| format!("x{} y{} z{}\n", i, i, i))
            .collect();
        let big_input = write_input(&dir, &big);
        let first = ingest_file(&big_input, &shards, &small_cfg()).unwrap();
        assert!(first.stats.shards >= 3);

        let small = "only two\n";
        let small_input = dir.join("small.txt");
        std::fs::write(&small_input, small).unwrap();
        let second = ingest_file(&small_input, &shards, &small_cfg()).unwrap();
        assert_eq!(second.stats.shards, 1);
        // the directory holds exactly the new run's single shard
        assert_eq!(
            decoded_stream(&shards, &second.vocab),
            reference_stream(small, &second.vocab)
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The in-memory pass-2 sink must produce exactly what the shard
    /// round trip produces.
    #[test]
    fn ingest_to_corpus_matches_sharded_ingest() {
        let dir = tmpdir("inmem");
        let mut text = String::new();
        for i in 0..60 {
            text.push_str(&format!("alpha w{} beta w{}.\n", i % 9, (i + 4) % 9));
        }
        let input = write_input(&dir, &text);
        let mut cfg = small_cfg();
        cfg.min_count = 2;
        let sharded = ingest_file(&input, &dir.join("shards"), &cfg).unwrap();
        let reloaded = Corpus::read_sharded(&dir.join("shards")).unwrap();
        let (vocab, corpus, stats) = ingest_to_corpus(&input, &cfg).unwrap();
        assert_eq!(vocab.to_tsv(), sharded.vocab.to_tsv());
        assert_eq!(corpus, reloaded);
        assert_eq!(stats.kept_tokens, sharded.stats.kept_tokens);
        assert_eq!(stats.oov_tokens, sharded.stats.oov_tokens);
        assert_eq!(stats.shards, 0, "in-memory path writes nothing");
        // the teeing variant persists the same shards AND returns the
        // same corpus without a read-back
        let (teed_out, teed_corpus) =
            ingest_file_and_load(&input, &dir.join("shards_tee"), &cfg).unwrap();
        assert_eq!(teed_corpus, reloaded);
        assert_eq!(teed_out.stats.shards, sharded.stats.shards);
        assert_eq!(
            Corpus::read_sharded(&dir.join("shards_tee")).unwrap(),
            reloaded
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ingest_publishes_an_atomic_manifest() {
        let dir = tmpdir("manifest");
        let mut text = String::new();
        for i in 0..40 {
            text.push_str(&format!("m{} m{} m{}\n", i % 7, (i + 1) % 7, (i + 2) % 7));
        }
        let input = write_input(&dir, &text);
        let shards = dir.join("shards");
        let out = ingest_file(&input, &shards, &small_cfg()).unwrap();
        let man = ShardManifest::load(&shards).unwrap().expect("manifest written");
        assert!(man.complete, "complete flag written last, set at the end");
        assert_eq!(man.num_shards(), out.stats.shards);
        assert_eq!(man.total_sentences(), out.stats.written_sentences);
        assert_eq!(man.tokens, out.stats.kept_tokens);
        assert!(man.schedule.is_none(), "plain ingest has no schedule block");
        // per-shard counts agree with the files themselves
        for (i, &n) in man.shard_sentences.iter().enumerate() {
            let c = Corpus::read_shard(&shards.join(format!("shard_{i}.bin"))).unwrap();
            assert_eq!(c.len() as u64, n, "manifest count for shard {i}");
        }
        // atomic publication leaves no staging debris behind
        for e in std::fs::read_dir(&shards).unwrap() {
            let name = e.unwrap().file_name().to_string_lossy().to_string();
            assert!(!name.ends_with(".tmp"), "staging debris left behind: {name}");
        }
        // re-ingesting a smaller input replaces the manifest wholesale
        let small_input = dir.join("small.txt");
        std::fs::write(&small_input, "m1 m2 m3\n").unwrap();
        let second = ingest_file(&small_input, &shards, &small_cfg()).unwrap();
        let man2 = ShardManifest::load(&shards).unwrap().unwrap();
        assert_eq!(man2.num_shards(), second.stats.shards);
        assert_eq!(man2.total_sentences(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The overlap contract: the schedule block published before pass 2
    /// must be **bitwise** what a worker computes by streaming the
    /// finished shards through its own PairEstimator — that equality is
    /// what makes overlapped training identical to sequential training.
    #[test]
    fn overlapped_schedule_block_matches_a_post_hoc_shard_pass_bitwise() {
        let dir = tmpdir("overlap_sched");
        let mut rng = Pcg64::new(0x0E7A);
        let mut text = String::new();
        for _ in 0..200 {
            let len = 1 + rng.gen_range_usize(10);
            for _ in 0..len {
                text.push_str(&format!("w{} ", rng.gen_range(30)));
            }
            text.push('\n');
        }
        let input = write_input(&dir, &text);
        let shards = dir.join("shards");
        let mut cfg = small_cfg();
        cfg.min_count = 2;
        let overlap = OverlapOptions::new(5, 1e-3);
        let out = ingest_file_overlapped(&input, &shards, &cfg, &overlap).unwrap();
        assert!(out.stats.schedule_secs > 0.0);
        let man = ShardManifest::load(&shards).unwrap().unwrap();
        let sched = man.schedule.as_ref().expect("overlap publishes a schedule");
        assert_eq!(sched.total_sentences, out.stats.written_sentences);
        assert_eq!(man.total_sentences(), sched.total_sentences);
        // a worker's view: vocab from vocab.tsv, sentences from shards
        let vocab = Vocab::from_tsv(
            &std::fs::read_to_string(shards.join("vocab.tsv")).unwrap(),
        )
        .unwrap();
        let corpus = Corpus::read_sharded(&shards).unwrap();
        let mut scfg = SgnsConfig::default();
        scfg.window = overlap.window;
        scfg.subsample_t = overlap.subsample_t;
        let mut est = PairEstimator::new(&vocab, &scfg);
        for s in &corpus.sentences {
            est.add_sentence(s);
        }
        assert_eq!(
            est.per_epoch().to_bits(),
            sched.per_epoch_pairs.to_bits(),
            "published schedule must equal the streamed recomputation bitwise"
        );
        assert!(sched.per_epoch_pairs > 0.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stats_summary_mentions_the_essentials() {
        let stats = IngestStats {
            bytes: 1_000_000,
            lines: 10,
            sentences: 20,
            written_sentences: 20,
            raw_tokens: 100,
            kept_tokens: 90,
            oov_tokens: 10,
            vocab_size: 7,
            shards: 2,
            pass1_secs: 0.5,
            schedule_secs: 0.0,
            pass2_secs: 0.5,
        };
        let s = stats.summary();
        assert!(s.contains("10 lines"));
        assert!(s.contains("10.00% OOV"));
        assert!(s.contains("vocab 7"));
        assert!((stats.bytes_per_sec() - 1e6).abs() < 1.0);
    }
}
