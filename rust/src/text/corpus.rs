//! Token-id corpus: in-memory store + sharded binary on-disk format.
//!
//! Sentences are `Vec<u32>` over a frozen [`super::vocab::Vocab`]. The
//! binary format is deliberately simple and streaming-friendly:
//!
//! ```text
//! shard file  := MAGIC u32 | VERSION u32 | n_sentences u64 | sentence*
//! sentence    := len u32 | token u32 × len
//! ```
//!
//! Shards let the mapper side of the MapReduce runtime assign contiguous
//! shard ranges to mapper threads (paper: HDFS splits → mappers).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0x6457_3256; // "dW2V"
const VERSION: u32 = 1;

/// In-memory corpus of id-encoded sentences.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Corpus {
    pub sentences: Vec<Vec<u32>>,
}

impl Corpus {
    pub fn new(sentences: Vec<Vec<u32>>) -> Self {
        Self { sentences }
    }

    pub fn len(&self) -> usize {
        self.sentences.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sentences.is_empty()
    }

    pub fn total_tokens(&self) -> u64 {
        self.sentences.iter().map(|s| s.len() as u64).sum()
    }

    /// Contiguous slice of sentences for mapper shard `shard` of `num`.
    pub fn shard_range(&self, shard: usize, num: usize) -> std::ops::Range<usize> {
        let chunk = self.len().div_ceil(num.max(1));
        let lo = (shard * chunk).min(self.len());
        let hi = ((shard + 1) * chunk).min(self.len());
        lo..hi
    }

    /// A sub-corpus restricted to the first `frac` of sentences — used by
    /// the Figure-2 proportion sweep.
    pub fn proportion(&self, frac: f64) -> Corpus {
        let n = ((self.len() as f64) * frac.clamp(0.0, 1.0)).round() as usize;
        Corpus::new(self.sentences[..n].to_vec())
    }

    // ---- binary shard I/O --------------------------------------------------

    pub fn write_shard(&self, path: &Path) -> std::io::Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.sentences.len() as u64).to_le_bytes())?;
        for s in &self.sentences {
            w.write_all(&(s.len() as u32).to_le_bytes())?;
            for &t in s {
                w.write_all(&t.to_le_bytes())?;
            }
        }
        w.flush()
    }

    /// Shard header (magic + version + n_sentences) in bytes.
    const SHARD_HEADER_BYTES: u64 = 4 + 4 + 8;

    pub fn read_shard(path: &Path) -> std::io::Result<Corpus> {
        let reader = Self::stream_shard(path)?;
        // the header check already bounded n against the file length, so
        // the capacity reservation is safe
        let mut sentences = Vec::with_capacity(reader.sentence_count());
        for s in reader {
            sentences.push(s?);
        }
        Ok(Corpus { sentences })
    }

    /// Open a shard file for **streaming**: the header is validated up
    /// front (every size claim checked against the real file length before
    /// any allocation, exactly like [`Self::read_shard`]), then sentences
    /// are yielded one at a time — peak memory is a single sentence, which
    /// is what lets a multi-process training worker iterate a corpus far
    /// larger than its address space.
    pub fn stream_shard(path: &Path) -> std::io::Result<ShardReader> {
        let invalid = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let file = File::open(path)?;
        let file_len = file.metadata()?.len();
        if file_len < Self::SHARD_HEADER_BYTES {
            return Err(invalid(format!(
                "corpus shard {} is {file_len} bytes — shorter than the header",
                path.display()
            )));
        }
        let mut r = BufReader::new(file);
        let magic = read_u32(&mut r)?;
        if magic != MAGIC {
            return Err(invalid(format!(
                "bad magic {magic:#x} in {}",
                path.display()
            )));
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            return Err(invalid(format!("unsupported corpus version {version}")));
        }
        let n = read_u64(&mut r)?;
        let remaining = file_len - Self::SHARD_HEADER_BYTES;
        // each sentence needs at least its 4-byte length prefix
        if n > remaining / 4 {
            return Err(invalid(format!(
                "shard header claims {n} sentences but only {remaining} bytes follow"
            )));
        }
        Ok(ShardReader {
            reader: r,
            remaining,
            total: n as usize,
            yielded: 0,
            done: false,
            path: path.to_path_buf(),
        })
    }

    /// Write the corpus as `num_shards` files `<dir>/shard_<i>.bin`.
    /// Stale `shard_*.bin` leftovers from a previous run are removed
    /// first — [`Self::read_sharded`] globs the whole directory, so a
    /// shorter re-run would otherwise splice the old corpus into the new.
    pub fn write_sharded(&self, dir: &Path, num_shards: usize) -> std::io::Result<Vec<PathBuf>> {
        std::fs::create_dir_all(dir)?;
        remove_stale_shards(dir)?;
        let mut paths = Vec::with_capacity(num_shards);
        for i in 0..num_shards {
            let range = self.shard_range(i, num_shards);
            let sub = Corpus::new(self.sentences[range].to_vec());
            let path = dir.join(format!("shard_{i}.bin"));
            sub.write_shard(&path)?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// Every `shard_*.bin` in a directory as `(numeric index, path)`
    /// pairs, sorted by the **numeric** shard index parsed from the file
    /// stem — `shard_10.bin` sorts after `shard_2.bin`, which a
    /// lexicographic sort would get wrong. The multi-process training
    /// path depends on this order: global sentence indices (and through
    /// them every routing and RNG decision) are assigned by concatenating
    /// shards in exactly this sequence.
    ///
    /// Integrity is enforced, not assumed:
    ///
    /// * a `shard_*.bin` whose stem doesn't parse as an index is a hard
    ///   error (it used to sort last and get spliced into the corpus,
    ///   silently shifting every global sentence index after it);
    /// * two files claiming the same index (`shard_7.bin` +
    ///   `shard_07.bin`) are a hard error (both used to load);
    /// * index **gaps** are surfaced through the returned indices — use
    ///   [`Self::first_shard_gap`] — so callers that require the full
    ///   concatenation ([`Self::read_sharded`], `ShardFileSource`) can
    ///   refuse, while a reader following a still-growing directory can
    ///   distinguish "contiguous prefix" from "hole".
    ///
    /// In-flight `shard_*.bin.tmp` files (the atomic-publication staging
    /// names) are never listed: a half-written shard is invisible until
    /// its rename.
    pub fn shard_entries(dir: &Path) -> std::io::Result<Vec<(usize, PathBuf)>> {
        let invalid = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let mut entries: Vec<(usize, PathBuf)> = Vec::new();
        for e in std::fs::read_dir(dir)? {
            let path = e?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if !(name.starts_with("shard_") && name.ends_with(".bin")) {
                continue; // other files, incl. in-flight `shard_*.bin.tmp`
            }
            let stem = &name["shard_".len()..name.len() - ".bin".len()];
            let idx = stem.parse::<usize>().map_err(|_| {
                invalid(format!(
                    "{}: shard stem {stem:?} is not a numeric shard index — \
                     refusing to guess its position in the corpus",
                    path.display()
                ))
            })?;
            entries.push((idx, path));
        }
        entries.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
        for w in entries.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(invalid(format!(
                    "{} and {} both claim shard index {} — the corpus \
                     concatenation order would be ambiguous",
                    w[0].1.display(),
                    w[1].1.display(),
                    w[0].0
                )));
            }
        }
        Ok(entries)
    }

    /// First missing index in a sorted, duplicate-free shard listing
    /// (shard indices must be exactly `0..n`), or `None` if contiguous.
    pub fn first_shard_gap(entries: &[(usize, PathBuf)]) -> Option<usize> {
        entries
            .iter()
            .enumerate()
            .find(|(i, (idx, _))| *i != *idx)
            .map(|(i, _)| i)
    }

    /// Every `shard_*.bin` in a directory, in shard order — the paths of
    /// [`Self::shard_entries`] with the same integrity errors.
    pub fn shard_files(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
        Ok(Self::shard_entries(dir)?.into_iter().map(|(_, p)| p).collect())
    }

    /// Load every `shard_*.bin` in a directory, in shard order. An index
    /// gap is a hard error: concatenating around a hole would silently
    /// shift the global index of every sentence after it.
    pub fn read_sharded(dir: &Path) -> std::io::Result<Corpus> {
        let entries = Self::shard_entries(dir)?;
        if let Some(gap) = Self::first_shard_gap(&entries) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "shard dir {} is missing shard index {gap} ({} shard files present)",
                    dir.display(),
                    entries.len()
                ),
            ));
        }
        let mut all = Corpus::default();
        for (_, path) in entries {
            all.sentences.extend(Self::read_shard(&path)?.sentences);
        }
        Ok(all)
    }
}

/// Streaming iterator over one shard file's sentences — see
/// [`Corpus::stream_shard`]. Yields `io::Result<Vec<u32>>`; the first
/// error (truncation, oversized sentence claim, trailing bytes) ends the
/// stream.
pub struct ShardReader {
    reader: BufReader<File>,
    /// payload bytes left after the header, per the real file length
    remaining: u64,
    /// sentence count the header claims
    total: usize,
    yielded: usize,
    done: bool,
    path: PathBuf,
}

impl ShardReader {
    /// Number of sentences the (validated) header claims.
    pub fn sentence_count(&self) -> usize {
        self.total
    }

    fn fail(&mut self, msg: String) -> Option<std::io::Result<Vec<u32>>> {
        self.done = true;
        Some(Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            msg,
        )))
    }
}

impl Iterator for ShardReader {
    type Item = std::io::Result<Vec<u32>>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        if self.yielded == self.total {
            self.done = true;
            if self.remaining != 0 {
                let (rem, path) = (self.remaining, self.path.display().to_string());
                return self.fail(format!(
                    "{rem} trailing bytes after the last sentence of {path}"
                ));
            }
            return None;
        }
        let i = self.yielded;
        // every streaming error names the shard file: a multi-shard
        // worker streams dozens of files through one iterator, and an
        // unattributed "unexpected end of file" is undebuggable
        if self.remaining < 4 {
            let path = self.path.display().to_string();
            return self.fail(format!(
                "shard {path} truncated before the length prefix of sentence {i}"
            ));
        }
        let len = match read_u32(&mut self.reader) {
            Ok(l) => l as u64,
            Err(e) => {
                let path = self.path.display().to_string();
                let kind = e.kind();
                self.done = true;
                return Some(Err(std::io::Error::new(
                    kind,
                    format!("shard {path}: reading the length prefix of sentence {i}: {e}"),
                )));
            }
        };
        self.remaining -= 4;
        let body = match len.checked_mul(4).filter(|&b| b <= self.remaining) {
            Some(b) => b,
            None => {
                let rem = self.remaining;
                let path = self.path.display().to_string();
                return self.fail(format!(
                    "sentence {i} of shard {path} claims {len} tokens but only {rem} bytes remain"
                ));
            }
        };
        self.remaining -= body;
        let mut buf = vec![0u8; body as usize];
        if let Err(e) = self.reader.read_exact(&mut buf) {
            let path = self.path.display().to_string();
            let kind = e.kind();
            self.done = true;
            return Some(Err(std::io::Error::new(
                kind,
                format!("shard {path}: reading the {len}-token body of sentence {i}: {e}"),
            )));
        }
        self.yielded += 1;
        Some(Ok(buf
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()))
    }
}

/// Delete every `shard_*.bin` in `dir` (leftovers from a previous
/// sharded write — synthetic or ingested — into the same directory),
/// plus the torn remains of an interrupted atomic publication
/// (`shard_*.bin.tmp`) and any stale `shards.json` manifest: a new
/// corpus must never be read against the previous run's manifest.
pub(crate) fn remove_stale_shards(dir: &Path) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let stale = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| {
                (n.starts_with("shard_") && (n.ends_with(".bin") || n.ends_with(".bin.tmp")))
                    || n == super::feed::MANIFEST_FILE
                    || n == super::feed::MANIFEST_TMP_FILE
            })
            .unwrap_or(false);
        if stale {
            std::fs::remove_file(&path)?;
        }
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Corpus {
        Corpus::new(vec![vec![1, 2, 3], vec![], vec![7], vec![4, 4, 4, 4]])
    }

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dw2v_corpus_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn token_counts() {
        let c = sample();
        assert_eq!(c.len(), 4);
        assert_eq!(c.total_tokens(), 8);
    }

    #[test]
    fn shard_ranges_partition() {
        let c = Corpus::new((0..10).map(|i| vec![i]).collect());
        let mut seen = Vec::new();
        for s in 0..3 {
            seen.extend(c.shard_range(s, 3));
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        // more shards than sentences still partitions
        let mut seen2 = Vec::new();
        for s in 0..20 {
            seen2.extend(c.shard_range(s, 20));
        }
        assert_eq!(seen2, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn proportion_slices() {
        let c = Corpus::new((0..100).map(|i| vec![i]).collect());
        assert_eq!(c.proportion(0.25).len(), 25);
        assert_eq!(c.proportion(1.0).len(), 100);
        assert_eq!(c.proportion(0.0).len(), 0);
    }

    #[test]
    fn single_shard_roundtrip() {
        let dir = tmpdir("single");
        let path = dir.join("x.bin");
        let c = sample();
        c.write_shard(&path).unwrap();
        let back = Corpus::read_shard(&path).unwrap();
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sharded_roundtrip_preserves_order() {
        let dir = tmpdir("sharded");
        let c = Corpus::new((0..57).map(|i| vec![i, i + 1]).collect());
        let paths = c.write_sharded(&dir, 5).unwrap();
        assert_eq!(paths.len(), 5);
        let back = Corpus::read_sharded(&dir).unwrap();
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_sharded_orders_numerically_beyond_ten_shards() {
        // regression: with ≥ 10 shards a lexicographic sort would splice
        // shard_10/shard_11 between shard_1 and shard_2, silently
        // permuting global sentence indices — every downstream routing
        // and per-sentence RNG decision in the multi-process path keys
        // off those indices
        let dir = tmpdir("twelve");
        let c = Corpus::new((0..120).map(|i| vec![i, i + 1000]).collect());
        let paths = c.write_sharded(&dir, 12).unwrap();
        assert_eq!(paths.len(), 12);
        let files = Corpus::shard_files(&dir).unwrap();
        assert_eq!(files, paths, "shard_files must sort by numeric index");
        let back = Corpus::read_sharded(&dir).unwrap();
        assert_eq!(back, c, "12-shard round trip must preserve order");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stream_shard_matches_batch_read() {
        let dir = tmpdir("stream");
        let path = dir.join("s.bin");
        let c = Corpus::new((0..33).map(|i| vec![i; (i as usize % 5) + 1]).collect());
        c.write_shard(&path).unwrap();
        let reader = Corpus::stream_shard(&path).unwrap();
        assert_eq!(reader.sentence_count(), 33);
        let streamed: Vec<Vec<u32>> = reader.map(|s| s.unwrap()).collect();
        assert_eq!(streamed, c.sentences);
        // streaming surfaces trailing garbage as an error mid-iteration
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xCD; 3]);
        std::fs::write(&path, &bytes).unwrap();
        let mut reader = Corpus::stream_shard(&path).unwrap();
        let mut last = None;
        for item in &mut reader {
            last = Some(item);
        }
        assert!(last.unwrap().is_err(), "trailing bytes must surface");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unparseable_shard_stem_is_a_hard_error() {
        // regression: `shard_backup.bin` used to sort last (usize::MAX
        // key) and get spliced into the corpus, shifting every global
        // sentence index after the real shards
        let dir = tmpdir("badstem");
        let c = Corpus::new((0..20).map(|i| vec![i]).collect());
        c.write_sharded(&dir, 2).unwrap();
        sample().write_shard(&dir.join("shard_backup.bin")).unwrap();
        let err = Corpus::shard_files(&dir).unwrap_err();
        assert!(
            err.to_string().contains("shard_backup.bin"),
            "error must name the offending file: {err}"
        );
        let err = Corpus::read_sharded(&dir).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn duplicate_shard_index_is_a_hard_error() {
        // regression: `shard_7.bin` and `shard_07.bin` both parse to
        // index 7 and both used to load, in unspecified relative order
        let dir = tmpdir("dupidx");
        let c = Corpus::new((0..40).map(|i| vec![i]).collect());
        c.write_sharded(&dir, 8).unwrap();
        std::fs::copy(dir.join("shard_7.bin"), dir.join("shard_07.bin")).unwrap();
        let err = Corpus::shard_files(&dir).unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("shard_07.bin") && msg.contains("shard_7.bin") && msg.contains('7'),
            "error must name both claimants: {msg}"
        );
        assert!(Corpus::read_sharded(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_index_gap_is_surfaced_and_fails_full_reads() {
        let dir = tmpdir("gap");
        let c = Corpus::new((0..30).map(|i| vec![i]).collect());
        c.write_sharded(&dir, 5).unwrap();
        std::fs::remove_file(dir.join("shard_2.bin")).unwrap();
        // the listing itself succeeds — a growing-dir reader needs it —
        // but the gap is visible through the indices
        let entries = Corpus::shard_entries(&dir).unwrap();
        assert_eq!(Corpus::first_shard_gap(&entries), Some(2));
        // a full concatenated read must refuse: splicing around the hole
        // would shift the global index of every sentence after it
        let err = Corpus::read_sharded(&dir).unwrap_err();
        assert!(
            err.to_string().contains("missing shard index 2"),
            "gap must be named: {err}"
        );
        // a contiguous prefix (a dir mid-growth) has no gap
        let prefix: Vec<_> = entries.iter().take(2).cloned().collect();
        assert_eq!(Corpus::first_shard_gap(&prefix), None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn inflight_tmp_shards_are_invisible_and_swept() {
        // torn-shard visibility: a `.tmp` staging file (atomic publication
        // in progress, or the debris of a killed writer) must never be
        // listed as corpus content, and a fresh sharded write sweeps it
        let dir = tmpdir("tmpvis");
        let c = Corpus::new((0..12).map(|i| vec![i]).collect());
        c.write_sharded(&dir, 3).unwrap();
        std::fs::write(dir.join("shard_3.bin.tmp"), b"half-written").unwrap();
        let files = Corpus::shard_files(&dir).unwrap();
        assert_eq!(files.len(), 3, "tmp file must be invisible: {files:?}");
        assert_eq!(Corpus::read_sharded(&dir).unwrap(), c);
        // a rewrite removes the debris along with the stale shards
        let small = Corpus::new(vec![vec![9]]);
        small.write_sharded(&dir, 1).unwrap();
        assert!(!dir.join("shard_3.bin.tmp").exists(), "tmp debris must be swept");
        assert_eq!(Corpus::read_sharded(&dir).unwrap(), small);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_middle_shard_errors_name_the_file() {
        // a multi-shard stream must attribute a mid-stream error to the
        // shard it came from, not just say "unexpected end of file"
        let dir = tmpdir("midcorrupt");
        let c = Corpus::new((0..60).map(|i| vec![i, i + 1, i + 2]).collect());
        c.write_sharded(&dir, 4).unwrap();
        let victim = dir.join("shard_2.bin");
        let full = std::fs::read(&victim).unwrap();
        // truncate mid-sentence-body
        std::fs::write(&victim, &full[..full.len() - 6]).unwrap();
        let err = Corpus::read_sharded(&dir).unwrap_err();
        assert!(
            err.to_string().contains("shard_2.bin"),
            "error must name the corrupt shard: {err}"
        );
        // oversized length claim, same attribution requirement
        let mut bytes = full.clone();
        let header = Corpus::SHARD_HEADER_BYTES as usize;
        bytes[header..header + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&victim, &bytes).unwrap();
        let err = Corpus::read_sharded(&dir).unwrap_err();
        assert!(
            err.to_string().contains("shard_2.bin"),
            "oversized-claim error must name the shard: {err}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rewrite_with_fewer_shards_removes_stale_files() {
        let dir = tmpdir("rewrite");
        let big = Corpus::new((0..50).map(|i| vec![i]).collect());
        big.write_sharded(&dir, 8).unwrap();
        let small = Corpus::new((0..6).map(|i| vec![i + 100]).collect());
        let paths = small.write_sharded(&dir, 2).unwrap();
        assert_eq!(paths.len(), 2);
        // no leftovers from the 8-shard run survive the glob
        let back = Corpus::read_sharded(&dir).unwrap();
        assert_eq!(back, small);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_corrupt_files() {
        let dir = tmpdir("corrupt");
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a corpus").unwrap();
        assert!(Corpus::read_shard(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_corpus_roundtrip() {
        let dir = tmpdir("empty");
        let path = dir.join("e.bin");
        let c = Corpus::default();
        c.write_shard(&path).unwrap();
        assert_eq!(Corpus::read_shard(&path).unwrap(), c);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn expect_invalid(path: &Path) {
        let err = Corpus::read_shard(path).unwrap_err();
        assert_eq!(
            err.kind(),
            std::io::ErrorKind::InvalidData,
            "expected InvalidData, got {err:?}"
        );
    }

    #[test]
    fn truncated_shard_is_invalid_data() {
        let dir = tmpdir("trunc");
        let path = dir.join("t.bin");
        let c = Corpus::new(vec![vec![1, 2, 3], vec![4, 5, 6, 7], vec![8]]);
        c.write_shard(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // cut at several points: inside the header, inside a sentence body,
        // inside a later length prefix
        for cut in [3usize, 10, full.len() - 5, full.len() - 1] {
            std::fs::write(&path, &full[..cut]).unwrap();
            expect_invalid(&path);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_version_is_invalid_data() {
        let dir = tmpdir("version");
        let path = dir.join("v.bin");
        sample().write_shard(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99; // version field follows the 4-byte magic
        std::fs::write(&path, &bytes).unwrap();
        expect_invalid(&path);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn huge_sentence_count_fails_before_allocating() {
        let dir = tmpdir("huge_n");
        let path = dir.join("h.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // ~2^64 sentences
        std::fs::write(&path, &bytes).unwrap();
        expect_invalid(&path);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn huge_sentence_length_fails_before_allocating() {
        let dir = tmpdir("huge_len");
        let path = dir.join("l.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // ~4 GiB sentence
        std::fs::write(&path, &bytes).unwrap();
        expect_invalid(&path);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn trailing_garbage_is_invalid_data() {
        let dir = tmpdir("trailing");
        let path = dir.join("g.bin");
        sample().write_shard(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xAB; 7]);
        std::fs::write(&path, &bytes).unwrap();
        expect_invalid(&path);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Property: any corpus (random sentence counts/lengths/tokens,
    /// including empty sentences and an empty corpus) survives a
    /// write → read round trip bit-exactly.
    #[test]
    fn shard_roundtrip_property() {
        use crate::util::rng::Pcg64;
        let dir = tmpdir("prop");
        let path = dir.join("p.bin");
        let mut rng = Pcg64::new(0xC0FF);
        for case in 0..20 {
            let n = rng.gen_range_usize(40);
            let sentences: Vec<Vec<u32>> = (0..n)
                .map(|_| {
                    let len = rng.gen_range_usize(25);
                    (0..len).map(|_| rng.next_u32()).collect()
                })
                .collect();
            let c = Corpus::new(sentences);
            c.write_shard(&path).unwrap();
            let back = Corpus::read_shard(&path).unwrap();
            assert_eq!(back, c, "case {case} failed round trip");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
