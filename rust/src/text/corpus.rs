//! Token-id corpus: in-memory store + sharded binary on-disk format.
//!
//! Sentences are `Vec<u32>` over a frozen [`super::vocab::Vocab`]. The
//! binary format is deliberately simple and streaming-friendly:
//!
//! ```text
//! shard file  := MAGIC u32 | VERSION u32 | n_sentences u64 | sentence*
//! sentence    := len u32 | token u32 × len
//! ```
//!
//! Shards let the mapper side of the MapReduce runtime assign contiguous
//! shard ranges to mapper threads (paper: HDFS splits → mappers).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0x6457_3256; // "dW2V"
const VERSION: u32 = 1;

/// In-memory corpus of id-encoded sentences.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Corpus {
    pub sentences: Vec<Vec<u32>>,
}

impl Corpus {
    pub fn new(sentences: Vec<Vec<u32>>) -> Self {
        Self { sentences }
    }

    pub fn len(&self) -> usize {
        self.sentences.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sentences.is_empty()
    }

    pub fn total_tokens(&self) -> u64 {
        self.sentences.iter().map(|s| s.len() as u64).sum()
    }

    /// Contiguous slice of sentences for mapper shard `shard` of `num`.
    pub fn shard_range(&self, shard: usize, num: usize) -> std::ops::Range<usize> {
        let chunk = self.len().div_ceil(num.max(1));
        let lo = (shard * chunk).min(self.len());
        let hi = ((shard + 1) * chunk).min(self.len());
        lo..hi
    }

    /// A sub-corpus restricted to the first `frac` of sentences — used by
    /// the Figure-2 proportion sweep.
    pub fn proportion(&self, frac: f64) -> Corpus {
        let n = ((self.len() as f64) * frac.clamp(0.0, 1.0)).round() as usize;
        Corpus::new(self.sentences[..n].to_vec())
    }

    // ---- binary shard I/O --------------------------------------------------

    pub fn write_shard(&self, path: &Path) -> std::io::Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.sentences.len() as u64).to_le_bytes())?;
        for s in &self.sentences {
            w.write_all(&(s.len() as u32).to_le_bytes())?;
            for &t in s {
                w.write_all(&t.to_le_bytes())?;
            }
        }
        w.flush()
    }

    /// Shard header (magic + version + n_sentences) in bytes.
    const SHARD_HEADER_BYTES: u64 = 4 + 4 + 8;

    pub fn read_shard(path: &Path) -> std::io::Result<Corpus> {
        let reader = Self::stream_shard(path)?;
        // the header check already bounded n against the file length, so
        // the capacity reservation is safe
        let mut sentences = Vec::with_capacity(reader.sentence_count());
        for s in reader {
            sentences.push(s?);
        }
        Ok(Corpus { sentences })
    }

    /// Open a shard file for **streaming**: the header is validated up
    /// front (every size claim checked against the real file length before
    /// any allocation, exactly like [`Self::read_shard`]), then sentences
    /// are yielded one at a time — peak memory is a single sentence, which
    /// is what lets a multi-process training worker iterate a corpus far
    /// larger than its address space.
    pub fn stream_shard(path: &Path) -> std::io::Result<ShardReader> {
        let invalid = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let file = File::open(path)?;
        let file_len = file.metadata()?.len();
        if file_len < Self::SHARD_HEADER_BYTES {
            return Err(invalid(format!(
                "corpus shard {} is {file_len} bytes — shorter than the header",
                path.display()
            )));
        }
        let mut r = BufReader::new(file);
        let magic = read_u32(&mut r)?;
        if magic != MAGIC {
            return Err(invalid(format!(
                "bad magic {magic:#x} in {}",
                path.display()
            )));
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            return Err(invalid(format!("unsupported corpus version {version}")));
        }
        let n = read_u64(&mut r)?;
        let remaining = file_len - Self::SHARD_HEADER_BYTES;
        // each sentence needs at least its 4-byte length prefix
        if n > remaining / 4 {
            return Err(invalid(format!(
                "shard header claims {n} sentences but only {remaining} bytes follow"
            )));
        }
        Ok(ShardReader {
            reader: r,
            remaining,
            total: n as usize,
            yielded: 0,
            done: false,
            path: path.to_path_buf(),
        })
    }

    /// Write the corpus as `num_shards` files `<dir>/shard_<i>.bin`.
    /// Stale `shard_*.bin` leftovers from a previous run are removed
    /// first — [`Self::read_sharded`] globs the whole directory, so a
    /// shorter re-run would otherwise splice the old corpus into the new.
    pub fn write_sharded(&self, dir: &Path, num_shards: usize) -> std::io::Result<Vec<PathBuf>> {
        std::fs::create_dir_all(dir)?;
        remove_stale_shards(dir)?;
        let mut paths = Vec::with_capacity(num_shards);
        for i in 0..num_shards {
            let range = self.shard_range(i, num_shards);
            let sub = Corpus::new(self.sentences[range].to_vec());
            let path = dir.join(format!("shard_{i}.bin"));
            sub.write_shard(&path)?;
            paths.push(path);
        }
        Ok(paths)
    }

    /// Every `shard_*.bin` in a directory, sorted by the **numeric** shard
    /// index parsed from the file stem — `shard_10.bin` sorts after
    /// `shard_2.bin`, which a lexicographic sort would get wrong. The
    /// multi-process training path depends on this order: global sentence
    /// indices (and through them every routing and RNG decision) are
    /// assigned by concatenating shards in exactly this sequence. Files
    /// whose stem doesn't parse sort last.
    pub fn shard_files(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("shard_") && n.ends_with(".bin"))
                    .unwrap_or(false)
            })
            .collect();
        entries.sort_by_key(|p| {
            p.file_stem()
                .and_then(|s| s.to_str())
                .and_then(|s| s.strip_prefix("shard_"))
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or(usize::MAX)
        });
        Ok(entries)
    }

    /// Load every `shard_*.bin` in a directory, in shard order.
    pub fn read_sharded(dir: &Path) -> std::io::Result<Corpus> {
        let mut all = Corpus::default();
        for path in Self::shard_files(dir)? {
            all.sentences.extend(Self::read_shard(&path)?.sentences);
        }
        Ok(all)
    }
}

/// Streaming iterator over one shard file's sentences — see
/// [`Corpus::stream_shard`]. Yields `io::Result<Vec<u32>>`; the first
/// error (truncation, oversized sentence claim, trailing bytes) ends the
/// stream.
pub struct ShardReader {
    reader: BufReader<File>,
    /// payload bytes left after the header, per the real file length
    remaining: u64,
    /// sentence count the header claims
    total: usize,
    yielded: usize,
    done: bool,
    path: PathBuf,
}

impl ShardReader {
    /// Number of sentences the (validated) header claims.
    pub fn sentence_count(&self) -> usize {
        self.total
    }

    fn fail(&mut self, msg: String) -> Option<std::io::Result<Vec<u32>>> {
        self.done = true;
        Some(Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            msg,
        )))
    }
}

impl Iterator for ShardReader {
    type Item = std::io::Result<Vec<u32>>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        if self.yielded == self.total {
            self.done = true;
            if self.remaining != 0 {
                let (rem, path) = (self.remaining, self.path.display().to_string());
                return self.fail(format!(
                    "{rem} trailing bytes after the last sentence of {path}"
                ));
            }
            return None;
        }
        let i = self.yielded;
        if self.remaining < 4 {
            return self.fail(format!(
                "shard truncated before the length prefix of sentence {i}"
            ));
        }
        let len = match read_u32(&mut self.reader) {
            Ok(l) => l as u64,
            Err(e) => {
                self.done = true;
                return Some(Err(e));
            }
        };
        self.remaining -= 4;
        let body = match len.checked_mul(4).filter(|&b| b <= self.remaining) {
            Some(b) => b,
            None => {
                let rem = self.remaining;
                return self.fail(format!(
                    "sentence {i} claims {len} tokens but only {rem} bytes remain"
                ));
            }
        };
        self.remaining -= body;
        let mut buf = vec![0u8; body as usize];
        if let Err(e) = self.reader.read_exact(&mut buf) {
            self.done = true;
            return Some(Err(e));
        }
        self.yielded += 1;
        Some(Ok(buf
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()))
    }
}

/// Delete every `shard_*.bin` in `dir` (leftovers from a previous
/// sharded write — synthetic or ingested — into the same directory).
pub(crate) fn remove_stale_shards(dir: &Path) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let is_shard = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.starts_with("shard_") && n.ends_with(".bin"))
            .unwrap_or(false);
        if is_shard {
            std::fs::remove_file(&path)?;
        }
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Corpus {
        Corpus::new(vec![vec![1, 2, 3], vec![], vec![7], vec![4, 4, 4, 4]])
    }

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dw2v_corpus_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn token_counts() {
        let c = sample();
        assert_eq!(c.len(), 4);
        assert_eq!(c.total_tokens(), 8);
    }

    #[test]
    fn shard_ranges_partition() {
        let c = Corpus::new((0..10).map(|i| vec![i]).collect());
        let mut seen = Vec::new();
        for s in 0..3 {
            seen.extend(c.shard_range(s, 3));
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        // more shards than sentences still partitions
        let mut seen2 = Vec::new();
        for s in 0..20 {
            seen2.extend(c.shard_range(s, 20));
        }
        assert_eq!(seen2, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn proportion_slices() {
        let c = Corpus::new((0..100).map(|i| vec![i]).collect());
        assert_eq!(c.proportion(0.25).len(), 25);
        assert_eq!(c.proportion(1.0).len(), 100);
        assert_eq!(c.proportion(0.0).len(), 0);
    }

    #[test]
    fn single_shard_roundtrip() {
        let dir = tmpdir("single");
        let path = dir.join("x.bin");
        let c = sample();
        c.write_shard(&path).unwrap();
        let back = Corpus::read_shard(&path).unwrap();
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sharded_roundtrip_preserves_order() {
        let dir = tmpdir("sharded");
        let c = Corpus::new((0..57).map(|i| vec![i, i + 1]).collect());
        let paths = c.write_sharded(&dir, 5).unwrap();
        assert_eq!(paths.len(), 5);
        let back = Corpus::read_sharded(&dir).unwrap();
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn read_sharded_orders_numerically_beyond_ten_shards() {
        // regression: with ≥ 10 shards a lexicographic sort would splice
        // shard_10/shard_11 between shard_1 and shard_2, silently
        // permuting global sentence indices — every downstream routing
        // and per-sentence RNG decision in the multi-process path keys
        // off those indices
        let dir = tmpdir("twelve");
        let c = Corpus::new((0..120).map(|i| vec![i, i + 1000]).collect());
        let paths = c.write_sharded(&dir, 12).unwrap();
        assert_eq!(paths.len(), 12);
        let files = Corpus::shard_files(&dir).unwrap();
        assert_eq!(files, paths, "shard_files must sort by numeric index");
        let back = Corpus::read_sharded(&dir).unwrap();
        assert_eq!(back, c, "12-shard round trip must preserve order");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stream_shard_matches_batch_read() {
        let dir = tmpdir("stream");
        let path = dir.join("s.bin");
        let c = Corpus::new((0..33).map(|i| vec![i; (i as usize % 5) + 1]).collect());
        c.write_shard(&path).unwrap();
        let reader = Corpus::stream_shard(&path).unwrap();
        assert_eq!(reader.sentence_count(), 33);
        let streamed: Vec<Vec<u32>> = reader.map(|s| s.unwrap()).collect();
        assert_eq!(streamed, c.sentences);
        // streaming surfaces trailing garbage as an error mid-iteration
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xCD; 3]);
        std::fs::write(&path, &bytes).unwrap();
        let mut reader = Corpus::stream_shard(&path).unwrap();
        let mut last = None;
        for item in &mut reader {
            last = Some(item);
        }
        assert!(last.unwrap().is_err(), "trailing bytes must surface");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rewrite_with_fewer_shards_removes_stale_files() {
        let dir = tmpdir("rewrite");
        let big = Corpus::new((0..50).map(|i| vec![i]).collect());
        big.write_sharded(&dir, 8).unwrap();
        let small = Corpus::new((0..6).map(|i| vec![i + 100]).collect());
        let paths = small.write_sharded(&dir, 2).unwrap();
        assert_eq!(paths.len(), 2);
        // no leftovers from the 8-shard run survive the glob
        let back = Corpus::read_sharded(&dir).unwrap();
        assert_eq!(back, small);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_corrupt_files() {
        let dir = tmpdir("corrupt");
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a corpus").unwrap();
        assert!(Corpus::read_shard(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_corpus_roundtrip() {
        let dir = tmpdir("empty");
        let path = dir.join("e.bin");
        let c = Corpus::default();
        c.write_shard(&path).unwrap();
        assert_eq!(Corpus::read_shard(&path).unwrap(), c);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn expect_invalid(path: &Path) {
        let err = Corpus::read_shard(path).unwrap_err();
        assert_eq!(
            err.kind(),
            std::io::ErrorKind::InvalidData,
            "expected InvalidData, got {err:?}"
        );
    }

    #[test]
    fn truncated_shard_is_invalid_data() {
        let dir = tmpdir("trunc");
        let path = dir.join("t.bin");
        let c = Corpus::new(vec![vec![1, 2, 3], vec![4, 5, 6, 7], vec![8]]);
        c.write_shard(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // cut at several points: inside the header, inside a sentence body,
        // inside a later length prefix
        for cut in [3usize, 10, full.len() - 5, full.len() - 1] {
            std::fs::write(&path, &full[..cut]).unwrap();
            expect_invalid(&path);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_version_is_invalid_data() {
        let dir = tmpdir("version");
        let path = dir.join("v.bin");
        sample().write_shard(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99; // version field follows the 4-byte magic
        std::fs::write(&path, &bytes).unwrap();
        expect_invalid(&path);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn huge_sentence_count_fails_before_allocating() {
        let dir = tmpdir("huge_n");
        let path = dir.join("h.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // ~2^64 sentences
        std::fs::write(&path, &bytes).unwrap();
        expect_invalid(&path);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn huge_sentence_length_fails_before_allocating() {
        let dir = tmpdir("huge_len");
        let path = dir.join("l.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // ~4 GiB sentence
        std::fs::write(&path, &bytes).unwrap();
        expect_invalid(&path);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn trailing_garbage_is_invalid_data() {
        let dir = tmpdir("trailing");
        let path = dir.join("g.bin");
        sample().write_shard(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xAB; 7]);
        std::fs::write(&path, &bytes).unwrap();
        expect_invalid(&path);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Property: any corpus (random sentence counts/lengths/tokens,
    /// including empty sentences and an empty corpus) survives a
    /// write → read round trip bit-exactly.
    #[test]
    fn shard_roundtrip_property() {
        use crate::util::rng::Pcg64;
        let dir = tmpdir("prop");
        let path = dir.join("p.bin");
        let mut rng = Pcg64::new(0xC0FF);
        for case in 0..20 {
            let n = rng.gen_range_usize(40);
            let sentences: Vec<Vec<u32>> = (0..n)
                .map(|_| {
                    let len = rng.gen_range_usize(25);
                    (0..len).map(|_| rng.next_u32()).collect()
                })
                .collect();
            let c = Corpus::new(sentences);
            c.write_shard(&path).unwrap();
            let back = Corpus::read_shard(&path).unwrap();
            assert_eq!(back, c, "case {case} failed round trip");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
