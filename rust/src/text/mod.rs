//! Text pipeline: tokenization, vocabulary, token-id corpus storage, and
//! streaming raw-text ingestion ([`ingest`]: raw file → vocab + binary
//! corpus shards, the paper's preprocess step). [`feed`] is the reader
//! side of ingest/training overlap: an atomically-published shard
//! manifest plus a `RoundSource` that follows a still-growing shard dir.
pub mod corpus;
pub mod feed;
pub mod ingest;
pub mod tokenize;
pub mod vocab;
