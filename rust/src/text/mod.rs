//! Text pipeline: tokenization, vocabulary, token-id corpus storage.
pub mod corpus;
pub mod tokenize;
pub mod vocab;
