//! Text pipeline: tokenization, vocabulary, token-id corpus storage, and
//! streaming raw-text ingestion ([`ingest`]: raw file → vocab + binary
//! corpus shards, the paper's preprocess step).
pub mod corpus;
pub mod ingest;
pub mod tokenize;
pub mod vocab;
