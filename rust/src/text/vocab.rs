//! Vocabulary: word ↔ id mapping, frequency statistics, subsampling.
//!
//! Mirrors word2vec/Gensim semantics: words are ranked by corpus frequency,
//! the vocabulary is capped to the most frequent `max_size` words above
//! `min_count`, and frequent-word subsampling uses the word2vec keep
//! probability `(sqrt(f/t) + 1) · t/f`.

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Vocab {
    words: Vec<String>,
    counts: Vec<u64>,
    index: HashMap<String, u32>,
    total_tokens: u64,
    retained_tokens: u64,
}

/// Incremental counter used before freezing into a `Vocab`.
#[derive(Default, Clone, Debug)]
pub struct VocabBuilder {
    counts: HashMap<String, u64>,
    total: u64,
}

impl VocabBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_token(&mut self, token: &str) {
        *self.counts.entry(token.to_string()).or_insert(0) += 1;
        self.total += 1;
    }

    pub fn add_sentence<S: AsRef<str>>(&mut self, tokens: &[S]) {
        for t in tokens {
            self.add_token(t.as_ref());
        }
    }

    /// Merge another builder's counts into this one (mapper-side partials).
    pub fn merge(&mut self, other: VocabBuilder) {
        for (w, c) in other.counts {
            *self.counts.entry(w).or_insert(0) += c;
        }
        self.total += other.total;
    }

    /// Freeze: keep words with count ≥ `min_count`, capped at the
    /// `max_size` most frequent; ids are assigned by descending frequency
    /// (ties broken lexicographically for determinism).
    pub fn build(self, min_count: u64, max_size: usize) -> Vocab {
        let mut entries: Vec<(String, u64)> = self
            .counts
            .into_iter()
            .filter(|(_, c)| *c >= min_count)
            .collect();
        entries.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        entries.truncate(max_size);
        let mut words = Vec::with_capacity(entries.len());
        let mut counts = Vec::with_capacity(entries.len());
        let mut index = HashMap::with_capacity(entries.len());
        let mut retained = 0u64;
        for (i, (w, c)) in entries.into_iter().enumerate() {
            index.insert(w.clone(), i as u32);
            words.push(w);
            counts.push(c);
            retained += c;
        }
        Vocab {
            words,
            counts,
            index,
            total_tokens: self.total,
            retained_tokens: retained,
        }
    }
}

impl Vocab {
    /// Build preserving the given id order (no frequency re-ranking). Used
    /// by the synthetic generator, where corpus token ids must stay
    /// identical to generator word ids.
    pub fn from_ordered(pairs: Vec<(String, u64)>) -> Self {
        let mut words = Vec::with_capacity(pairs.len());
        let mut counts = Vec::with_capacity(pairs.len());
        let mut index = HashMap::with_capacity(pairs.len());
        let mut total = 0;
        for (i, (w, c)) in pairs.into_iter().enumerate() {
            index.insert(w.clone(), i as u32);
            words.push(w);
            counts.push(c);
            total += c;
        }
        Vocab {
            words,
            counts,
            index,
            total_tokens: total,
            retained_tokens: total,
        }
    }

    /// Build directly from known (word, count) pairs — used by the synthetic
    /// generator where words are just `w<id>`.
    pub fn from_counts(pairs: Vec<(String, u64)>) -> Self {
        let mut b = VocabBuilder::new();
        for (w, c) in &pairs {
            b.counts.insert(w.clone(), *c);
            b.total += *c;
        }
        b.build(1, usize::MAX)
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn id(&self, word: &str) -> Option<u32> {
        self.index.get(word).copied()
    }

    pub fn word(&self, id: u32) -> &str {
        &self.words[id as usize]
    }

    pub fn count(&self, id: u32) -> u64 {
        self.counts[id as usize]
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total tokens seen at build time, **including** the mass of words
    /// later dropped by `min_count`/`max_size`. This is corpus size, not
    /// trainable mass — use [`Self::retained_tokens`] for the latter.
    pub fn total_tokens(&self) -> u64 {
        self.total_tokens
    }

    /// Token mass retained in the vocabulary after `min_count`/`max_size`
    /// filtering — word2vec's `train_words`, the denominator for anything
    /// that reasons about *trainable* tokens (subsampling, lr schedules,
    /// OOV rates).
    pub fn retained_tokens(&self) -> u64 {
        self.retained_tokens
    }

    /// In-vocabulary token mass (alias for [`Self::retained_tokens`]).
    pub fn in_vocab_tokens(&self) -> u64 {
        self.retained_tokens
    }

    /// Unigram probability of an in-vocab word (relative to in-vocab mass).
    pub fn unigram_prob(&self, id: u32) -> f64 {
        self.counts[id as usize] as f64 / self.retained_tokens.max(1) as f64
    }

    /// word2vec keep-probability for frequent-word subsampling with
    /// threshold `t`; returns 1.0 for rare words.
    pub fn keep_probability(&self, id: u32, t: f64) -> f64 {
        if t <= 0.0 {
            return 1.0;
        }
        let f = self.unigram_prob(id);
        if f <= t {
            return 1.0;
        }
        ((t / f).sqrt() + t / f).min(1.0)
    }

    /// Map a tokenized sentence to ids, dropping OOV tokens.
    pub fn encode<S: AsRef<str>>(&self, tokens: &[S]) -> Vec<u32> {
        tokens
            .iter()
            .filter_map(|t| self.id(t.as_ref()))
            .collect()
    }

    /// Serialize as TSV lines `word<TAB>count`.
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        for (w, c) in self.words.iter().zip(&self.counts) {
            out.push_str(w);
            out.push('\t');
            out.push_str(&c.to_string());
            out.push('\n');
        }
        out
    }

    /// Parse [`Self::to_tsv`] output. **Line order is id order**: `to_tsv`
    /// writes words by ascending id, and any corpus/embedding persisted
    /// next to a `vocab.tsv` is encoded against those ids — re-ranking by
    /// frequency here (as this used to do) silently remapped every token
    /// of a reloaded corpus whenever the original vocab wasn't already
    /// frequency-sorted (the synthetic generator's, for one, is ordered by
    /// generator id). The multi-process training workers and `dw2v serve
    /// --vocab` both rely on this round trip being id-exact.
    pub fn from_tsv(text: &str) -> Result<Self, String> {
        let mut pairs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let (w, c) = line
                .split_once('\t')
                .ok_or_else(|| format!("line {}: missing tab", lineno + 1))?;
            let count: u64 = c
                .parse()
                .map_err(|_| format!("line {}: bad count '{c}'", lineno + 1))?;
            pairs.push((w.to_string(), count));
        }
        Ok(Self::from_ordered(pairs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_vocab() -> Vocab {
        let mut b = VocabBuilder::new();
        for (w, n) in [("the", 50), ("cat", 10), ("sat", 10), ("rare", 1)] {
            for _ in 0..n {
                b.add_token(w);
            }
        }
        b.build(1, usize::MAX)
    }

    #[test]
    fn ids_ordered_by_frequency() {
        let v = sample_vocab();
        assert_eq!(v.word(0), "the");
        assert_eq!(v.count(0), 50);
        // ties broken lexicographically: cat before sat
        assert_eq!(v.word(1), "cat");
        assert_eq!(v.word(2), "sat");
        assert_eq!(v.id("rare"), Some(3));
    }

    #[test]
    fn min_count_and_cap() {
        let mut b = VocabBuilder::new();
        for (w, n) in [("a", 5), ("b", 4), ("c", 3), ("d", 1)] {
            for _ in 0..n {
                b.add_token(w);
            }
        }
        let v = b.clone().build(3, usize::MAX);
        assert_eq!(v.len(), 3);
        assert_eq!(v.id("d"), None);
        let capped = b.build(1, 2);
        assert_eq!(capped.len(), 2);
        assert_eq!(capped.word(0), "a");
    }

    #[test]
    fn encode_drops_oov() {
        let v = sample_vocab();
        let ids = v.encode(&["the", "unknown", "cat"]);
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn keep_probability_penalizes_frequent_words() {
        let v = sample_vocab();
        let p_the = v.keep_probability(0, 1e-2);
        let p_rare = v.keep_probability(3, 1e-2);
        assert!(p_the < 1.0);
        assert_eq!(p_rare, 1.0);
        assert!(p_the > 0.0);
    }

    #[test]
    fn keep_probability_disabled_with_zero_threshold() {
        let v = sample_vocab();
        assert_eq!(v.keep_probability(0, 0.0), 1.0);
    }

    #[test]
    fn merge_combines_counts() {
        let mut a = VocabBuilder::new();
        a.add_sentence(&["x", "y"]);
        let mut b = VocabBuilder::new();
        b.add_sentence(&["y", "z"]);
        a.merge(b);
        let v = a.build(1, usize::MAX);
        assert_eq!(v.count(v.id("y").unwrap()), 2);
        assert_eq!(v.len(), 3);
        assert_eq!(v.total_tokens(), 4);
    }

    #[test]
    fn tsv_roundtrip() {
        let v = sample_vocab();
        let v2 = Vocab::from_tsv(&v.to_tsv()).unwrap();
        assert_eq!(v2.len(), v.len());
        for i in 0..v.len() as u32 {
            assert_eq!(v2.word(i), v.word(i));
            assert_eq!(v2.count(i), v.count(i));
        }
    }

    #[test]
    fn tsv_roundtrip_preserves_non_frequency_id_order() {
        // the synthetic generator's vocab is ordered by generator id, not
        // frequency, and counts can tie with lexicographic order
        // disagreeing with id order ("w12" < "w7" as strings) — a
        // frequency re-rank on load would swap ids and silently corrupt
        // every corpus/embedding encoded against them
        let v = Vocab::from_ordered(vec![
            ("w7".to_string(), 5),
            ("w12".to_string(), 5),
            ("rare".to_string(), 9),
        ]);
        let back = Vocab::from_tsv(&v.to_tsv()).unwrap();
        for i in 0..v.len() as u32 {
            assert_eq!(back.word(i), v.word(i), "id {i} must survive the tsv round trip");
            assert_eq!(back.count(i), v.count(i));
        }
        assert_eq!(back.id("w7"), Some(0));
        assert_eq!(back.retained_tokens(), v.retained_tokens());
    }

    #[test]
    fn tsv_rejects_malformed() {
        assert!(Vocab::from_tsv("word_without_tab").is_err());
        assert!(Vocab::from_tsv("w\tnotanumber").is_err());
    }

    #[test]
    fn total_vs_retained_tokens() {
        let mut b = VocabBuilder::new();
        for (w, n) in [("a", 6), ("b", 4), ("c", 2), ("d", 1)] {
            for _ in 0..n {
                b.add_token(w);
            }
        }
        // no filtering: both accessors agree
        let full = b.clone().build(1, usize::MAX);
        assert_eq!(full.total_tokens(), 13);
        assert_eq!(full.retained_tokens(), 13);
        // min_count drops d's mass from retained but not from total
        let filtered = b.clone().build(2, usize::MAX);
        assert_eq!(filtered.total_tokens(), 13);
        assert_eq!(filtered.retained_tokens(), 12);
        assert_eq!(filtered.in_vocab_tokens(), 12);
        // max_size cap drops the tail's mass too
        let capped = b.build(1, 2);
        assert_eq!(capped.total_tokens(), 13);
        assert_eq!(capped.retained_tokens(), 10);
    }

    #[test]
    fn unigram_prob_uses_retained_mass() {
        let mut b = VocabBuilder::new();
        for (w, n) in [("a", 8), ("b", 2), ("rare", 1)] {
            for _ in 0..n {
                b.add_token(w);
            }
        }
        let v = b.build(2, usize::MAX); // drops "rare"
        // probabilities are relative to the 10 retained tokens, not 11
        assert!((v.unigram_prob(0) - 0.8).abs() < 1e-12);
        assert!((v.unigram_prob(1) - 0.2).abs() < 1e-12);
        let total: f64 = (0..v.len() as u32).map(|i| v.unigram_prob(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unigram_probs_sum_to_one() {
        let v = sample_vocab();
        let total: f64 = (0..v.len() as u32).map(|i| v.unigram_prob(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }
}
