//! Growing-shard-dir corpus feed: the reader side of ingest/training
//! overlap.
//!
//! ## The publication protocol
//!
//! Ingest pass 2 ([`super::ingest`]) publishes every spilled shard with
//! the artifact idiom — write `shard_<i>.bin.tmp`, fsync-free rename to
//! `shard_<i>.bin` — and then atomically rewrites a `shards.json`
//! manifest describing everything published so far. The ordering is the
//! contract: **the shard file is renamed into place before its manifest
//! row appears**, so a manifest that lists shard `i` guarantees
//! `shard_<i>.bin` is complete and readable. A reader therefore never
//! globs the directory (where it could race a rename or meet `.tmp`
//! debris); it follows the manifest.
//!
//! ## Manifest format (`shards.json`)
//!
//! ```json
//! {
//!   "version": 1,
//!   "complete": false,
//!   "shards": 3,
//!   "shard_sentences": [4000, 4200, 4145],
//!   "sentences": 12345,
//!   "tokens": 456789,
//!   "schedule": {
//!     "total_sentences": 52000,
//!     "per_epoch_pairs": 812345.25,
//!     "per_epoch_pairs_bits": "4741671816371830784",
//!     "window": 5,
//!     "subsample_t": 0.0001,
//!     "subsample_t_bits": "4547007122018943789"
//!   }
//! }
//! ```
//!
//! * `complete: false` distinguishes "shard 7 not written **yet**" from
//!   "shard 7 missing" — the integrity gap [`Corpus::shard_entries`]
//!   surfaces is only an error once the manifest is complete.
//! * `shard_sentences` carries per-shard sentence counts so any reader
//!   can compute the global-index base of shard `i` (the prefix sum)
//!   without opening the earlier files — global sentence indices are
//!   assigned by shard-index concatenation exactly as
//!   [`crate::coordinator::mapper::ShardFileSource`] assigns them over a
//!   finished directory.
//! * The optional `schedule` block is written by an overlapped ingest
//!   **before pass 2 starts** (after a dedicated schedule pass over the
//!   encoded stream): the total sentence count and the exact
//!   [`crate::sgns::schedule::PairEstimator`] per-epoch sum, f64 bits
//!   preserved via the `_bits` fields. Because that estimator is a plain
//!   sequential sum in sentence order, the value is **bitwise identical**
//!   to what a worker would compute by streaming the finished shards —
//!   which is what lets a worker start gradient updates on `shard_0.bin`
//!   while ingest is still writing `shard_40.bin`, and still finish
//!   bitwise identical to a back-to-back run.
//!
//! ## The feed
//!
//! [`ShardFeed`] is a [`RoundSource`] over a (possibly still growing)
//! shard directory: it yields shard `i`'s sentences as soon as the
//! manifest lists shard `i`, polls while the next index is unpublished
//! (invoking an optional wait hook each poll — the training worker
//! beacons a `waiting` phase from it so the supervisor sees liveness),
//! and terminates when the manifest is complete and every listed shard
//! has been streamed. Mid-stream errors latch like `ShardFileSource`'s
//! (`RoundSource` iterators cannot carry errors); callers must check
//! [`ShardFeed::take_error`] after the run.

use crate::exec::mapreduce::RoundSource;
use crate::text::corpus::Corpus;
use crate::util::json::{arr, inum, num, obj, s, Json};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Manifest file name inside a shard directory.
pub const MANIFEST_FILE: &str = "shards.json";
/// Staging name for the atomic manifest rewrite.
pub const MANIFEST_TMP_FILE: &str = "shards.json.tmp";
const MANIFEST_VERSION: usize = 1;

/// The lr-schedule inputs an overlapped ingest publishes ahead of the
/// shards: everything a training worker needs *before its first gradient*
/// that normally requires a pass over the finished corpus.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleBlock {
    /// total sentences pass 2 will write (the divider's corpus length)
    pub total_sentences: u64,
    /// exact `PairEstimator::per_epoch()` over the encoded stream
    pub per_epoch_pairs: f64,
    /// the SGNS window the estimate was computed under
    pub window: usize,
    /// the subsampling threshold the estimate was computed under
    pub subsample_t: f64,
}

impl ScheduleBlock {
    fn to_json(&self) -> Json {
        obj(vec![
            ("total_sentences", inum(self.total_sentences)),
            ("per_epoch_pairs", num(self.per_epoch_pairs)),
            (
                "per_epoch_pairs_bits",
                s(&self.per_epoch_pairs.to_bits().to_string()),
            ),
            ("window", inum(self.window)),
            ("subsample_t", num(self.subsample_t)),
            ("subsample_t_bits", s(&self.subsample_t.to_bits().to_string())),
        ])
    }

    fn from_json(v: &Json) -> Result<Self, String> {
        let bits_f64 = |key: &str| -> Result<f64, String> {
            let text = v
                .get(key)
                .as_str()
                .ok_or_else(|| format!("schedule block lacks {key}"))?;
            text.parse::<u64>()
                .map(f64::from_bits)
                .map_err(|_| format!("schedule {key} {text:?} is not a u64 bit pattern"))
        };
        Ok(Self {
            total_sentences: v
                .get("total_sentences")
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .ok_or("schedule block lacks total_sentences")? as u64,
            per_epoch_pairs: bits_f64("per_epoch_pairs_bits")?,
            window: v
                .get("window")
                .as_usize()
                .ok_or("schedule block lacks window")?,
            subsample_t: bits_f64("subsample_t_bits")?,
        })
    }
}

/// The `shards.json` manifest: what ingest has published so far.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardManifest {
    /// set (last) once pass 2 has published every shard
    pub complete: bool,
    /// sentences in each published shard, in shard-index order
    pub shard_sentences: Vec<u64>,
    /// kept tokens across all published shards
    pub tokens: u64,
    /// lr-schedule inputs, present only for an overlapped ingest
    pub schedule: Option<ScheduleBlock>,
}

impl ShardManifest {
    /// Shards published so far.
    pub fn num_shards(&self) -> usize {
        self.shard_sentences.len()
    }

    /// Sentences across all published shards.
    pub fn total_sentences(&self) -> u64 {
        self.shard_sentences.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("version", inum(MANIFEST_VERSION)),
            ("complete", Json::Bool(self.complete)),
            ("shards", inum(self.num_shards())),
            (
                "shard_sentences",
                arr(self.shard_sentences.iter().map(|&n| inum(n)).collect()),
            ),
            ("sentences", inum(self.total_sentences())),
            ("tokens", inum(self.tokens)),
        ];
        if let Some(sched) = &self.schedule {
            fields.push(("schedule", sched.to_json()));
        }
        obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let version = v.get("version").as_usize().ok_or("manifest lacks version")?;
        if version != MANIFEST_VERSION {
            return Err(format!("unsupported manifest version {version}"));
        }
        let shard_sentences: Vec<u64> = v
            .get("shard_sentences")
            .as_arr()
            .ok_or("manifest lacks shard_sentences")?
            .iter()
            .map(|j| {
                j.as_f64()
                    .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                    .map(|n| n as u64)
                    .ok_or_else(|| format!("bad shard_sentences entry {j}"))
            })
            .collect::<Result<_, _>>()?;
        let claimed = v.get("shards").as_usize().ok_or("manifest lacks shards")?;
        if claimed != shard_sentences.len() {
            return Err(format!(
                "manifest claims {claimed} shards but lists {} sentence counts",
                shard_sentences.len()
            ));
        }
        let schedule = match v.get("schedule") {
            Json::Null => None,
            sched => Some(ScheduleBlock::from_json(sched)?),
        };
        Ok(Self {
            complete: v.get("complete").as_bool().ok_or("manifest lacks complete")?,
            shard_sentences,
            tokens: v
                .get("tokens")
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .ok_or("manifest lacks tokens")? as u64,
            schedule,
        })
    }

    /// Read `dir/shards.json`. `Ok(None)` means the manifest does not
    /// exist (yet) — a reader distinguishing "not written" from
    /// "missing". A manifest that exists but does not parse is a hard
    /// error: publication is atomic, so a torn manifest is impossible and
    /// garbage means real corruption.
    pub fn load(dir: &Path) -> Result<Option<Self>, String> {
        let path = dir.join(MANIFEST_FILE);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format!("read {}: {e}", path.display())),
        };
        let v = Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
        Self::from_json(&v).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Atomically (re)write `dir/shards.json`: write the staging file,
    /// rename into place. A reader observes either the previous manifest
    /// or this one, never a prefix.
    pub fn publish(&self, dir: &Path) -> Result<(), String> {
        let tmp = dir.join(MANIFEST_TMP_FILE);
        let path = dir.join(MANIFEST_FILE);
        std::fs::write(&tmp, self.to_json().to_string_pretty())
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path).map_err(|e| format!("publish {}: {e}", path.display()))
    }
}

/// Poll cadence and progress deadline for a [`ShardFeed`] (and for
/// [`wait_for_schedule`]).
#[derive(Clone, Debug)]
pub struct FeedOptions {
    /// sleep between manifest polls while the next shard is unpublished
    pub poll: Duration,
    /// give up if the manifest makes **no progress** for this long — the
    /// clock resets every time a new shard (or the complete flag)
    /// appears, so a slow ingest is fine but a dead one is an error, not
    /// a hang
    pub timeout: Duration,
}

impl Default for FeedOptions {
    fn default() -> Self {
        Self {
            poll: Duration::from_millis(25),
            timeout: Duration::from_secs(300),
        }
    }
}

/// Counters a feed keeps about its own history, for tests and logs.
#[derive(Clone, Copy, Debug, Default)]
pub struct FeedStats {
    /// shards the manifest listed when the feed was opened — under
    /// overlap this is how many shards existed "at training start"
    pub shards_at_open: usize,
    /// manifest polls that found the next shard still unpublished
    pub waits: u64,
    /// total wall-clock spent parked in those polls — how long training
    /// was actually blocked on ingest, for the run report
    pub wait_secs: f64,
}

/// Called on every poll while the feed is blocked on an unpublished
/// shard: `(shard index awaited, shards published so far)`. The training
/// worker installs a hook that publishes a `waiting` beacon, keeping the
/// supervisor's stall detector happy while ingest catches up.
pub type WaitHook = Box<dyn Fn(usize, usize) + Send + Sync>;

/// A [`RoundSource`] over a growing shard directory — see the module
/// docs. Yields `(global sentence index, sentence)` exactly like
/// `ShardFileSource` does over a finished directory, so Divider routing
/// and per-sentence RNG are identical between the overlapped and
/// sequential paths.
pub struct ShardFeed {
    dir: PathBuf,
    opts: FeedOptions,
    error: Mutex<Option<String>>,
    wait_hook: Option<WaitHook>,
    stats: Mutex<FeedStats>,
}

impl ShardFeed {
    /// Open a feed over `dir`. The manifest must already exist (an
    /// overlapped coordinator waits for the schedule block before
    /// spawning workers, which implies the manifest); shards may not.
    pub fn open(dir: &Path, opts: FeedOptions) -> Result<Self, String> {
        let man = ShardManifest::load(dir)?.ok_or_else(|| {
            format!(
                "no {MANIFEST_FILE} in {} — not a published shard dir",
                dir.display()
            )
        })?;
        let feed = Self {
            dir: dir.to_path_buf(),
            opts,
            error: Mutex::new(None),
            wait_hook: None,
            stats: Mutex::new(FeedStats {
                shards_at_open: man.num_shards(),
                waits: 0,
                wait_secs: 0.0,
            }),
        };
        Ok(feed)
    }

    /// Install the poll-time hook (see [`WaitHook`]).
    pub fn set_wait_hook(&mut self, hook: WaitHook) {
        self.wait_hook = Some(hook);
    }

    pub fn stats(&self) -> FeedStats {
        *self.stats.lock().unwrap()
    }

    /// Take the first streaming error latched during iteration, if any.
    pub fn take_error(&self) -> Option<String> {
        self.error.lock().unwrap().take()
    }

    fn latch_error(&self, msg: String) {
        let mut slot = self.error.lock().unwrap();
        if slot.is_none() {
            *slot = Some(msg);
        }
    }

    /// Block until the manifest lists shard `f` (or is complete with
    /// fewer shards). Returns the refreshed manifest, or `None` after a
    /// latched error / progress timeout.
    fn wait_for_shard(&self, f: usize, mut man: ShardManifest) -> Option<ShardManifest> {
        let mut last_progress = Instant::now();
        let mut last_shards = man.num_shards();
        loop {
            if man.num_shards() > f || man.complete {
                return Some(man);
            }
            if last_progress.elapsed() > self.opts.timeout {
                self.latch_error(format!(
                    "timed out after {:.0}s waiting for shard_{f}.bin in {} \
                     ({} shards published, manifest not complete) — ingest dead?",
                    self.opts.timeout.as_secs_f64(),
                    self.dir.display(),
                    man.num_shards()
                ));
                return None;
            }
            if let Some(hook) = &self.wait_hook {
                hook(f, man.num_shards());
            }
            {
                let mut st = self.stats.lock().unwrap();
                st.waits += 1;
                st.wait_secs += self.opts.poll.as_secs_f64();
            }
            std::thread::sleep(self.opts.poll);
            man = match ShardManifest::load(&self.dir) {
                Ok(Some(m)) => m,
                Ok(None) => {
                    self.latch_error(format!(
                        "{MANIFEST_FILE} vanished from {} mid-feed",
                        self.dir.display()
                    ));
                    return None;
                }
                Err(e) => {
                    self.latch_error(e);
                    return None;
                }
            };
            if man.num_shards() != last_shards || man.complete {
                last_shards = man.num_shards();
                last_progress = Instant::now();
            }
        }
    }

    /// Stream the published shard `f` (base = global index of its first
    /// sentence), latching errors; cross-checks the header against the
    /// manifest's sentence count.
    fn stream_file(
        &self,
        f: usize,
        base: usize,
        expect_sentences: u64,
    ) -> impl Iterator<Item = (usize, Vec<u32>)> + '_ {
        let path = self.dir.join(format!("shard_{f}.bin"));
        let mut reader = match Corpus::stream_shard(&path) {
            Ok(r) => {
                if reader_count_matches(&r, expect_sentences) {
                    Some(r)
                } else {
                    self.latch_error(format!(
                        "shard {} holds {} sentences but the manifest recorded {} — \
                         shard dir inconsistent",
                        path.display(),
                        r.sentence_count(),
                        expect_sentences
                    ));
                    None
                }
            }
            Err(e) => {
                self.latch_error(format!("open shard {}: {e}", path.display()));
                None
            }
        };
        let mut local = 0usize;
        std::iter::from_fn(move || {
            let r = reader.as_mut()?;
            match r.next() {
                Some(Ok(sentence)) => {
                    let idx = base + local;
                    local += 1;
                    Some((idx, sentence))
                }
                Some(Err(e)) => {
                    self.latch_error(format!("stream shard: {e}"));
                    reader = None;
                    None
                }
                None => None,
            }
        })
    }
}

fn reader_count_matches(r: &crate::text::corpus::ShardReader, expect: u64) -> bool {
    r.sentence_count() as u64 == expect
}

impl RoundSource for ShardFeed {
    type Item = (usize, Vec<u32>);

    /// Mapper `shard` of `num_shards` streams the shard files whose index
    /// `≡ shard (mod num_shards)` — round-robin, because the total file
    /// count is unknown while the directory is still growing. Global
    /// sentence indices come from the manifest's per-shard counts, so
    /// every mapper agrees on them without opening the files it skips.
    fn shard(
        &self,
        _round: usize,
        shard: usize,
        num_shards: usize,
    ) -> Box<dyn Iterator<Item = (usize, Vec<u32>)> + '_> {
        let stride = num_shards.max(1);
        let mine = shard;
        let mut man = match ShardManifest::load(&self.dir) {
            Ok(Some(m)) => Some(m),
            Ok(None) => {
                self.latch_error(format!(
                    "{MANIFEST_FILE} vanished from {} mid-feed",
                    self.dir.display()
                ));
                None
            }
            Err(e) => {
                self.latch_error(e);
                None
            }
        };
        let mut f = 0usize; // next file index to visit
        let mut base = 0usize; // global index of file f's first sentence
        let mut current: Option<Box<dyn Iterator<Item = (usize, Vec<u32>)> + '_>> = None;
        Box::new(std::iter::from_fn(move || loop {
            if let Some(it) = current.as_mut() {
                match it.next() {
                    Some(item) => return Some(item),
                    None => current = None,
                }
                continue;
            }
            let m = man.as_ref()?;
            if f >= m.num_shards() {
                if m.complete {
                    return None; // every published shard streamed
                }
                man = self.wait_for_shard(f, man.take().unwrap());
                continue;
            }
            let n = man.as_ref().unwrap().shard_sentences[f];
            let this_base = base;
            base += n as usize;
            let this_f = f;
            f += 1;
            if this_f % stride == mine {
                current = Some(Box::new(self.stream_file(this_f, this_base, n)));
            }
        }))
    }
}

/// Poll `dir` until its manifest carries a schedule block (an overlapped
/// ingest writes it after the vocabulary freeze, before pass 2), calling
/// `on_poll` each round. The progress deadline follows
/// [`FeedOptions::timeout`] semantics.
pub fn wait_for_schedule(
    dir: &Path,
    opts: &FeedOptions,
    mut on_poll: impl FnMut(),
) -> Result<(ShardManifest, ScheduleBlock), String> {
    let start = Instant::now();
    loop {
        if let Some(man) = ShardManifest::load(dir)? {
            if let Some(sched) = man.schedule.clone() {
                return Ok((man, sched));
            }
        }
        if start.elapsed() > opts.timeout {
            return Err(format!(
                "timed out after {:.0}s waiting for a schedule block in {}/{MANIFEST_FILE} \
                 — is an overlapped ingest actually running?",
                opts.timeout.as_secs_f64(),
                dir.display()
            ));
        }
        on_poll();
        std::thread::sleep(opts.poll);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("dw2v_feed_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Publish `c` into `dir` the way an overlapped ingest does: per-shard
    /// tmp→rename, manifest row after each shard, `complete` last.
    fn publish_incrementally(c: &Corpus, dir: &Path, num_shards: usize) {
        let mut man = ShardManifest::default();
        for i in 0..num_shards {
            let range = c.shard_range(i, num_shards);
            let sub = Corpus::new(c.sentences[range].to_vec());
            let tmp = dir.join(format!("shard_{i}.bin.tmp"));
            sub.write_shard(&tmp).unwrap();
            std::fs::rename(&tmp, dir.join(format!("shard_{i}.bin"))).unwrap();
            man.tokens += sub.total_tokens();
            man.shard_sentences.push(sub.len() as u64);
            man.publish(dir).unwrap();
        }
        man.complete = true;
        man.publish(dir).unwrap();
    }

    fn sample(n: usize) -> Corpus {
        Corpus::new((0..n as u32).map(|i| vec![i, i + 1, i + 2]).collect())
    }

    #[test]
    fn manifest_roundtrip_preserves_f64_bits() {
        let dir = tmpdir("roundtrip");
        let man = ShardManifest {
            complete: false,
            shard_sentences: vec![10, 0, 7],
            tokens: 12345,
            schedule: Some(ScheduleBlock {
                total_sentences: 999,
                // a value with a non-terminating decimal expansion: the
                // display field would round, the bits field must not
                per_epoch_pairs: 0.1f64 + 0.2f64,
                window: 5,
                subsample_t: 1e-4,
            }),
        };
        man.publish(&dir).unwrap();
        let back = ShardManifest::load(&dir).unwrap().expect("manifest exists");
        assert_eq!(back, man);
        let (a, b) = (
            back.schedule.as_ref().unwrap().per_epoch_pairs,
            man.schedule.as_ref().unwrap().per_epoch_pairs,
        );
        assert_eq!(a.to_bits(), b.to_bits(), "f64 bits must round-trip exactly");
        assert!(!dir.join(MANIFEST_TMP_FILE).exists(), "publication is atomic");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_is_none_but_garbage_is_an_error() {
        let dir = tmpdir("absent");
        assert!(ShardManifest::load(&dir).unwrap().is_none());
        std::fs::write(dir.join(MANIFEST_FILE), "{ torn").unwrap();
        assert!(ShardManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn feed_over_complete_dir_matches_concatenated_indices() {
        let dir = tmpdir("complete");
        let c = sample(57);
        publish_incrementally(&c, &dir, 5);
        let feed = ShardFeed::open(&dir, FeedOptions::default()).unwrap();
        let all: Vec<(usize, Vec<u32>)> = feed.shard(0, 0, 1).collect();
        assert!(feed.take_error().is_none());
        assert_eq!(all.len(), 57);
        for (i, (idx, sent)) in all.iter().enumerate() {
            assert_eq!(*idx, i, "global indices must be the shard concatenation");
            assert_eq!(sent, &c.sentences[i]);
        }
        // round-robin partitioning over 3 mappers covers the same items
        let mut union: Vec<(usize, Vec<u32>)> =
            (0..3).flat_map(|m| feed.shard(0, m, 3)).collect();
        union.sort_by_key(|(i, _)| *i);
        assert_eq!(union, all);
        assert!(feed.take_error().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn feed_follows_a_growing_dir_and_never_sees_tmp_files() {
        let dir = tmpdir("growing");
        let c = sample(60);
        let num_shards = 6;
        // first two shards exist up front; a writer thread publishes the
        // rest with delays, leaving a torn `.tmp` visible the whole time
        let head = Corpus::new(c.sentences[..20].to_vec());
        publish_incrementally(&head, &dir, 2);
        let mut man = ShardManifest::load(&dir).unwrap().unwrap();
        man.complete = false;
        man.publish(&dir).unwrap();
        std::fs::write(dir.join("shard_9.bin.tmp"), b"torn forever").unwrap();

        let mut feed = ShardFeed::open(
            &dir,
            FeedOptions {
                poll: Duration::from_millis(5),
                timeout: Duration::from_secs(30),
            },
        )
        .unwrap();
        let waited = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let waited2 = std::sync::Arc::clone(&waited);
        feed.set_wait_hook(Box::new(move |_f, _published| {
            waited2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }));

        let dir2 = dir.clone();
        let tail: Vec<Vec<u32>> = c.sentences[20..].to_vec();
        let writer = std::thread::spawn(move || {
            let mut man = ShardManifest::load(&dir2).unwrap().unwrap();
            for (k, chunk) in tail.chunks(10).enumerate() {
                std::thread::sleep(Duration::from_millis(30));
                let i = 2 + k;
                let sub = Corpus::new(chunk.to_vec());
                let tmp = dir2.join(format!("shard_{i}.bin.tmp"));
                sub.write_shard(&tmp).unwrap();
                std::fs::rename(&tmp, dir2.join(format!("shard_{i}.bin"))).unwrap();
                man.tokens += sub.total_tokens();
                man.shard_sentences.push(sub.len() as u64);
                man.publish(&dir2).unwrap();
            }
            man.complete = true;
            man.publish(&dir2).unwrap();
        });

        let all: Vec<(usize, Vec<u32>)> = feed.shard(0, 0, 1).collect();
        writer.join().unwrap();
        assert!(feed.take_error().is_none());
        assert_eq!(all.len(), 60);
        for (i, (idx, sent)) in all.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(sent, &c.sentences[i]);
        }
        let stats = feed.stats();
        assert_eq!(stats.shards_at_open, 2, "feed opened before the dir finished");
        assert!(stats.waits > 0, "feed must actually have waited");
        assert!(
            waited.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "wait hook must fire while blocked"
        );
        assert_eq!(ShardManifest::load(&dir).unwrap().unwrap().num_shards(), num_shards);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn feed_times_out_when_ingest_dies() {
        let dir = tmpdir("timeout");
        let c = sample(20);
        publish_incrementally(&c, &dir, 2);
        // manifest stuck incomplete: the producer "died"
        let mut man = ShardManifest::load(&dir).unwrap().unwrap();
        man.complete = false;
        man.publish(&dir).unwrap();
        let feed = ShardFeed::open(
            &dir,
            FeedOptions {
                poll: Duration::from_millis(5),
                timeout: Duration::from_millis(60),
            },
        )
        .unwrap();
        let got: Vec<(usize, Vec<u32>)> = feed.shard(0, 0, 1).collect();
        assert_eq!(got.len(), 20, "published shards still stream");
        let err = feed.take_error().expect("timeout must latch an error");
        assert!(err.contains("timed out"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn feed_detects_manifest_shard_disagreement() {
        let dir = tmpdir("mismatch");
        let c = sample(30);
        publish_incrementally(&c, &dir, 3);
        let mut man = ShardManifest::load(&dir).unwrap().unwrap();
        man.shard_sentences[1] += 1; // lie about shard 1
        man.publish(&dir).unwrap();
        let feed = ShardFeed::open(&dir, FeedOptions::default()).unwrap();
        let _: Vec<(usize, Vec<u32>)> = feed.shard(0, 0, 1).collect();
        let err = feed.take_error().expect("mismatch must latch");
        assert!(err.contains("manifest"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wait_for_schedule_times_out_without_overlap_ingest() {
        let dir = tmpdir("nosched");
        let c = sample(10);
        publish_incrementally(&c, &dir, 1); // manifest without a schedule
        let opts = FeedOptions {
            poll: Duration::from_millis(5),
            timeout: Duration::from_millis(50),
        };
        let mut polls = 0u32;
        let err = wait_for_schedule(&dir, &opts, || polls += 1).unwrap_err();
        assert!(err.contains("schedule block"), "{err}");
        assert!(polls > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
