//! Shared hot-path vector kernels.
//!
//! Every inner loop that matters in this repo — the Hogwild SGNS pair
//! step, cosine/nearest evaluation, and the merge-phase linalg — reduces
//! over contiguous rows. This module is the single place those loops live.
//!
//! ## The auto-vectorization contract
//!
//! The kernels are plain safe Rust written so that LLVM reliably emits
//! SIMD without any `std::arch` intrinsics:
//!
//! * **Chunked accumulator lanes.** A single-accumulator float reduction
//!   (`acc += a[i] * b[i]`) cannot be vectorized: float addition is not
//!   associative and LLVM must preserve the sequential rounding order.
//!   Splitting the stream into [`LANES`]-wide chunks with one independent
//!   accumulator per lane makes the reassociation explicit in the source,
//!   so the loop body becomes a pure SIMD multiply-add at any opt level
//!   that vectorizes.
//! * **`chunks_exact` + a scalar tail.** `chunks_exact` hands LLVM a
//!   constant trip count per chunk and eliminates bounds checks, which is
//!   what actually unlocks the vector codegen; the sub-`LANES` remainder
//!   runs scalar.
//! * **No explicit `std::arch` (yet).** The portable form already reaches
//!   memory-bandwidth-bound throughput on the row lengths we care about
//!   (d = 32–320) and stays correct on every target. If a future target
//!   needs wider lanes or FMA contraction, add a `cfg`-gated intrinsic
//!   path *behind the same function signatures* and extend the parity
//!   tests — callers must never care.
//!
//! Each vectorized kernel has a scalar reference twin in [`scalar`]; the
//! parity tests assert agreement within 1e-5 across odd lengths including
//! the remainder-lane cases (1, 7, 15) — if you touch a kernel, those
//! tests are the contract.
//!
//! Verify the speedup with `cargo bench --bench perf_hotpath` (the
//! `kernel dot` row reports scalar vs vectorized throughput; results land
//! in `bench_results/perf_hotpath.json`).

pub mod scalar;
pub mod sigmoid;

pub use sigmoid::SigmoidTable;

/// Accumulator width of the chunked loops. 8 × f32 = one AVX2 register;
/// on narrower targets LLVM splits the lanes, on wider ones it fuses
/// iterations — the value only has to be a small power of two.
pub const LANES: usize = 8;

/// Vectorized dot product ⟨a, b⟩.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let main = a.len() - a.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for (ca, cb) in a[..main]
        .chunks_exact(LANES)
        .zip(b[..main].chunks_exact(LANES))
    {
        for l in 0..LANES {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for (x, y) in a[main..].iter().zip(&b[main..]) {
        sum += x * y;
    }
    sum
}

/// Widening dot product: f32 rows, f64 accumulation. The eval paths
/// (cosine, nearest) score in f64 — same contract as the pre-kernel
/// implementation — so near-tie neighbour ranks don't shift with row
/// length; the f64 lanes still vectorize (half the width of [`dot`]).
#[inline]
pub fn dot_wide(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let main = a.len() - a.len() % LANES;
    let mut acc = [0.0f64; LANES];
    for (ca, cb) in a[..main]
        .chunks_exact(LANES)
        .zip(b[..main].chunks_exact(LANES))
    {
        for l in 0..LANES {
            acc[l] += ca[l] as f64 * cb[l] as f64;
        }
    }
    let mut sum: f64 = acc.iter().sum();
    for (x, y) in a[main..].iter().zip(&b[main..]) {
        sum += *x as f64 * *y as f64;
    }
    sum
}

/// Widening squared L2 norm: f32 row, f64 accumulation (see [`dot_wide`]).
#[inline]
pub fn norm_sq_wide(a: &[f32]) -> f64 {
    let main = a.len() - a.len() % LANES;
    let mut acc = [0.0f64; LANES];
    for ca in a[..main].chunks_exact(LANES) {
        for l in 0..LANES {
            acc[l] += ca[l] as f64 * ca[l] as f64;
        }
    }
    let mut sum: f64 = acc.iter().sum();
    for x in &a[main..] {
        sum += *x as f64 * *x as f64;
    }
    sum
}

/// Vectorized squared L2 norm ⟨a, a⟩.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    let main = a.len() - a.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for ca in a[..main].chunks_exact(LANES) {
        for l in 0..LANES {
            acc[l] += ca[l] * ca[l];
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for x in &a[main..] {
        sum += x * x;
    }
    sum
}

/// Vectorized y ← y + α·x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let main = x.len() - x.len() % LANES;
    let (xm, xt) = x.split_at(main);
    let (ym, yt) = y.split_at_mut(main);
    for (cy, cx) in ym.chunks_exact_mut(LANES).zip(xm.chunks_exact(LANES)) {
        for l in 0..LANES {
            cy[l] += alpha * cx[l];
        }
    }
    for (yv, xv) in yt.iter_mut().zip(xt) {
        *yv += alpha * xv;
    }
}

/// Vectorized out ← a + α·b (written, not accumulated).
#[inline]
pub fn scaled_add(out: &mut [f32], a: &[f32], b: &[f32], alpha: f32) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x + alpha * y;
    }
}

/// Vectorized y ← s·y.
#[inline]
pub fn scale(y: &mut [f32], s: f32) {
    for v in y {
        *v *= s;
    }
}

/// The fused SGNS pair-step tail: given gradient scale `g`,
/// `neu ← neu + g·c` (using c's pre-update values) and `c ← c + g·w`,
/// in one pass over the three rows.
#[inline]
pub fn dual_axpy(g: f32, w: &[f32], c: &mut [f32], neu: &mut [f32]) {
    debug_assert_eq!(w.len(), c.len());
    debug_assert_eq!(w.len(), neu.len());
    let main = w.len() - w.len() % LANES;
    let (wm, wt) = w.split_at(main);
    let (cm, ct) = c.split_at_mut(main);
    let (nm, nt) = neu.split_at_mut(main);
    for ((cc, cn), cw) in cm
        .chunks_exact_mut(LANES)
        .zip(nm.chunks_exact_mut(LANES))
        .zip(wm.chunks_exact(LANES))
    {
        for l in 0..LANES {
            let cv = cc[l];
            cn[l] += g * cv;
            cc[l] = cv + g * cw[l];
        }
    }
    for ((cv, nv), wv) in ct.iter_mut().zip(nt.iter_mut()).zip(wt) {
        let c_old = *cv;
        *nv += g * c_old;
        *cv = c_old + g * wv;
    }
}

/// The full fused SGNS pair step for one (word, context, label) triple:
/// dot → sigmoid → gradient → dual row update. Returns the raw dot
/// product so the caller can derive the monitoring loss without a second
/// pass.
#[inline]
pub fn dot_sigmoid_update(
    w: &[f32],
    c: &mut [f32],
    neu: &mut [f32],
    label: f32,
    lr: f32,
    sigmoid: &SigmoidTable,
) -> f32 {
    let x = dot(w, c);
    let g = (label - sigmoid.get(x)) * lr;
    dual_axpy(g, w, c, neu);
    x
}

/// Vectorized int8-dequantizing dot product: Σ codes[i]·q[i].
///
/// The distance hot path of the serving layer's quantized row store
/// (`serve::quant`): rows live as int8 codes with one f32 scale per row,
/// and the query stays f32, so the reduction widens each code to f32 in
/// the lane loop. The caller multiplies the result by the row scale — one
/// multiply per row instead of one per element.
#[inline]
pub fn dot_i8_dequant(codes: &[i8], q: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), q.len());
    let main = codes.len() - codes.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for (cc, cq) in codes[..main]
        .chunks_exact(LANES)
        .zip(q[..main].chunks_exact(LANES))
    {
        for l in 0..LANES {
            acc[l] += cc[l] as f32 * cq[l];
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for (c, x) in codes[main..].iter().zip(&q[main..]) {
        sum += *c as f32 * x;
    }
    sum
}

// ---------------------------------------------------------------- f64 ----
// The merge-phase linalg (`linalg::mat`) reduces in f64; same contract.

/// Vectorized f64 dot product.
#[inline]
pub fn dot64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let main = a.len() - a.len() % LANES;
    let mut acc = [0.0f64; LANES];
    for (ca, cb) in a[..main]
        .chunks_exact(LANES)
        .zip(b[..main].chunks_exact(LANES))
    {
        for l in 0..LANES {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut sum: f64 = acc.iter().sum();
    for (x, y) in a[main..].iter().zip(&b[main..]) {
        sum += x * y;
    }
    sum
}

/// Vectorized f64 squared L2 norm.
#[inline]
pub fn norm_sq64(a: &[f64]) -> f64 {
    let main = a.len() - a.len() % LANES;
    let mut acc = [0.0f64; LANES];
    for ca in a[..main].chunks_exact(LANES) {
        for l in 0..LANES {
            acc[l] += ca[l] * ca[l];
        }
    }
    let mut sum: f64 = acc.iter().sum();
    for x in &a[main..] {
        sum += x * x;
    }
    sum
}

/// Vectorized f64 y ← y + α·x — the SAXPY inside the cache-blocked matmul.
#[inline]
pub fn axpy64(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let main = x.len() - x.len() % LANES;
    let (xm, xt) = x.split_at(main);
    let (ym, yt) = y.split_at_mut(main);
    for (cy, cx) in ym.chunks_exact_mut(LANES).zip(xm.chunks_exact(LANES)) {
        for l in 0..LANES {
            cy[l] += alpha * cx[l];
        }
    }
    for (yv, xv) in yt.iter_mut().zip(xt) {
        *yv += alpha * xv;
    }
}

/// Vectorized f64 y ← s·y.
#[inline]
pub fn scale64(y: &mut [f64], s: f64) {
    for v in y {
        *v *= s;
    }
}

/// Widen an f32 row into an f64 row (merge-boundary conversion).
#[inline]
pub fn widen(dst: &mut [f64], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s as f64;
    }
}

/// Narrow an f64 row back to f32 (merge-boundary conversion).
#[inline]
pub fn narrow(dst: &mut [f32], src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// The satellite parity contract: odd lengths exercise every
    /// remainder-lane path (1 and 7 are pure tail, 15 is one chunk + tail,
    /// 64 is exact chunks, 300 is the realistic row length).
    const PARITY_LENS: [usize; 5] = [1, 7, 15, 64, 300];

    fn rand_vec(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gen_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn dot_matches_scalar_reference() {
        let mut rng = Pcg64::new(41);
        for n in PARITY_LENS {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let fast = dot(&a, &b);
            let slow = scalar::dot(&a, &b);
            assert!(
                (fast - slow).abs() < 1e-5,
                "dot parity n={n}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn wide_kernels_match_sequential_f64_accumulation() {
        let mut rng = Pcg64::new(48);
        for n in PARITY_LENS {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let naive_dot: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            assert!(
                (dot_wide(&a, &b) - naive_dot).abs() < 1e-10,
                "dot_wide parity n={n}"
            );
            let naive_norm: f64 = a.iter().map(|x| *x as f64 * *x as f64).sum();
            assert!(
                (norm_sq_wide(&a) - naive_norm).abs() < 1e-10,
                "norm_sq_wide parity n={n}"
            );
        }
    }

    #[test]
    fn norm_sq_matches_scalar_reference() {
        let mut rng = Pcg64::new(42);
        for n in PARITY_LENS {
            let a = rand_vec(&mut rng, n);
            let fast = norm_sq(&a);
            let slow = scalar::norm_sq(&a);
            assert!(
                (fast - slow).abs() < 1e-5,
                "norm_sq parity n={n}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn axpy_matches_scalar_reference() {
        let mut rng = Pcg64::new(43);
        for n in PARITY_LENS {
            let x = rand_vec(&mut rng, n);
            let mut y1 = rand_vec(&mut rng, n);
            let mut y2 = y1.clone();
            axpy(0.37, &x, &mut y1);
            scalar::axpy(0.37, &x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-5, "axpy parity n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn scaled_add_and_scale_match_reference() {
        let mut rng = Pcg64::new(44);
        for n in PARITY_LENS {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let mut out = vec![0.0f32; n];
            scaled_add(&mut out, &a, &b, -1.5);
            for i in 0..n {
                assert!((out[i] - (a[i] - 1.5 * b[i])).abs() < 1e-5);
            }
            let mut s1 = a.clone();
            scale(&mut s1, 0.25);
            for i in 0..n {
                assert!((s1[i] - a[i] * 0.25).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn dual_axpy_matches_sequential_pair_loop() {
        let mut rng = Pcg64::new(45);
        for n in PARITY_LENS {
            let w = rand_vec(&mut rng, n);
            let mut c1 = rand_vec(&mut rng, n);
            let mut c2 = c1.clone();
            let mut n1 = rand_vec(&mut rng, n);
            let mut n2 = n1.clone();
            let g = 0.05f32;
            dual_axpy(g, &w, &mut c1, &mut n1);
            // the original hogwild inner loop, verbatim
            for k in 0..n {
                n2[k] += g * c2[k];
                c2[k] += g * w[k];
            }
            for k in 0..n {
                assert!((c1[k] - c2[k]).abs() < 1e-5, "c parity n={n} k={k}");
                assert!((n1[k] - n2[k]).abs() < 1e-5, "neu parity n={n} k={k}");
            }
        }
    }

    #[test]
    fn dot_i8_dequant_matches_scalar_reference() {
        let mut rng = Pcg64::new(47);
        for n in PARITY_LENS {
            let codes: Vec<i8> =
                (0..n).map(|_| (rng.gen_range(255) as i64 - 127) as i8).collect();
            let q = rand_vec(&mut rng, n);
            let fast = dot_i8_dequant(&codes, &q);
            let slow = scalar::dot_i8_dequant(&codes, &q);
            // codes span ±127, so partial sums are ~100× larger than the
            // f32 parity kernels' — scale the reassociation tolerance
            assert!(
                (fast - slow).abs() < 1e-2 + slow.abs() * 1e-4,
                "dot_i8_dequant parity n={n}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn f64_kernels_match_scalar_reference() {
        let mut rng = Pcg64::new(46);
        for n in PARITY_LENS {
            let a: Vec<f64> = (0..n).map(|_| rng.gen_gauss()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.gen_gauss()).collect();
            let naive_dot: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot64(&a, &b) - naive_dot).abs() < 1e-10);
            let naive_norm: f64 = a.iter().map(|x| x * x).sum();
            assert!((norm_sq64(&a) - naive_norm).abs() < 1e-10);
            let mut y1 = b.clone();
            axpy64(0.71, &a, &mut y1);
            for i in 0..n {
                assert!((y1[i] - (b[i] + 0.71 * a[i])).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn widen_narrow_roundtrip() {
        let src = vec![1.5f32, -2.25, 0.0, 3.125];
        let mut wide = vec![0.0f64; 4];
        widen(&mut wide, &src);
        assert_eq!(wide, vec![1.5, -2.25, 0.0, 3.125]);
        let mut back = vec![0.0f32; 4];
        narrow(&mut back, &wide);
        assert_eq!(back, src);
    }

    #[test]
    fn dot_sigmoid_update_moves_rows_toward_label() {
        let table = SigmoidTable::new();
        let w = vec![0.1f32; 16];
        let mut c = vec![0.1f32; 16];
        let mut neu = vec![0.0f32; 16];
        // label 1 with small positive dot: gradient must push c toward w
        let x = dot_sigmoid_update(&w, &mut c, &mut neu, 1.0, 0.5, &table);
        assert!((x - 16.0 * 0.01).abs() < 1e-4);
        assert!(c.iter().all(|&v| v > 0.1), "positive pair must grow c");
        assert!(neu.iter().all(|&v| v > 0.0), "neu accumulates the w-update");
    }
}
