//! Scalar reference implementations of the vectorized kernels.
//!
//! These are the ground truth the parity suite checks `super`'s chunked
//! kernels against, and the "before" side of the `perf_hotpath` kernel
//! rows. Single sequential accumulator, element-at-a-time — exactly the
//! shape LLVM must *not* reassociate, so they stay scalar at every opt
//! level and preserve the seed implementation's rounding order.

/// Sequential dot product — the loop the Hogwild trainer shipped with.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Sequential squared L2 norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in a {
        acc += x * x;
    }
    acc
}

/// Sequential y ← y + α·x.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Sequential int8-dequantizing dot product: Σ codes[i]·q[i], one widening
/// multiply-add at a time (the caller applies the per-row scale).
#[inline]
pub fn dot_i8_dequant(codes: &[i8], q: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), q.len());
    let mut acc = 0.0f32;
    for i in 0..codes.len() {
        acc += codes[i] as f32 * q[i];
    }
    acc
}
