//! word2vec-style sigmoid lookup table, shared by every CPU trainer.
//!
//! Two accuracy upgrades over the original 1024-slot nearest-entry table:
//! 4096 entries over [-CLAMP, CLAMP] and linear interpolation between
//! adjacent slots. Max error drops from ~3e-3 (nearest slot at 1024
//! entries) to ~1e-7 (lerp error is O(h²·σ″) with slot width
//! h = 12/4095), so the table is no longer a visible noise source in the
//! gradient while the lookup stays two loads + one fma.

/// Number of table slots.
pub const SIGMOID_TABLE_SIZE: usize = 4096;
/// Inputs beyond ±CLAMP saturate to 1/0 exactly, like word2vec's expTable.
pub const SIGMOID_CLAMP: f32 = 6.0;

/// Interpolated sigmoid lookup table over [-CLAMP, CLAMP].
pub struct SigmoidTable {
    table: Vec<f32>,
}

impl SigmoidTable {
    pub fn new() -> Self {
        // slot i sits exactly at x_i = (i/(N-1)·2 − 1)·CLAMP, so the
        // interpolation below is anchored on exact function values
        let table = (0..SIGMOID_TABLE_SIZE)
            .map(|i| {
                let x = (i as f32 / (SIGMOID_TABLE_SIZE - 1) as f32 * 2.0 - 1.0) * SIGMOID_CLAMP;
                1.0 / (1.0 + (-x).exp())
            })
            .collect();
        Self { table }
    }

    /// σ(x) via clamped, linearly interpolated table lookup.
    #[inline]
    pub fn get(&self, x: f32) -> f32 {
        if x >= SIGMOID_CLAMP {
            return 1.0;
        }
        if x <= -SIGMOID_CLAMP {
            return 0.0;
        }
        let pos = (x + SIGMOID_CLAMP) / (2.0 * SIGMOID_CLAMP) * (SIGMOID_TABLE_SIZE - 1) as f32;
        let idx = pos as usize;
        let frac = pos - idx as f32;
        let lo = self.table[idx];
        let hi = self.table[(idx + 1).min(SIGMOID_TABLE_SIZE - 1)];
        lo + (hi - lo) * frac
    }
}

impl Default for SigmoidTable {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_table_accuracy() {
        let t = SigmoidTable::new();
        // dense sweep over the whole representable range plus the exact
        // values the old nearest-slot test used
        let mut xs: Vec<f32> = (-590..=590).map(|i| i as f32 / 100.0).collect();
        xs.extend([-5.0f32, -1.0, -0.1, 0.0, 0.1, 1.0, 5.0]);
        for x in xs {
            let exact = 1.0 / (1.0 + (-x).exp());
            assert!(
                (t.get(x) - exact).abs() < 0.002,
                "x={x}: table {} exact {exact}",
                t.get(x)
            );
        }
        assert_eq!(t.get(100.0), 1.0);
        assert_eq!(t.get(-100.0), 0.0);
        assert_eq!(t.get(SIGMOID_CLAMP), 1.0);
        assert_eq!(t.get(-SIGMOID_CLAMP), 0.0);
    }

    #[test]
    fn interpolation_is_monotone_and_symmetric() {
        let t = SigmoidTable::new();
        let mut prev = -1.0f32;
        for i in -600..=600 {
            let x = i as f32 / 100.0;
            let v = t.get(x);
            assert!(v >= prev, "sigmoid must be monotone at x={x}");
            prev = v;
            // σ(x) + σ(−x) = 1 up to table rounding
            assert!((v + t.get(-x) - 1.0).abs() < 1e-5, "symmetry at x={x}");
        }
    }
}
