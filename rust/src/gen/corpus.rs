//! Synthetic corpus generator — the substitute for the paper's Wikipedia
//! (14 GB) and Web (268 GB) corpora (see DESIGN.md §3 Substitutions).
//!
//! The generator plants a *ground-truth semantic geometry* and emits a
//! corpus whose unigram and bigram distributions carry it, which is exactly
//! the property the paper's Hypothesis 1 (via Levy–Goldberg) relies on:
//!
//! * every word `w` has a ground-truth vector `g_w = ĉ(cluster(w)) + δ_w`
//!   — a cluster center plus a word-specific identity component;
//! * the unigram distribution is Zipf with configurable exponent (word id
//!   = frequency rank), matching natural-language marginals;
//! * sentences are cluster random-walks: consecutive words come from the
//!   same or a geometrically-close cluster (transition ∝ exp(ĉ_i·ĉ_j/τ)),
//!   so the bigram distribution encodes cluster geometry;
//! * within a cluster, word choice is biased by a per-sentence style
//!   vector against `δ_w`, making the identity component observable from
//!   co-occurrence too.
//!
//! SGNS trained on such a corpus recovers an embedding whose similarity
//! structure correlates with `g`, which is what the gold benchmarks in
//! [`super::benchmarks`] score against.

use crate::text::corpus::Corpus;
use crate::text::vocab::Vocab;
use crate::util::rng::Pcg64;

/// Parameters of the generative model.
#[derive(Clone, Debug)]
pub struct GeneratorConfig {
    pub vocab: usize,
    pub clusters: usize,
    pub truth_dim: usize,
    pub zipf_exponent: f64,
    pub avg_sentence_len: usize,
    /// probability of staying in the current cluster between tokens
    pub stay_prob: f64,
    /// temperature of the cluster-transition softmax
    pub transition_temp: f64,
    /// scale of the word identity component δ relative to the unit centers
    pub identity_scale: f64,
    /// strength of the style-vector bias on within-cluster word choice
    pub style_strength: f64,
    /// sentences per document — consecutive sentences share a document
    /// anchor cluster, and anchors drift across the corpus. This is the
    /// topical locality of real corpora (Wikipedia articles) that makes
    /// EqualPartitioning's sequential chunks distributionally skewed
    /// (Figure 1's whole point). 0 disables document structure.
    pub doc_sentences: usize,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self {
            vocab: 2000,
            clusters: 40,
            truth_dim: 16,
            zipf_exponent: 1.0,
            avg_sentence_len: 18,
            stay_prob: 0.7,
            transition_temp: 0.5,
            identity_scale: 0.45,
            style_strength: 2.0,
            doc_sentences: 40,
        }
    }
}

/// The planted geometry: everything gold benchmarks need.
pub struct GroundTruth {
    pub cfg: GeneratorConfig,
    /// cluster centers, clusters × truth_dim, unit norm
    pub centers: Vec<Vec<f64>>,
    /// word identity components δ_w, vocab × truth_dim
    pub identity: Vec<Vec<f64>>,
    /// cluster assignment per word
    pub cluster_of: Vec<usize>,
    /// unnormalized Zipf mass per word (word id = rank)
    pub zipf_mass: Vec<f64>,
    /// relation partner: analogy pairing word ↔ partner in the paired
    /// cluster (see `relation_partner`); None when clusters is odd at edges
    pub partner: Vec<Option<u32>>,
}

impl GroundTruth {
    /// The full ground-truth vector g_w = ĉ + δ (not normalized; benchmarks
    /// use cosine so scale is irrelevant).
    pub fn vector(&self, w: u32) -> Vec<f64> {
        let c = &self.centers[self.cluster_of[w as usize]];
        let d = &self.identity[w as usize];
        c.iter().zip(d).map(|(a, b)| a + b).collect()
    }

    pub fn cosine(&self, a: u32, b: u32) -> f64 {
        let va = self.vector(a);
        let vb = self.vector(b);
        let dot: f64 = va.iter().zip(&vb).map(|(x, y)| x * y).sum();
        let na: f64 = va.iter().map(|x| x * x).sum::<f64>().sqrt();
        let nb: f64 = vb.iter().map(|x| x * x).sum::<f64>().sqrt();
        dot / (na * nb).max(1e-12)
    }

    /// Words of one cluster, ordered by frequency rank.
    pub fn cluster_members(&self, c: usize) -> Vec<u32> {
        (0..self.cfg.vocab as u32)
            .filter(|&w| self.cluster_of[w as usize] == c)
            .collect()
    }
}

/// Build the planted geometry deterministically from a seed.
pub fn build_ground_truth(cfg: &GeneratorConfig, seed: u64) -> GroundTruth {
    assert!(cfg.clusters >= 2 && cfg.vocab >= cfg.clusters);
    let mut rng = Pcg64::new_stream(seed, 0x6774); // "gt"
    // Unit-norm cluster centers. Paired clusters (2i, 2i+1) are related by
    // ONE global relation direction: center[2i+1] ∝ center[2i] + 0.6·r.
    // This makes (a) the planted analogies' offsets globally consistent
    // (good 3CosAdd structure) and (b) pair-merged categories (cat-broad)
    // geometrically coherent.
    let relation: Vec<f64> = {
        let mut v: Vec<f64> = (0..cfg.truth_dim).map(|_| rng.gen_gauss()).collect();
        let n = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        v.iter_mut().for_each(|x| *x /= n);
        v
    };
    let mut centers: Vec<Vec<f64>> = Vec::with_capacity(cfg.clusters);
    for c in 0..cfg.clusters {
        let mut v: Vec<f64> = if c % 2 == 1 {
            centers[c - 1]
                .iter()
                .zip(&relation)
                .map(|(a, r)| a + 0.6 * r)
                .collect()
        } else {
            (0..cfg.truth_dim).map(|_| rng.gen_gauss()).collect()
        };
        let n = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        v.iter_mut().for_each(|x| *x /= n);
        centers.push(v);
    }
    // round-robin cluster assignment: every cluster gets words across the
    // whole frequency spectrum (so no cluster is all-rare)
    let cluster_of: Vec<usize> = (0..cfg.vocab).map(|w| w % cfg.clusters).collect();
    // analogy pairing: cluster 2i ↔ 2i+1; the j-th member of 2i pairs with
    // the j-th member of 2i+1 and SHARES its identity δ (so the ground
    // truth offset g_partner − g_w is the same center difference for every
    // pair of the relation — a planted analogy).
    let mut identity: Vec<Vec<f64>> = (0..cfg.vocab)
        .map(|_| {
            (0..cfg.truth_dim)
                .map(|_| rng.gen_gauss() * cfg.identity_scale)
                .collect()
        })
        .collect();
    let mut partner: Vec<Option<u32>> = vec![None; cfg.vocab];
    let members_of: Vec<Vec<u32>> = (0..cfg.clusters)
        .map(|c| (0..cfg.vocab as u32).filter(|&w| cluster_of[w as usize] == c).collect())
        .collect();
    for pair in 0..cfg.clusters / 2 {
        let (a, b) = (2 * pair, 2 * pair + 1);
        let n = members_of[a].len().min(members_of[b].len());
        for j in 0..n {
            let wa = members_of[a][j];
            let wb = members_of[b][j];
            identity[wb as usize] = identity[wa as usize].clone();
            partner[wa as usize] = Some(wb);
            partner[wb as usize] = Some(wa);
        }
    }
    let zipf_mass: Vec<f64> = (0..cfg.vocab)
        .map(|w| 1.0 / ((w + 1) as f64).powf(cfg.zipf_exponent))
        .collect();
    GroundTruth {
        cfg: cfg.clone(),
        centers,
        identity,
        cluster_of,
        zipf_mass,
        partner,
    }
}

/// Sampling tables derived from the ground truth.
struct SamplingTables {
    /// per-cluster member list + their zipf masses (cdf)
    members: Vec<Vec<u32>>,
    member_cdf: Vec<Vec<f64>>,
    /// cluster transition cdf rows (clusters × clusters)
    transition_cdf: Vec<Vec<f64>>,
    /// initial-cluster cdf (by total zipf mass)
    initial_cdf: Vec<f64>,
}

fn build_tables(gt: &GroundTruth) -> SamplingTables {
    let m = gt.cfg.clusters;
    let members: Vec<Vec<u32>> = (0..m).map(|c| gt.cluster_members(c)).collect();
    let member_cdf = members
        .iter()
        .map(|ws| cdf_of(ws.iter().map(|&w| gt.zipf_mass[w as usize])))
        .collect();
    let mut transition_cdf = Vec::with_capacity(m);
    for i in 0..m {
        let weights = (0..m).map(|j| {
            let dot: f64 = gt.centers[i]
                .iter()
                .zip(&gt.centers[j])
                .map(|(a, b)| a * b)
                .sum();
            (dot / gt.cfg.transition_temp).exp()
        });
        transition_cdf.push(cdf_of(weights));
    }
    let initial_cdf = cdf_of(
        members
            .iter()
            .map(|ws| ws.iter().map(|&w| gt.zipf_mass[w as usize]).sum::<f64>()),
    );
    SamplingTables {
        members,
        member_cdf,
        transition_cdf,
        initial_cdf,
    }
}

fn cdf_of(weights: impl Iterator<Item = f64>) -> Vec<f64> {
    let mut cdf: Vec<f64> = weights.collect();
    let mut acc = 0.0;
    for w in &mut cdf {
        acc += *w;
        *w = acc;
    }
    let total = acc.max(1e-300);
    for w in &mut cdf {
        *w /= total;
    }
    cdf
}

fn sample_cdf(cdf: &[f64], rng: &mut Pcg64) -> usize {
    let u = rng.gen_f64();
    match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
        Ok(i) => (i + 1).min(cdf.len() - 1),
        Err(i) => i.min(cdf.len() - 1),
    }
}

/// Generate `n_sentences` sentences from the planted model.
pub fn generate_corpus(gt: &GroundTruth, n_sentences: usize, seed: u64) -> Corpus {
    let tables = build_tables(gt);
    let mut rng = Pcg64::new_stream(seed, 0x636F); // "co"
    let mut sentences = Vec::with_capacity(n_sentences);
    let dg = gt.cfg.truth_dim;
    let mut style = vec![0.0f64; dg];
    let m = gt.cfg.clusters;
    for sent_idx in 0..n_sentences {
        // sentence length: uniform in [avg/2, 3*avg/2]
        let avg = gt.cfg.avg_sentence_len.max(2);
        let len = avg / 2 + rng.gen_range_usize(avg + 1).max(1);
        for s in style.iter_mut() {
            *s = rng.gen_gauss();
        }
        // Document locality: consecutive sentences of one "document" start
        // their cluster walk at the document's anchor, and anchors sweep
        // the cluster space across the corpus — sequential chunks are
        // therefore topically skewed, like contiguous Wikipedia articles.
        let mut cluster = if gt.cfg.doc_sentences > 0 {
            let doc = sent_idx / gt.cfg.doc_sentences;
            let num_docs = n_sentences.div_ceil(gt.cfg.doc_sentences).max(1);
            ((doc * m) / num_docs + (doc % 3)) % m
        } else {
            sample_cdf(&tables.initial_cdf, &mut rng)
        };
        let mut sent = Vec::with_capacity(len);
        for _ in 0..len {
            if !rng.gen_bool(gt.cfg.stay_prob) {
                cluster = sample_cdf(&tables.transition_cdf[cluster], &mut rng);
            }
            let members = &tables.members[cluster];
            // style-biased within-cluster choice: rejection-sample against
            // exp(style·δ) capped via logistic acceptance — cheap and avoids
            // recomputing a softmax per token.
            let mut pick = members[sample_cdf(&tables.member_cdf[cluster], &mut rng)];
            for _ in 0..4 {
                let dot: f64 = gt.identity[pick as usize]
                    .iter()
                    .zip(&style)
                    .map(|(a, b)| a * b)
                    .sum();
                let accept = 1.0 / (1.0 + (-gt.cfg.style_strength * dot).exp());
                if rng.gen_bool(accept) {
                    break;
                }
                pick = members[sample_cdf(&tables.member_cdf[cluster], &mut rng)];
            }
            sent.push(pick);
        }
        sentences.push(sent);
    }
    Corpus::new(sentences)
}

/// The matching `Vocab`: word string `w<id>`, counts from the actual corpus.
pub fn vocab_of(corpus: &Corpus, vocab_size: usize) -> Vocab {
    let mut counts = vec![0u64; vocab_size];
    for s in &corpus.sentences {
        for &t in s {
            counts[t as usize] += 1;
        }
    }
    // Word ids must stay identical to generator ids (the corpus is already
    // id-encoded), so build the vocab order-preserving: vocab id i == word
    // "w<i>" == generator id i. Counts are taken from the actual corpus so
    // subsampling/negative tables see the realized distribution.
    let pairs: Vec<(String, u64)> = counts
        .iter()
        .enumerate()
        .map(|(i, &c)| (format!("w{i}"), c.max(1)))
        .collect();
    Vocab::from_ordered(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> GeneratorConfig {
        GeneratorConfig {
            vocab: 120,
            clusters: 8,
            truth_dim: 8,
            avg_sentence_len: 12,
            ..Default::default()
        }
    }

    #[test]
    fn ground_truth_is_deterministic() {
        let cfg = small_cfg();
        let a = build_ground_truth(&cfg, 9);
        let b = build_ground_truth(&cfg, 9);
        assert_eq!(a.cluster_of, b.cluster_of);
        assert_eq!(a.identity, b.identity);
        let c = build_ground_truth(&cfg, 10);
        assert_ne!(a.identity, c.identity);
    }

    #[test]
    fn centers_are_unit_norm() {
        let gt = build_ground_truth(&small_cfg(), 1);
        for c in &gt.centers {
            let n: f64 = c.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn partners_share_identity_and_are_symmetric() {
        let gt = build_ground_truth(&small_cfg(), 2);
        let mut found = 0;
        for w in 0..gt.cfg.vocab as u32 {
            if let Some(p) = gt.partner[w as usize] {
                assert_eq!(gt.partner[p as usize], Some(w));
                assert_eq!(gt.identity[w as usize], gt.identity[p as usize]);
                // partners live in paired clusters (2i, 2i+1)
                let (cw, cp) = (gt.cluster_of[w as usize], gt.cluster_of[p as usize]);
                assert_eq!(cw / 2, cp / 2);
                assert_ne!(cw, cp);
                found += 1;
            }
        }
        assert!(found > gt.cfg.vocab / 2, "most words should be paired");
    }

    #[test]
    fn same_cluster_words_more_similar_on_average() {
        let gt = build_ground_truth(&small_cfg(), 3);
        let (mut same, mut cross) = (Vec::new(), Vec::new());
        for a in 0..60u32 {
            for b in (a + 1)..60u32 {
                let cos = gt.cosine(a, b);
                if gt.cluster_of[a as usize] == gt.cluster_of[b as usize] {
                    same.push(cos);
                } else {
                    cross.push(cos);
                }
            }
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(avg(&same) > avg(&cross) + 0.2, "same={} cross={}", avg(&same), avg(&cross));
    }

    #[test]
    fn corpus_has_requested_shape() {
        let cfg = small_cfg();
        let gt = build_ground_truth(&cfg, 4);
        let corpus = generate_corpus(&gt, 500, 4);
        assert_eq!(corpus.len(), 500);
        let avg = corpus.total_tokens() as f64 / 500.0;
        assert!((avg - cfg.avg_sentence_len as f64).abs() < 3.0, "avg={avg}");
        for s in &corpus.sentences {
            assert!(s.iter().all(|&t| (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn corpus_unigram_is_roughly_zipf() {
        let cfg = small_cfg();
        let gt = build_ground_truth(&cfg, 5);
        let corpus = generate_corpus(&gt, 4000, 5);
        let mut counts = vec![0u64; cfg.vocab];
        for s in &corpus.sentences {
            for &t in s {
                counts[t as usize] += 1;
            }
        }
        // head words must be much more frequent than tail words
        let head: u64 = counts[..10].iter().sum();
        let tail: u64 = counts[cfg.vocab - 10..].iter().sum();
        assert!(head > tail * 5, "head={head} tail={tail}");
    }

    #[test]
    fn bigrams_prefer_same_cluster() {
        let cfg = small_cfg();
        let gt = build_ground_truth(&cfg, 6);
        let corpus = generate_corpus(&gt, 2000, 6);
        let (mut same, mut total) = (0u64, 0u64);
        for s in &corpus.sentences {
            for w in s.windows(2) {
                total += 1;
                if gt.cluster_of[w[0] as usize] == gt.cluster_of[w[1] as usize] {
                    same += 1;
                }
            }
        }
        let frac = same as f64 / total as f64;
        // random assignment would give 1/clusters = 0.125
        assert!(frac > 0.4, "same-cluster bigram fraction {frac}");
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let cfg = small_cfg();
        let gt = build_ground_truth(&cfg, 7);
        let a = generate_corpus(&gt, 50, 123);
        let b = generate_corpus(&gt, 50, 123);
        let c = generate_corpus(&gt, 50, 124);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn vocab_of_covers_all_words() {
        let cfg = small_cfg();
        let gt = build_ground_truth(&cfg, 8);
        let corpus = generate_corpus(&gt, 300, 8);
        let v = vocab_of(&corpus, cfg.vocab);
        assert_eq!(v.len(), cfg.vocab);
        assert!(v.id("w0").is_some());
    }
}
