//! Gold evaluation benchmarks derived from the planted ground truth —
//! the substitutes for MEN/RG65/RareWords/WS353 (similarity), AP/Battig
//! (categorization) and Google/SemEval (analogy). Sizes and difficulty
//! tiers mirror the paper's Table 1; the evaluation *code paths*
//! (Spearman ρ, purity, 3CosAdd accuracy, OOV accounting) are identical to
//! what the real benchmarks would exercise.

use super::corpus::GroundTruth;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub enum BenchmarkKind {
    Similarity,
    Categorization,
    Analogy,
}

/// One gold similarity pair: two word ids + ground-truth score.
#[derive(Clone, Debug)]
pub struct SimPair {
    pub a: u32,
    pub b: u32,
    pub gold: f64,
}

/// One analogy question a : b :: c : d (d is the gold answer).
#[derive(Clone, Debug)]
pub struct AnalogyQuad {
    pub a: u32,
    pub b: u32,
    pub c: u32,
    pub d: u32,
}

/// A categorization item: word id + gold category.
#[derive(Clone, Debug)]
pub struct CatItem {
    pub word: u32,
    pub category: usize,
}

#[derive(Clone, Debug)]
pub enum BenchmarkData {
    Similarity(Vec<SimPair>),
    Categorization { items: Vec<CatItem>, num_categories: usize },
    Analogy(Vec<AnalogyQuad>),
}

#[derive(Clone, Debug)]
pub struct Benchmark {
    pub name: String,
    pub kind: BenchmarkKind,
    pub data: BenchmarkData,
}

impl Benchmark {
    pub fn unique_words(&self) -> Vec<u32> {
        let mut ws: Vec<u32> = match &self.data {
            BenchmarkData::Similarity(pairs) => {
                pairs.iter().flat_map(|p| [p.a, p.b]).collect()
            }
            BenchmarkData::Categorization { items, .. } => {
                items.iter().map(|i| i.word).collect()
            }
            BenchmarkData::Analogy(quads) => {
                quads.iter().flat_map(|q| [q.a, q.b, q.c, q.d]).collect()
            }
        };
        ws.sort_unstable();
        ws.dedup();
        ws
    }

    pub fn len(&self) -> usize {
        match &self.data {
            BenchmarkData::Similarity(p) => p.len(),
            BenchmarkData::Categorization { items, .. } => items.len(),
            BenchmarkData::Analogy(q) => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remap every word id through `f`, dropping items touching an id `f`
    /// cannot map. Carries a gold suite across vocabularies — e.g. from
    /// the synthetic generator's ids onto the frequency-ranked ids a
    /// re-ingested copy of the same corpus gets.
    pub fn remap_words(&self, f: impl Fn(u32) -> Option<u32>) -> Benchmark {
        let data = match &self.data {
            BenchmarkData::Similarity(pairs) => BenchmarkData::Similarity(
                pairs
                    .iter()
                    .filter_map(|p| {
                        Some(SimPair {
                            a: f(p.a)?,
                            b: f(p.b)?,
                            gold: p.gold,
                        })
                    })
                    .collect(),
            ),
            BenchmarkData::Categorization {
                items,
                num_categories,
            } => BenchmarkData::Categorization {
                items: items
                    .iter()
                    .filter_map(|i| {
                        Some(CatItem {
                            word: f(i.word)?,
                            category: i.category,
                        })
                    })
                    .collect(),
                num_categories: *num_categories,
            },
            BenchmarkData::Analogy(quads) => BenchmarkData::Analogy(
                quads
                    .iter()
                    .filter_map(|q| {
                        Some(AnalogyQuad {
                            a: f(q.a)?,
                            b: f(q.b)?,
                            c: f(q.c)?,
                            d: f(q.d)?,
                        })
                    })
                    .collect(),
            ),
        };
        Benchmark {
            name: self.name.clone(),
            kind: self.kind.clone(),
            data,
        }
    }
}

/// Frequency tier helpers: word id == frequency rank under Zipf.
fn tier(vocab: usize, lo_frac: f64, hi_frac: f64) -> std::ops::Range<u32> {
    let lo = (vocab as f64 * lo_frac) as u32;
    let hi = (vocab as f64 * hi_frac) as u32;
    lo..hi.max(lo + 1)
}

fn gen_sim_pairs(
    gt: &GroundTruth,
    rng: &mut Pcg64,
    n: usize,
    words: std::ops::Range<u32>,
) -> Vec<SimPair> {
    let span = (words.end - words.start) as u64;
    let mut pairs = Vec::with_capacity(n);
    for i in 0..n {
        // mix: 1/3 same-cluster (high sim), 1/3 paired-cluster, 1/3 random
        let a = words.start + rng.gen_range(span) as u32;
        let random_b = words.start + rng.gen_range(span) as u32;
        let b = match i % 3 {
            0 => {
                // same-cluster pick, restricted to the frequency tier
                let members: Vec<u32> = gt
                    .cluster_members(gt.cluster_of[a as usize])
                    .into_iter()
                    .filter(|w| words.contains(w))
                    .collect();
                if members.is_empty() {
                    random_b
                } else {
                    members[rng.gen_range_usize(members.len())]
                }
            }
            1 => gt.partner[a as usize].filter(|p| words.contains(p)).unwrap_or(random_b),
            _ => random_b,
        };
        if a == b {
            continue;
        }
        pairs.push(SimPair {
            a,
            b,
            gold: gt.cosine(a, b),
        });
    }
    pairs
}

/// Build the full 8-benchmark suite mirroring the paper's Table 1.
///
/// | here       | paper analogue | role                                |
/// |------------|----------------|-------------------------------------|
/// | sim-men    | MEN (3000)     | large similarity, common words      |
/// | sim-rg65   | RG65 (65)      | tiny similarity set                 |
/// | sim-rare   | RareWords      | similarity over the Zipf tail       |
/// | sim-ws353  | WS353 (353)    | medium, mixed frequencies           |
/// | cat-broad  | AP (21 cls)    | categorization, few categories      |
/// | cat-fine   | Battig (56 cls)| categorization, many categories     |
/// | ana-google | Google         | analogy over common words           |
/// | ana-sem    | SemEval        | analogy incl. rarer words           |
pub fn build_suite(gt: &GroundTruth, seed: u64) -> Vec<Benchmark> {
    let v = gt.cfg.vocab;
    let mut rng = Pcg64::new_stream(seed, 0x6265); // "be"
    let mut out = Vec::new();

    out.push(Benchmark {
        name: "sim-men".into(),
        kind: BenchmarkKind::Similarity,
        data: BenchmarkData::Similarity(gen_sim_pairs(gt, &mut rng, 600, tier(v, 0.0, 0.5))),
    });
    out.push(Benchmark {
        name: "sim-rg65".into(),
        kind: BenchmarkKind::Similarity,
        data: BenchmarkData::Similarity(gen_sim_pairs(gt, &mut rng, 65, tier(v, 0.0, 0.25))),
    });
    out.push(Benchmark {
        name: "sim-rare".into(),
        kind: BenchmarkKind::Similarity,
        data: BenchmarkData::Similarity(gen_sim_pairs(gt, &mut rng, 400, tier(v, 0.7, 1.0))),
    });
    out.push(Benchmark {
        name: "sim-ws353".into(),
        kind: BenchmarkKind::Similarity,
        data: BenchmarkData::Similarity(gen_sim_pairs(gt, &mut rng, 353, tier(v, 0.0, 0.8))),
    });

    // categorization: sample words, gold category = coarse/fine cluster id
    let broad_cats = (gt.cfg.clusters / 2).max(2); // paired clusters merged
    let mut broad_items = Vec::new();
    let mut fine_items = Vec::new();
    for w in tier(v, 0.0, 0.6) {
        if rng.gen_bool(0.35) {
            broad_items.push(CatItem {
                word: w,
                category: gt.cluster_of[w as usize] / 2,
            });
        }
        if rng.gen_bool(0.5) {
            fine_items.push(CatItem {
                word: w,
                category: gt.cluster_of[w as usize],
            });
        }
    }
    out.push(Benchmark {
        name: "cat-broad".into(),
        kind: BenchmarkKind::Categorization,
        data: BenchmarkData::Categorization {
            items: broad_items,
            num_categories: broad_cats,
        },
    });
    out.push(Benchmark {
        name: "cat-fine".into(),
        kind: BenchmarkKind::Categorization,
        data: BenchmarkData::Categorization {
            items: fine_items,
            num_categories: gt.cfg.clusters,
        },
    });

    // analogy: a : partner(a) :: c : partner(c) within the same cluster pair
    let mut quads_common = Vec::new();
    let mut quads_rare = Vec::new();
    for _ in 0..4000 {
        let a = rng.gen_range(v as u64) as u32;
        let Some(b) = gt.partner[a as usize] else { continue };
        let members = gt.cluster_members(gt.cluster_of[a as usize]);
        let c = members[rng.gen_range_usize(members.len())];
        if c == a {
            continue;
        }
        let Some(d) = gt.partner[c as usize] else { continue };
        let quad = AnalogyQuad { a, b, c, d };
        let rare_cut = (v as f64 * 0.6) as u32;
        if a < rare_cut && c < rare_cut {
            if quads_common.len() < 500 {
                quads_common.push(quad);
            }
        } else if quads_rare.len() < 300 {
            quads_rare.push(quad);
        }
    }
    out.push(Benchmark {
        name: "ana-google".into(),
        kind: BenchmarkKind::Analogy,
        data: BenchmarkData::Analogy(quads_common),
    });
    out.push(Benchmark {
        name: "ana-sem".into(),
        kind: BenchmarkKind::Analogy,
        data: BenchmarkData::Analogy(quads_rare),
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::corpus::{build_ground_truth, GeneratorConfig};

    fn gt() -> GroundTruth {
        build_ground_truth(
            &GeneratorConfig {
                vocab: 400,
                clusters: 10,
                truth_dim: 8,
                ..Default::default()
            },
            77,
        )
    }

    #[test]
    fn suite_has_eight_benchmarks() {
        let suite = build_suite(&gt(), 1);
        assert_eq!(suite.len(), 8);
        let names: Vec<&str> = suite.iter().map(|b| b.name.as_str()).collect();
        assert!(names.contains(&"sim-rare"));
        assert!(names.contains(&"ana-google"));
        for b in &suite {
            assert!(!b.is_empty(), "{} is empty", b.name);
        }
    }

    #[test]
    fn sim_gold_scores_are_cosines() {
        let g = gt();
        let suite = build_suite(&g, 2);
        let BenchmarkData::Similarity(pairs) = &suite[0].data else {
            panic!("expected similarity")
        };
        for p in pairs.iter().take(50) {
            assert!((-1.0..=1.0).contains(&p.gold));
            assert!((p.gold - g.cosine(p.a, p.b)).abs() < 1e-12);
            assert_ne!(p.a, p.b);
        }
    }

    #[test]
    fn rare_benchmark_uses_tail_words() {
        let g = gt();
        let suite = build_suite(&g, 3);
        let rare = suite.iter().find(|b| b.name == "sim-rare").unwrap();
        let cut = (g.cfg.vocab as f64 * 0.7) as u32;
        for w in rare.unique_words() {
            assert!(w >= cut, "rare benchmark contains common word {w}");
        }
    }

    #[test]
    fn analogy_quads_are_gold_consistent() {
        let g = gt();
        let suite = build_suite(&g, 4);
        let ana = suite.iter().find(|b| b.name == "ana-google").unwrap();
        let BenchmarkData::Analogy(quads) = &ana.data else { panic!() };
        for q in quads.iter().take(100) {
            assert_eq!(g.partner[q.a as usize], Some(q.b));
            assert_eq!(g.partner[q.c as usize], Some(q.d));
            assert_eq!(g.cluster_of[q.a as usize], g.cluster_of[q.c as usize]);
            assert_ne!(q.a, q.c);
        }
    }

    #[test]
    fn categorization_items_match_clusters() {
        let g = gt();
        let suite = build_suite(&g, 5);
        let cat = suite.iter().find(|b| b.name == "cat-fine").unwrap();
        let BenchmarkData::Categorization { items, num_categories } = &cat.data else {
            panic!()
        };
        assert_eq!(*num_categories, g.cfg.clusters);
        for it in items.iter().take(100) {
            assert_eq!(it.category, g.cluster_of[it.word as usize]);
        }
    }

    #[test]
    fn suite_is_deterministic() {
        let g = gt();
        let a = build_suite(&g, 6);
        let b = build_suite(&g, 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.unique_words(), y.unique_words());
        }
    }

    #[test]
    fn remap_words_translates_and_drops() {
        let b = Benchmark {
            name: "t".into(),
            kind: BenchmarkKind::Analogy,
            data: BenchmarkData::Analogy(vec![
                AnalogyQuad { a: 0, b: 1, c: 2, d: 3 },
                AnalogyQuad { a: 0, b: 1, c: 2, d: 9 }, // 9 unmappable
            ]),
        };
        let mapped = b.remap_words(|w| if w < 4 { Some(w + 100) } else { None });
        assert_eq!(mapped.len(), 1);
        let BenchmarkData::Analogy(quads) = &mapped.data else { panic!() };
        assert_eq!(quads[0].a, 100);
        assert_eq!(quads[0].d, 103);
        // similarity keeps gold scores through the remap
        let sim = Benchmark {
            name: "s".into(),
            kind: BenchmarkKind::Similarity,
            data: BenchmarkData::Similarity(vec![SimPair { a: 1, b: 2, gold: 0.7 }]),
        };
        let mapped = sim.remap_words(|w| Some(w * 2));
        let BenchmarkData::Similarity(pairs) = &mapped.data else { panic!() };
        assert_eq!(pairs[0].a, 2);
        assert!((pairs[0].gold - 0.7).abs() < 1e-12);
    }

    #[test]
    fn unique_words_dedup() {
        let b = Benchmark {
            name: "t".into(),
            kind: BenchmarkKind::Similarity,
            data: BenchmarkData::Similarity(vec![
                SimPair { a: 3, b: 1, gold: 0.5 },
                SimPair { a: 1, b: 3, gold: 0.5 },
            ]),
        };
        assert_eq!(b.unique_words(), vec![1, 3]);
    }
}
