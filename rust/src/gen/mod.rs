//! Synthetic data: planted-geometry corpus generator + gold benchmarks
//! (the substitution for the paper's Wikipedia/Web corpora and NLP
//! benchmark suite — see DESIGN.md §3).
pub mod benchmarks;
pub mod corpus;
