//! The compute-backend abstraction: one trait, two engines.
//!
//! Everything above this module (trainers, reducers, the leader, the
//! baselines, benches, examples) drives a sub-model through [`Backend`]:
//! a packed `[rows, dim]` parameter state plus the batched
//! `(centers, ctx, weights, lr)` SGNS macro-step protocol of
//! `python/compile/model.py`. Two implementations exist:
//!
//! * [`crate::runtime::native::NativeBackend`] — pure rust on the shared
//!   vectorized kernels (`crate::kernels`); always available, fully
//!   deterministic, what CI exercises end to end.
//! * [`crate::runtime::client::Runtime`] — the PJRT/XLA AOT bridge,
//!   compiled behind the `xla` feature; needs `make artifacts`.
//!
//! [`load_backend`] resolves an experiment's `BackendKind` to a concrete
//! engine, with `auto` preferring XLA artifacts when they load and
//! falling back to native otherwise — so `dw2v pipeline`, the examples
//! and every bench harness run on any machine with no XLA toolchain.

use crate::info;
use crate::runtime::artifacts::{ArtifactConfig, Manifest};
use crate::runtime::client::Runtime;
use crate::runtime::native::{NativeBackend, NativeState};
use crate::runtime::params::Metrics;
use crate::util::config::{BackendKind, ExperimentConfig};

/// Static shape of the sub-model a backend hosts — the backend-neutral
/// half of the artifact contract. The packed state is `[rows, dim]` with
/// rows `0..vocab` = input embeddings `W`, `vocab..2·vocab` = context
/// embeddings `C`, one zero pad row (the target of the padding sentinel
/// `vocab`) and one metrics row `[loss_sum, examples, micro_steps, …]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelShape {
    /// vocabulary capacity V (ids `0..V`; `V` itself is the pad sentinel)
    pub vocab: usize,
    /// embedding dimensionality d
    pub dim: usize,
    /// examples per micro-step B
    pub batch: usize,
    /// negatives per positive K
    pub negatives: usize,
    /// micro-steps per dispatch S
    pub steps: usize,
    /// total packed rows (2·V + 2 in the canonical layout)
    pub rows: usize,
}

impl ModelShape {
    /// Canonical native layout for a `(vocab, dim)` model.
    pub fn native(
        vocab: usize,
        dim: usize,
        batch: usize,
        negatives: usize,
        steps: usize,
    ) -> Self {
        assert!(vocab > 0, "empty vocabulary");
        assert!(dim >= 3, "dim must be >= 3 to hold the metrics row");
        Self {
            vocab,
            dim,
            batch: batch.max(1),
            negatives,
            steps: steps.max(1),
            rows: 2 * vocab + 2,
        }
    }

    /// The shape an AOT artifact implements.
    pub fn from_artifact(a: &ArtifactConfig) -> Self {
        Self {
            vocab: a.vocab,
            dim: a.dim,
            batch: a.batch,
            negatives: a.negatives,
            steps: a.steps,
            rows: a.rows,
        }
    }

    /// Native shape sized for an experiment's actual vocabulary.
    pub fn for_experiment(cfg: &ExperimentConfig, vocab: usize) -> Self {
        Self::native(
            vocab,
            cfg.dim,
            cfg.trainer_batch,
            cfg.negatives,
            cfg.trainer_steps,
        )
    }

    /// Context ids per example (positive + negatives).
    pub fn k1(&self) -> usize {
        self.negatives + 1
    }

    /// Examples per macro-batch dispatch.
    pub fn batch_capacity(&self) -> usize {
        self.batch * self.steps
    }

    /// Row index the pad sentinel maps to.
    pub fn pad_row(&self) -> usize {
        2 * self.vocab
    }

    /// Row index of the running metrics counters.
    pub fn metrics_row(&self) -> usize {
        2 * self.vocab + 1
    }

    /// Total f32 elements in the packed state.
    pub fn state_len(&self) -> usize {
        self.rows * self.dim
    }
}

/// A compute engine executing the SGNS macro-batch protocol over opaque
/// per-sub-model state. `Sync` because many reducer threads share one
/// backend; `State: Send` because each reducer owns its state on its own
/// thread.
pub trait Backend: Sync {
    type State: Send;

    /// The model shape every state of this backend has.
    fn shape(&self) -> &ModelShape;

    /// Short human-readable engine name (`"native"` / `"xla"`).
    fn name(&self) -> &'static str;

    /// Materialize a packed host state (length `shape().state_len()`)
    /// wherever this backend computes.
    fn state_from_host(&self, host: &[f32]) -> Result<Self::State, String>;

    /// One training macro-step over `steps × batch` examples: fwd + grad +
    /// update, in place. `centers[S·B]`, `ctx[S·B·(K+1)]` (col 0 = the
    /// positive), `weights[S·B]` (0 = padding), one scalar `lr`.
    fn train_macro_batch(
        &self,
        state: &mut Self::State,
        centers: &[i32],
        ctx: &[i32],
        weights: &[f32],
        lr: f32,
    ) -> Result<(), String>;

    /// Read the running loss counters (cheap; no full download).
    fn metrics(&self, state: &Self::State) -> Result<Metrics, String>;

    /// Overwrite the running loss counters with exact values, e.g. when
    /// resuming from a checkpoint. The packed f32 state only carries the
    /// counters rounded to f32 (the metrics row), so backends that keep
    /// higher-precision accumulators override this to restore them
    /// losslessly; the default keeps the f32 approximation already loaded
    /// by [`Backend::state_from_host`].
    fn restore_metrics(&self, _state: &mut Self::State, _m: Metrics) -> Result<(), String> {
        Ok(())
    }

    /// Cosine similarity between `W` rows for each (query, candidate) pair.
    fn similarity(&self, state: &Self::State, pairs: &[(u32, u32)]) -> Result<Vec<f32>, String>;

    /// Download the full packed state (end of training / checkpoints).
    fn download(&self, state: &Self::State) -> Result<Vec<f32>, String>;
}

/// Runtime-selected backend for the CLI / examples / bench harnesses,
/// where the engine is picked from config rather than a type parameter.
/// The PJRT engine is boxed: it drags the whole artifact config along,
/// and the enum is constructed once per run.
pub enum AnyBackend {
    Native(NativeBackend),
    Xla(Box<Runtime>),
}

/// State of an [`AnyBackend`] — tagged with the engine that owns it.
pub enum AnyState {
    Native(NativeState),
    Xla(crate::runtime::client::DeviceBuffer),
}

const STATE_MISMATCH: &str = "sub-model state belongs to a different backend";

impl Backend for AnyBackend {
    type State = AnyState;

    fn shape(&self) -> &ModelShape {
        match self {
            AnyBackend::Native(b) => b.shape(),
            AnyBackend::Xla(b) => b.shape(),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            AnyBackend::Native(b) => b.name(),
            AnyBackend::Xla(b) => b.name(),
        }
    }

    fn state_from_host(&self, host: &[f32]) -> Result<AnyState, String> {
        match self {
            AnyBackend::Native(b) => b.state_from_host(host).map(AnyState::Native),
            AnyBackend::Xla(b) => b.state_from_host(host).map(AnyState::Xla),
        }
    }

    fn train_macro_batch(
        &self,
        state: &mut AnyState,
        centers: &[i32],
        ctx: &[i32],
        weights: &[f32],
        lr: f32,
    ) -> Result<(), String> {
        match (self, state) {
            (AnyBackend::Native(b), AnyState::Native(s)) => {
                b.train_macro_batch(s, centers, ctx, weights, lr)
            }
            (AnyBackend::Xla(b), AnyState::Xla(s)) => {
                b.train_macro_batch(s, centers, ctx, weights, lr)
            }
            _ => Err(STATE_MISMATCH.to_string()),
        }
    }

    fn metrics(&self, state: &AnyState) -> Result<Metrics, String> {
        match (self, state) {
            (AnyBackend::Native(b), AnyState::Native(s)) => b.metrics(s),
            (AnyBackend::Xla(b), AnyState::Xla(s)) => b.metrics(s),
            _ => Err(STATE_MISMATCH.to_string()),
        }
    }

    fn restore_metrics(&self, state: &mut AnyState, m: Metrics) -> Result<(), String> {
        match (self, state) {
            (AnyBackend::Native(b), AnyState::Native(s)) => b.restore_metrics(s, m),
            (AnyBackend::Xla(b), AnyState::Xla(s)) => b.restore_metrics(s, m),
            _ => Err(STATE_MISMATCH.to_string()),
        }
    }

    fn similarity(&self, state: &AnyState, pairs: &[(u32, u32)]) -> Result<Vec<f32>, String> {
        // fully-qualified: `Runtime` also has an inherent (query, candidate)
        // `similarity` whose name would otherwise shadow the trait method
        match (self, state) {
            (AnyBackend::Native(b), AnyState::Native(s)) => Backend::similarity(b, s, pairs),
            (AnyBackend::Xla(b), AnyState::Xla(s)) => Backend::similarity(b, s, pairs),
            _ => Err(STATE_MISMATCH.to_string()),
        }
    }

    fn download(&self, state: &AnyState) -> Result<Vec<f32>, String> {
        match (self, state) {
            (AnyBackend::Native(b), AnyState::Native(s)) => b.download(s),
            (AnyBackend::Xla(b), AnyState::Xla(s)) => b.download(s),
            _ => Err(STATE_MISMATCH.to_string()),
        }
    }
}

/// Try to stand up the PJRT/XLA engine for an experiment: resolve the
/// artifact manifest and compile the executables.
fn load_xla(cfg: &ExperimentConfig, vocab: usize) -> Result<Runtime, String> {
    let manifest = Manifest::load(std::path::Path::new(&cfg.artifact_dir))?;
    let artifact = manifest.resolve(vocab, cfg.dim)?;
    Runtime::load(artifact)
}

/// Stand up the native engine, turning bad experiment config into a
/// clean error (the asserts in [`ModelShape::native`] guard programmer
/// misuse, not user flags).
fn load_native(cfg: &ExperimentConfig, vocab: usize) -> Result<AnyBackend, String> {
    if vocab == 0 {
        return Err("cannot build a native backend over an empty vocabulary".to_string());
    }
    if cfg.dim < 3 {
        return Err(format!(
            "the native backend needs dim >= 3 to hold the metrics row (got {})",
            cfg.dim
        ));
    }
    Ok(AnyBackend::Native(NativeBackend::new(
        ModelShape::for_experiment(cfg, vocab),
    )))
}

/// Resolve the experiment's configured [`BackendKind`] to a live engine.
///
/// `auto` prefers the XLA artifacts when they load (feature compiled,
/// manifest present, artifact fits) and otherwise falls back to the
/// native backend with a log line explaining why — the pipeline, the
/// examples and the bench harnesses therefore run everywhere.
pub fn load_backend(cfg: &ExperimentConfig, vocab: usize) -> Result<AnyBackend, String> {
    match cfg.backend {
        BackendKind::Native => load_native(cfg, vocab),
        BackendKind::Xla => load_xla(cfg, vocab).map(|rt| AnyBackend::Xla(Box::new(rt))),
        BackendKind::Auto => match load_xla(cfg, vocab) {
            Ok(rt) => Ok(AnyBackend::Xla(Box::new(rt))),
            Err(why) => {
                info!("xla backend unavailable ({why}); falling back to native");
                load_native(cfg, vocab)
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_layout_invariants() {
        let sh = ModelShape::native(100, 16, 8, 3, 2);
        assert_eq!(sh.rows, 202);
        assert_eq!(sh.pad_row(), 200);
        assert_eq!(sh.metrics_row(), 201);
        assert_eq!(sh.k1(), 4);
        assert_eq!(sh.batch_capacity(), 16);
        assert_eq!(sh.state_len(), 202 * 16);
    }

    #[test]
    fn for_experiment_uses_trainer_knobs() {
        let mut cfg = ExperimentConfig::default();
        cfg.dim = 16;
        cfg.negatives = 3;
        cfg.trainer_batch = 32;
        cfg.trainer_steps = 2;
        let sh = ModelShape::for_experiment(&cfg, 500);
        assert_eq!(sh.vocab, 500);
        assert_eq!(sh.dim, 16);
        assert_eq!(sh.batch, 32);
        assert_eq!(sh.steps, 2);
        assert_eq!(sh.negatives, 3);
    }

    #[test]
    fn auto_falls_back_to_native_without_artifacts() {
        let mut cfg = ExperimentConfig::default();
        cfg.artifact_dir = "/nonexistent/artifacts".to_string();
        cfg.dim = 8;
        let b = load_backend(&cfg, 64).expect("auto must always produce a backend");
        assert_eq!(b.name(), "native");
        assert_eq!(b.shape().vocab, 64);
    }

    #[test]
    fn explicit_xla_without_artifacts_is_an_error() {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = BackendKind::Xla;
        cfg.artifact_dir = "/nonexistent/artifacts".to_string();
        assert!(load_backend(&cfg, 64).is_err());
    }

    #[test]
    fn explicit_native_ignores_artifacts() {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = BackendKind::Native;
        cfg.artifact_dir = "/nonexistent/artifacts".to_string();
        cfg.dim = 8;
        let b = load_backend(&cfg, 32).unwrap();
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn bad_user_config_is_an_error_not_a_panic() {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = BackendKind::Native;
        cfg.dim = 2; // too small for the metrics row
        let err = load_backend(&cfg, 32).unwrap_err();
        assert!(err.contains("dim"), "error should name the knob: {err}");
        cfg.dim = 8;
        assert!(load_backend(&cfg, 0).is_err(), "empty vocab must not panic");
    }
}
