//! The pure-rust compute backend: the SGNS macro-batch step executed
//! directly on the shared vectorized kernels (`crate::kernels`).
//!
//! Semantics mirror the AOT artifact (`python/compile/model.py`) over the
//! same packed `[rows, dim]` state and the same batched
//! `(centers, ctx, weights)` protocol — validated by the shared trainer
//! and pipeline tests — with two deliberate differences:
//!
//! * examples inside one dispatch are applied **sequentially** (classic
//!   SGD order) rather than as the artifact's per-micro-step vectorized
//!   update; per Ji et al. (arXiv:1604.04661) minibatched/shared-memory
//!   SGNS steps match sequential quality, so the two engines are
//!   statistically interchangeable;
//! * the metrics counters live in f64 shadows (materialized into the f32
//!   metrics row on download), so long runs don't lose monitoring
//!   precision once the running sums outgrow f32's 2^24 integer range.
//!
//! The backend is `Sync` and stateless across calls — every reducer owns
//! its [`NativeState`] — and a run is bitwise deterministic given the
//! same batch sequence.

use super::backend::{Backend, ModelShape};
use super::params::Metrics;
use crate::kernels;
use crate::kernels::SigmoidTable;

/// Host-resident packed sub-model state (`shape.rows × shape.dim` f32).
///
/// The metrics counters are additionally shadowed in f64: an f32 running
/// sum stops absorbing per-dispatch deltas near 2^24, which would flatten
/// per-epoch loss deltas on long runs. The packed row is materialized
/// from the shadows on every [`Backend::download`], so
/// download → `state_from_host` round trips preserve the counters.
pub struct NativeState {
    pub data: Vec<f32>,
    /// f64 twins of the metrics row's `[loss_sum, examples, micro_steps]`
    counters: [f64; 3],
}

/// CPU engine executing macro-batches on the PR-1 kernels
/// (`dot_sigmoid_update`, `dual_axpy`, `axpy`).
pub struct NativeBackend {
    shape: ModelShape,
    sigmoid: SigmoidTable,
}

impl NativeBackend {
    pub fn new(shape: ModelShape) -> Self {
        assert!(shape.dim >= 3, "dim must be >= 3 to hold the metrics row");
        assert!(
            shape.rows >= 2 * shape.vocab + 2,
            "packed layout needs 2V+2 rows"
        );
        Self {
            shape,
            sigmoid: SigmoidTable::new(),
        }
    }
}

/// Monitoring loss for one (dot, label): softplus of the signed logit,
/// clamped like the Hogwild baseline so saturated pairs can't blow up
/// the counter.
#[inline]
fn pair_loss(dot: f32, label: f32) -> f64 {
    let x = f64::from(if label > 0.5 { -dot } else { dot });
    (1.0 + x.exp()).ln().min(20.0)
}

impl Backend for NativeBackend {
    type State = NativeState;

    fn shape(&self) -> &ModelShape {
        &self.shape
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn state_from_host(&self, host: &[f32]) -> Result<NativeState, String> {
        if host.len() != self.shape.state_len() {
            return Err(format!(
                "native state length {} != rows*dim = {}",
                host.len(),
                self.shape.state_len()
            ));
        }
        let m = self.shape.metrics_row() * self.shape.dim;
        Ok(NativeState {
            counters: [host[m] as f64, host[m + 1] as f64, host[m + 2] as f64],
            data: host.to_vec(),
        })
    }

    fn train_macro_batch(
        &self,
        state: &mut NativeState,
        centers: &[i32],
        ctx: &[i32],
        weights: &[f32],
        lr: f32,
    ) -> Result<(), String> {
        let sh = &self.shape;
        let (v, d, k1, cap) = (sh.vocab, sh.dim, sh.k1(), sh.batch_capacity());
        if centers.len() != cap || weights.len() != cap || ctx.len() != cap * k1 {
            return Err(format!(
                "macro-batch shape mismatch: centers {} weights {} ctx {} \
                 vs capacity {cap} (k+1 = {k1})",
                centers.len(),
                weights.len(),
                ctx.len(),
            ));
        }
        // split the packed state into the W block and everything after it
        // (C rows, pad row, metrics row) so a center row and its context
        // rows can be borrowed simultaneously
        let (wblock, cblock) = state.data.split_at_mut(v * d);
        let mut neu = vec![0.0f32; d];
        let mut loss = 0.0f64;
        let mut examples = 0.0f64;
        for i in 0..cap {
            let weight = weights[i];
            let center = centers[i] as usize;
            // padding sentinel (or weight 0) → the artifact's pad row: a no-op
            if weight <= 0.0 || center >= v {
                continue;
            }
            examples += weight as f64;
            let wrow = center * d;
            neu.fill(0.0);
            for j in 0..k1 {
                // clamp out-of-range ids onto the pad row like the artifact's
                // gather does (cblock row v IS the pad row)
                let cid = (ctx[i * k1 + j] as usize).min(v);
                let label = if j == 0 { 1.0f32 } else { 0.0 };
                let crow = &mut cblock[cid * d..(cid + 1) * d];
                let dot = kernels::dot_sigmoid_update(
                    &wblock[wrow..wrow + d],
                    crow,
                    &mut neu,
                    label,
                    lr * weight,
                    &self.sigmoid,
                );
                loss += weight as f64 * pair_loss(dot, label);
            }
            kernels::axpy(1.0, &neu, &mut wblock[wrow..wrow + d]);
        }
        // fold the dispatch's counters into the f64 shadows (the packed
        // row is materialized from these on download)
        state.counters[0] += loss;
        state.counters[1] += examples;
        state.counters[2] += sh.steps as f64;
        Ok(())
    }

    fn metrics(&self, state: &NativeState) -> Result<Metrics, String> {
        Ok(Metrics {
            loss_sum: state.counters[0],
            examples: state.counters[1],
            micro_steps: state.counters[2],
        })
    }

    fn restore_metrics(&self, state: &mut NativeState, m: Metrics) -> Result<(), String> {
        // the f32 metrics row only carries a rounded copy; restoring the
        // f64 shadows exactly is what keeps a checkpoint-resumed run's
        // loss curve bitwise equal to an uninterrupted one
        state.counters = [m.loss_sum, m.examples, m.micro_steps];
        Ok(())
    }

    fn similarity(&self, state: &NativeState, pairs: &[(u32, u32)]) -> Result<Vec<f32>, String> {
        let (v, d) = (self.shape.vocab, self.shape.dim);
        pairs
            .iter()
            .map(|&(a, b)| {
                let (a, b) = (a as usize, b as usize);
                if a >= v || b >= v {
                    return Err(format!("similarity ids ({a}, {b}) out of vocab {v}"));
                }
                let ra = &state.data[a * d..(a + 1) * d];
                let rb = &state.data[b * d..(b + 1) * d];
                let dot = kernels::dot_wide(ra, rb);
                let na = kernels::norm_sq_wide(ra).sqrt();
                let nb = kernels::norm_sq_wide(rb).sqrt();
                Ok((dot / (na * nb).max(1e-12)) as f32)
            })
            .collect()
    }

    fn download(&self, state: &NativeState) -> Result<Vec<f32>, String> {
        let mut out = state.data.clone();
        let m = self.shape.metrics_row() * self.shape.dim;
        for (cell, &c) in out[m..m + 3].iter_mut().zip(&state.counters) {
            *cell = c as f32;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::params::SubModel;
    use crate::util::rng::Pcg64;

    fn backend() -> NativeBackend {
        NativeBackend::new(ModelShape::native(64, 8, 8, 2, 2))
    }

    #[test]
    fn metrics_row_starts_zero_and_counts_steps() {
        let be = backend();
        let sh = be.shape().clone();
        let mut model = SubModel::init(&be, 1).unwrap();
        let m0 = model.metrics(&be).unwrap();
        assert_eq!(m0.loss_sum, 0.0);
        assert_eq!(m0.micro_steps, 0.0);

        let cap = sh.batch_capacity();
        let centers = vec![0i32; cap];
        let ctx = vec![1i32; cap * sh.k1()];
        let weights = vec![1.0f32; cap];
        model
            .train_macro_batch(&be, &centers, &ctx, &weights, 0.01)
            .unwrap();
        let m1 = model.metrics(&be).unwrap();
        assert_eq!(m1.micro_steps, sh.steps as f64);
        assert_eq!(m1.examples, cap as f64);
        assert!(m1.loss_sum > 0.0);
        // untrained loss per example ≈ (1+k)·ln2
        let per = m1.loss_sum / m1.examples;
        let expect = (1.0 + sh.negatives as f64) * std::f64::consts::LN_2;
        assert!((per - expect).abs() < 0.2, "per-example loss {per} vs {expect}");
    }

    #[test]
    fn padding_batches_touch_nothing_but_metrics() {
        let be = backend();
        let sh = be.shape().clone();
        let mut model = SubModel::init(&be, 2).unwrap();
        let before = model.download_packed(&be).unwrap();
        let cap = sh.batch_capacity();
        let centers = vec![sh.vocab as i32; cap]; // all padding sentinel
        let ctx = vec![sh.vocab as i32; cap * sh.k1()];
        let weights = vec![0.0f32; cap];
        model
            .train_macro_batch(&be, &centers, &ctx, &weights, 0.5)
            .unwrap();
        let after = model.download_packed(&be).unwrap();
        let params = sh.metrics_row() * sh.dim;
        assert_eq!(
            before[..params],
            after[..params],
            "padding must not move parameters"
        );
        // micro_steps still advance
        assert_eq!(model.metrics(&be).unwrap().micro_steps, sh.steps as f64);
        assert_eq!(model.metrics(&be).unwrap().examples, 0.0);
    }

    #[test]
    fn training_reduces_loss_on_planted_pattern() {
        let be = NativeBackend::new(ModelShape::native(64, 8, 8, 2, 2));
        let sh = be.shape().clone();
        let mut model = SubModel::init(&be, 3).unwrap();
        let cap = sh.batch_capacity();
        // planted: word i co-occurs with word i+32; negatives from 0..32
        let mut rng = Pcg64::new(5);
        let mut make_batch = |rng: &mut Pcg64| {
            let mut centers = Vec::with_capacity(cap);
            let mut ctx = Vec::with_capacity(cap * sh.k1());
            for _ in 0..cap {
                let c = rng.gen_range(32) as i32;
                centers.push(c);
                ctx.push(c + 32); // positive
                for _ in 0..sh.negatives {
                    ctx.push(rng.gen_range(32) as i32);
                }
            }
            (centers, ctx, vec![1.0f32; cap])
        };
        let mut losses = Vec::new();
        let mut prev = 0.0;
        for _ in 0..80 {
            let (c, x, w) = make_batch(&mut rng);
            model.train_macro_batch(&be, &c, &x, &w, 0.3).unwrap();
            let m = model.metrics(&be).unwrap();
            losses.push(m.loss_sum - prev);
            prev = m.loss_sum;
        }
        let early: f64 = losses[..5].iter().sum();
        let late: f64 = losses[75..].iter().sum();
        assert!(
            late < early * 0.8,
            "loss should drop: early {early:.2} late {late:.2}"
        );
    }

    #[test]
    fn similarity_matches_host_cosine_via_embedding() {
        let be = backend();
        let sh = be.shape().clone();
        let mut model = SubModel::init(&be, 7).unwrap();
        let cap = sh.batch_capacity();
        let centers: Vec<i32> = (0..cap as i32).map(|i| i % 60).collect();
        let ctx: Vec<i32> = (0..(cap * sh.k1()) as i32).map(|i| i % 60).collect();
        model
            .train_macro_batch(&be, &centers, &ctx, &vec![1.0; cap], 0.5)
            .unwrap();
        let pairs: Vec<(u32, u32)> = vec![(0, 1), (2, 3), (10, 50), (5, 5)];
        let dev = model.similarity(&be, &pairs).unwrap();
        let emb = model
            .into_embedding(&be, sh.vocab, vec![true; sh.vocab])
            .unwrap();
        for ((x, y), s) in pairs.iter().zip(dev) {
            let host = emb.cosine(*x, *y).unwrap();
            assert!(
                (host - s as f64).abs() < 1e-4,
                "({x},{y}): host {host} backend {s}"
            );
        }
    }

    #[test]
    fn out_of_vocab_similarity_is_an_error() {
        let be = backend();
        let model = SubModel::init(&be, 9).unwrap();
        assert!(model.similarity(&be, &[(0, 10_000)]).is_err());
    }

    #[test]
    fn dispatch_is_deterministic() {
        let be = backend();
        let sh = be.shape().clone();
        let run = || {
            let mut model = SubModel::init(&be, 11).unwrap();
            let cap = sh.batch_capacity();
            let mut rng = Pcg64::new(4);
            for _ in 0..10 {
                let centers: Vec<i32> = (0..cap).map(|_| rng.gen_range(64) as i32).collect();
                let ctx: Vec<i32> =
                    (0..cap * sh.k1()).map(|_| rng.gen_range(64) as i32).collect();
                model
                    .train_macro_batch(&be, &centers, &ctx, &vec![1.0; cap], 0.1)
                    .unwrap();
            }
            model.download_packed(&be).unwrap()
        };
        assert_eq!(run(), run(), "native training must be bitwise deterministic");
    }

    #[test]
    fn restore_metrics_is_exact_beyond_f32() {
        let be = backend();
        let mut state = be.state_from_host(&vec![0.0; be.shape().state_len()]).unwrap();
        let m = Metrics {
            loss_sum: 1.0 + 1e-12,
            examples: 16_777_217.0, // 2^24 + 1: not representable in f32
            micro_steps: 3.0,
        };
        be.restore_metrics(&mut state, m).unwrap();
        let got = be.metrics(&state).unwrap();
        assert_eq!(got.loss_sum.to_bits(), (1.0f64 + 1e-12).to_bits());
        assert_eq!(got.examples, 16_777_217.0);
        // the packed-row round trip is lossy by design — restore_metrics
        // exists precisely because this path rounds
        let packed = be.download(&state).unwrap();
        let rt = be.state_from_host(&packed).unwrap();
        assert_ne!(be.metrics(&rt).unwrap().examples, 16_777_217.0);
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let be = backend();
        let mut model = SubModel::init(&be, 1).unwrap();
        let err = model.train_macro_batch(&be, &[0, 1], &[0, 1, 2], &[1.0, 1.0], 0.1);
        assert!(err.is_err());
        assert!(be.state_from_host(&[0.0; 3]).is_err());
    }
}
