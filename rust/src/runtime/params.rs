//! Device-resident sub-model state management.
//!
//! A [`SubModel`] owns the packed `[2V+2, D]` parameter buffer of one
//! reducer's SGNS model. It is initialized host-side (word2vec init),
//! uploaded once, then only ever touched on-device by chaining
//! `train_step` outputs back as inputs. The embedding is downloaded a
//! single time when training finishes.

use super::client::{DeviceBuffer, Runtime};
use crate::embedding::Embedding;
use crate::util::rng::Pcg64;

/// Metrics row interpretation (mirrors python/compile/model.py).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Metrics {
    pub loss_sum: f64,
    pub examples: f64,
    pub micro_steps: f64,
}

impl Metrics {
    pub fn from_row(row: &[f32]) -> Self {
        Self {
            loss_sum: row.first().copied().unwrap_or(0.0) as f64,
            examples: row.get(1).copied().unwrap_or(0.0) as f64,
            micro_steps: row.get(2).copied().unwrap_or(0.0) as f64,
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.examples > 0.0 {
            self.loss_sum / self.examples
        } else {
            0.0
        }
    }
}

/// One reducer's device-resident model.
pub struct SubModel {
    state: DeviceBuffer,
    /// dispatches executed (each = artifact.steps micro-steps)
    pub dispatches: u64,
}

impl SubModel {
    /// word2vec init: W ~ U(−0.5/D, 0.5/D), C/pad/metrics zero; uploaded
    /// to the device once.
    pub fn init(rt: &Runtime, seed: u64) -> Result<Self, String> {
        let a = &rt.artifact;
        let mut host = vec![0.0f32; a.rows * a.dim];
        let mut rng = Pcg64::new_stream(seed, 0x7374); // "st"
        for x in host[..a.vocab * a.dim].iter_mut() {
            *x = (rng.gen_f32() - 0.5) / a.dim as f32;
        }
        let state = rt.upload_f32(&host, &[a.rows, a.dim])?;
        Ok(Self {
            state,
            dispatches: 0,
        })
    }

    /// Restore from a previously downloaded packed state (tests/checkpoints).
    pub fn from_host(rt: &Runtime, host: &[f32]) -> Result<Self, String> {
        let a = &rt.artifact;
        assert_eq!(host.len(), a.rows * a.dim);
        Ok(Self {
            state: rt.upload_f32(host, &[a.rows, a.dim])?,
            dispatches: 0,
        })
    }

    /// Execute one macro-batch (uploads the index tensors, chains the
    /// state buffer on-device).
    pub fn train_macro_batch(
        &mut self,
        rt: &Runtime,
        centers: &[i32],
        ctx: &[i32],
        weights: &[f32],
        lr: f32,
    ) -> Result<(), String> {
        let a = &rt.artifact;
        debug_assert_eq!(centers.len(), a.batch_capacity());
        debug_assert_eq!(ctx.len(), a.batch_capacity() * a.k1());
        debug_assert_eq!(weights.len(), a.batch_capacity());
        let c = rt.upload_i32(centers, &[a.steps, a.batch])?;
        let x = rt.upload_i32(ctx, &[a.steps, a.batch, a.k1()])?;
        let w = rt.upload_f32(weights, &[a.steps, a.batch])?;
        let l = rt.upload_f32(&[lr], &[1])?;
        self.state = rt.train_step(&self.state, &c, &x, &w, &l)?;
        self.dispatches += 1;
        Ok(())
    }

    /// Running loss counters (cheap on-device slice + tiny readback).
    pub fn metrics(&self, rt: &Runtime) -> Result<Metrics, String> {
        Ok(Metrics::from_row(&rt.read_metrics(&self.state)?))
    }

    /// On-device cosine similarity between word pairs.
    pub fn similarity(
        &self,
        rt: &Runtime,
        pairs: &[(u32, u32)],
    ) -> Result<Vec<f32>, String> {
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(rt.artifact.sim_q) {
            let q: Vec<i32> = chunk.iter().map(|p| p.0 as i32).collect();
            let c: Vec<i32> = chunk.iter().map(|p| p.1 as i32).collect();
            out.extend(rt.similarity(&self.state, &q, &c)?);
        }
        Ok(out)
    }

    /// Download the full packed state (checkpointing / the round-trip
    /// ablation bench). Pair with [`SubModel::from_host`].
    pub fn download_packed(&self, rt: &Runtime) -> Result<Vec<f32>, String> {
        rt.download_state(&self.state)
    }

    /// Download the trained input embeddings (`W` block), restricted to the
    /// experiment's actual vocabulary. `present` marks which words this
    /// sub-model is allowed to claim (per-sub-model count thresholding).
    pub fn into_embedding(
        self,
        rt: &Runtime,
        actual_vocab: usize,
        present: Vec<bool>,
    ) -> Result<Embedding, String> {
        let a = &rt.artifact;
        assert!(actual_vocab <= a.vocab);
        assert_eq!(present.len(), actual_vocab);
        let host = rt.download_state(&self.state)?;
        let data = host[..actual_vocab * a.dim].to_vec();
        Ok(Embedding {
            vocab: actual_vocab,
            dim: a.dim,
            data,
            present,
        })
    }
}
