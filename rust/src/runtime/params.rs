//! Backend-resident sub-model state management.
//!
//! A [`SubModel`] owns the packed `[2V+2, D]` parameter state of one
//! reducer's SGNS model, wherever its [`Backend`] keeps it (host memory
//! for the native engine, a device buffer for PJRT). It is initialized
//! host-side (word2vec init), materialized once, then only ever touched
//! through the backend's macro-batch protocol. The embedding is
//! downloaded a single time when training finishes.

use super::backend::{Backend, ModelShape};
use crate::embedding::Embedding;
use crate::util::rng::Pcg64;

/// Metrics row interpretation (mirrors python/compile/model.py).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Metrics {
    pub loss_sum: f64,
    pub examples: f64,
    pub micro_steps: f64,
}

impl Metrics {
    pub fn from_row(row: &[f32]) -> Self {
        Self {
            loss_sum: row.first().copied().unwrap_or(0.0) as f64,
            examples: row.get(1).copied().unwrap_or(0.0) as f64,
            micro_steps: row.get(2).copied().unwrap_or(0.0) as f64,
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.examples > 0.0 {
            self.loss_sum / self.examples
        } else {
            0.0
        }
    }
}

/// word2vec host-side init of a packed state: `W ~ U(−0.5/D, 0.5/D)`,
/// C/pad/metrics rows zero. Shared by every backend and the
/// parameter-averaging baseline.
pub fn init_host(shape: &ModelShape, seed: u64) -> Vec<f32> {
    let mut host = vec![0.0f32; shape.state_len()];
    let mut rng = Pcg64::new_stream(seed, 0x7374); // "st"
    for x in host[..shape.vocab * shape.dim].iter_mut() {
        *x = (rng.gen_f32() - 0.5) / shape.dim as f32;
    }
    host
}

/// One reducer's backend-resident model.
pub struct SubModel<B: Backend> {
    state: B::State,
    /// dispatches executed (each = shape.steps micro-steps)
    pub dispatches: u64,
}

impl<B: Backend> SubModel<B> {
    /// word2vec init, materialized on the backend once.
    pub fn init(backend: &B, seed: u64) -> Result<Self, String> {
        let host = init_host(backend.shape(), seed);
        Self::from_host(backend, &host)
    }

    /// Restore from a previously downloaded packed state (tests /
    /// checkpoints / the parameter-averaging baseline).
    pub fn from_host(backend: &B, host: &[f32]) -> Result<Self, String> {
        Ok(Self {
            state: backend.state_from_host(host)?,
            dispatches: 0,
        })
    }

    /// Execute one macro-batch through the backend.
    pub fn train_macro_batch(
        &mut self,
        backend: &B,
        centers: &[i32],
        ctx: &[i32],
        weights: &[f32],
        lr: f32,
    ) -> Result<(), String> {
        backend.train_macro_batch(&mut self.state, centers, ctx, weights, lr)?;
        self.dispatches += 1;
        Ok(())
    }

    /// Running loss counters (cheap; no full state download).
    pub fn metrics(&self, backend: &B) -> Result<Metrics, String> {
        backend.metrics(&self.state)
    }

    /// Reinstate exact loss counters after [`SubModel::from_host`] — the
    /// packed metrics row only carries f32-rounded copies of the
    /// backend's (possibly higher-precision) accumulators.
    pub fn restore_metrics(&mut self, backend: &B, m: Metrics) -> Result<(), String> {
        backend.restore_metrics(&mut self.state, m)
    }

    /// Cosine similarity between word pairs, computed by the backend.
    pub fn similarity(&self, backend: &B, pairs: &[(u32, u32)]) -> Result<Vec<f32>, String> {
        backend.similarity(&self.state, pairs)
    }

    /// Download the full packed state (checkpointing / the round-trip
    /// ablation bench). Pair with [`SubModel::from_host`].
    pub fn download_packed(&self, backend: &B) -> Result<Vec<f32>, String> {
        backend.download(&self.state)
    }

    /// Download the trained input embeddings (`W` block), restricted to the
    /// experiment's actual vocabulary. `present` marks which words this
    /// sub-model is allowed to claim (per-sub-model count thresholding).
    pub fn into_embedding(
        self,
        backend: &B,
        actual_vocab: usize,
        present: Vec<bool>,
    ) -> Result<Embedding, String> {
        let shape = backend.shape();
        assert!(actual_vocab <= shape.vocab);
        assert_eq!(present.len(), actual_vocab);
        let host = backend.download(&self.state)?;
        let data = host[..actual_vocab * shape.dim].to_vec();
        Ok(Embedding {
            vocab: actual_vocab,
            dim: shape.dim,
            data,
            present,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_host_layout() {
        let sh = ModelShape::native(10, 4, 2, 1, 1);
        let host = init_host(&sh, 7);
        assert_eq!(host.len(), sh.state_len());
        // W block initialized within the word2vec range
        for &x in &host[..10 * 4] {
            assert!(x.abs() <= 0.5 / 4.0 + 1e-6);
        }
        // at least one W value is non-zero
        assert!(host[..10 * 4].iter().any(|&x| x != 0.0));
        // C / pad / metrics rows are zero
        assert!(host[10 * 4..].iter().all(|&x| x == 0.0));
        // deterministic per seed, distinct across seeds
        assert_eq!(host, init_host(&sh, 7));
        assert_ne!(host, init_host(&sh, 8));
    }

    #[test]
    fn metrics_from_short_row_is_zero_filled() {
        let m = Metrics::from_row(&[1.5]);
        assert_eq!(m.loss_sum, 1.5);
        assert_eq!(m.examples, 0.0);
        assert_eq!(m.mean_loss(), 0.0);
        let m2 = Metrics::from_row(&[6.0, 2.0, 1.0]);
        assert_eq!(m2.mean_loss(), 3.0);
    }
}
