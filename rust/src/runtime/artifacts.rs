//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! lowered configuration (shapes, packed-state row layout, file names).
//! The runtime resolves an experiment's (vocab, dim) requirement to the
//! smallest compatible artifact — the HLO's vocab is a static shape, so a
//! corpus with fewer words simply leaves the upper rows untouched.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One lowered model configuration (mirrors aot.py's manifest_entry).
#[derive(Clone, Debug)]
pub struct ArtifactConfig {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    pub batch: usize,
    pub negatives: usize,
    pub steps: usize,
    pub rows: usize,
    pub pad_row: usize,
    pub metrics_row: usize,
    pub sim_q: usize,
    pub vmem_block_bytes: usize,
    pub train_file: PathBuf,
    pub metrics_file: PathBuf,
    pub sim_file: PathBuf,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: Vec<ArtifactConfig>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        let mut configs = Vec::new();
        for entry in j.get("configs").as_arr().ok_or("manifest: missing configs")? {
            let need_usize = |key: &str| {
                entry
                    .get(key)
                    .as_usize()
                    .ok_or_else(|| format!("manifest entry missing '{key}'"))
            };
            let file = |key: &str| -> Result<PathBuf, String> {
                Ok(dir.join(
                    entry
                        .get("files")
                        .get(key)
                        .as_str()
                        .ok_or_else(|| format!("manifest entry missing file '{key}'"))?,
                ))
            };
            configs.push(ArtifactConfig {
                name: entry
                    .get("name")
                    .as_str()
                    .ok_or("manifest entry missing 'name'")?
                    .to_string(),
                vocab: need_usize("vocab")?,
                dim: need_usize("dim")?,
                batch: need_usize("batch")?,
                negatives: need_usize("negatives")?,
                steps: need_usize("steps")?,
                rows: need_usize("rows")?,
                pad_row: need_usize("pad_row")?,
                metrics_row: need_usize("metrics_row")?,
                sim_q: need_usize("sim_q")?,
                vmem_block_bytes: need_usize("vmem_block_bytes")?,
                train_file: file("train")?,
                metrics_file: file("metrics")?,
                sim_file: file("sim")?,
            });
        }
        if configs.is_empty() {
            return Err("manifest has no configs".to_string());
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            configs,
        })
    }

    /// Smallest artifact that can host `vocab` words at dimensionality
    /// `dim`. Returns a helpful error when nothing fits.
    pub fn resolve(&self, vocab: usize, dim: usize) -> Result<&ArtifactConfig, String> {
        self.configs
            .iter()
            .filter(|c| c.dim == dim && c.vocab >= vocab)
            .min_by_key(|c| c.vocab)
            .ok_or_else(|| {
                let have: Vec<String> = self
                    .configs
                    .iter()
                    .map(|c| format!("{} (V={}, D={})", c.name, c.vocab, c.dim))
                    .collect();
                format!(
                    "no artifact fits vocab={vocab} dim={dim}; available: [{}]. \
                     Rebuild with: cd python && python -m compile.aot \
                     --out-dir ../artifacts --cfg {vocab},{dim},256,5,8",
                    have.join(", ")
                )
            })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactConfig> {
        self.configs.iter().find(|c| c.name == name)
    }
}

impl ArtifactConfig {
    pub fn k1(&self) -> usize {
        self.negatives + 1
    }

    /// Shape of one macro-batch dispatch.
    pub fn batch_capacity(&self) -> usize {
        self.batch * self.steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, entries: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let text = format!(r#"{{"version": 1, "configs": [{entries}]}}"#);
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    fn entry(name: &str, vocab: usize, dim: usize) -> String {
        format!(
            r#"{{"name": "{name}", "vocab": {vocab}, "dim": {dim}, "batch": 8,
                "negatives": 2, "steps": 2, "rows": {}, "pad_row": {},
                "metrics_row": {}, "sim_q": 256, "vmem_block_bytes": 1024,
                "files": {{"train": "t.hlo.txt", "metrics": "m.hlo.txt",
                           "sim": "s.hlo.txt"}}}}"#,
            2 * vocab + 2,
            2 * vocab,
            2 * vocab + 1
        )
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dw2v_manifest_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_and_resolves() {
        let dir = tmp("resolve");
        write_manifest(
            &dir,
            &format!("{}, {}, {}", entry("a", 64, 8), entry("b", 2000, 8), entry("c", 2000, 32)),
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.configs.len(), 3);
        // smallest fitting artifact
        assert_eq!(m.resolve(50, 8).unwrap().name, "a");
        assert_eq!(m.resolve(100, 8).unwrap().name, "b");
        assert_eq!(m.resolve(100, 32).unwrap().name, "c");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resolve_failure_is_actionable() {
        let dir = tmp("fail");
        write_manifest(&dir, &entry("a", 64, 8));
        let m = Manifest::load(&dir).unwrap();
        let err = m.resolve(1_000_000, 8).unwrap_err();
        assert!(err.contains("compile.aot"), "error should tell the user how to fix: {err}");
        assert!(m.resolve(10, 999).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn row_layout_fields() {
        let dir = tmp("layout");
        write_manifest(&dir, &entry("a", 64, 8));
        let m = Manifest::load(&dir).unwrap();
        let c = &m.configs[0];
        assert_eq!(c.rows, 130);
        assert_eq!(c.pad_row, 128);
        assert_eq!(c.metrics_row, 129);
        assert_eq!(c.k1(), 3);
        assert_eq!(c.batch_capacity(), 16);
        assert!(c.train_file.ends_with("t.hlo.txt"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn by_name_lookup() {
        let dir = tmp("byname");
        write_manifest(&dir, &format!("{}, {}", entry("x", 64, 8), entry("y", 128, 8)));
        let m = Manifest::load(&dir).unwrap();
        assert!(m.by_name("y").is_some());
        assert!(m.by_name("zzz").is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
