//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! many reducer threads with device-resident parameters.
//!
//! The real bridge lives behind the `xla` feature because the `xla` crate
//! (the xla_extension bindings) is an external dependency this repo cannot
//! fetch in offline build environments. Default builds get a stub
//! [`Runtime`] with the identical surface whose `load` returns an
//! actionable error — everything above this module (trainers, coordinator,
//! benches, examples) compiles and unit-tests either way, and only actual
//! PJRT execution requires the feature.
//!
//! Thread-safety (real bridge): the `xla` crate's wrappers hold raw
//! pointers and are `!Send`, but the underlying PJRT CPU client *is*
//! thread-safe (the C++ TfrtCpuClient serializes what it must internally
//! and supports concurrent `Execute`). We therefore wrap the handles in
//! newtypes that assert `Send`/`Sync`; every call still goes through
//! `&self`.
//!
//! Key bridge facts (established by `rust/src/bin/bridge_probe.rs`):
//! * a single-array-output computation returns exactly one chainable
//!   buffer — this is why the whole model state is ONE packed array;
//! * `execute_b` accepts prior output buffers directly → zero host copies
//!   on the train path;
//! * `CopyRawToHost` is unimplemented on CPU, so the metrics row is read
//!   through a tiny companion executable that slices it on-device.

#[cfg(feature = "xla")]
mod pjrt {
    use crate::runtime::artifacts::ArtifactConfig;
    use crate::runtime::backend::ModelShape;
    use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

    /// A compiled executable, shareable across threads.
    pub struct Executable(PjRtLoadedExecutable);
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    /// A device buffer whose ownership may cross threads (PJRT buffers are
    /// plain handles; all operations go through the thread-safe client).
    pub struct DeviceBuffer(PjRtBuffer);
    unsafe impl Send for DeviceBuffer {}
    unsafe impl Sync for DeviceBuffer {}

    /// The process-wide PJRT runtime: one client + the compiled executables of
    /// one artifact configuration.
    pub struct Runtime {
        client: PjRtClient,
        pub artifact: ArtifactConfig,
        pub shape: ModelShape,
        train: Executable,
        metrics: Executable,
        sim: Executable,
    }

    unsafe impl Send for Runtime {}
    unsafe impl Sync for Runtime {}

    impl Runtime {
        /// Create a CPU PJRT client and compile the three executables of
        /// `artifact`. Compilation happens once; reducers share the result.
        pub fn load(artifact: &ArtifactConfig) -> Result<Self, String> {
            let client = PjRtClient::cpu().map_err(|e| format!("PjRtClient::cpu: {e}"))?;
            let compile = |path: &std::path::Path| -> Result<Executable, String> {
                let proto = HloModuleProto::from_text_file(path)
                    .map_err(|e| format!("parse {}: {e}", path.display()))?;
                let comp = XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map(Executable)
                    .map_err(|e| format!("compile {}: {e}", path.display()))
            };
            Ok(Self {
                train: compile(&artifact.train_file)?,
                metrics: compile(&artifact.metrics_file)?,
                sim: compile(&artifact.sim_file)?,
                artifact: artifact.clone(),
                shape: ModelShape::from_artifact(artifact),
                client,
            })
        }

        /// Upload a host f32 tensor.
        pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuffer, String> {
            self.client
                .buffer_from_host_buffer(data, dims, None)
                .map(DeviceBuffer)
                .map_err(|e| format!("upload_f32: {e}"))
        }

        /// Upload a host i32 tensor.
        pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuffer, String> {
            self.client
                .buffer_from_host_buffer(data, dims, None)
                .map(DeviceBuffer)
                .map_err(|e| format!("upload_i32: {e}"))
        }

        /// One training macro-step: state' = train(state, centers, ctx,
        /// weights, lr). All inputs already on device; output stays on device.
        pub fn train_step(
            &self,
            state: &DeviceBuffer,
            centers: &DeviceBuffer,
            ctx: &DeviceBuffer,
            weights: &DeviceBuffer,
            lr: &DeviceBuffer,
        ) -> Result<DeviceBuffer, String> {
            let mut out = self
                .train
                .0
                .execute_b(&[&state.0, &centers.0, &ctx.0, &weights.0, &lr.0])
                .map_err(|e| format!("train execute: {e}"))?;
            Ok(DeviceBuffer(out.remove(0).remove(0)))
        }

        /// Read the metrics row [loss_sum, examples, steps, ...] without
        /// copying the whole state to the host.
        pub fn read_metrics(&self, state: &DeviceBuffer) -> Result<Vec<f32>, String> {
            let out = self
                .metrics
                .0
                .execute_b(&[&state.0])
                .map_err(|e| format!("metrics execute: {e}"))?;
            out[0][0]
                .to_literal_sync()
                .and_then(|l| l.to_vec::<f32>())
                .map_err(|e| format!("metrics readback: {e}"))
        }

        /// Batched on-device cosine similarity between query/candidate rows
        /// (the eval fast path). Inputs are padded to the artifact's sim_q.
        pub fn similarity(
            &self,
            state: &DeviceBuffer,
            queries: &[i32],
            candidates: &[i32],
        ) -> Result<Vec<f32>, String> {
            assert_eq!(queries.len(), candidates.len());
            let q = self.artifact.sim_q;
            assert!(queries.len() <= q, "query batch exceeds artifact sim_q");
            let mut qb = queries.to_vec();
            let mut cb = candidates.to_vec();
            qb.resize(q, 0);
            cb.resize(q, 0);
            let qbuf = self.upload_i32(&qb, &[q])?;
            let cbuf = self.upload_i32(&cb, &[q])?;
            let out = self
                .sim
                .0
                .execute_b(&[&state.0, &qbuf.0, &cbuf.0])
                .map_err(|e| format!("sim execute: {e}"))?;
            let mut vals = out[0][0]
                .to_literal_sync()
                .and_then(|l| l.to_vec::<f32>())
                .map_err(|e| format!("sim readback: {e}"))?;
            vals.truncate(queries.len());
            Ok(vals)
        }

        /// Download the full packed state (end of training only).
        pub fn download_state(&self, state: &DeviceBuffer) -> Result<Vec<f32>, String> {
            state
                .0
                .to_literal_sync()
                .and_then(|l: Literal| l.to_vec::<f32>())
                .map_err(|e| format!("state download: {e}"))
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{DeviceBuffer, Executable, Runtime};

#[cfg(not(feature = "xla"))]
mod stub {
    use crate::runtime::artifacts::ArtifactConfig;
    use crate::runtime::backend::ModelShape;

    const UNAVAILABLE: &str = "dw2v was built without the `xla` feature, so the PJRT \
         runtime is unavailable; add the vendored xla crate to rust/Cargo.toml \
         [dependencies] and rebuild with `cargo build --features xla` (see the \
         feature notes in rust/Cargo.toml), or run with the native backend \
         (`--backend native`, the default fallback)";

    /// Stub device buffer: never constructed (the stub `Runtime` cannot be
    /// instantiated), exists so the runtime API typechecks feature-off.
    pub struct DeviceBuffer(());

    /// Stub runtime with the real bridge's surface; `load` always errors.
    pub struct Runtime {
        pub artifact: ArtifactConfig,
        pub shape: ModelShape,
        _sealed: (),
    }

    impl Runtime {
        pub fn load(_artifact: &ArtifactConfig) -> Result<Self, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn upload_f32(&self, _data: &[f32], _dims: &[usize]) -> Result<DeviceBuffer, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn upload_i32(&self, _data: &[i32], _dims: &[usize]) -> Result<DeviceBuffer, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn train_step(
            &self,
            _state: &DeviceBuffer,
            _centers: &DeviceBuffer,
            _ctx: &DeviceBuffer,
            _weights: &DeviceBuffer,
            _lr: &DeviceBuffer,
        ) -> Result<DeviceBuffer, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn read_metrics(&self, _state: &DeviceBuffer) -> Result<Vec<f32>, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn similarity(
            &self,
            _state: &DeviceBuffer,
            _queries: &[i32],
            _candidates: &[i32],
        ) -> Result<Vec<f32>, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn download_state(&self, _state: &DeviceBuffer) -> Result<Vec<f32>, String> {
            Err(UNAVAILABLE.to_string())
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{DeviceBuffer, Runtime};

// The PJRT engine as a [`Backend`]: the macro-batch protocol maps to
// uploads of the index tensors plus one chained `train_step` whose output
// state buffer replaces the input. Written once against the shared
// surface of the real bridge and the stub, so generic callers compile —
// and unit-test — with or without the `xla` feature.
impl crate::runtime::backend::Backend for Runtime {
    type State = DeviceBuffer;

    fn shape(&self) -> &crate::runtime::backend::ModelShape {
        &self.shape
    }

    fn name(&self) -> &'static str {
        "xla"
    }

    fn state_from_host(&self, host: &[f32]) -> Result<DeviceBuffer, String> {
        let a = &self.artifact;
        if host.len() != a.rows * a.dim {
            return Err(format!(
                "packed state length {} != rows*dim = {}",
                host.len(),
                a.rows * a.dim
            ));
        }
        self.upload_f32(host, &[a.rows, a.dim])
    }

    fn train_macro_batch(
        &self,
        state: &mut DeviceBuffer,
        centers: &[i32],
        ctx: &[i32],
        weights: &[f32],
        lr: f32,
    ) -> Result<(), String> {
        let a = &self.artifact;
        debug_assert_eq!(centers.len(), a.batch_capacity());
        debug_assert_eq!(ctx.len(), a.batch_capacity() * a.k1());
        debug_assert_eq!(weights.len(), a.batch_capacity());
        let c = self.upload_i32(centers, &[a.steps, a.batch])?;
        let x = self.upload_i32(ctx, &[a.steps, a.batch, a.k1()])?;
        let w = self.upload_f32(weights, &[a.steps, a.batch])?;
        let l = self.upload_f32(&[lr], &[1])?;
        *state = self.train_step(state, &c, &x, &w, &l)?;
        Ok(())
    }

    fn metrics(&self, state: &DeviceBuffer) -> Result<crate::runtime::params::Metrics, String> {
        Ok(crate::runtime::params::Metrics::from_row(
            &self.read_metrics(state)?,
        ))
    }

    fn similarity(&self, state: &DeviceBuffer, pairs: &[(u32, u32)]) -> Result<Vec<f32>, String> {
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(self.artifact.sim_q.max(1)) {
            let q: Vec<i32> = chunk.iter().map(|p| p.0 as i32).collect();
            let c: Vec<i32> = chunk.iter().map(|p| p.1 as i32).collect();
            out.extend(Runtime::similarity(self, state, &q, &c)?);
        }
        Ok(out)
    }

    fn download(&self, state: &DeviceBuffer) -> Result<Vec<f32>, String> {
        self.download_state(state)
    }
}

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        // a throwaway artifact config; load must fail before touching files
        let cfg = crate::runtime::artifacts::ArtifactConfig {
            name: "none".to_string(),
            vocab: 8,
            dim: 4,
            batch: 2,
            negatives: 1,
            steps: 1,
            rows: 18,
            pad_row: 16,
            metrics_row: 17,
            sim_q: 8,
            vmem_block_bytes: 1024,
            train_file: "/nonexistent/t".into(),
            metrics_file: "/nonexistent/m".into(),
            sim_file: "/nonexistent/s".into(),
        };
        let err = super::Runtime::load(&cfg).unwrap_err();
        assert!(err.contains("xla"), "error should name the feature: {err}");
    }
}
