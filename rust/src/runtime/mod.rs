//! PJRT runtime: artifact manifest resolution, executable loading, and
//! device-resident sub-model state (the rust side of the AOT bridge).
pub mod artifacts;
pub mod client;
pub mod params;
