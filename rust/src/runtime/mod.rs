//! Runtime layer: the [`backend::Backend`] abstraction over the SGNS
//! macro-batch protocol, its two engines (the pure-rust
//! [`native::NativeBackend`] and the PJRT/XLA bridge in [`client`]),
//! artifact manifest resolution, and backend-resident sub-model state.
pub mod artifacts;
pub mod backend;
pub mod client;
pub mod native;
pub mod params;

pub use backend::{load_backend, AnyBackend, Backend, ModelShape};
