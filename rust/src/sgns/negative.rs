//! Negative sampling from the unigram^0.75 noise distribution.
//!
//! word2vec draws k negatives per positive from P(w) ∝ count(w)^{3/4}.
//! The original implementation materializes a 100M-slot table; we use
//! Walker's alias method instead: same O(1) draw, O(V) memory, exact
//! probabilities. An optional CDF binary-search sampler is kept as the
//! ablation comparator (`cargo bench --bench perf_hotpath`).

use crate::util::rng::Pcg64;

/// Alias-method sampler over word ids.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from unnormalized weights.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "empty weight vector");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must have positive mass");
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut alias: Vec<u32> = (0..n as u32).collect();
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s as usize] = l;
            // large donates its excess to small
            prob[l as usize] = (prob[l as usize] + prob[s as usize]) - 1.0;
            if prob[l as usize] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // leftovers are numerically 1.0
        for i in small.into_iter().chain(large) {
            prob[i as usize] = 1.0;
        }
        Self { prob, alias }
    }

    /// Build the word2vec noise distribution count^power (power = 0.75).
    pub fn unigram_noise(counts: &[u64], power: f64) -> Self {
        let weights: Vec<f64> = counts.iter().map(|&c| (c as f64).powf(power)).collect();
        Self::new(&weights)
    }

    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        let n = self.prob.len();
        let i = rng.gen_range_usize(n);
        if rng.gen_f64() < self.prob[i] {
            i as u32
        } else {
            self.alias[i]
        }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

/// CDF + binary-search sampler — the ablation baseline for the alias table.
#[derive(Clone, Debug)]
pub struct CdfTable {
    cdf: Vec<f64>,
}

impl CdfTable {
    /// Build from unnormalized weights. Panics on an empty or non-positive
    /// total — the same contract as [`AliasTable::new`], so the two
    /// samplers are interchangeable (a zero total would otherwise divide
    /// into an all-NaN cdf whose binary search returns garbage slots).
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "empty weight vector");
        let mut cdf: Vec<f64> = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for w in weights {
            acc += w;
            cdf.push(acc);
        }
        assert!(
            acc > 0.0 && acc.is_finite(),
            "weights must have positive mass"
        );
        for c in &mut cdf {
            *c /= acc;
        }
        Self { cdf }
    }

    pub fn unigram_noise(counts: &[u64], power: f64) -> Self {
        let weights: Vec<f64> = counts.iter().map(|&c| (c as f64).powf(power)).collect();
        Self::new(&weights)
    }

    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        let u = rng.gen_f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => ((i + 1).min(self.cdf.len() - 1)) as u32,
            Err(i) => (i.min(self.cdf.len() - 1)) as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(table: &AliasTable, draws: usize, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        let mut counts = vec![0u64; n];
        for _ in 0..draws {
            counts[table.sample(&mut rng) as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn alias_matches_target_distribution() {
        let weights = [1.0, 2.0, 3.0, 4.0];
        let table = AliasTable::new(&weights);
        let freq = empirical(&table, 200_000, 4, 1);
        for (i, w) in weights.iter().enumerate() {
            let expect = w / 10.0;
            assert!(
                (freq[i] - expect).abs() < 0.01,
                "slot {i}: got {} want {expect}",
                freq[i]
            );
        }
    }

    #[test]
    fn alias_handles_degenerate_single_element() {
        let table = AliasTable::new(&[5.0]);
        let mut rng = Pcg64::new(2);
        for _ in 0..100 {
            assert_eq!(table.sample(&mut rng), 0);
        }
    }

    #[test]
    fn alias_handles_zero_weights() {
        let table = AliasTable::new(&[0.0, 1.0, 0.0, 1.0]);
        let mut rng = Pcg64::new(3);
        for _ in 0..1000 {
            let s = table.sample(&mut rng);
            assert!(s == 1 || s == 3, "sampled zero-weight slot {s}");
        }
    }

    #[test]
    fn unigram_power_flattens_distribution() {
        // ^0.75 must give the tail more mass than raw counts
        let counts = [1000u64, 10];
        let raw = AliasTable::unigram_noise(&counts, 1.0);
        let flat = AliasTable::unigram_noise(&counts, 0.75);
        let f_raw = empirical(&raw, 100_000, 2, 4)[1];
        let f_flat = empirical(&flat, 100_000, 2, 4)[1];
        assert!(f_flat > f_raw, "0.75 power should upweight rare words");
    }

    #[test]
    fn cdf_and_alias_agree() {
        let weights = [0.5, 0.1, 3.0, 1.2, 0.7];
        let alias = AliasTable::new(&weights);
        let cdf = CdfTable::new(&weights);
        let mut rng1 = Pcg64::new(5);
        let mut rng2 = Pcg64::new(6);
        let n = 100_000;
        let mut c1 = vec![0u64; 5];
        let mut c2 = vec![0u64; 5];
        for _ in 0..n {
            c1[alias.sample(&mut rng1) as usize] += 1;
            c2[cdf.sample(&mut rng2) as usize] += 1;
        }
        for i in 0..5 {
            let f1 = c1[i] as f64 / n as f64;
            let f2 = c2[i] as f64 / n as f64;
            assert!((f1 - f2).abs() < 0.012, "slot {i}: alias {f1} cdf {f2}");
        }
    }

    #[test]
    #[should_panic(expected = "positive mass")]
    fn rejects_all_zero_weights() {
        AliasTable::new(&[0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "positive mass")]
    fn cdf_rejects_all_zero_weights() {
        CdfTable::new(&[0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "empty weight vector")]
    fn cdf_rejects_empty_weights() {
        CdfTable::new(&[]);
    }

    #[test]
    #[should_panic(expected = "positive mass")]
    fn cdf_rejects_nan_total() {
        CdfTable::new(&[1.0, f64::NAN]);
    }

    #[test]
    fn cdf_handles_degenerate_single_element() {
        let table = CdfTable::new(&[5.0]);
        let mut rng = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(table.sample(&mut rng), 0);
        }
    }

    #[test]
    fn cdf_skips_zero_weight_slots() {
        let table = CdfTable::new(&[0.0, 1.0, 0.0, 1.0]);
        let mut rng = Pcg64::new(8);
        for _ in 0..2000 {
            let s = table.sample(&mut rng);
            assert!(s == 1 || s == 3, "sampled zero-weight slot {s}");
        }
    }

    /// A single-word vocabulary must work through both samplers — the
    /// smallest corpus a text ingest can produce.
    #[test]
    fn single_word_unigram_noise_on_both_samplers() {
        let counts = [12u64];
        let alias = AliasTable::unigram_noise(&counts, 0.75);
        let cdf = CdfTable::unigram_noise(&counts, 0.75);
        let mut rng = Pcg64::new(9);
        for _ in 0..50 {
            assert_eq!(alias.sample(&mut rng), 0);
            assert_eq!(cdf.sample(&mut rng), 0);
        }
    }
}
