//! The PJRT-backed SGNS trainer: one instance per reducer/sub-model.
//!
//! Wires the streaming [`BatchBuilder`] to a device-resident [`SubModel`]:
//! sentences come in from the mapper, full macro-batches are dispatched to
//! the AOT executable, the learning rate follows the word2vec linear decay
//! on the dispatched-pair counter, and per-word receive counts drive the
//! sub-model's presence mask (paper §4.2: per-sub-model frequency
//! threshold 100/k).

use super::batch::{BatchBuilder, BatchShape, MacroBatch};
use super::config::SgnsConfig;
use super::negative::AliasTable;
use crate::embedding::Embedding;
use crate::runtime::client::Runtime;
use crate::runtime::params::{Metrics, SubModel};
use crate::text::vocab::Vocab;
use crate::util::rng::Pcg64;

pub struct SubModelTrainer<'rt> {
    rt: &'rt Runtime,
    model: SubModel,
    builder: BatchBuilder,
    cfg: SgnsConfig,
    actual_vocab: usize,
    /// expected total pairs across all epochs (lr schedule denominator)
    expected_pairs: u64,
    /// pairs already sent to the device (lr schedule numerator)
    dispatched_pairs: u64,
    /// per-word tokens routed to this sub-model (presence mask)
    seen_counts: Vec<u64>,
    /// reusable emission buffer (steady-state: capacity stays allocated)
    ready: Vec<MacroBatch>,
    pub sentences_received: u64,
    /// cumulative wall-clock spent in device dispatches — the per-reducer
    /// "busy time" a dedicated cluster node would experience as its train
    /// phase (Table 4's per-model training time)
    pub device_secs: f64,
}

impl<'rt> SubModelTrainer<'rt> {
    /// `expected_pairs` should estimate the total pairs this trainer will
    /// see over the whole run (tokens_routed × window × epochs) — it only
    /// shapes the lr decay.
    pub fn new(
        rt: &'rt Runtime,
        vocab: &Vocab,
        cfg: &SgnsConfig,
        expected_pairs: u64,
        seed: u64,
    ) -> Result<Self, String> {
        let a = &rt.artifact;
        assert!(vocab.len() <= a.vocab, "vocab exceeds artifact capacity");
        assert_eq!(cfg.dim, a.dim, "dim mismatch with artifact");
        let shape = BatchShape {
            batch: a.batch,
            steps: a.steps,
            negatives: a.negatives,
            vocab: a.vocab, // padding sentinel = artifact vocab
        };
        let noise = AliasTable::unigram_noise(vocab.counts(), cfg.noise_power);
        let keep = BatchBuilder::keep_table(vocab.counts(), cfg.subsample_t);
        let builder = BatchBuilder::new(
            shape,
            cfg.window,
            keep,
            noise,
            Pcg64::new_stream(seed, 0x6261), // "ba"
        );
        Ok(Self {
            rt,
            model: SubModel::init(rt, seed)?,
            builder,
            cfg: cfg.clone(),
            actual_vocab: vocab.len(),
            expected_pairs: expected_pairs.max(1),
            dispatched_pairs: 0,
            seen_counts: vec![0; vocab.len()],
            ready: Vec::new(),
            sentences_received: 0,
            device_secs: 0.0,
        })
    }

    fn drain_ready(&mut self) -> Result<(), String> {
        // take the buffer to avoid borrowing self twice
        let mut ready = std::mem::take(&mut self.ready);
        for mb in ready.drain(..) {
            let lr = self.cfg.lr_at(self.dispatched_pairs, self.expected_pairs);
            self.dispatched_pairs += mb.real_pairs as u64;
            let t = std::time::Instant::now();
            self.model
                .train_macro_batch(self.rt, &mb.centers, &mb.ctx, &mb.weights, lr)?;
            self.device_secs += t.elapsed().as_secs_f64();
        }
        self.ready = ready; // keep the allocation
        Ok(())
    }

    /// Feed one sentence; dispatches to the device whenever macro-batches
    /// fill up. `sentence_id` must identify the (epoch, sentence) pair so
    /// pair extraction is independent of delivery order.
    pub fn push_sentence(&mut self, sentence_id: u64, sentence: &[u32]) -> Result<(), String> {
        self.sentences_received += 1;
        for &w in sentence {
            if (w as usize) < self.actual_vocab {
                self.seen_counts[w as usize] += 1;
            }
        }
        let ready = &mut self.ready;
        self.builder.push_sentence(sentence_id, sentence, &mut |mb| ready.push(mb));
        if self.ready.is_empty() {
            Ok(())
        } else {
            self.drain_ready()
        }
    }

    /// Flush the partial batch (padded) — call at the end of every epoch.
    pub fn flush(&mut self) -> Result<(), String> {
        let ready = &mut self.ready;
        self.builder.flush(&mut |mb| ready.push(mb));
        self.drain_ready()
    }

    pub fn pairs_emitted(&self) -> u64 {
        self.builder.pairs_emitted
    }

    pub fn dispatches(&self) -> u64 {
        self.model.dispatches
    }

    pub fn metrics(&self) -> Result<Metrics, String> {
        self.model.metrics(self.rt)
    }

    /// Words this trainer would mark present at threshold `min_count`.
    pub fn present_mask(&self, min_count: u64) -> Vec<bool> {
        self.seen_counts
            .iter()
            .map(|&c| c >= min_count.max(1))
            .collect()
    }

    /// Finish training: flush, apply the presence threshold, download `W`.
    pub fn into_embedding(mut self, min_count: u64) -> Result<Embedding, String> {
        self.flush()?;
        let present = self.present_mask(min_count);
        self.model.into_embedding(self.rt, self.actual_vocab, present)
    }
}
