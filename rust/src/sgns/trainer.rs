//! The backend-driven SGNS trainer: one instance per reducer/sub-model.
//!
//! Wires the streaming [`BatchBuilder`] to a backend-resident
//! [`SubModel`]: sentences come in from the mapper, full macro-batches
//! are dispatched through the [`Backend`] (native kernels or the PJRT
//! executable), the learning rate follows the word2vec linear decay on
//! the dispatched-pair counter, and per-word receive counts drive the
//! sub-model's presence mask (paper §4.2: per-sub-model frequency
//! threshold 100/k).

use super::batch::{BatchBuilder, BatchShape, MacroBatch};
use super::config::SgnsConfig;
use super::negative::AliasTable;
use crate::embedding::Embedding;
use crate::runtime::backend::Backend;
use crate::runtime::params::{Metrics, SubModel};
use crate::text::vocab::Vocab;
use crate::util::rng::Pcg64;

/// A resumable snapshot of a [`SubModelTrainer`] taken at an epoch
/// boundary (partial batch drained). The batch builder's base RNG never
/// advances — every sentence derives a child stream from the immutable
/// base — so the snapshot carries **no RNG state**: packed parameters
/// plus the counters below reconstruct the trainer losslessly, and on
/// the native backend a restored trainer replays the remaining epochs
/// bitwise identical to an uninterrupted one.
#[derive(Clone, Debug)]
pub struct TrainerSnapshot {
    /// full packed `[rows, dim]` device state
    pub packed: Vec<f32>,
    /// per-word receive counts (presence mask input)
    pub seen_counts: Vec<u64>,
    /// lr-schedule position
    pub dispatched_pairs: u64,
    /// builder's cumulative pair counter (== dispatched at a boundary)
    pub pairs_emitted: u64,
    pub sentences_received: u64,
    pub dispatches: u64,
    /// exact f64 loss counters (the packed row rounds them to f32)
    pub metrics: Metrics,
}

pub struct SubModelTrainer<'b, B: Backend> {
    backend: &'b B,
    model: SubModel<B>,
    builder: BatchBuilder,
    cfg: SgnsConfig,
    actual_vocab: usize,
    /// expected total pairs across all epochs (lr schedule denominator)
    expected_pairs: u64,
    /// pairs already dispatched to the backend (lr schedule numerator)
    dispatched_pairs: u64,
    /// per-word tokens routed to this sub-model (presence mask)
    seen_counts: Vec<u64>,
    /// reusable emission buffer (steady-state: capacity stays allocated)
    ready: Vec<MacroBatch>,
    pub sentences_received: u64,
    /// cumulative wall-clock spent in backend dispatches — the per-reducer
    /// "busy time" a dedicated cluster node would experience as its train
    /// phase (Table 4's per-model training time)
    pub device_secs: f64,
}

impl<'b, B: Backend> SubModelTrainer<'b, B> {
    /// `expected_pairs` should estimate the total pairs this trainer will
    /// see over the whole run (tokens_routed × window × epochs) — it only
    /// shapes the lr decay.
    pub fn new(
        backend: &'b B,
        vocab: &Vocab,
        cfg: &SgnsConfig,
        expected_pairs: u64,
        seed: u64,
    ) -> Result<Self, String> {
        let sh = backend.shape();
        assert!(vocab.len() <= sh.vocab, "vocab exceeds backend capacity");
        assert_eq!(cfg.dim, sh.dim, "dim mismatch with backend shape");
        let shape = BatchShape {
            batch: sh.batch,
            steps: sh.steps,
            negatives: sh.negatives,
            vocab: sh.vocab, // padding sentinel = backend vocab capacity
        };
        let noise = AliasTable::unigram_noise(vocab.counts(), cfg.noise_power);
        let keep = BatchBuilder::keep_table(vocab.counts(), cfg.subsample_t);
        let builder = BatchBuilder::new(
            shape,
            cfg.window,
            keep,
            noise,
            Pcg64::new_stream(seed, 0x6261), // "ba"
        );
        Ok(Self {
            backend,
            model: SubModel::init(backend, seed)?,
            builder,
            cfg: cfg.clone(),
            actual_vocab: vocab.len(),
            expected_pairs: expected_pairs.max(1),
            dispatched_pairs: 0,
            seen_counts: vec![0; vocab.len()],
            ready: Vec::new(),
            sentences_received: 0,
            device_secs: 0.0,
        })
    }

    fn drain_ready(&mut self) -> Result<(), String> {
        // take the buffer to avoid borrowing self twice
        let mut ready = std::mem::take(&mut self.ready);
        for mb in ready.drain(..) {
            let lr = self.cfg.lr_at(self.dispatched_pairs, self.expected_pairs);
            self.dispatched_pairs += mb.real_pairs as u64;
            let t = std::time::Instant::now();
            self.model
                .train_macro_batch(self.backend, &mb.centers, &mb.ctx, &mb.weights, lr)?;
            self.device_secs += t.elapsed().as_secs_f64();
        }
        self.ready = ready; // keep the allocation
        Ok(())
    }

    /// Feed one sentence; dispatches to the backend whenever macro-batches
    /// fill up. `sentence_id` must identify the (epoch, sentence) pair so
    /// pair extraction is independent of delivery order.
    pub fn push_sentence(&mut self, sentence_id: u64, sentence: &[u32]) -> Result<(), String> {
        self.sentences_received += 1;
        for &w in sentence {
            if (w as usize) < self.actual_vocab {
                self.seen_counts[w as usize] += 1;
            }
        }
        let ready = &mut self.ready;
        self.builder.push_sentence(sentence_id, sentence, &mut |mb| ready.push(mb));
        if self.ready.is_empty() {
            Ok(())
        } else {
            self.drain_ready()
        }
    }

    /// Flush the partial batch (padded) — call at the end of every epoch.
    pub fn flush(&mut self) -> Result<(), String> {
        let ready = &mut self.ready;
        self.builder.flush(&mut |mb| ready.push(mb));
        self.drain_ready()
    }

    pub fn pairs_emitted(&self) -> u64 {
        self.builder.pairs_emitted
    }

    pub fn dispatches(&self) -> u64 {
        self.model.dispatches
    }

    pub fn metrics(&self) -> Result<Metrics, String> {
        self.model.metrics(self.backend)
    }

    /// Capture a [`TrainerSnapshot`]. Only legal at an epoch boundary —
    /// a partially filled macro-batch cannot be serialized (its pair
    /// stream is mid-sentence), so callers flush first; the builder is
    /// always empty right after an epoch's `flush()`.
    pub fn snapshot(&self) -> Result<TrainerSnapshot, String> {
        if self.builder.pending() != 0 {
            return Err(format!(
                "cannot snapshot mid-batch: {} pairs pending (snapshot only at epoch \
                 boundaries, after flush)",
                self.builder.pending()
            ));
        }
        Ok(TrainerSnapshot {
            packed: self.model.download_packed(self.backend)?,
            seen_counts: self.seen_counts.clone(),
            dispatched_pairs: self.dispatched_pairs,
            pairs_emitted: self.builder.pairs_emitted,
            sentences_received: self.sentences_received,
            dispatches: self.model.dispatches,
            metrics: self.metrics()?,
        })
    }

    /// Overwrite this (freshly constructed) trainer with a snapshot's
    /// state: packed parameters, exact loss counters, and every progress
    /// counter. The trainer must have been built with the same backend
    /// shape, vocab, and seed as the one that was snapshotted — the seed
    /// lives in the builder's derive-only RNG, which restore does not
    /// (and need not) touch.
    pub fn restore(&mut self, snap: &TrainerSnapshot) -> Result<(), String> {
        if snap.packed.len() != self.backend.shape().state_len() {
            return Err(format!(
                "snapshot state length {} != backend rows*dim = {}",
                snap.packed.len(),
                self.backend.shape().state_len()
            ));
        }
        if snap.seen_counts.len() != self.actual_vocab {
            return Err(format!(
                "snapshot seen-count vocab {} != trainer vocab {}",
                snap.seen_counts.len(),
                self.actual_vocab
            ));
        }
        let mut model = SubModel::from_host(self.backend, &snap.packed)?;
        model.restore_metrics(self.backend, snap.metrics)?;
        model.dispatches = snap.dispatches;
        self.model = model;
        self.seen_counts = snap.seen_counts.clone();
        self.dispatched_pairs = snap.dispatched_pairs;
        self.builder.pairs_emitted = snap.pairs_emitted;
        self.sentences_received = snap.sentences_received;
        Ok(())
    }

    /// Words this trainer would mark present at threshold `min_count`.
    pub fn present_mask(&self, min_count: u64) -> Vec<bool> {
        self.seen_counts
            .iter()
            .map(|&c| c >= min_count.max(1))
            .collect()
    }

    /// Finish training: flush, apply the presence threshold, download `W`.
    pub fn into_embedding(mut self, min_count: u64) -> Result<Embedding, String> {
        self.flush()?;
        let present = self.present_mask(min_count);
        self.model
            .into_embedding(self.backend, self.actual_vocab, present)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::ModelShape;
    use crate::runtime::native::NativeBackend;

    fn vocab(n: usize) -> Vocab {
        Vocab::from_ordered((0..n).map(|i| (format!("w{i}"), 10)).collect())
    }

    #[test]
    fn trainer_presence_mask_respects_min_count() {
        let be = NativeBackend::new(ModelShape::native(64, 8, 8, 2, 2));
        let vocab = vocab(60);
        let cfg = SgnsConfig {
            dim: 8,
            negatives: 2,
            ..Default::default()
        };
        let mut trainer = SubModelTrainer::new(&be, &vocab, &cfg, 1000, 11).unwrap();
        // words 0..5 appear 4 times each, word 6 once
        for _ in 0..4 {
            trainer.push_sentence(0, &[0, 1, 2, 3, 4, 5]).unwrap();
        }
        trainer.push_sentence(99, &[6, 0]).unwrap();
        let mask = trainer.present_mask(3);
        assert!(mask[..6].iter().all(|&m| m));
        assert!(!mask[6]);
        assert!(!mask[30]);
        let emb = trainer.into_embedding(3).unwrap();
        assert_eq!(emb.present_count(), 6);
        assert_eq!(emb.vocab, 60);
    }

    #[test]
    fn snapshot_restore_resumes_bitwise() {
        let be = NativeBackend::new(ModelShape::native(64, 8, 4, 2, 2));
        let vocab = vocab(64);
        let cfg = SgnsConfig {
            dim: 8,
            negatives: 2,
            window: 3,
            subsample_t: 0.0,
            ..Default::default()
        };
        let sentences: Vec<Vec<u32>> = (0..60u64)
            .map(|sid| (0..9u32).map(|i| (sid as u32 * 13 + i * 5) % 64).collect())
            .collect();
        let sid = |epoch: u64, idx: usize| (epoch << 40) | idx as u64;

        // uninterrupted reference: two epochs straight through
        let mut whole = SubModelTrainer::new(&be, &vocab, &cfg, 10_000, 21).unwrap();
        for epoch in 0..2u64 {
            for (idx, s) in sentences.iter().enumerate() {
                whole.push_sentence(sid(epoch, idx), s).unwrap();
            }
            whole.flush().unwrap();
        }

        // interrupted: epoch 0, snapshot, fresh trainer, restore, epoch 1
        let mut first = SubModelTrainer::new(&be, &vocab, &cfg, 10_000, 21).unwrap();
        for (idx, s) in sentences.iter().enumerate() {
            first.push_sentence(sid(0, idx), s).unwrap();
        }
        first.flush().unwrap();
        let snap = first.snapshot().unwrap();
        drop(first);
        let mut resumed = SubModelTrainer::new(&be, &vocab, &cfg, 10_000, 21).unwrap();
        resumed.restore(&snap).unwrap();
        for (idx, s) in sentences.iter().enumerate() {
            resumed.push_sentence(sid(1, idx), s).unwrap();
        }
        resumed.flush().unwrap();

        let a = whole.snapshot().unwrap();
        let b = resumed.snapshot().unwrap();
        assert_eq!(a.dispatched_pairs, b.dispatched_pairs);
        assert_eq!(a.pairs_emitted, b.pairs_emitted);
        assert_eq!(a.dispatches, b.dispatches);
        assert_eq!(a.sentences_received, b.sentences_received);
        assert_eq!(a.seen_counts, b.seen_counts);
        assert_eq!(a.metrics.loss_sum.to_bits(), b.metrics.loss_sum.to_bits());
        assert_eq!(a.metrics.examples.to_bits(), b.metrics.examples.to_bits());
        for (i, (x, y)) in a.packed.iter().zip(&b.packed).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "packed state diverges at {i}");
        }
    }

    #[test]
    fn snapshot_mid_batch_is_refused() {
        let be = NativeBackend::new(ModelShape::native(64, 8, 8, 2, 2));
        let vocab = vocab(64);
        let cfg = SgnsConfig {
            dim: 8,
            negatives: 2,
            window: 3,
            subsample_t: 0.0,
            ..Default::default()
        };
        let mut t = SubModelTrainer::new(&be, &vocab, &cfg, 10_000, 9).unwrap();
        let mut idx = 0u64;
        while t.builder.pending() == 0 {
            t.push_sentence(idx, &[1, 2, 3, 4, 5, 6, 7]).unwrap();
            idx += 1;
            assert!(idx < 1000, "builder never accumulated a partial batch");
        }
        let err = t.snapshot().unwrap_err();
        assert!(err.contains("pending"), "{err}");
        t.flush().unwrap();
        assert!(t.snapshot().is_ok(), "boundary snapshot must succeed");
    }

    #[test]
    fn trainer_dispatches_and_counts_pairs() {
        let be = NativeBackend::new(ModelShape::native(64, 8, 4, 2, 2));
        let vocab = vocab(64);
        let cfg = SgnsConfig {
            dim: 8,
            negatives: 2,
            window: 3,
            subsample_t: 0.0,
            ..Default::default()
        };
        let mut trainer = SubModelTrainer::new(&be, &vocab, &cfg, 10_000, 3).unwrap();
        for sid in 0..40u64 {
            let sent: Vec<u32> = (0..10).map(|i| ((sid as u32 * 7 + i) % 64)).collect();
            trainer.push_sentence(sid, &sent).unwrap();
        }
        trainer.flush().unwrap();
        assert!(trainer.pairs_emitted() > 100);
        assert!(trainer.dispatches() > 0);
        let m = trainer.metrics().unwrap();
        assert!(m.loss_sum > 0.0);
        assert!((m.examples - trainer.pairs_emitted() as f64).abs() < 1e-3);
    }
}
