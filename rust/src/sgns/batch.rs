//! Training-pair extraction and macro-batch assembly.
//!
//! This is the Layer-3 side of the hot path: sentences stream in, and out
//! come fixed-shape macro-batches matching the AOT artifact's signature
//! (`centers[S,B]`, `ctx[S,B,K+1]`, `weights[S,B]`). Semantics follow
//! word2vec: dynamic window (uniform in [1, window]), frequent-word
//! subsampling applied *before* windowing, `K` negatives per positive from
//! the unigram^0.75 alias table.
//!
//! Index convention (shared with python/compile/model.py): ids are
//! vocab-relative `0..V-1`; `V` is the padding sentinel that maps to the
//! artifact's zero pad-row with weight 0.

use super::negative::AliasTable;
use crate::util::rng::Pcg64;

/// One dispatch-ready macro-batch (S micro-steps × B examples).
#[derive(Clone, Debug)]
pub struct MacroBatch {
    pub centers: Vec<i32>, // S*B
    pub ctx: Vec<i32>,     // S*B*(K+1); col 0 = positive
    pub weights: Vec<f32>, // S*B
    pub real_pairs: usize,
}

/// Shape parameters of the artifact the batches must match.
#[derive(Clone, Copy, Debug)]
pub struct BatchShape {
    pub batch: usize,     // B
    pub steps: usize,     // S
    pub negatives: usize, // K
    pub vocab: usize,     // V (also the padding sentinel)
}

impl BatchShape {
    pub fn k1(&self) -> usize {
        self.negatives + 1
    }

    pub fn capacity(&self) -> usize {
        self.batch * self.steps
    }
}

/// Streaming builder: feed sentences, emit full macro-batches via callback.
pub struct BatchBuilder {
    shape: BatchShape,
    window: usize,
    /// per-word keep probability for subsampling (empty = disabled)
    keep_prob: Vec<f32>,
    noise: AliasTable,
    rng: Pcg64,
    // fill state
    centers: Vec<i32>,
    ctx: Vec<i32>,
    weights: Vec<f32>,
    fill: usize,
    /// total real (non-pad) pairs emitted so far — drives lr decay
    pub pairs_emitted: u64,
    /// scratch: subsampled sentence
    kept: Vec<u32>,
}

impl BatchBuilder {
    pub fn new(
        shape: BatchShape,
        window: usize,
        keep_prob: Vec<f32>,
        noise: AliasTable,
        rng: Pcg64,
    ) -> Self {
        let cap = shape.capacity();
        let k1 = shape.k1();
        Self {
            shape,
            window: window.max(1),
            keep_prob,
            noise,
            rng,
            centers: vec![shape.vocab as i32; cap],
            ctx: vec![shape.vocab as i32; cap * k1],
            weights: vec![0.0; cap],
            fill: 0,
            pairs_emitted: 0,
            kept: Vec::new(),
        }
    }

    /// Build the keep-probability table from vocab counts.
    pub fn keep_table(counts: &[u64], t: f64) -> Vec<f32> {
        if t <= 0.0 {
            return Vec::new();
        }
        let total: u64 = counts.iter().sum();
        counts
            .iter()
            .map(|&c| {
                let f = c as f64 / total.max(1) as f64;
                if f <= t {
                    1.0
                } else {
                    (((t / f).sqrt() + t / f) as f32).min(1.0)
                }
            })
            .collect()
    }

    #[inline]
    fn push_pair(
        &mut self,
        center: u32,
        pos: u32,
        rng: &mut Pcg64,
        emit: &mut impl FnMut(MacroBatch),
    ) {
        let k1 = self.shape.k1();
        let i = self.fill;
        self.centers[i] = center as i32;
        self.weights[i] = 1.0;
        self.ctx[i * k1] = pos as i32;
        for j in 1..k1 {
            // word2vec keeps negatives even when they collide with the
            // positive — the expectation argument tolerates it
            self.ctx[i * k1 + j] = self.noise.sample(rng) as i32;
        }
        self.fill += 1;
        self.pairs_emitted += 1;
        if self.fill == self.shape.capacity() {
            emit(self.take_batch());
        }
    }

    fn take_batch(&mut self) -> MacroBatch {
        let cap = self.shape.capacity();
        let k1 = self.shape.k1();
        let pad = self.shape.vocab as i32;
        let batch = MacroBatch {
            centers: std::mem::replace(&mut self.centers, vec![pad; cap]),
            ctx: std::mem::replace(&mut self.ctx, vec![pad; cap * k1]),
            weights: std::mem::replace(&mut self.weights, vec![0.0; cap]),
            real_pairs: self.fill,
        };
        self.fill = 0;
        batch
    }

    /// Process one sentence; full macro-batches are handed to `emit`.
    ///
    /// All randomness for a sentence (subsampling, window widths, negative
    /// draws) comes from a stream derived from `(builder seed, sentence_id)`
    /// — **order-independent**, so a run's pair extraction is reproducible
    /// no matter how mapper threads interleave deliveries. `sentence_id`
    /// should be the global sentence index mixed with the epoch.
    pub fn push_sentence(
        &mut self,
        sentence_id: u64,
        sentence: &[u32],
        emit: &mut impl FnMut(MacroBatch),
    ) {
        let mut rng = self.rng.derive(sentence_id);
        // subsample frequent words first (word2vec order)
        self.kept.clear();
        for &w in sentence {
            debug_assert!((w as usize) < self.shape.vocab);
            let keep = self
                .keep_prob
                .get(w as usize)
                .copied()
                .unwrap_or(1.0);
            if keep >= 1.0 || rng.gen_f32() < keep {
                self.kept.push(w);
            }
        }
        if self.kept.len() < 2 {
            return;
        }
        let kept = std::mem::take(&mut self.kept); // appease the borrow checker
        for (pos, &center) in kept.iter().enumerate() {
            let win = 1 + rng.gen_range_usize(self.window);
            let lo = pos.saturating_sub(win);
            let hi = (pos + win + 1).min(kept.len());
            for other in lo..hi {
                if other != pos {
                    self.push_pair(center, kept[other], &mut rng, emit);
                }
            }
        }
        self.kept = kept;
    }

    /// Flush the partially-filled batch (padded with sentinels).
    pub fn flush(&mut self, emit: &mut impl FnMut(MacroBatch)) {
        if self.fill > 0 {
            emit(self.take_batch());
        }
    }

    pub fn pending(&self) -> usize {
        self.fill
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> BatchShape {
        BatchShape {
            batch: 4,
            steps: 2,
            negatives: 2,
            vocab: 50,
        }
    }

    fn builder(subsample: Vec<f32>) -> BatchBuilder {
        let noise = AliasTable::new(&vec![1.0; 50]);
        BatchBuilder::new(shape(), 2, subsample, noise, Pcg64::new(1))
    }

    fn collect_batches(b: &mut BatchBuilder, sentences: &[Vec<u32>]) -> Vec<MacroBatch> {
        let mut out = Vec::new();
        for (i, s) in sentences.iter().enumerate() {
            b.push_sentence(i as u64, s, &mut |mb| out.push(mb));
        }
        b.flush(&mut |mb| out.push(mb));
        out
    }

    #[test]
    fn emits_full_shape_batches() {
        let mut b = builder(Vec::new());
        let sentences: Vec<Vec<u32>> = (0..6).map(|_| (0..6).collect()).collect();
        let batches = collect_batches(&mut b, &sentences);
        assert!(!batches.is_empty());
        for mb in &batches {
            assert_eq!(mb.centers.len(), 8);
            assert_eq!(mb.ctx.len(), 8 * 3);
            assert_eq!(mb.weights.len(), 8);
        }
    }

    #[test]
    fn pairs_are_center_context_within_window() {
        let mut b = builder(Vec::new());
        let batches = collect_batches(&mut b, &[vec![1, 2, 3, 4, 5]]);
        for mb in &batches {
            for i in 0..mb.centers.len() {
                if mb.weights[i] == 0.0 {
                    assert_eq!(mb.centers[i], 50); // padding sentinel
                    continue;
                }
                let c = mb.centers[i];
                let pos = mb.ctx[i * 3];
                assert!((1..=5).contains(&c));
                assert!((1..=5).contains(&pos));
                assert_ne!(c, pos, "center cannot be its own positive");
                assert!((c - pos).abs() <= 2, "window violated: {c} {pos}");
            }
        }
    }

    #[test]
    fn padding_is_sentinel_with_zero_weight() {
        let mut b = builder(Vec::new());
        // one tiny sentence -> partial batch, flushed with padding
        let batches = collect_batches(&mut b, &[vec![1, 2]]);
        assert_eq!(batches.len(), 1);
        let mb = &batches[0];
        assert!(mb.real_pairs >= 2);
        for i in mb.real_pairs..mb.centers.len() {
            assert_eq!(mb.centers[i], 50);
            assert_eq!(mb.weights[i], 0.0);
            for j in 0..3 {
                assert_eq!(mb.ctx[i * 3 + j], 50);
            }
        }
    }

    #[test]
    fn pair_count_conservation() {
        let mut b = builder(Vec::new());
        let sentences: Vec<Vec<u32>> = (0..20).map(|i| vec![i, i + 1, i + 2, i + 3]).collect();
        let batches = collect_batches(&mut b, &sentences);
        let total_real: usize = batches.iter().map(|mb| mb.real_pairs).sum();
        let weight_sum: f32 = batches.iter().flat_map(|mb| &mb.weights).sum();
        assert_eq!(total_real as f32, weight_sum);
        assert_eq!(total_real as u64, b.pairs_emitted);
    }

    #[test]
    fn subsampling_drops_frequent_word() {
        // word 0 has keep prob 0 — it must never appear
        let mut keep = vec![1.0f32; 50];
        keep[0] = 0.0;
        let mut b = builder(keep);
        let sentences: Vec<Vec<u32>> = (0..50).map(|_| vec![0, 1, 2, 0, 3]).collect();
        let batches = collect_batches(&mut b, &sentences);
        for mb in &batches {
            for i in 0..mb.centers.len() {
                if mb.weights[i] > 0.0 {
                    assert_ne!(mb.centers[i], 0);
                    assert_ne!(mb.ctx[i * 3], 0); // positive can't be word 0
                }
            }
        }
    }

    #[test]
    fn short_sentences_produce_nothing() {
        let mut b = builder(Vec::new());
        let batches = collect_batches(&mut b, &[vec![7], vec![]]);
        assert!(batches.is_empty());
        assert_eq!(b.pairs_emitted, 0);
    }

    #[test]
    fn keep_table_matches_word2vec_formula() {
        let counts = [900u64, 90, 10];
        let t = 0.05;
        let table = BatchBuilder::keep_table(&counts, t);
        // word 0: f = 0.9 >> t -> heavily subsampled
        assert!(table[0] < 0.5);
        // word 2: f = 0.01 <= t -> always kept
        assert_eq!(table[2], 1.0);
        // disabled
        assert!(BatchBuilder::keep_table(&counts, 0.0).is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let noise = AliasTable::new(&vec![1.0; 50]);
            BatchBuilder::new(shape(), 2, Vec::new(), noise, Pcg64::new(9))
        };
        let mut b1 = mk();
        let mut b2 = mk();
        let s: Vec<Vec<u32>> = (0..10).map(|_| (0..8).collect()).collect();
        let x1 = collect_batches(&mut b1, &s);
        let x2 = collect_batches(&mut b2, &s);
        assert_eq!(x1.len(), x2.len());
        for (a, b) in x1.iter().zip(&x2) {
            assert_eq!(a.centers, b.centers);
            assert_eq!(a.ctx, b.ctx);
        }
    }
}
