//! Hogwild SGNS: the paper's single-node baseline (Gensim/word2vec.c).
//!
//! Lock-free multithreaded SGD exactly as in Recht et al. [27] / the
//! original word2vec: all threads update the shared `W`/`C` matrices
//! through raw pointers with **no synchronization whatsoever** — races are
//! tolerated by design (conflicts are rare for large vocabularies). The
//! sigmoid is a lookup table like word2vec's `expTable`, and the learning
//! rate decays linearly on a pair counter.
//!
//! Two deliberate perf choices in the inner loop (see `crate::kernels`):
//! * the per-pair dot/update runs on the shared vectorized kernels
//!   (`dot_sigmoid_update` + `axpy`) instead of scalar loops;
//! * the lr schedule reads a **thread-local** pair count that is flushed
//!   to the shared atomic only every [`COUNTER_FLUSH`] pairs — word2vec's
//!   `word_count_actual` trick. A per-pair `fetch_add` puts one cache-line
//!   ping-pong on the critical path of every pair; the schedule happily
//!   tolerates a count that is stale by ≤ threads × COUNTER_FLUSH pairs,
//!   so we batch. Final totals stay exact because each thread flushes its
//!   remainder before exiting.
//!
//! This is deliberately the *CPU* implementation the paper timed as its
//! baseline; the PJRT trainer (`super::trainer`) is the paper-system's
//! per-reducer engine.

use super::batch::BatchBuilder;
use super::config::SgnsConfig;
use super::negative::AliasTable;
use crate::embedding::Embedding;
use crate::kernels;
use crate::text::corpus::Corpus;
use crate::text::vocab::Vocab;
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicU64, Ordering};

pub use crate::kernels::sigmoid::SigmoidTable;

/// Pairs accumulated locally before a thread publishes them to the shared
/// counter (word2vec flushes every 10k words for the same reason).
pub const COUNTER_FLUSH: u64 = 10_000;

/// Raw shared parameter block. Safety: Hogwild semantics — concurrent
/// unsynchronized writes are *intended*; torn f32 writes are benign on
/// x86-64 (aligned 4-byte stores are atomic at the hardware level).
struct SharedParams {
    w: *mut f32,
    c: *mut f32,
}

unsafe impl Send for SharedParams {}
unsafe impl Sync for SharedParams {}

/// Training statistics returned with the embedding.
#[derive(Debug, Clone, Default)]
pub struct TrainStats {
    pub pairs: u64,
    /// expected pairs the lr schedule annealed over (see
    /// [`super::schedule::expected_pairs`])
    pub expected_pairs: u64,
    /// learning rate at the end of training — lands near `lr_min` iff the
    /// pair expectation was calibrated
    pub final_lr: f32,
    pub seconds: f64,
    /// mean SGNS loss over the final epoch (monitoring only)
    pub final_epoch_loss: f64,
}

/// Train SGNS with Hogwild threads over the whole corpus.
///
/// Returns the input-embedding matrix `W` (the usual word vectors) plus
/// run statistics. `threads` sentence shards are trained concurrently per
/// epoch.
pub fn train(
    corpus: &Corpus,
    vocab: &Vocab,
    cfg: &SgnsConfig,
    threads: usize,
    seed: u64,
) -> (Embedding, TrainStats) {
    let v = vocab.len();
    let d = cfg.dim;
    let mut rng = Pcg64::new_stream(seed, 0x6877); // "hw"
    let mut w = vec![0.0f32; v * d];
    for x in &mut w {
        *x = (rng.gen_f32() - 0.5) / d as f32;
    }
    let mut c = vec![0.0f32; v * d];
    let noise = AliasTable::unigram_noise(vocab.counts(), cfg.noise_power);
    let keep = BatchBuilder::keep_table(vocab.counts(), cfg.subsample_t);
    let sigmoid = SigmoidTable::new();

    // expected total pairs for the lr schedule: subsampling keep-mass ×
    // mean dynamic window with boundary clipping (see `super::schedule`) —
    // the naive tokens × window × epochs is off in both directions
    let expected_pairs = super::schedule::expected_pairs(corpus, vocab, cfg);
    let pair_counter = AtomicU64::new(0);
    // global metrics ride the existing COUNTER_FLUSH cadence: resolve the
    // instrument once here, pay one extra fetch_add per 10k pairs per
    // thread at the flush points below (nothing per pair)
    let metrics_on = crate::obs::metrics::global().enabled();
    let pairs_metric = crate::obs::metrics::global().counter("sgns_pairs_total");
    let loss_accum = AtomicU64::new(0); // micro-units of 1e-6
    let loss_pairs = AtomicU64::new(0);

    let params = SharedParams {
        w: w.as_mut_ptr(),
        c: c.as_mut_ptr(),
    };
    let start = std::time::Instant::now();
    let threads = threads.max(1);

    for epoch in 0..cfg.epochs {
        let last_epoch = epoch + 1 == cfg.epochs;
        if last_epoch {
            loss_accum.store(0, Ordering::Relaxed);
            loss_pairs.store(0, Ordering::Relaxed);
        }
        std::thread::scope(|scope| {
            for t in 0..threads {
                let range = corpus.shard_range(t, threads);
                let sentences = &corpus.sentences[range];
                let noise = &noise;
                let keep = &keep;
                let sigmoid = &sigmoid;
                let params = &params;
                let pair_counter = &pair_counter;
                let loss_accum = &loss_accum;
                let loss_pairs = &loss_pairs;
                let pairs_metric = &pairs_metric;
                let mut trng =
                    Pcg64::new_stream(seed ^ 0x7468_7264, (epoch * threads + t) as u64);
                scope.spawn(move || {
                    let mut kept: Vec<u32> = Vec::new();
                    let mut neu: Vec<f32> = vec![0.0; d];
                    let mut local_pairs = 0u64;
                    let mut local_loss = 0.0f64;
                    // batched counter: lr reads done_snapshot + pending,
                    // the shared atomic is touched once per COUNTER_FLUSH
                    let mut done_snapshot = pair_counter.load(Ordering::Relaxed);
                    let mut pending = 0u64;
                    for sent in sentences {
                        // subsample
                        kept.clear();
                        for &word in sent {
                            let p = keep.get(word as usize).copied().unwrap_or(1.0);
                            if p >= 1.0 || trng.gen_f32() < p {
                                kept.push(word);
                            }
                        }
                        if kept.len() < 2 {
                            continue;
                        }
                        for pos in 0..kept.len() {
                            let center = kept[pos] as usize;
                            let win = 1 + trng.gen_range_usize(cfg.window);
                            let lo = pos.saturating_sub(win);
                            let hi = (pos + win + 1).min(kept.len());
                            for other in lo..hi {
                                if other == pos {
                                    continue;
                                }
                                let lr =
                                    cfg.lr_at(done_snapshot + pending, expected_pairs);
                                pending += 1;
                                if pending >= COUNTER_FLUSH {
                                    done_snapshot = pair_counter
                                        .fetch_add(pending, Ordering::Relaxed)
                                        + pending;
                                    if metrics_on {
                                        pairs_metric.add(pending);
                                    }
                                    pending = 0;
                                }
                                let target = kept[other] as usize;
                                // SAFETY: Hogwild — racy but benign
                                unsafe {
                                    let wrow = std::slice::from_raw_parts_mut(
                                        params.w.add(center * d),
                                        d,
                                    );
                                    neu.fill(0.0);
                                    // positive + negatives
                                    for s in 0..=cfg.negatives {
                                        let (ctx_id, label) = if s == 0 {
                                            (target, 1.0f32)
                                        } else {
                                            (noise.sample(&mut trng) as usize, 0.0f32)
                                        };
                                        let crow = std::slice::from_raw_parts_mut(
                                            params.c.add(ctx_id * d),
                                            d,
                                        );
                                        let dot = kernels::dot_sigmoid_update(
                                            wrow, crow, &mut neu, label, lr, sigmoid,
                                        );
                                        if last_epoch {
                                            // softplus loss for monitoring
                                            let x = if label > 0.5 { -dot } else { dot };
                                            local_loss +=
                                                (1.0 + x.exp()).ln().min(20.0) as f64;
                                        }
                                    }
                                    kernels::axpy(1.0, &neu, wrow);
                                }
                                local_pairs += 1;
                            }
                        }
                    }
                    if pending > 0 {
                        pair_counter.fetch_add(pending, Ordering::Relaxed);
                        if metrics_on {
                            pairs_metric.add(pending);
                        }
                    }
                    if last_epoch && local_pairs > 0 {
                        loss_accum.fetch_add(
                            (local_loss * 1e6) as u64,
                            Ordering::Relaxed,
                        );
                        loss_pairs.fetch_add(local_pairs, Ordering::Relaxed);
                    }
                });
            }
        });
    }

    let pairs = pair_counter.load(Ordering::Relaxed);
    let lp = loss_pairs.load(Ordering::Relaxed).max(1);
    let stats = TrainStats {
        pairs,
        expected_pairs,
        final_lr: cfg.lr_at(pairs, expected_pairs),
        seconds: start.elapsed().as_secs_f64(),
        final_epoch_loss: loss_accum.load(Ordering::Relaxed) as f64 * 1e-6 / lp as f64,
    };
    (Embedding::from_rows(v, d, w), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::corpus::{build_ground_truth, generate_corpus, vocab_of, GeneratorConfig};

    fn tiny_setup() -> (Corpus, Vocab, GeneratorConfig) {
        let gcfg = GeneratorConfig {
            vocab: 80,
            clusters: 8,
            truth_dim: 8,
            avg_sentence_len: 10,
            ..Default::default()
        };
        let gt = build_ground_truth(&gcfg, 5);
        let corpus = generate_corpus(&gt, 1500, 5);
        let vocab = vocab_of(&corpus, gcfg.vocab);
        (corpus, vocab, gcfg)
    }

    fn cluster_separation(emb: &Embedding, gcfg: &GeneratorConfig) -> (f64, f64) {
        let gt = build_ground_truth(gcfg, 5);
        let mut rng = Pcg64::new(1);
        let (mut same, mut cross) = (Vec::new(), Vec::new());
        for _ in 0..3000 {
            let a = rng.gen_range(80) as u32;
            let b = rng.gen_range(80) as u32;
            if a == b {
                continue;
            }
            let cos = emb.cosine(a, b).unwrap();
            if gt.cluster_of[a as usize] == gt.cluster_of[b as usize] {
                same.push(cos);
            } else {
                cross.push(cos);
            }
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        (avg(&same), avg(&cross))
    }

    #[test]
    fn training_learns_cluster_structure() {
        let (corpus, vocab, gcfg) = tiny_setup();
        let cfg = SgnsConfig {
            dim: 16,
            epochs: 4,
            window: 4,
            negatives: 4,
            ..Default::default()
        };
        let (emb, stats) = train(&corpus, &vocab, &cfg, 2, 7);
        assert!(stats.pairs > 10_000, "too few pairs: {}", stats.pairs);
        // same-cluster cosine must exceed cross-cluster on average
        let (same, cross) = cluster_separation(&emb, &gcfg);
        assert!(same > cross + 0.05, "same={same:.3} cross={cross:.3}");
    }

    #[test]
    fn multithreaded_matches_singlethread_quality() {
        let (corpus, vocab, _) = tiny_setup();
        let cfg = SgnsConfig {
            dim: 12,
            epochs: 2,
            ..Default::default()
        };
        let (e1, s1) = train(&corpus, &vocab, &cfg, 1, 3);
        let (e4, s4) = train(&corpus, &vocab, &cfg, 4, 3);
        // same total work
        // different shardings use different RNG streams, so subsampling
        // draws differ stochastically — counts agree only in expectation
        let rel = (s1.pairs as f64 - s4.pairs as f64).abs() / (s1.pairs as f64);
        assert!(rel < 0.05, "pair counts diverge: {rel}");
        // both produce finite, non-degenerate embeddings
        for e in [&e1, &e4] {
            assert!(e.data.iter().all(|x| x.is_finite()));
            let norm: f32 = e.row(0).iter().map(|x| x * x).sum();
            assert!(norm > 0.0);
        }
    }

    /// The batched counter must not change what a single thread computes:
    /// two identical 1-thread runs are bitwise equal (no races, exact lr
    /// sequence), the reported pair count is exact, and the run still
    /// learns the planted cluster structure.
    #[test]
    fn single_thread_batched_counter_is_deterministic_and_learns() {
        let (corpus, vocab, gcfg) = tiny_setup();
        let cfg = SgnsConfig {
            dim: 16,
            epochs: 3,
            window: 4,
            negatives: 4,
            ..Default::default()
        };
        let (e1, s1) = train(&corpus, &vocab, &cfg, 1, 13);
        let (e2, s2) = train(&corpus, &vocab, &cfg, 1, 13);
        assert_eq!(s1.pairs, s2.pairs, "1-thread pair counts must be exact");
        assert_eq!(e1.data, e2.data, "1-thread training must be deterministic");
        let (same, cross) = cluster_separation(&e1, &gcfg);
        assert!(same > cross + 0.05, "same={same:.3} cross={cross:.3}");
    }

    /// Regression test for the lr-anneal miscalibration: the schedule's
    /// pair expectation must track the pairs the inner loop actually emits
    /// (dynamic window on both sides × subsampling keep-mass), so the
    /// final lr lands near `lr_min` instead of either slamming into the
    /// floor early or never annealing.
    #[test]
    fn lr_anneals_to_the_floor_under_subsampling() {
        let (corpus, vocab, _) = tiny_setup();
        // light and heavy subsampling plus disabled — all three regimes
        // must stay calibrated
        for t in [0.0, 1e-2, 1e-3] {
            let cfg = SgnsConfig {
                dim: 8,
                epochs: 3,
                window: 5,
                negatives: 2,
                subsample_t: t,
                ..Default::default()
            };
            let (_, stats) = train(&corpus, &vocab, &cfg, 1, 17);
            let ratio = stats.pairs as f64 / stats.expected_pairs.max(1) as f64;
            assert!(
                (ratio - 1.0).abs() < 0.10,
                "t={t}: emitted {} vs expected {} (ratio {ratio:.3})",
                stats.pairs,
                stats.expected_pairs
            );
            // linear decay over a ±10%-calibrated total ends within 10% of
            // lr0 above the floor; the old tokens×window×epochs estimate
            // left final_lr at ~0.4·lr0 under this subsampling
            assert!(
                stats.final_lr <= cfg.lr0 * 0.10 + cfg.lr_min,
                "t={t}: final lr {} did not anneal (lr0 {}, lr_min {})",
                stats.final_lr,
                cfg.lr0,
                cfg.lr_min
            );
            assert!(stats.final_lr >= cfg.lr_min);
        }
    }

    #[test]
    fn loss_monitoring_is_positive_and_finite() {
        let (corpus, vocab, _) = tiny_setup();
        let cfg = SgnsConfig {
            dim: 8,
            epochs: 2,
            ..Default::default()
        };
        let (_, stats) = train(&corpus, &vocab, &cfg, 2, 11);
        assert!(stats.final_epoch_loss.is_finite());
        assert!(stats.final_epoch_loss > 0.0);
        // a trained model should beat the untrained loss (1+k)·ln2 ≈ 4.16
        let untrained = (1.0 + cfg.negatives as f64) * std::f64::consts::LN_2;
        assert!(
            stats.final_epoch_loss < untrained,
            "loss {} should be below untrained {}",
            stats.final_epoch_loss,
            untrained
        );
    }
}
