//! Expected-pair estimation for the linear lr schedule.
//!
//! word2vec's learning rate decays linearly over the *expected* number of
//! (center, context) training pairs. A naive estimate of
//! `tokens × window × epochs` is miscalibrated in two ways:
//!
//! * **subsampling** — frequent-word subsampling removes token mass
//!   *before* windowing, so with heavy subsampling the naive estimate is
//!   several times too large and the lr never anneals;
//! * **dynamic window** — the inner loop draws `win ∈ [1, window]` per
//!   center and pairs on **both** sides, emitting `2·E[win] = window + 1`
//!   pairs per kept token before boundary clipping, so with light
//!   subsampling the naive `window` factor is too *small* and the lr
//!   slams into `lr_min` early.
//!
//! The estimator here accounts for both, plus sentence-boundary clipping:
//! for a sentence whose kept length is `n`, a center at position `p` with
//! window draw `win` emits `min(p, win) + min(n−1−p, win)` pairs, so
//!
//! ```text
//! E[pairs | n] = (2 / W) · Σ_{p=0}^{n−1} g(p),
//! g(k) = Σ_{win=1}^{W} min(k, win)
//! ```
//!
//! The kept length per sentence is random (a Poisson-binomial over the
//! per-token keep probabilities); `E[pairs | n]` is convex around the
//! `n < 2` cutoff and the window kink, so evaluating it at the mean kept
//! length alone under-counts by >10% under heavy subsampling. Short
//! sentences near that region therefore get the exact Poisson-binomial
//! expectation (O(len²) DP, validated against Monte-Carlo to <0.5%), and
//! everything safely inside the linear regime uses the mean directly.

use super::batch::BatchBuilder;
use super::config::SgnsConfig;
use crate::text::corpus::Corpus;
use crate::text::vocab::Vocab;

/// Sentences at most this long get the exact kept-length DP when they sit
/// near the cutoff; longer ones fall back to a variance correction.
const EXACT_DP_MAX_LEN: usize = 64;

/// Streaming accumulator behind [`expected_pairs_per_epoch`]: feed
/// sentences one at a time and read off the per-epoch expectation. The
/// multi-process training workers use this directly — they estimate the
/// lr-schedule denominator while streaming shard files from disk, never
/// holding the corpus in memory — and because the accumulation is a plain
/// sequential f64 sum in sentence order, a streamed pass over the shards
/// produces **bitwise** the same value as the leader's in-memory pass.
pub struct PairEstimator {
    keep: Vec<f32>,
    window: usize,
    probs: Vec<f64>,
    total: f64,
}

impl PairEstimator {
    pub fn new(vocab: &Vocab, cfg: &SgnsConfig) -> Self {
        Self {
            keep: BatchBuilder::keep_table(vocab.counts(), cfg.subsample_t),
            window: cfg.window.max(1),
            probs: Vec::new(),
            total: 0.0,
        }
    }

    /// Accumulate one sentence's expected pair count.
    pub fn add_sentence(&mut self, s: &[u32]) {
        let w = self.window;
        let v = if self.keep.is_empty() {
            expected_sentence_pairs(s.len() as f64, w)
        } else {
            self.probs.clear();
            self.probs.extend(
                s.iter()
                    .map(|&t| self.keep.get(t as usize).copied().unwrap_or(1.0) as f64),
            );
            expected_sentence_pairs_subsampled(&self.probs, w)
        };
        self.total += v;
    }

    /// Expected pairs for one epoch over everything fed so far.
    pub fn per_epoch(&self) -> f64 {
        self.total
    }
}

/// Expected pairs emitted by one pass (epoch) over `corpus`, under
/// `cfg`'s subsampling threshold and dynamic window.
pub fn expected_pairs_per_epoch(corpus: &Corpus, vocab: &Vocab, cfg: &SgnsConfig) -> f64 {
    let mut est = PairEstimator::new(vocab, cfg);
    for s in &corpus.sentences {
        est.add_sentence(s);
    }
    est.per_epoch()
}

/// Expected pairs for one sentence whose tokens survive independently
/// with the given keep probabilities.
fn expected_sentence_pairs_subsampled(probs: &[f64], w: usize) -> f64 {
    let m: f64 = probs.iter().sum();
    let var: f64 = probs.iter().map(|p| p * (1.0 - p)).sum();
    // deep in the linear regime E[pairs | n] is affine in n, so the mean
    // kept length is exact; only the cutoff/kink region needs more care
    if var < 1e-12 || m - 3.0 * var.sqrt() >= (w + 2) as f64 {
        return expected_sentence_pairs(m, w);
    }
    if probs.len() <= EXACT_DP_MAX_LEN {
        // exact: Poisson-binomial distribution over the kept length
        let mut dist = vec![0.0f64; probs.len() + 1];
        dist[0] = 1.0;
        for (i, &p) in probs.iter().enumerate() {
            for n in (1..=i + 1).rev() {
                dist[n] = dist[n] * (1.0 - p) + dist[n - 1] * p;
            }
            dist[0] *= 1.0 - p;
        }
        dist.iter()
            .enumerate()
            .map(|(n, &pr)| pr * exact_sentence_pairs(n, w))
            .sum()
    } else {
        // long sentence that still straddles the cutoff (rare): second-
        // order correction E[f(n)] ≈ f(m) + ½·Var(n)·f''(m)
        let n0 = (m.round() as usize).max(1);
        let d2 = exact_sentence_pairs(n0 + 1, w) + exact_sentence_pairs(n0 - 1, w)
            - 2.0 * exact_sentence_pairs(n0, w);
        (expected_sentence_pairs(m, w) + 0.5 * var * d2).max(0.0)
    }
}

/// Expected total pairs over all epochs — the `total` the lr schedule
/// ([`SgnsConfig::lr_at`]) should anneal over.
pub fn expected_pairs(corpus: &Corpus, vocab: &Vocab, cfg: &SgnsConfig) -> u64 {
    (expected_pairs_per_epoch(corpus, vocab, cfg) * cfg.epochs as f64).round() as u64
}

/// Expected pairs for a sentence of (fractional) kept length `m` with max
/// window `w`; linear interpolation between the exact integer-length
/// values. Sentences whose kept length falls below 2 emit nothing.
fn expected_sentence_pairs(m: f64, w: usize) -> f64 {
    if m < 2.0 {
        return 0.0;
    }
    let n0 = m.floor() as usize;
    let frac = m - n0 as f64;
    let f0 = exact_sentence_pairs(n0, w);
    if frac <= 0.0 {
        f0
    } else {
        f0 + frac * (exact_sentence_pairs(n0 + 1, w) - f0)
    }
}

/// Exact `E[pairs]` for an integer kept length `n`:
/// `(2/W) · Σ_{p<n} g(p)` with `g(k) = Σ_{win≤W} min(k, win)`; positions
/// at least `W` from both ends contribute the unclipped `W(W+1)/2`.
fn exact_sentence_pairs(n: usize, w: usize) -> f64 {
    let g = |k: usize| -> f64 {
        if k >= w {
            (w * (w + 1)) as f64 / 2.0
        } else {
            // Σ_{win=1}^{k} win + (W − k) draws clipped at k
            (k * (k + 1)) as f64 / 2.0 + ((w - k) * k) as f64
        }
    };
    let s: f64 = if n > w {
        (0..w).map(g).sum::<f64>() + (n - w) as f64 * (w * (w + 1)) as f64 / 2.0
    } else {
        (0..n).map(g).sum()
    };
    2.0 * s / w as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn unclipped_sentence_approaches_window_plus_one_per_token() {
        // long sentence, boundary effects amortize away
        let w = 5;
        let per_token = exact_sentence_pairs(10_000, w) / 10_000.0;
        assert!(
            (per_token - (w as f64 + 1.0)).abs() < 0.02,
            "per-token {per_token}"
        );
    }

    #[test]
    fn two_token_sentence_emits_two_pairs() {
        // each token pairs with the only other token regardless of win
        for w in [1, 3, 5, 10] {
            assert!((exact_sentence_pairs(2, w) - 2.0).abs() < 1e-12, "w={w}");
        }
    }

    #[test]
    fn short_sentences_emit_nothing() {
        assert_eq!(expected_sentence_pairs(0.0, 5), 0.0);
        assert_eq!(expected_sentence_pairs(1.9, 5), 0.0);
    }

    /// The estimator must match a Monte-Carlo simulation of the actual
    /// inner-loop pair emission (dynamic window, both sides, clipping).
    #[test]
    fn matches_simulated_pair_counts() {
        let mut rng = Pcg64::new(31);
        for (n, w) in [(5usize, 2usize), (10, 5), (18, 5), (7, 8)] {
            let trials = 40_000;
            let mut total = 0u64;
            for _ in 0..trials {
                for pos in 0..n {
                    let win = 1 + rng.gen_range_usize(w);
                    let lo = pos.saturating_sub(win);
                    let hi = (pos + win + 1).min(n);
                    total += (hi - lo - 1) as u64;
                }
            }
            let simulated = total as f64 / trials as f64;
            let predicted = exact_sentence_pairs(n, w);
            let rel = (simulated - predicted).abs() / predicted;
            assert!(rel < 0.01, "n={n} w={w}: sim {simulated} vs {predicted}");
        }
    }

    /// The subsampled estimator (DP + linear-regime shortcut) must match
    /// a Monte-Carlo simulation of the actual inner loop: subsample with
    /// the keep probs, draw dynamic windows, count clipped pairs.
    #[test]
    fn subsampled_estimator_matches_simulation() {
        let mut rng = Pcg64::new(0xE57);
        let w = 5usize;
        // heterogeneous keep probs spanning heavy to no subsampling
        let keep: Vec<f64> = (0..30)
            .map(|i| match i % 3 {
                0 => 0.15 + 0.02 * (i as f64),
                1 => 0.5,
                _ => 1.0,
            })
            .map(|p: f64| p.min(1.0))
            .collect();
        let sentences: Vec<Vec<u32>> = (0..400)
            .map(|_| {
                let len = 3 + rng.gen_range_usize(13);
                (0..len).map(|_| rng.gen_range(30) as u32).collect()
            })
            .collect();
        let predicted: f64 = sentences
            .iter()
            .map(|s| {
                let probs: Vec<f64> = s.iter().map(|&t| keep[t as usize]).collect();
                expected_sentence_pairs_subsampled(&probs, w)
            })
            .sum();
        let trials = 200;
        let mut total = 0u64;
        for _ in 0..trials {
            for s in &sentences {
                let kept: Vec<u32> = s
                    .iter()
                    .copied()
                    .filter(|&t| {
                        let p = keep[t as usize];
                        p >= 1.0 || rng.gen_f64() < p
                    })
                    .collect();
                if kept.len() < 2 {
                    continue;
                }
                for pos in 0..kept.len() {
                    let win = 1 + rng.gen_range_usize(w);
                    let lo = pos.saturating_sub(win);
                    let hi = (pos + win + 1).min(kept.len());
                    total += (hi - lo - 1) as u64;
                }
            }
        }
        let simulated = total as f64 / trials as f64;
        let rel = (simulated - predicted).abs() / predicted;
        assert!(
            rel < 0.02,
            "simulated {simulated:.0} vs predicted {predicted:.0} (rel {rel:.4})"
        );
    }

    #[test]
    fn subsampling_scales_expectation_down() {
        use crate::text::vocab::VocabBuilder;
        let mut b = VocabBuilder::new();
        let mut sentences = Vec::new();
        let mut rng = Pcg64::new(5);
        for _ in 0..200 {
            let s: Vec<u32> = (0..12).map(|_| rng.gen_range(20) as u32).collect();
            for &t in &s {
                b.add_token(&format!("w{t}"));
            }
            sentences.push(s);
        }
        // remap ids: vocab orders by frequency, corpus uses raw ids — for
        // this test only the *counts* distribution matters, and a uniform
        // draw over 20 words keeps both id spaces statistically identical
        let vocab = b.build(1, usize::MAX);
        let corpus = Corpus::new(sentences);
        let mut cfg = SgnsConfig::default();
        cfg.subsample_t = 0.0;
        let no_sub = expected_pairs_per_epoch(&corpus, &vocab, &cfg);
        cfg.subsample_t = 1e-3; // every word is frequent at V=20
        let heavy_sub = expected_pairs_per_epoch(&corpus, &vocab, &cfg);
        assert!(no_sub > 0.0);
        assert!(
            heavy_sub < 0.5 * no_sub,
            "heavy subsampling must shrink the expectation: {heavy_sub} vs {no_sub}"
        );
    }

    #[test]
    fn streamed_estimation_is_bitwise_identical_to_batch() {
        // the worker path streams sentences from shard files through a
        // PairEstimator; the leader path walks the in-memory corpus — the
        // two must agree exactly or the lr schedules (and therefore the
        // sub-models) of the two paths diverge
        let mut rng = Pcg64::new(0xE5);
        let mut b = crate::text::vocab::VocabBuilder::new();
        let sentences: Vec<Vec<u32>> = (0..150)
            .map(|_| {
                let len = rng.gen_range_usize(20);
                (0..len).map(|_| rng.gen_range(25) as u32).collect()
            })
            .collect();
        for s in &sentences {
            for &t in s {
                b.add_token(&format!("w{t}"));
            }
        }
        let vocab = b.build(1, usize::MAX);
        let corpus = Corpus::new(sentences);
        let mut cfg = SgnsConfig::default();
        cfg.subsample_t = 1e-3;
        let batch = expected_pairs_per_epoch(&corpus, &vocab, &cfg);
        let mut est = PairEstimator::new(&vocab, &cfg);
        for s in &corpus.sentences {
            est.add_sentence(s);
        }
        assert_eq!(batch.to_bits(), est.per_epoch().to_bits());
        assert!(batch > 0.0);
    }

    #[test]
    fn epochs_multiply_the_total() {
        let vocab = crate::text::vocab::Vocab::from_counts(
            (0..10).map(|i| (format!("w{i}"), 5u64)).collect(),
        );
        let corpus = Corpus::new(vec![vec![0, 1, 2, 3, 4]; 20]);
        let mut cfg = SgnsConfig::default();
        cfg.subsample_t = 0.0;
        cfg.epochs = 1;
        let one = expected_pairs(&corpus, &vocab, &cfg);
        cfg.epochs = 3;
        let three = expected_pairs(&corpus, &vocab, &cfg);
        assert_eq!(three, 3 * one);
    }
}
