//! SGNS hyperparameters shared by every trainer implementation
//! (PJRT-backed, Hogwild baseline, parameter-averaging baseline).

#[derive(Clone, Debug)]
pub struct SgnsConfig {
    /// embedding dimensionality d
    pub dim: usize,
    /// max window size (actual window per center is uniform in [1, window])
    pub window: usize,
    /// negative samples per positive pair
    pub negatives: usize,
    /// frequent-word subsampling threshold t (0 disables)
    pub subsample_t: f64,
    /// initial learning rate
    pub lr0: f32,
    /// floor for the linear lr decay
    pub lr_min: f32,
    /// training epochs (passes over each sub-corpus)
    pub epochs: usize,
    /// noise distribution exponent (word2vec: 0.75)
    pub noise_power: f64,
}

impl Default for SgnsConfig {
    fn default() -> Self {
        Self {
            dim: 32,
            window: 5,
            negatives: 5,
            subsample_t: 1e-3,
            lr0: 0.05,
            lr_min: 1e-4,
            epochs: 3,
            noise_power: 0.75,
        }
    }
}

impl SgnsConfig {
    /// Linearly decayed learning rate after `done` of `total` expected
    /// training pairs (word2vec schedule).
    pub fn lr_at(&self, done: u64, total: u64) -> f32 {
        if total == 0 {
            return self.lr0;
        }
        let frac = (done as f64 / total as f64).min(1.0);
        let lr = self.lr0 as f64 * (1.0 - frac);
        lr.max(self.lr_min as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_decays_linearly_to_floor() {
        let cfg = SgnsConfig {
            lr0: 0.1,
            lr_min: 0.001,
            ..Default::default()
        };
        assert_eq!(cfg.lr_at(0, 100), 0.1);
        assert!((cfg.lr_at(50, 100) - 0.05).abs() < 1e-6);
        assert_eq!(cfg.lr_at(100, 100), 0.001);
        assert_eq!(cfg.lr_at(1000, 100), 0.001); // clamped past the end
    }

    #[test]
    fn zero_total_keeps_lr0() {
        let cfg = SgnsConfig::default();
        assert_eq!(cfg.lr_at(5, 0), cfg.lr0);
    }
}
