//! SGNS (skip-gram with negative sampling): configuration, negative
//! sampling, batch assembly, and the two trainer implementations —
//! the backend-driven per-reducer trainer (the paper system's engine,
//! running on the native or PJRT [`crate::runtime::Backend`]) and the
//! lock-free Hogwild CPU baseline the paper compares against.
pub mod batch;
pub mod config;
pub mod hogwild;
pub mod negative;
pub mod schedule;
pub mod trainer;
