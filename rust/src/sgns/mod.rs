//! SGNS (skip-gram with negative sampling): configuration, negative
//! sampling, batch assembly, and the two trainer implementations —
//! the PJRT-backed per-reducer trainer (the paper system's engine) and
//! the lock-free Hogwild CPU baseline the paper compares against.
pub mod batch;
pub mod config;
pub mod hogwild;
pub mod negative;
pub mod trainer;
