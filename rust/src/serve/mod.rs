//! The embedding-serving layer: what happens *after* training.
//!
//! The paper's end product is a merged embedding meant to answer
//! similarity, analogy and missing-word queries at interactive rates over
//! huge vocabularies; a brute-force `O(V)` scan per query cannot carry
//! that. This subsystem is the read-optimized path:
//!
//! * [`index`] — an HNSW-style approximate nearest-neighbor graph over the
//!   normalized rows (deterministic seeded build, tunable `M` /
//!   `ef_construction` / `ef_search`, exact-scan fallback for tiny
//!   vocabularies, recall measured against the exact scan);
//! * [`quant`] — int8 scalar quantization of the row store (per-row scale,
//!   the widening [`crate::kernels::dot_i8_dequant`] kernel on the
//!   distance hot path, ~4× smaller resident vectors);
//! * [`engine`] — the [`ServeEngine`](engine::ServeEngine) tying both
//!   together behind an `Arc`: word/analogy/batched queries answered
//!   concurrently on an [`exec::pool`](crate::exec::pool) worker pool, and
//!   missing words served from reconstructions precomputed at startup
//!   through per-sub-model Procrustes rotations (the merge-phase linalg,
//!   reused — the sub-models themselves are not kept resident).
//!
//! Entry points: `dw2v serve` (CLI), `examples/serve_queries.rs`
//! (library usage), `rust/benches/serve_qps.rs` (exact vs ANN vs ANN+int8
//! throughput/recall), `rust/tests/serve_e2e.rs` (acceptance suite).

pub mod engine;
pub mod index;
pub mod quant;

pub use engine::{Neighbor, Query, QueryResult, ServeConfig, ServeEngine};
pub use index::{AnnIndex, AnnParams};
pub use quant::QuantizedStore;
