//! The serving engine: ANN index + quantized store + batch query API.
//!
//! [`ServeEngine`] is the read-optimized front end for a merged/saved
//! [`Embedding`]: it builds the [`AnnIndex`](super::index::AnnIndex) (and,
//! by default, its int8 [`QuantizedStore`](super::quant::QuantizedStore))
//! once, parks everything immutable behind an `Arc`, and answers
//!
//! * `nearest_words` — top-k cosine neighbors of a word,
//! * `analogy` — 3CosAdd `b − a + c` queries,
//! * `batch` — a slice of mixed queries fanned out across an
//!   [`exec::pool::ThreadPool`](crate::exec::pool::ThreadPool), with
//!   results reassembled in request order so concurrent answers are
//!   *identical* to sequential ones,
//!
//! plus **missing-word reconstruction** (paper §5.4): when the engine is
//! given the trained sub-models, it fits one orthogonal-Procrustes
//! rotation per sub-model onto the consensus (the merge-phase linalg,
//! reused), precomputes every missing word as the mean of its rotated
//! sub-model rows — the same estimate the ALiR merge would have produced —
//! and drops the sub-models; a query for an absent word is then an O(1)
//! lookup into those reconstructions.

use super::index::{AnnIndex, AnnParams};
use super::quant::QuantizedStore;
use crate::embedding::Embedding;
use crate::exec::pool::ThreadPool;
use crate::kernels;
use crate::obs::metrics::{self, Counter, Histogram};
use crate::linalg::mat::Mat;
use crate::linalg::procrustes::orthogonal_procrustes;
use crate::merge::align::extract_rows;
use crate::text::vocab::Vocab;
use std::sync::mpsc;
use std::sync::Arc;

/// Engine-level knobs; the ANN build/search knobs live in [`AnnParams`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub ann: AnnParams,
    /// Score candidates on the int8 store instead of the f32 rows
    /// (~4× smaller resident vectors, ≤ ~1e-2 cosine error).
    pub quantize: bool,
    /// Worker threads answering batched queries.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            ann: AnnParams::default(),
            quantize: true,
            workers: 4,
        }
    }
}

/// One serving request, as carried by [`ServeEngine::batch`].
#[derive(Clone, Debug)]
pub enum Query {
    /// Top-k neighbors of `word` (itself excluded).
    Nearest { word: String, k: usize },
    /// 3CosAdd analogy a : b :: c : ? (a, b, c excluded).
    Analogy {
        a: String,
        b: String,
        c: String,
        k: usize,
    },
}

/// One ranked answer row.
#[derive(Clone, Debug, PartialEq)]
pub struct Neighbor {
    pub id: u32,
    pub word: String,
    pub score: f32,
}

/// Every query answers with a ranked list or a human-readable error.
pub type QueryResult = Result<Vec<Neighbor>, String>;

/// The immutable serving state shared (via `Arc`) by all worker threads.
struct Inner {
    emb: Embedding,
    /// precomputed row norms for the exact-scan path
    norms: Vec<f64>,
    index: AnnIndex,
    quant: Option<QuantizedStore>,
    vocab: Option<Vocab>,
    /// missing word id → vector reconstructed from sub-model projections.
    /// Precomputed at startup (the missing set is exactly `!present`), so
    /// the full f32 sub-models never stay resident and a missing-word
    /// query is an O(1) lookup.
    reconstructed: std::collections::HashMap<u32, Vec<f32>>,
    cfg: ServeConfig,
    /// registry instruments, resolved once at build so the per-query cost
    /// is one atomic add + one histogram observe (or nothing when the
    /// registry is disabled)
    queries: Arc<Counter>,
    query_secs: Arc<Histogram>,
}

pub struct ServeEngine {
    inner: Arc<Inner>,
    pool: ThreadPool,
}

impl ServeEngine {
    /// Build the engine from a merged/saved embedding. `vocab` enables
    /// querying by surface word; without it words are addressed as
    /// numeric ids (`"17"` or `"#17"`).
    pub fn new(emb: Embedding, vocab: Option<Vocab>, cfg: ServeConfig) -> Self {
        Self::with_submodels(emb, vocab, cfg, Vec::new())
    }

    /// [`ServeEngine::new`] plus the trained sub-models, enabling
    /// missing-word reconstruction. At startup one d×d Procrustes rotation
    /// is fitted per sub-model (skipped when a sub-model shares fewer than
    /// `dim` present words with the consensus — underdetermined), every
    /// missing word's vector is reconstructed as the mean of its rotated
    /// sub-model rows, and the sub-models are then dropped — only the
    /// handful of reconstructed d-vectors stays resident.
    pub fn with_submodels(
        emb: Embedding,
        vocab: Option<Vocab>,
        cfg: ServeConfig,
        submodels: Vec<Embedding>,
    ) -> Self {
        let mut index = AnnIndex::build(&emb, cfg.ann.clone());
        let quant = cfg.quantize.then(|| index.quantize());
        if quant.is_some() {
            // the int8 store now carries all scoring; dropping the index's
            // f32 rows is what actually delivers the ~4× memory cut
            index.release_rows();
        }
        let norms = emb.row_norms();
        let mut rotations: Vec<(usize, Mat)> = Vec::new();
        for (mi, m) in submodels.iter().enumerate() {
            assert_eq!(m.dim, emb.dim, "sub-model {mi} dim mismatch");
            assert_eq!(m.vocab, emb.vocab, "sub-model {mi} vocab mismatch");
            let shared: Vec<u32> = (0..emb.vocab as u32)
                .filter(|&w| m.is_present(w) && emb.is_present(w))
                .collect();
            if shared.len() < emb.dim {
                continue;
            }
            let a = extract_rows(m, &shared);
            let b = extract_rows(&emb, &shared);
            rotations.push((mi, orthogonal_procrustes(&a, &b)));
        }
        // precompute every missing word once — the missing set is exactly
        // the !present rows of the merged embedding
        let d = emb.dim;
        let mut reconstructed = std::collections::HashMap::new();
        for w in 0..emb.vocab as u32 {
            if emb.is_present(w) {
                continue;
            }
            let mut acc = vec![0.0f64; d];
            let mut count = 0usize;
            for (mi, rot) in &rotations {
                let m = &submodels[*mi];
                if !m.is_present(w) {
                    continue;
                }
                // acc += row · W   (1×d times d×d)
                for (i, &x) in m.row(w).iter().enumerate() {
                    let xi = x as f64;
                    for j in 0..d {
                        acc[j] += xi * rot[(i, j)];
                    }
                }
                count += 1;
            }
            if count > 0 {
                let row: Vec<f32> =
                    acc.iter().map(|v| (*v / count as f64) as f32).collect();
                reconstructed.insert(w, row);
            }
        }
        drop(submodels);
        let workers = cfg.workers.max(1);
        let reg = metrics::global();
        let inner = Inner {
            emb,
            norms,
            index,
            quant,
            vocab,
            reconstructed,
            cfg,
            queries: reg.counter("serve_queries_total"),
            query_secs: reg.histogram("serve_query_secs"),
        };
        Self {
            inner: Arc::new(inner),
            pool: ThreadPool::new(workers),
        }
    }

    pub fn embedding(&self) -> &Embedding {
        &self.inner.emb
    }

    pub fn index(&self) -> &AnnIndex {
        &self.inner.index
    }

    pub fn config(&self) -> &ServeConfig {
        &self.inner.cfg
    }

    /// Resident bytes of the vector store actually used for scoring
    /// (int8 codes + scales when quantization is on, f32 rows otherwise).
    pub fn store_bytes(&self) -> usize {
        match &self.inner.quant {
            Some(q) => q.resident_bytes(),
            None => self.inner.index.rows().len() * 4,
        }
    }

    /// Top-k neighbors of one word (served from the reconstruction path
    /// when the word is absent from the merged embedding).
    pub fn nearest_words(&self, word: &str, k: usize) -> QueryResult {
        self.inner.nearest(word, k, false)
    }

    /// 3CosAdd analogy a : b :: c : ?.
    pub fn analogy(&self, a: &str, b: &str, c: &str, k: usize) -> QueryResult {
        self.inner.analogy(a, b, c, k, false)
    }

    /// Answer one [`Query`] (the sequential reference for [`Self::batch`]).
    pub fn answer(&self, q: &Query) -> QueryResult {
        self.inner.answer(q)
    }

    /// Answer one [`Query`] with the exact O(V) scan instead of the ANN
    /// index — the ground truth the approximate answers are measured
    /// against (`dw2v serve --exact` prints both side by side).
    pub fn exact_answer(&self, q: &Query) -> QueryResult {
        self.inner.answer_impl(q, true)
    }

    /// Answer a batch of queries concurrently on the worker pool. Results
    /// come back in request order and are bit-identical to calling
    /// [`Self::answer`] sequentially — the shared state is immutable and
    /// each index search is deterministic.
    pub fn batch(&self, queries: &[Query]) -> Vec<QueryResult> {
        let (tx, rx) = mpsc::channel();
        for (i, q) in queries.iter().cloned().enumerate() {
            let inner = Arc::clone(&self.inner);
            let tx = tx.clone();
            self.pool.execute(move || {
                let _ = tx.send((i, inner.answer(&q)));
            });
        }
        drop(tx);
        let mut out: Vec<QueryResult> = vec![Err("unanswered".to_string()); queries.len()];
        for (i, r) in rx {
            out[i] = r;
        }
        out
    }

    /// ANN search for a raw query vector (ids are global word ids).
    pub fn nearest_vector(&self, query: &[f32], k: usize, exclude: &[u32]) -> Vec<(u32, f32)> {
        self.inner.search_vec(query, k, exclude)
    }

    /// Exact O(V) scan for the same query — the recall reference.
    pub fn exact_nearest(&self, query: &[f32], k: usize, exclude: &[u32]) -> Vec<(u32, f64)> {
        self.inner
            .emb
            .nearest_with_norms(query, k, exclude, &self.inner.norms)
    }

    /// An absent word's vector as reconstructed from the sub-model
    /// projections at startup (errors when the word is present — use the
    /// stored row — or when no rotated sub-model contained it).
    pub fn reconstruct(&self, word: &str) -> Result<Vec<f32>, String> {
        let id = self.inner.resolve(word)?;
        if self.inner.emb.is_present(id) {
            return Err(format!("'{word}' is present; reconstruction is for missing words"));
        }
        self.inner
            .reconstruct(id)
            .cloned()
            .ok_or_else(|| format!("'{word}' absent from every rotated sub-model"))
    }
}

impl Inner {
    fn resolve(&self, word: &str) -> Result<u32, String> {
        if let Some(v) = &self.vocab {
            let id = v
                .id(word)
                .ok_or_else(|| format!("unknown word '{word}'"))?;
            // the vocab file may be larger than the model (mismatched
            // artifacts): reject instead of indexing out of bounds
            if (id as usize) >= self.emb.vocab {
                return Err(format!(
                    "word '{word}' (id {id}) is outside the model's vocab of {}",
                    self.emb.vocab
                ));
            }
            return Ok(id);
        }
        word.trim_start_matches('#')
            .parse::<u32>()
            .ok()
            .filter(|&id| (id as usize) < self.emb.vocab)
            .ok_or_else(|| {
                format!(
                    "no vocab loaded; expected a word id < {}, got '{word}'",
                    self.emb.vocab
                )
            })
    }

    fn word_of(&self, id: u32) -> String {
        match &self.vocab {
            // a vocab file smaller than the model must not panic while
            // formatting an answer — fall back to id addressing for rows
            // it doesn't cover
            Some(v) if (id as usize) < v.len() => v.word(id).to_string(),
            _ => format!("#{id}"),
        }
    }

    /// The query row for a word: its stored row when present, else the
    /// sub-model reconstruction.
    fn query_vector(&self, word: &str) -> Result<(u32, Vec<f32>), String> {
        let id = self.resolve(word)?;
        if self.emb.is_present(id) {
            return Ok((id, self.emb.row(id).to_vec()));
        }
        match self.reconstruct(id) {
            Some(v) => Ok((id, v.clone())),
            None => Err(format!(
                "'{word}' is missing from the merged embedding and cannot be \
                 reconstructed (no sub-models attached, or none contain it)"
            )),
        }
    }

    fn search_vec(&self, query: &[f32], k: usize, exclude: &[u32]) -> Vec<(u32, f32)> {
        match &self.quant {
            Some(store) => self.index.search_quantized(store, query, k, 0, exclude),
            None => self.index.search(query, k, 0, exclude),
        }
    }

    /// The exact-scan twin of [`Inner::search_vec`] (same cosine scores,
    /// f64-accumulated then narrowed).
    fn exact_hits(&self, query: &[f32], k: usize, exclude: &[u32]) -> Vec<(u32, f32)> {
        self.emb
            .nearest_with_norms(query, k, exclude, &self.norms)
            .into_iter()
            .map(|(w, s)| (w, s as f32))
            .collect()
    }

    fn to_neighbors(&self, hits: Vec<(u32, f32)>) -> Vec<Neighbor> {
        hits.into_iter()
            .map(|(id, score)| Neighbor {
                id,
                word: self.word_of(id),
                score,
            })
            .collect()
    }

    fn nearest(&self, word: &str, k: usize, exact: bool) -> QueryResult {
        let (id, query) = self.query_vector(word)?;
        let hits = if exact {
            self.exact_hits(&query, k, &[id])
        } else {
            self.search_vec(&query, k, &[id])
        };
        Ok(self.to_neighbors(hits))
    }

    fn analogy(&self, a: &str, b: &str, c: &str, k: usize, exact: bool) -> QueryResult {
        let (ia, va) = self.query_vector(a)?;
        let (ib, vb) = self.query_vector(b)?;
        let (ic, vc) = self.query_vector(c)?;
        // 3CosAdd works on unit vectors: query = b̂ − â + ĉ
        let ua = unit(&va);
        let ub = unit(&vb);
        let uc = unit(&vc);
        let mut query = vec![0.0f32; self.emb.dim];
        kernels::scaled_add(&mut query, &ub, &ua, -1.0);
        kernels::axpy(1.0, &uc, &mut query);
        let excl = [ia, ib, ic];
        let hits = if exact {
            self.exact_hits(&query, k, &excl)
        } else {
            self.search_vec(&query, k, &excl)
        };
        Ok(self.to_neighbors(hits))
    }

    fn answer(&self, q: &Query) -> QueryResult {
        if !metrics::global().enabled() {
            return self.answer_impl(q, false);
        }
        let started = std::time::Instant::now();
        let out = self.answer_impl(q, false);
        self.queries.add(1);
        self.query_secs.observe(started.elapsed().as_secs_f64());
        out
    }

    fn answer_impl(&self, q: &Query, exact: bool) -> QueryResult {
        match q {
            Query::Nearest { word, k } => self.nearest(word, *k, exact),
            Query::Analogy { a, b, c, k } => self.analogy(a, b, c, *k, exact),
        }
    }

    /// The startup-precomputed reconstruction of a missing word — `None`
    /// when no rotated sub-model had it.
    fn reconstruct(&self, word: u32) -> Option<&Vec<f32>> {
        self.reconstructed.get(&word)
    }
}

/// L2-normalized copy of a row (zero rows pass through unchanged).
fn unit(v: &[f32]) -> Vec<f32> {
    let mut out = v.to_vec();
    let norm = kernels::norm_sq(&out).sqrt();
    if norm > 1e-12 {
        kernels::scale(&mut out, 1.0 / norm);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_embedding(vocab: usize, dim: usize, seed: u64) -> Embedding {
        let mut e = Embedding::zeros(vocab, dim);
        let mut rng = Pcg64::new(seed);
        for w in 0..vocab as u32 {
            for v in e.row_mut(w) {
                *v = rng.gen_gauss() as f32;
            }
        }
        e
    }

    fn id_vocab(n: usize) -> Vocab {
        Vocab::from_ordered((0..n).map(|i| (format!("w{i}"), 1u64)).collect())
    }

    #[test]
    fn nearest_words_round_trips_through_vocab() {
        let e = random_embedding(200, 16, 21);
        let engine = ServeEngine::new(e, Some(id_vocab(200)), ServeConfig::default());
        let res = engine.nearest_words("w5", 4).unwrap();
        assert_eq!(res.len(), 4);
        assert!(res.iter().all(|n| n.word != "w5"));
        assert!(engine.nearest_words("nope", 4).is_err());
    }

    #[test]
    fn id_addressing_without_vocab() {
        let e = random_embedding(100, 8, 22);
        let engine = ServeEngine::new(e, None, ServeConfig::default());
        let res = engine.nearest_words("#7", 3).unwrap();
        assert_eq!(res.len(), 3);
        assert!(res.iter().all(|n| n.id != 7));
        assert!(engine.nearest_words("w7", 3).is_err(), "surface words need a vocab");
        assert!(engine.nearest_words("9999", 3).is_err(), "id out of range");
    }

    #[test]
    fn undersized_vocab_renders_uncovered_ids_instead_of_panicking() {
        // vocab covers only the first 30 of 120 rows: queries on covered
        // words work, neighbors outside the vocab render as "#id"
        let e = random_embedding(120, 8, 25);
        let engine = ServeEngine::new(e, Some(id_vocab(30)), ServeConfig::default());
        let res = engine.nearest_words("w3", 10).unwrap();
        assert_eq!(res.len(), 10);
        for n in &res {
            if n.id < 30 {
                assert_eq!(n.word, format!("w{}", n.id));
            } else {
                assert_eq!(n.word, format!("#{}", n.id));
            }
        }
    }

    #[test]
    fn batch_is_identical_to_sequential() {
        let e = random_embedding(300, 16, 23);
        let engine = ServeEngine::new(e, Some(id_vocab(300)), ServeConfig::default());
        let queries: Vec<Query> = (0..40)
            .map(|i| {
                if i % 3 == 0 {
                    Query::Analogy {
                        a: format!("w{i}"),
                        b: format!("w{}", i + 1),
                        c: format!("w{}", i + 2),
                        k: 5,
                    }
                } else {
                    Query::Nearest { word: format!("w{i}"), k: 5 }
                }
            })
            .collect();
        let sequential: Vec<QueryResult> = queries.iter().map(|q| engine.answer(q)).collect();
        for _ in 0..3 {
            assert_eq!(engine.batch(&queries), sequential);
        }
    }

    #[test]
    fn quantize_off_serves_from_f32_rows() {
        let e = random_embedding(150, 16, 24);
        let mut cfg = ServeConfig::default();
        cfg.quantize = false;
        let f32_engine = ServeEngine::new(e.clone(), None, cfg);
        let q_engine = ServeEngine::new(e, None, ServeConfig::default());
        assert!(q_engine.store_bytes() < f32_engine.store_bytes() / 3);
        // both agree on the neighbor *sets* for a few probes
        for w in ["#3", "#77", "#149"] {
            let ids = |e: &ServeEngine| -> Vec<u32> {
                e.nearest_words(w, 5).unwrap().iter().map(|n| n.id).collect()
            };
            let a = ids(&f32_engine);
            let b = ids(&q_engine);
            let inter = a.iter().filter(|id| b.contains(id)).count();
            // int8 scoring may legitimately swap true near-ties at the k
            // boundary; a majority overlap is the meaningful invariant
            assert!(inter >= 3, "{w}: {a:?} vs {b:?}");
        }
    }
}
