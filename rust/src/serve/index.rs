//! HNSW-style approximate nearest-neighbor index over normalized rows.
//!
//! The structure is the standard hierarchical navigable-small-world graph
//! (Malkov & Yashunin): every present word becomes a node with a
//! geometrically distributed top level (`mL = 1/ln(M)`), upper layers are
//! sparse expressways descended greedily, and layer 0 holds the dense
//! neighborhood graph searched with an `ef`-bounded best-first beam.
//! Similarity is the cosine (rows are L2-normalized at build time, so one
//! vectorized [`crate::kernels::dot`] per candidate), and *higher is
//! better* throughout — the heaps are similarity-ordered, not
//! distance-ordered.
//!
//! Determinism: level draws come from a seeded [`Pcg64`] stream, nodes are
//! inserted in ascending word-id order, and every comparison breaks score
//! ties by ascending node id (`Cand`'s `Ord`). Two builds from the same
//! embedding + params produce the identical graph, and repeated searches
//! the identical result list — the property the exact-vs-ANN recall tests
//! in `rust/tests/serve_e2e.rs` pin down.
//!
//! Tiny vocabularies (≤ [`AnnParams::brute_force_below`]) skip graph
//! construction entirely and serve exact scans over the same normalized
//! row store — at that scale the O(V) scan is both faster and trivially
//! recall-1.0.
//!
//! Scoring is pluggable per search: [`AnnIndex::search`] runs on the f32
//! rows, [`AnnIndex::search_quantized`] on an int8
//! [`QuantizedStore`](super::quant::QuantizedStore) built over the same
//! compact node space — the graph is shared, only the distance kernel
//! changes.

use super::quant::QuantizedStore;
use crate::embedding::Embedding;
use crate::kernels;
use crate::util::rng::Pcg64;
use std::cell::RefCell;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Hard cap on node levels — with mL = 1/ln(16), P(level ≥ 16) < 1e-19.
const MAX_LEVEL: usize = 16;

thread_local! {
    /// Reusable visited-stamp scratch for [`AnnIndex::search_layer`]:
    /// `(stamps, epoch)` where `stamps[node] == epoch` means "visited in
    /// the current search". Bumping the epoch invalidates the whole array
    /// in O(1), so per-query work is proportional to the nodes actually
    /// touched, not to V — allocating and zeroing an O(V) bitmap per
    /// query would reintroduce the linear cost the index exists to avoid.
    /// Per-thread, shared by all indexes (searches never nest).
    static VISITED: RefCell<(Vec<u64>, u64)> = const { RefCell::new((Vec::new(), 0)) };
}

/// Tunable build/search knobs of the [`AnnIndex`].
#[derive(Clone, Debug)]
pub struct AnnParams {
    /// Target out-degree per node and layer (layer 0 allows 2·M).
    pub m: usize,
    /// Beam width while inserting nodes (build-time graph quality).
    pub ef_construction: usize,
    /// Default beam width at query time; larger = higher recall, slower.
    pub ef_search: usize,
    /// At or below this many present words, serve exact scans instead of
    /// building a graph.
    pub brute_force_below: usize,
    /// Seed of the level-draw RNG stream (build determinism).
    pub seed: u64,
}

impl Default for AnnParams {
    fn default() -> Self {
        Self {
            m: 16,
            ef_construction: 100,
            ef_search: 64,
            brute_force_below: 128,
            seed: 0x5EA7,
        }
    }
}

/// A scored candidate; `Ord` is score-descending with ascending-id
/// tie-break so heap pops (and therefore whole searches) are deterministic.
#[derive(Copy, Clone, Debug, PartialEq)]
struct Cand {
    score: f32,
    idx: u32,
}

impl Eq for Cand {}

impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        self.score
            .total_cmp(&other.score)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The built index: compact node space over the present words, normalized
/// row store, and the layered neighbor lists.
pub struct AnnIndex {
    params: AnnParams,
    dim: usize,
    /// compact node index → global word id (ascending)
    words: Vec<u32>,
    /// n × dim, L2-normalized copies of the present rows
    rows: Vec<f32>,
    /// `neighbors[node][level]` → adjacent nodes; a node owns
    /// `its_level + 1` layers
    neighbors: Vec<Vec<Vec<u32>>>,
    entry: u32,
    max_level: usize,
    brute: bool,
}

impl AnnIndex {
    /// Build the index over every present row of `emb`. Deterministic for
    /// fixed `(emb, params)`. Degenerate knobs are clamped to sane minima
    /// (`m ≥ 2`, `ef_construction ≥ m`, `ef_search ≥ 1`) — an `m` of 0
    /// would otherwise build an edgeless graph that silently answers every
    /// query with just the entry point.
    pub fn build(emb: &Embedding, mut params: AnnParams) -> Self {
        params.m = params.m.max(2);
        params.ef_construction = params.ef_construction.max(params.m);
        params.ef_search = params.ef_search.max(1);
        let dim = emb.dim;
        let words: Vec<u32> = (0..emb.vocab as u32).filter(|&w| emb.is_present(w)).collect();
        let n = words.len();
        let mut rows = vec![0.0f32; n * dim];
        for (i, &w) in words.iter().enumerate() {
            let dst = &mut rows[i * dim..(i + 1) * dim];
            dst.copy_from_slice(emb.row(w));
            let norm = kernels::norm_sq(dst).sqrt();
            if norm > 1e-12 {
                kernels::scale(dst, 1.0 / norm);
            }
        }
        let brute = n <= params.brute_force_below;
        let mut index = Self {
            params,
            dim,
            words,
            rows,
            neighbors: Vec::new(),
            entry: 0,
            max_level: 0,
            brute,
        };
        if !index.brute {
            let ml = 1.0 / (index.params.m as f64).ln();
            let mut rng = Pcg64::new_stream(index.params.seed, 0x484E_5357); // "HNSW"
            index.neighbors.reserve(n);
            for node in 0..n as u32 {
                let draw = rng.gen_f64().max(1e-12);
                let level = ((-draw.ln() * ml) as usize).min(MAX_LEVEL);
                index.insert(node, level);
            }
        }
        index
    }

    /// Number of indexed (present) words.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// True when the tiny-vocabulary exact-scan fallback is active.
    pub fn is_brute_force(&self) -> bool {
        self.brute
    }

    pub fn params(&self) -> &AnnParams {
        &self.params
    }

    /// Global word ids in compact node order.
    pub fn words(&self) -> &[u32] {
        &self.words
    }

    /// The normalized row store (compact node order, row-major).
    pub fn rows(&self) -> &[f32] {
        &self.rows
    }

    /// Int8-quantize the index's own row store; node indices line up, so
    /// the result plugs straight into [`AnnIndex::search_quantized`].
    pub fn quantize(&self) -> QuantizedStore {
        QuantizedStore::from_rows(&self.rows, self.words.len(), self.dim)
    }

    /// Drop the f32 row store once an int8 store (from
    /// [`AnnIndex::quantize`]) has taken over scoring — this is what
    /// actually realizes the ~4× resident-memory cut; keeping both stores
    /// would make quantization a pure slowdown. Afterwards only
    /// [`AnnIndex::search_quantized`] works; [`AnnIndex::search`] asserts.
    pub fn release_rows(&mut self) {
        self.rows = Vec::new();
    }

    /// False after [`AnnIndex::release_rows`] on a non-empty index.
    pub fn has_rows(&self) -> bool {
        !self.rows.is_empty() || self.words.is_empty()
    }

    /// Top-`k` most-cosine-similar words to `query` (any scale — it is
    /// normalized internally), excluding the global ids in `exclude`.
    /// `ef = 0` means "use `params.ef_search`".
    pub fn search(&self, query: &[f32], k: usize, ef: usize, exclude: &[u32]) -> Vec<(u32, f32)> {
        assert!(
            self.has_rows(),
            "f32 rows were released (release_rows); use search_quantized"
        );
        let qn = self.normalize_query(query);
        self.search_with(&|i| self.score_node(i, &qn), k, ef, exclude)
    }

    /// [`AnnIndex::search`] but scoring through an int8 store built by
    /// [`AnnIndex::quantize`] — same graph walk, quantized distance kernel.
    pub fn search_quantized(
        &self,
        store: &QuantizedStore,
        query: &[f32],
        k: usize,
        ef: usize,
        exclude: &[u32],
    ) -> Vec<(u32, f32)> {
        debug_assert_eq!(store.len(), self.words.len());
        let qn = self.normalize_query(query);
        self.search_with(&|i| store.dot(i as usize, &qn), k, ef, exclude)
    }

    /// Mean recall@k versus the exact scan, averaged over `queries` (each
    /// a present global word id queried by its own row, self-excluded).
    pub fn measure_recall(
        &self,
        emb: &Embedding,
        queries: &[u32],
        k: usize,
        ef: usize,
    ) -> f64 {
        let norms = emb.row_norms();
        let mut total = 0.0;
        let mut used = 0usize;
        for &q in queries {
            if !emb.is_present(q) {
                continue;
            }
            let exact = emb.nearest_with_norms(emb.row(q), k, &[q], &norms);
            if exact.is_empty() {
                continue;
            }
            let approx = self.search(emb.row(q), k, ef, &[q]);
            let exact_ids: std::collections::HashSet<u32> =
                exact.iter().map(|(w, _)| *w).collect();
            let hits = approx.iter().filter(|(w, _)| exact_ids.contains(w)).count();
            total += hits as f64 / exact.len() as f64;
            used += 1;
        }
        if used == 0 {
            0.0
        } else {
            total / used as f64
        }
    }

    // ---------------------------------------------------------- internals ----

    fn normalize_query(&self, query: &[f32]) -> Vec<f32> {
        assert_eq!(query.len(), self.dim, "query dim mismatch");
        let mut qn = query.to_vec();
        let norm = kernels::norm_sq(&qn).sqrt();
        if norm > 1e-12 {
            kernels::scale(&mut qn, 1.0 / norm);
        }
        qn
    }

    #[inline]
    fn node_row(&self, i: u32) -> &[f32] {
        &self.rows[i as usize * self.dim..(i as usize + 1) * self.dim]
    }

    #[inline]
    fn score_node(&self, i: u32, query: &[f32]) -> f32 {
        kernels::dot(self.node_row(i), query)
    }

    fn max_conn(&self, level: usize) -> usize {
        if level == 0 {
            self.params.m * 2
        } else {
            self.params.m
        }
    }

    /// Insert `node` (compact index, == `self.neighbors.len()`) at `level`.
    fn insert(&mut self, node: u32, level: usize) {
        debug_assert_eq!(node as usize, self.neighbors.len());
        self.neighbors.push(vec![Vec::new(); level + 1]);
        if node == 0 {
            self.entry = 0;
            self.max_level = level;
            return;
        }
        let query: Vec<f32> = self.node_row(node).to_vec();
        let mut ep = self.entry;
        // greedy descent through layers above the new node's level
        if level < self.max_level {
            for l in ((level + 1)..=self.max_level).rev() {
                ep = self.greedy_with(&|i| self.score_node(i, &query), ep, l);
            }
        }
        // connect at every shared layer, top-down
        for l in (0..=level.min(self.max_level)).rev() {
            // the scorer borrows `self` only for this statement, so the
            // neighbor-list mutations below stay legal
            let cands = self.search_layer(
                &|i| self.score_node(i, &query),
                &[ep],
                l,
                self.params.ef_construction,
            );
            let selected: Vec<u32> =
                cands.iter().take(self.params.m).map(|c| c.idx).collect();
            if let Some(best) = cands.first() {
                ep = best.idx;
            }
            let max_conn = self.max_conn(l);
            self.neighbors[node as usize][l].clone_from(&selected);
            for &nb in &selected {
                self.neighbors[nb as usize][l].push(node);
                if self.neighbors[nb as usize][l].len() > max_conn {
                    self.prune(nb, l, max_conn);
                }
            }
        }
        if level > self.max_level {
            self.max_level = level;
            self.entry = node;
        }
    }

    /// Shrink an over-full neighbor list to the `max_conn` most similar
    /// (to the owning node's row), deterministically.
    fn prune(&mut self, node: u32, level: usize, max_conn: usize) {
        let mut scored: Vec<Cand> = self.neighbors[node as usize][level]
            .iter()
            .map(|&j| Cand {
                score: kernels::dot(
                    &self.rows[node as usize * self.dim..(node as usize + 1) * self.dim],
                    &self.rows[j as usize * self.dim..(j as usize + 1) * self.dim],
                ),
                idx: j,
            })
            .collect();
        scored.sort_by(|a, b| b.cmp(a));
        scored.truncate(max_conn);
        self.neighbors[node as usize][level] = scored.into_iter().map(|c| c.idx).collect();
    }

    /// Greedy hill-climb at one (sparse) layer: move to the best-scoring
    /// neighbor until no neighbor improves.
    fn greedy_with<S: Fn(u32) -> f32>(&self, score: &S, start: u32, level: usize) -> u32 {
        let mut cur = start;
        let mut cur_score = score(cur);
        loop {
            let mut best = cur;
            let mut best_score = cur_score;
            for &nb in &self.neighbors[cur as usize][level] {
                let s = score(nb);
                if s > best_score || (s == best_score && nb < best) {
                    best = nb;
                    best_score = s;
                }
            }
            if best == cur {
                return cur;
            }
            cur = best;
            cur_score = best_score;
        }
    }

    /// `ef`-bounded best-first beam at one layer; returns up to `ef`
    /// candidates sorted score-descending (ties by ascending id).
    fn search_layer<S: Fn(u32) -> f32>(
        &self,
        score: &S,
        entries: &[u32],
        level: usize,
        ef: usize,
    ) -> Vec<Cand> {
        let ef = ef.max(1);
        VISITED.with(|cell| {
            let mut scratch = cell.borrow_mut();
            if scratch.0.len() < self.words.len() {
                let n = self.words.len();
                scratch.0.resize(n, 0);
            }
            scratch.1 += 1;
            let epoch = scratch.1;
            let stamps = &mut scratch.0;
            let mut frontier: BinaryHeap<Cand> = BinaryHeap::new();
            let mut results: BinaryHeap<Reverse<Cand>> = BinaryHeap::new();
            for &e in entries {
                if std::mem::replace(&mut stamps[e as usize], epoch) == epoch {
                    continue;
                }
                let c = Cand { score: score(e), idx: e };
                frontier.push(c);
                results.push(Reverse(c));
                if results.len() > ef {
                    results.pop();
                }
            }
            while let Some(c) = frontier.pop() {
                let worst = results.peek().map(|r| r.0.score).unwrap_or(f32::NEG_INFINITY);
                if results.len() >= ef && c.score < worst {
                    break; // no frontier candidate can improve the result set
                }
                for &nb in &self.neighbors[c.idx as usize][level] {
                    if std::mem::replace(&mut stamps[nb as usize], epoch) == epoch {
                        continue;
                    }
                    let s = score(nb);
                    let worst =
                        results.peek().map(|r| r.0.score).unwrap_or(f32::NEG_INFINITY);
                    if results.len() < ef || s > worst {
                        let cand = Cand { score: s, idx: nb };
                        frontier.push(cand);
                        results.push(Reverse(cand));
                        if results.len() > ef {
                            results.pop();
                        }
                    }
                }
            }
            let mut out: Vec<Cand> = results.into_iter().map(|r| r.0).collect();
            out.sort_by(|a, b| b.cmp(a));
            out
        })
    }

    /// Shared top-k driver over an arbitrary node scorer.
    fn search_with<S: Fn(u32) -> f32>(
        &self,
        score: &S,
        k: usize,
        ef: usize,
        exclude: &[u32],
    ) -> Vec<(u32, f32)> {
        if self.words.is_empty() || k == 0 {
            return Vec::new();
        }
        let mut excl = exclude.to_vec();
        excl.sort_unstable();
        let keep = |idx: u32| excl.binary_search(&self.words[idx as usize]).is_err();
        if self.brute {
            let mut all: Vec<Cand> = (0..self.words.len() as u32)
                .filter(|&i| keep(i))
                .map(|i| Cand { score: score(i), idx: i })
                .collect();
            all.sort_by(|a, b| b.cmp(a));
            all.truncate(k);
            return all
                .into_iter()
                .map(|c| (self.words[c.idx as usize], c.score))
                .collect();
        }
        // ef = 0 means the built default; any explicit value — larger or
        // smaller — is honored (recall-vs-ef sweeps depend on this).
        // Excluded nodes stay traversable; widen the beam so the top-k
        // survive the final filter.
        let ef = if ef == 0 { self.params.ef_search } else { ef };
        let ef = ef.max(k + excl.len());
        let mut ep = self.entry;
        for l in (1..=self.max_level).rev() {
            ep = self.greedy_with(score, ep, l);
        }
        let cands = self.search_layer(score, &[ep], 0, ef);
        cands
            .into_iter()
            .filter(|c| keep(c.idx))
            .take(k)
            .map(|c| (self.words[c.idx as usize], c.score))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_embedding(vocab: usize, dim: usize, seed: u64) -> Embedding {
        let mut e = Embedding::zeros(vocab, dim);
        let mut rng = Pcg64::new(seed);
        for w in 0..vocab as u32 {
            for v in e.row_mut(w) {
                *v = rng.gen_gauss() as f32;
            }
        }
        e
    }

    #[test]
    fn brute_force_fallback_matches_exact_scan() {
        let e = random_embedding(60, 12, 3);
        let idx = AnnIndex::build(&e, AnnParams::default());
        assert!(idx.is_brute_force());
        let norms = e.row_norms();
        for q in [0u32, 17, 59] {
            let exact = e.nearest_with_norms(e.row(q), 5, &[q], &norms);
            let approx = idx.search(e.row(q), 5, 0, &[q]);
            assert_eq!(approx.len(), 5);
            for ((we, _), (wa, _)) in exact.iter().zip(&approx) {
                assert_eq!(we, wa, "query {q}");
            }
        }
    }

    #[test]
    fn graph_search_has_high_recall_on_random_rows() {
        let e = random_embedding(600, 24, 5);
        let idx = AnnIndex::build(&e, AnnParams::default());
        assert!(!idx.is_brute_force());
        let queries: Vec<u32> = (0..60).map(|i| i * 10).collect();
        let recall = idx.measure_recall(&e, &queries, 10, 0);
        assert!(recall >= 0.9, "recall@10 = {recall}");
    }

    #[test]
    fn build_and_search_are_deterministic() {
        let e = random_embedding(400, 16, 7);
        let a = AnnIndex::build(&e, AnnParams::default());
        let b = AnnIndex::build(&e, AnnParams::default());
        assert_eq!(a.neighbors, b.neighbors);
        assert_eq!(a.entry, b.entry);
        for q in [1u32, 100, 399] {
            assert_eq!(
                a.search(e.row(q), 8, 0, &[q]),
                b.search(e.row(q), 8, 0, &[q])
            );
        }
    }

    #[test]
    fn respects_exclusions_and_absent_words() {
        let mut e = random_embedding(300, 16, 9);
        e.present[42] = false;
        let idx = AnnIndex::build(&e, AnnParams::default());
        assert_eq!(idx.len(), 299);
        let res = idx.search(e.row(7), 10, 0, &[7, 8, 9]);
        assert_eq!(res.len(), 10);
        for (w, _) in &res {
            assert!(![7u32, 8, 9, 42].contains(w), "{w} should be excluded");
        }
        // scores come back sorted descending
        for pair in res.windows(2) {
            assert!(pair[0].1 >= pair[1].1);
        }
    }

    #[test]
    fn quantized_search_agrees_with_f32_search() {
        let e = random_embedding(500, 32, 11);
        let idx = AnnIndex::build(&e, AnnParams::default());
        let store = idx.quantize();
        let mut overlap = 0usize;
        let mut total = 0usize;
        for q in (0..500u32).step_by(25) {
            let f = idx.search(e.row(q), 10, 0, &[q]);
            let qz = idx.search_quantized(&store, e.row(q), 10, 0, &[q]);
            let fs: std::collections::HashSet<u32> = f.iter().map(|(w, _)| *w).collect();
            overlap += qz.iter().filter(|(w, _)| fs.contains(w)).count();
            total += f.len();
        }
        let agreement = overlap as f64 / total as f64;
        assert!(agreement >= 0.8, "quantized/f32 top-10 agreement {agreement}");
    }

    #[test]
    fn degenerate_params_are_clamped_and_still_answer() {
        let e = random_embedding(300, 16, 15);
        let mut p = AnnParams::default();
        p.m = 0; // would be an edgeless graph without the clamp
        p.ef_construction = 0;
        p.brute_force_below = 0;
        let idx = AnnIndex::build(&e, p);
        assert_eq!(idx.params().m, 2);
        assert!(idx.params().ef_construction >= 2);
        let res = idx.search(e.row(5), 8, 0, &[5]);
        assert_eq!(res.len(), 8);
        let ids: std::collections::HashSet<u32> = res.iter().map(|(w, _)| *w).collect();
        assert_eq!(ids.len(), 8, "results must be distinct nodes");
    }

    #[test]
    fn released_rows_still_serve_quantized_searches() {
        let e = random_embedding(400, 16, 17);
        let mut idx = AnnIndex::build(&e, AnnParams::default());
        let store = idx.quantize();
        let before = idx.search_quantized(&store, e.row(9), 5, 0, &[9]);
        idx.release_rows();
        assert!(!idx.has_rows());
        let after = idx.search_quantized(&store, e.row(9), 5, 0, &[9]);
        assert_eq!(before, after);
    }

    #[test]
    fn zero_k_and_empty_index_are_safe() {
        let e = random_embedding(50, 8, 13);
        let idx = AnnIndex::build(&e, AnnParams::default());
        assert!(idx.search(e.row(0), 0, 0, &[]).is_empty());
        let mut none = Embedding::zeros(4, 8);
        none.present = vec![false; 4];
        let empty = AnnIndex::build(&none, AnnParams::default());
        assert!(empty.is_empty());
        assert!(empty.search(&[0.0; 8], 5, 0, &[]).is_empty());
    }
}
