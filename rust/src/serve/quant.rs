//! Int8 scalar quantization of the serving row store.
//!
//! Each row is quantized symmetrically against its own max-|v| — one f32
//! scale per row, `code = round(v / scale)` clamped to ±127 — which cuts
//! resident memory for the vectors ~4× (4 bytes/element → 1 byte + the
//! amortized per-row scale). The distance hot path never materializes the
//! dequantized row: [`QuantizedStore::dot`] runs the widening
//! [`crate::kernels::dot_i8_dequant`] kernel over the codes and applies
//! the scale once per row.
//!
//! For the L2-normalized rows the ANN index serves (|v| ≤ 1), the
//! worst-case per-element rounding error is `scale/2 = max|v|/254`, so
//! quantized cosine scores stay within ~1e-2 of their f32 values — tight
//! enough that top-k neighbor sets are essentially unchanged (the
//! `serve_e2e` suite asserts a 2e-2 bound and the `serve_qps` bench
//! reports the measured recall cost).

use crate::kernels;

/// Read-optimized int8 row store: `n` rows of `dim` codes + one scale each.
#[derive(Clone, Debug)]
pub struct QuantizedStore {
    n: usize,
    dim: usize,
    codes: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantizedStore {
    /// Quantize `n` contiguous row-major `dim`-wide f32 rows.
    pub fn from_rows(rows: &[f32], n: usize, dim: usize) -> Self {
        assert_eq!(rows.len(), n * dim);
        let mut codes = vec![0i8; n * dim];
        let mut scales = vec![0.0f32; n];
        for i in 0..n {
            let row = &rows[i * dim..(i + 1) * dim];
            let max_abs = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if max_abs == 0.0 {
                continue; // all-zero row: scale 0, codes stay 0
            }
            let scale = max_abs / 127.0;
            scales[i] = scale;
            let out = &mut codes[i * dim..(i + 1) * dim];
            for (c, &v) in out.iter_mut().zip(row) {
                *c = (v / scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
        Self {
            n,
            dim,
            codes,
            scales,
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// ⟨row i, query⟩ computed on the int8 codes (one scale multiply per
    /// row) — the quantized serving hot path.
    #[inline]
    pub fn dot(&self, i: usize, query: &[f32]) -> f32 {
        let codes = &self.codes[i * self.dim..(i + 1) * self.dim];
        kernels::dot_i8_dequant(codes, query) * self.scales[i]
    }

    /// Materialize row `i` back to f32 (result return path, not scoring).
    pub fn dequantize(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let codes = &self.codes[i * self.dim..(i + 1) * self.dim];
        let s = self.scales[i];
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = c as f32 * s;
        }
    }

    /// Resident bytes of the quantized store (codes + scales).
    pub fn resident_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn unit_rows(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        let mut rows = vec![0.0f32; n * dim];
        for r in rows.chunks_exact_mut(dim) {
            for v in r.iter_mut() {
                *v = rng.gen_gauss() as f32;
            }
            let norm = kernels::norm_sq(r).sqrt();
            kernels::scale(r, 1.0 / norm.max(1e-12));
        }
        rows
    }

    #[test]
    fn quantized_dot_tracks_f32_dot() {
        let (n, dim) = (40, 48);
        let rows = unit_rows(n, dim, 7);
        let store = QuantizedStore::from_rows(&rows, n, dim);
        for i in 0..n {
            for j in 0..n {
                let q = &rows[j * dim..(j + 1) * dim];
                let exact = kernels::dot(&rows[i * dim..(i + 1) * dim], q);
                let approx = store.dot(i, q);
                assert!(
                    (exact - approx).abs() < 2e-2,
                    "dot({i},{j}): exact {exact} vs quantized {approx}"
                );
            }
        }
    }

    #[test]
    fn dequantize_reconstructs_rows_closely() {
        let (n, dim) = (10, 32);
        let rows = unit_rows(n, dim, 9);
        let store = QuantizedStore::from_rows(&rows, n, dim);
        let mut back = vec![0.0f32; dim];
        for i in 0..n {
            store.dequantize(i, &mut back);
            for (a, b) in rows[i * dim..(i + 1) * dim].iter().zip(&back) {
                // per-element error bound: scale/2 with scale = max|v|/127
                assert!((a - b).abs() <= 1.0 / 254.0 + 1e-6, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn zero_rows_survive() {
        let rows = vec![0.0f32; 3 * 8];
        let store = QuantizedStore::from_rows(&rows, 3, 8);
        assert_eq!(store.dot(1, &[1.0; 8]), 0.0);
        let mut back = [9.0f32; 8];
        store.dequantize(2, &mut back);
        assert!(back.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn memory_is_roughly_quartered() {
        let (n, dim) = (100, 64);
        let rows = unit_rows(n, dim, 11);
        let store = QuantizedStore::from_rows(&rows, n, dim);
        let f32_bytes = n * dim * 4;
        assert!(store.resident_bytes() < f32_bytes / 3);
    }
}
