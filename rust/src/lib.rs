//! # dist-w2v
//!
//! A reproduction of **“Asynchronous Training of Word Embeddings for Large
//! Text Corpora”** (Anand, Khosla, Singh, Zab, Zhang — WSDM 2019) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   corpus pipeline, the divide phase (EqualPartitioning / RandomSampling /
//!   Shuffle), a MapReduce-lite runtime whose reducers train SGNS sub-models
//!   fully asynchronously, the merge phase (Concat / PCA / ALiR), the
//!   evaluation harness and the Hogwild / parameter-averaging baselines.
//! * **Layer 2 (python/compile/model.py)** — the SGNS train step as a JAX
//!   function over a packed parameter state, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/sgns.py)** — the fused SGNS
//!   loss+gradient Pallas kernel invoked by Layer 2.
//!
//! ## Compute backends
//!
//! Training dispatches go through the [`runtime::Backend`] abstraction
//! (`runtime/backend.rs`); the same batched `(centers, ctx, weights)`
//! protocol runs on either engine, selected per experiment with
//! `--backend` / the `backend` config key:
//!
//! | backend  | engine                                  | needs                               |
//! |----------|-----------------------------------------|-------------------------------------|
//! | `native` | pure-rust vectorized kernels ([`kernels`]) | nothing — default builds, CI     |
//! | `xla`    | PJRT AOT executables (`runtime/client.rs`) | `--features xla` + `make artifacts` |
//! | `auto`   | `xla` when loadable, else `native`      | nothing (the default)               |
//!
//! With the native backend the full divide → train → merge → eval
//! pipeline, the examples and the bench harnesses run — and are tested —
//! on any machine with no XLA toolchain; with the `xla` feature the hot
//! path is rust driving PJRT-compiled executables with device-resident
//! parameters.
//!
//! ## Corpus sources
//!
//! Every experiment trains from one of two corpus sources behind the same
//! [`world::World`]:
//!
//! * **synthetic** ([`world::build_world`]) — the planted-ground-truth
//!   generator with its gold benchmark suite; deterministic from one
//!   seed, used by the bench harnesses and tests;
//! * **raw text** ([`world::World::from_text`], CLI `--text`) — a real
//!   text file streamed through [`text::ingest`]: pass 1 tokenizes and
//!   counts the vocabulary in parallel chunks (partial
//!   [`text::vocab::VocabBuilder`]s merged mapper-style), pass 2
//!   re-streams, id-encodes against the frozen vocab and spills binary
//!   [`text::corpus::Corpus`] shards every `shard_tokens` tokens. Peak
//!   memory is one chunk of raw text + one shard of ids — never the
//!   corpus. Real-corpus models are scored with
//!   [`eval::questions`] (the standard `questions-words.txt` analogy
//!   format); `cargo bench --bench ingest_throughput` measures the
//!   two-pass MB/s, and `cargo run --example text_ingest` shows the text
//!   round trip matching the direct synthetic run.
//!
//! ## Multi-process training
//!
//! [`coordinator::procs`] promotes the paper's zero-synchronization
//! claim from threads to OS processes: `dw2v pipeline-procs` spawns one
//! `dw2v train-worker` process per sub-model over a persisted shard
//! directory (`shard_*.bin` + `vocab.tsv`, the `gen-corpus` /
//! `--shard-dir` layout). Each worker streams sentences one at a time
//! from the shard files (peak corpus memory: a single sentence), routes
//! them with the same stateless counter-based
//! [`coordinator::divider::Divider`] as the in-process leader — agreeing
//! on the partition from nothing but `(seed, strategy, rate, epoch)` —
//! and publishes a versioned [`embedding::SubModelArtifact`]
//! (write-then-rename). The coordinator monitors the workers, collects
//! whatever artifacts came back and funnels the survivors into the same
//! merge + eval tail as the in-process pipeline
//! ([`coordinator::leader::merge_and_eval`]). A crashed or killed worker
//! costs exactly its sub-model: the failure is reported and the merge
//! proceeds over the rest — the paper's missing-words robustness at
//! sub-model granularity. With `mappers = 1` the multi-process run is
//! bitwise identical to the in-process one on the native backend
//! (`cargo test --test procs_e2e`).
//!
//! ## Supervision, checkpoint/resume & fault injection
//!
//! [`coordinator::supervisor`] wraps the multi-process coordinator in a
//! recovery loop. Every worker atomically publishes a heartbeat beacon
//! (`beacon_<s>.json`: phase, epoch, sentence/pair counters, a `seq`
//! that makes consecutive writes differ — write-to-temp + rename like
//! every artifact) and checkpoints its trainer at each epoch boundary
//! (`submodel_<s>.ckpt`, an [`embedding::CheckpointArtifact`]: packed
//! parameter state in the embedding body format + the exact f64 loss
//! counters the f32 metrics row would round). The supervisor's poll loop
//! classifies each worker **healthy** (beacon bytes changed recently),
//! **stalled** (no change within the stall timeout ⇒ killed) or **dead**
//! (exited without a valid artifact), then applies the configured
//! [`coordinator::supervisor::FailurePolicy`]: `retry` respawns after a
//! capped exponential backoff (base 200 ms doubling to a 5 s cap) up to
//! the retry budget — the respawned worker resumes from its checkpoint
//! and, because divider routing is stateless and the batch RNG never
//! advances, finishes **bitwise identical** to an uninterrupted run on
//! the native backend; `degrade` abandons the worker and merges the
//! survivors; `fail-fast` kills the pool. Chaos testing is first-class:
//! `DW2V_FAULT` (parsed by [`coordinator::supervisor::FaultSpec`];
//! grammar `clause (';' clause)*` with `crash@pairs=N`, `stall@epoch=K`,
//! `corrupt-artifact`, `slow@factor=F`, each optionally scoped
//! `@submodel=S`) injects deterministic crashes, hangs, torn artifacts
//! and stragglers into real worker processes —
//! `cargo test --test supervisor_e2e` drives crash→resume→bitwise-equal,
//! stall→timeout→respawn, corrupt-artifact→degrade and fail-fast
//! end-to-end.
//!
//! ## Ingest-while-training overlap
//!
//! For corpora large enough that preprocessing is itself a long job, the
//! ingest and the training fleet can share one shard directory
//! concurrently ([`coordinator::overlap::run_overlapped`], CLI
//! `pipeline-procs --overlap --text FILE`). The contract: the ingest
//! publishes every shard atomically (temp + rename) and maintains a
//! manifest (`shards.json`, [`text::feed::ShardManifest`]) whose rows
//! appear only *after* the shard they describe is readable; before the
//! first shard it publishes a schedule block carrying the exact sentence
//! total and the bits-exact per-epoch pair sum. Workers read the
//! directory through [`text::feed::ShardFeed`] — manifest-driven, never
//! a directory listing, so torn `.tmp` files are invisible — training
//! shard `i` the moment it lands and beaconing a `waiting` phase while
//! blocked on `i+1` (healthy under the stall detector; a *dead* ingest
//! surfaces as a feed progress-timeout error instead). Because divider
//! routing, per-sentence RNG and the lr schedule depend only on the
//! schedule-block numbers and global sentence order, the overlapped run
//! merges **bitwise identical** to ingest-then-train on the native
//! backend (`cargo test --test overlap_e2e`).
//!
//! ## Transport layer
//!
//! Every coordinator↔worker exchange — shards in, artifacts, beacons,
//! checkpoints, feed statistics and journal events out — goes through
//! the pluggable [`transport`] layer ([`transport::ShardStore`] /
//! [`transport::ArtifactStore`] / [`transport::ControlPlane`]).
//! [`transport::fs::FsTransport`] is the local run-dir implementation
//! (byte-for-byte the pre-transport behavior); `dw2v shard-server` +
//! `train-worker --connect HOST:PORT` put the same contract on a
//! length-prefixed TCP protocol ([`transport::frame`]), with the server
//! mirroring every upload into an ordinary run dir so supervision and
//! reporting work unchanged over either transport
//! (`cargo test --test transport_e2e`).
//!
//! ## Serving layer
//!
//! Trained models are *used* through [`serve`]: an HNSW-style ANN index +
//! int8-quantized row store + concurrent batch query engine over any
//! merged/saved [`embedding::Embedding`] (`dw2v serve` on the CLI). The
//! exact-vs-approximate trade-off is one knob — `ef_search` (higher =
//! better recall, slower) — plus `quantize` on/off for the ~4× smaller
//! int8 store; `cargo bench --bench serve_qps` reports queries/sec and
//! recall@10 for exact vs ANN vs ANN+int8, and
//! [`eval::analogy::evaluate_indexed`] runs the analogy benchmark through
//! the index so approximate accuracy can be compared with the exact scan.
//!
//! ## Observability
//!
//! Every phase of the pipeline reports into [`obs`]: processes append
//! typed events to per-role JSONL journals (`events_<role>.jsonl`,
//! single-write `O_APPEND` lines, torn-final-line tolerated on read —
//! [`obs::journal`]), hot paths feed the lock-free metrics registry
//! ([`obs::metrics`], counters/gauges/p50-p99 latency histograms with
//! the same thread-local-flush batching as the SGNS pair counter, and a
//! runtime kill switch so the bench harness can price instrumentation),
//! and two CLI verbs consume the files: `dw2v status <run-dir>` tails
//! the beacons into a live per-worker progress table, `dw2v report
//! <run-dir>` replays journals + beacons + feedstats into
//! `run_report.json` plus a self-contained HTML render
//! ([`obs::report`]) — per-phase wallclock, per-worker
//! crash/stall/respawn timeline, pairs/s curves, ingest throughput.
//! Telemetry is strictly best-effort: an unopenable journal degrades to
//! a no-op writer, and instrumentation never perturbs training
//! (routing and RNG are untouched; the measured overhead rides in
//! `table4_wallclock`'s instrumented-vs-clean row).
//!
//! ## Invariants (enforced by `cargo xtask lint`)
//!
//! The architectural contracts the sections above rely on are machine-
//! checked: the `xtask` workspace crate lexes every file under
//! `rust/src/` and fails CI (the required `lint` job) on any violation.
//! Each rule encodes an invariant some PR's correctness argument leans
//! on — see the `xtask` crate docs for the full catalog, the suppression
//! grammar (`// lint-allow: <rule-id> <reason>`) and the scan's limits:
//!
//! * **fs-outside-seam** — coordinator code never touches the
//!   filesystem directly; everything rides the [`transport`] seams, so
//!   local and TCP runs stay behaviorally identical (transport layer).
//! * **final-path-create** — final artifact names (`*.dwsm`, `*.ckpt`,
//!   `shards.json`, beacons, bench trajectories) are only ever produced
//!   by tmp→rename, the atomic-publication contract the overlap and
//!   supervision designs assume (multi-process + overlap).
//! * **json-int-precision** — integers enter JSON via
//!   [`util::json::inum`] / [`util::json::u64s`] (f32 via
//!   [`util::json::fnum`]), never a bare `as f64` cast, so counters
//!   past 2^53 cannot silently round (journals/beacons/reports).
//! * **env-var-outside-env** — every `DW2V_*` knob is read in
//!   [`util::env`] alone, keeping the knob registry complete.
//! * **nondeterministic-call** — no wall clock or ambient randomness in
//!   the bitwise-deterministic paths (divider, trainer, native runtime)
//!   that the resume/overlap equivalence proofs depend on.
//! * **unhandled-message** — every frame type in [`transport::frame`]
//!   is dispatched by the shard server; adding a message without
//!   handling it is a compile-adjacent failure, not a runtime surprise.
//! * **relaxed-ordering** — `Ordering::Relaxed` outside the two
//!   sanctioned lock-free modules ([`obs::metrics`],
//!   `sgns::hogwild`) carries a written justification.
//!
//! The lock-free paths themselves are dynamically checked in CI: loom
//! models (`util::sync` shim, `RUSTFLAGS="--cfg loom"`) exhaustively
//! interleave the metrics flush/kill-switch, pool pending-count and
//! channel gauge protocols; ThreadSanitizer runs the `exec::`/`obs::`/
//! `sgns::` unit tests (minus the intentionally-racy Hogwild trainers);
//! Miri interprets `kernels::` and `obs::` for UB.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for measured reproductions of every table and figure.

// Style lints we deliberately keep: indexed loops mirror the papers'
// notation in the numeric kernels, and test/bench fixtures mutate a
// Default config field-by-field for readability.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::field_reassign_with_default)]

pub mod baselines;
pub mod bench_util;
pub mod coordinator;
pub mod eval;
pub mod embedding;
pub mod exec;
pub mod gen;
pub mod kernels;
pub mod linalg;
pub mod merge;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod sgns;
pub mod text;
pub mod transport;
pub mod util;
pub mod world;
