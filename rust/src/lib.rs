//! # dist-w2v
//!
//! A reproduction of **“Asynchronous Training of Word Embeddings for Large
//! Text Corpora”** (Anand, Khosla, Singh, Zab, Zhang — WSDM 2019) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator:
//!   corpus pipeline, the divide phase (EqualPartitioning / RandomSampling /
//!   Shuffle), a MapReduce-lite runtime whose reducers train SGNS sub-models
//!   fully asynchronously, the merge phase (Concat / PCA / ALiR), the
//!   evaluation harness and the Hogwild / parameter-averaging baselines.
//! * **Layer 2 (python/compile/model.py)** — the SGNS train step as a JAX
//!   function over a packed parameter state, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/sgns.py)** — the fused SGNS
//!   loss+gradient Pallas kernel invoked by Layer 2.
//!
//! Python runs only at build time (`make artifacts`); the training hot path
//! is rust driving PJRT-compiled executables with device-resident
//! parameters.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for measured reproductions of every table and figure.

pub mod baselines;
pub mod bench_util;
pub mod coordinator;
pub mod eval;
pub mod embedding;
pub mod exec;
pub mod gen;
pub mod kernels;
pub mod linalg;
pub mod merge;
pub mod runtime;
pub mod sgns;
pub mod text;
pub mod util;
pub mod world;
