//! MapReduce-lite: the distributed-runtime substrate the paper assumes.
//!
//! The paper trains on a Hadoop cluster: *mappers* sample/route sentences,
//! *reducers* train sub-models, one MapReduce round per epoch. This module
//! reproduces that execution model in-process with OS threads and bounded
//! channels (see DESIGN.md §3 for why this preserves the paper's claims:
//! reducers share no parameters, rounds are barriers, routing is stateless).
//!
//! Genericity: a [`RoundSource`] yields the input shard for (round, mapper);
//! a [`Mapper`] emits `(reducer_index, item)` pairs; each [`Reducer`]
//! consumes its queue. Reducer state lives across rounds — exactly like the
//! paper's reducers that keep training the same sub-model every epoch.

use super::channel::{bounded, ChannelStats};
use std::sync::Arc;

/// Supplies the input stream for a given round and mapper shard.
pub trait RoundSource: Sync {
    type Item: Send;
    fn shard(
        &self,
        round: usize,
        shard: usize,
        num_shards: usize,
    ) -> Box<dyn Iterator<Item = Self::Item> + '_>;
}

/// Stateless-per-item mapper: inspects an item and emits zero or more
/// routed outputs. A fresh mapper is constructed per (round, shard), so
/// per-epoch re-seeding (the Shuffle divider) is natural.
pub trait Mapper<In, Out>: Send {
    fn map(&mut self, item: In, emit: &mut dyn FnMut(usize, Out));
}

/// Stateful reducer; lives across rounds.
pub trait Reducer<In>: Send {
    /// Consume one routed item.
    fn reduce(&mut self, item: In);
    /// Called at the round barrier after this reducer's queue drained.
    fn end_round(&mut self, _round: usize) {}
}

/// Wall-clock + backpressure accounting for a run.
#[derive(Debug, Default, Clone)]
pub struct RunStats {
    pub rounds: usize,
    pub round_secs: Vec<f64>,
    pub messages: u64,
    pub send_blocked_secs: f64,
}

impl RunStats {
    pub fn total_secs(&self) -> f64 {
        self.round_secs.iter().sum()
    }
}

/// Execution-shape knobs.
pub struct MapReduce {
    pub num_mappers: usize,
    pub queue_capacity: usize,
}

impl Default for MapReduce {
    fn default() -> Self {
        Self {
            num_mappers: 2,
            queue_capacity: 64,
        }
    }
}

impl MapReduce {
    /// Run `rounds` rounds over `source`, building a fresh mapper per
    /// (round, shard) via `make_mapper`, routing into `reducers`.
    pub fn run<S, M, Out, R>(
        &self,
        rounds: usize,
        source: &S,
        make_mapper: impl Fn(usize, usize) -> M + Sync,
        reducers: &mut [R],
    ) -> RunStats
    where
        S: RoundSource,
        M: Mapper<S::Item, Out>,
        Out: Send,
        R: Reducer<Out>,
    {
        self.run_range(0..rounds, source, make_mapper, reducers)
    }

    /// Run an explicit half-open round range. Channels and worker threads
    /// are constructed fresh per round, so `run_range(k..k+1)` called once
    /// per epoch is behaviorally identical to one `run(n)` call — the hook
    /// a checkpoint-resuming worker needs to restart at round `k` while
    /// `make_mapper`/`end_round` still see the true round number.
    pub fn run_range<S, M, Out, R>(
        &self,
        rounds: std::ops::Range<usize>,
        source: &S,
        make_mapper: impl Fn(usize, usize) -> M + Sync,
        reducers: &mut [R],
    ) -> RunStats
    where
        S: RoundSource,
        M: Mapper<S::Item, Out>,
        Out: Send,
        R: Reducer<Out>,
    {
        let num_reducers = reducers.len();
        assert!(num_reducers > 0, "need at least one reducer");
        let mut stats = RunStats {
            rounds: rounds.len(),
            ..Default::default()
        };
        for round in rounds {
            let timer = std::time::Instant::now();
            let mut txs = Vec::with_capacity(num_reducers);
            let mut rxs = Vec::with_capacity(num_reducers);
            for _ in 0..num_reducers {
                let (tx, rx) = bounded::<Out>(self.queue_capacity);
                txs.push(tx);
                rxs.push(rx);
            }
            let chan_stats: Vec<Arc<ChannelStats>> =
                txs.iter().map(|t| t.stats()).collect();

            std::thread::scope(|scope| {
                // reducer threads: drain own queue until mappers hang up
                for (rdx, (reducer, rx)) in
                    reducers.iter_mut().zip(rxs.into_iter()).enumerate()
                {
                    scope.spawn(move || {
                        while let Ok(item) = rx.recv() {
                            reducer.reduce(item);
                        }
                        let _ = rdx;
                    });
                }
                // mapper threads: each owns a clone of every sender; when
                // the last mapper finishes, receivers see disconnect — the
                // round barrier.
                for shard in 0..self.num_mappers {
                    let txs = txs.clone();
                    let make_mapper = &make_mapper;
                    let source = &source;
                    scope.spawn(move || {
                        let mut mapper = make_mapper(round, shard);
                        let mut emit = |target: usize, out: Out| {
                            let _ = txs[target].send(out);
                        };
                        for item in source.shard(round, shard, self.num_mappers) {
                            mapper.map(item, &mut emit);
                        }
                    });
                }
                drop(txs); // release the scope-held copies
            });

            for r in reducers.iter_mut() {
                r.end_round(round);
            }
            stats.round_secs.push(timer.elapsed().as_secs_f64());
            for cs in &chan_stats {
                // lint-allow: relaxed-ordering post-join counter read; the scope already synchronized
                stats.messages += cs.sent.load(std::sync::atomic::Ordering::Relaxed);
                stats.send_blocked_secs += cs.send_blocked_secs();
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Source: the numbers [0, n), round-independent, sharded contiguously.
    struct Numbers(usize);

    impl RoundSource for Numbers {
        type Item = usize;
        fn shard(
            &self,
            _round: usize,
            shard: usize,
            num_shards: usize,
        ) -> Box<dyn Iterator<Item = usize> + '_> {
            let chunk = self.0.div_ceil(num_shards);
            let lo = shard * chunk;
            let hi = ((shard + 1) * chunk).min(self.0);
            Box::new(lo..hi)
        }
    }

    /// Mapper: route each number to (n mod reducers), emitting n*2.
    struct ModRouter(usize);

    impl Mapper<usize, usize> for ModRouter {
        fn map(&mut self, item: usize, emit: &mut dyn FnMut(usize, usize)) {
            emit(item % self.0, item * 2);
        }
    }

    #[derive(Default)]
    struct Summer {
        sum: u64,
        rounds_seen: usize,
        count: u64,
    }

    impl Reducer<usize> for Summer {
        fn reduce(&mut self, item: usize) {
            self.sum += item as u64;
            self.count += 1;
        }
        fn end_round(&mut self, _round: usize) {
            self.rounds_seen += 1;
        }
    }

    #[test]
    fn routes_every_item_to_the_right_reducer() {
        let mr = MapReduce {
            num_mappers: 3,
            queue_capacity: 8,
        };
        let mut reducers = vec![Summer::default(), Summer::default()];
        let n = 1000;
        let stats = mr.run(1, &Numbers(n), |_, _| ModRouter(2), &mut reducers);
        // reducer 0 gets evens*2, reducer 1 odds*2
        let even_sum: u64 = (0..n as u64).filter(|x| x % 2 == 0).map(|x| x * 2).sum();
        let odd_sum: u64 = (0..n as u64).filter(|x| x % 2 == 1).map(|x| x * 2).sum();
        assert_eq!(reducers[0].sum, even_sum);
        assert_eq!(reducers[1].sum, odd_sum);
        assert_eq!(stats.messages, n as u64);
        assert_eq!(stats.round_secs.len(), 1);
    }

    #[test]
    fn reducer_state_persists_across_rounds() {
        let mr = MapReduce::default();
        let mut reducers = vec![Summer::default()];
        mr.run(3, &Numbers(10), |_, _| ModRouter(1), &mut reducers);
        assert_eq!(reducers[0].rounds_seen, 3);
        assert_eq!(reducers[0].count, 30); // 10 items × 3 rounds
    }

    #[test]
    fn round_is_a_barrier() {
        // A mapper that tags items with the round; the reducer asserts it
        // never sees round r+1 before end_round(r) ran.
        struct RoundTag;
        impl Mapper<usize, (usize, usize)> for RoundTag {
            fn map(&mut self, item: usize, emit: &mut dyn FnMut(usize, (usize, usize))) {
                emit(0, (item, item));
            }
        }
        struct TagSource;
        impl RoundSource for TagSource {
            type Item = usize;
            fn shard(
                &self,
                round: usize,
                _s: usize,
                _n: usize,
            ) -> Box<dyn Iterator<Item = usize> + '_> {
                Box::new(std::iter::repeat(round).take(50))
            }
        }
        #[derive(Default)]
        struct BarrierCheck {
            current_round: usize,
            violations: usize,
        }
        impl Reducer<(usize, usize)> for BarrierCheck {
            fn reduce(&mut self, (round, _): (usize, usize)) {
                if round != self.current_round {
                    self.violations += 1;
                }
            }
            fn end_round(&mut self, _round: usize) {
                self.current_round += 1;
            }
        }
        let mr = MapReduce {
            num_mappers: 4,
            queue_capacity: 4,
        };
        let mut reducers = vec![BarrierCheck::default()];
        mr.run(4, &TagSource, |round, _| {
            let _ = round;
            RoundTag
        }, &mut reducers);
        assert_eq!(reducers[0].violations, 0);
    }

    #[test]
    fn run_range_split_per_round_matches_one_run() {
        // one run(3) vs three run_range(k..k+1) calls over the same
        // reducer: identical item counts, identical round numbers seen
        let mr = MapReduce::default();
        let mut whole = vec![Summer::default()];
        mr.run(3, &Numbers(10), |_, _| ModRouter(1), &mut whole);

        let mut split = vec![Summer::default()];
        let mut rounds_total = 0;
        for k in 0..3 {
            let stats = mr.run_range(k..k + 1, &Numbers(10), |_, _| ModRouter(1), &mut split);
            assert_eq!(stats.rounds, 1);
            rounds_total += stats.rounds;
        }
        assert_eq!(rounds_total, 3);
        assert_eq!(split[0].sum, whole[0].sum);
        assert_eq!(split[0].count, whole[0].count);
        assert_eq!(split[0].rounds_seen, whole[0].rounds_seen);
    }

    #[test]
    fn fan_out_to_many_reducers_under_tiny_queues() {
        let mr = MapReduce {
            num_mappers: 2,
            queue_capacity: 1, // force heavy backpressure
        };
        let mut reducers: Vec<Summer> = (0..8).map(|_| Summer::default()).collect();
        let stats = mr.run(2, &Numbers(400), |_, _| ModRouter(8), &mut reducers);
        let total: u64 = reducers.iter().map(|r| r.sum).sum();
        let expected: u64 = (0..400u64).map(|x| x * 2).sum::<u64>() * 2;
        assert_eq!(total, expected);
        assert_eq!(stats.messages, 800);
    }
}
