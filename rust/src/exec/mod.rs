//! Execution substrates: thread pool, bounded channels, MapReduce-lite.
pub mod channel;
pub mod mapreduce;
pub mod pool;
