//! Thread-pool substrate (tokio/rayon are unavailable offline).
//!
//! Two primitives cover everything the coordinator needs:
//! * [`ThreadPool`] — long-lived workers consuming boxed jobs, used for
//!   background work with `'static` lifetimes.
//! * [`parallel_for`] / [`parallel_map`] — fork-join over borrowed data via
//!   `std::thread::scope`, used by the trainers and the merge phase.

use crate::util::logging;
use crate::util::sync::{yield_now, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// The submitted-but-unfinished counter `wait_idle` spins on — the one
/// piece of lock-free protocol this module owns. Extracted from
/// [`ThreadPool`] so the loom model can drive it with modeled threads
/// (`std::mpsc` and real spawns are outside loom's reach): the invariant
/// is that **every** submitted job — panicking included — decrements
/// exactly once, or `wait_idle` wedges.
pub struct PendingJobs(AtomicUsize);

impl Default for PendingJobs {
    fn default() -> Self {
        PendingJobs(AtomicUsize::new(0))
    }
}

impl PendingJobs {
    /// Record a submission; pairs with exactly one [`PendingJobs::finish`].
    pub fn submit(&self) {
        self.0.fetch_add(1, Ordering::Acquire);
    }

    /// Record a completion — called from the worker even when the job
    /// panicked, or the count leaks and `wait_idle` spins forever.
    pub fn finish(&self) {
        self.0.fetch_sub(1, Ordering::Release);
    }

    pub fn pending(&self) -> usize {
        self.0.load(Ordering::Acquire)
    }

    /// Busy-wait (with yields) until all submitted jobs completed.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            yield_now();
        }
    }
}

/// Run one job under the pool's panic containment: a panicking job must
/// neither kill the worker thread (the pool would silently lose
/// capacity) nor leak the queued count (`wait_idle` would spin forever)
/// — contain the unwind, always decrement, keep the payload debuggable.
/// Free function so the loom model exercises the exact code the workers
/// run.
fn run_job(job: Job, queued: &PendingJobs) {
    if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".to_string());
        logging::log(
            logging::Level::Warn,
            "exec::pool",
            &format!("worker job panicked: {msg}"),
        );
    }
    queued.finish();
}

/// Fixed-size pool of long-lived worker threads.
pub struct ThreadPool {
    tx: Sender<Message>,
    handles: Vec<JoinHandle<()>>,
    queued: Arc<PendingJobs>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        let (tx, rx) = channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(PendingJobs::default());
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job)) => run_job(job, &queued),
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        Self {
            tx,
            handles,
            queued,
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.submit();
        self.tx
            .send(Message::Run(Box::new(f)))
            .expect("pool receiver alive");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.pending()
    }

    /// Busy-wait (with yields) until all submitted jobs completed.
    pub fn wait_idle(&self) {
        self.queued.wait_idle();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Message::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run `f(chunk_index, item_index_range)` over `n` items split into
/// `workers` contiguous chunks, in parallel, borrowing the environment.
pub fn parallel_for<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            scope.spawn(move || f(w, lo..hi));
        }
    });
}

/// Parallel map over items; preserves input order in the output.
pub fn parallel_map<T: Sync, R: Send, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<R>>> =
        out.iter_mut().map(Mutex::new).collect();
    parallel_for(n, workers, |_, range| {
        for i in range {
            let r = f(&items[i]);
            **slots[i].lock().unwrap() = Some(r);
        }
    });
    drop(slots);
    out.into_iter().map(|r| r.expect("all slots filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool); // must wait for queued jobs' workers to finish current job
        // all ten may not run (shutdown drains), but no panic/hang allowed
    }

    #[test]
    fn pool_survives_contended_submit_and_drain_cycles() {
        // many producers hammering execute() while the main thread drains:
        // every job must run exactly once across repeated drain cycles
        let pool = Arc::new(ThreadPool::new(4));
        let counter = Arc::new(AtomicU64::new(0));
        for _cycle in 0..5 {
            std::thread::scope(|scope| {
                for _ in 0..8 {
                    let pool = Arc::clone(&pool);
                    let counter = Arc::clone(&counter);
                    scope.spawn(move || {
                        for _ in 0..50 {
                            let c = Arc::clone(&counter);
                            pool.execute(move || {
                                c.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                }
            });
            pool.wait_idle();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 5 * 8 * 50);
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn panicking_job_does_not_poison_the_pool() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        // interleave panicking and normal jobs onto both workers
        for i in 0..20 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i % 4 == 0 {
                    panic!("job {i} exploded");
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        // wait_idle must terminate (panicked jobs still decrement queued)…
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 15);
        // …and the workers must still be alive for a fresh round of work
        for _ in 0..30 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 8, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_handles_edge_sizes() {
        parallel_for(0, 4, |_, _| panic!("no work expected"));
        let hits = AtomicU64::new(0);
        parallel_for(1, 16, |_, range| {
            hits.fetch_add(range.len() as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..500).collect();
        let out = parallel_map(&items, 7, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }
}

/// Loom models (CI loom job, `RUSTFLAGS="--cfg loom"`). The pool's
/// worker loop sits behind `std::mpsc` and real thread spawns, which
/// loom cannot model — so the models drive [`PendingJobs`] + [`run_job`]
/// directly, the extracted protocol the workers execute verbatim.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;

    /// The panic-containment invariant: a job that unwinds must still
    /// decrement, under every interleaving, or `wait_idle` wedges.
    #[test]
    fn panicking_job_cannot_wedge_wait_idle() {
        loom::model(|| {
            let queued = Arc::new(PendingJobs::default());
            queued.submit();
            queued.submit();
            let q1 = Arc::clone(&queued);
            let q2 = Arc::clone(&queued);
            let bad = loom::thread::spawn(move || {
                run_job(Box::new(|| panic!("job exploded")), &q1);
            });
            let good = loom::thread::spawn(move || {
                run_job(Box::new(|| {}), &q2);
            });
            bad.join().unwrap();
            good.join().unwrap();
            assert_eq!(queued.pending(), 0, "a panicked job leaked the count");
        });
    }

    /// Submit/finish pairing can never drive the count below zero or
    /// lose a submission, whatever order the two sides interleave in.
    #[test]
    fn submit_finish_pairing_is_exact() {
        loom::model(|| {
            let queued = Arc::new(PendingJobs::default());
            queued.submit();
            let q = Arc::clone(&queued);
            let worker = loom::thread::spawn(move || {
                q.finish();
            });
            queued.submit();
            let seen = queued.pending();
            assert!((1..=2).contains(&seen), "pending out of range: {seen}");
            worker.join().unwrap();
            assert_eq!(queued.pending(), 1);
        });
    }
}
