//! Bounded channels with backpressure metrics.
//!
//! The mapper→reducer data path is a set of bounded queues: when a reducer
//! (training worker) falls behind, its queue fills and the mapper blocks —
//! that *is* the backpressure mechanism, and these wrappers make it
//! observable (blocked time, message counts) so the leader can report
//! whether routing or training is the bottleneck.

use crate::util::sync::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

/// Shared counters for one channel.
#[derive(Debug)]
pub struct ChannelStats {
    pub sent: AtomicU64,
    pub received: AtomicU64,
    /// nanoseconds senders spent blocked on a full queue
    pub send_blocked_ns: AtomicU64,
}

// manual impl: loom's atomics provide no `Default`
impl Default for ChannelStats {
    fn default() -> Self {
        ChannelStats {
            sent: AtomicU64::new(0),
            received: AtomicU64::new(0),
            send_blocked_ns: AtomicU64::new(0),
        }
    }
}

impl ChannelStats {
    pub fn send_blocked_secs(&self) -> f64 {
        // lint-allow: relaxed-ordering monotonic telemetry counter read; no data guarded by it
        self.send_blocked_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn in_flight(&self) -> u64 {
        self.sent
            // lint-allow: relaxed-ordering approximate gauge; saturating_sub absorbs any skew
            .load(Ordering::Relaxed)
            // lint-allow: relaxed-ordering approximate gauge; saturating_sub absorbs any skew
            .saturating_sub(self.received.load(Ordering::Relaxed))
    }
}

pub struct BoundedSender<T> {
    tx: SyncSender<T>,
    stats: Arc<ChannelStats>,
}

impl<T> Clone for BoundedSender<T> {
    fn clone(&self) -> Self {
        Self {
            tx: self.tx.clone(),
            stats: Arc::clone(&self.stats),
        }
    }
}

pub struct BoundedReceiver<T> {
    rx: Receiver<T>,
    stats: Arc<ChannelStats>,
}

/// Create a bounded channel of the given capacity with shared stats.
pub fn bounded<T>(capacity: usize) -> (BoundedSender<T>, BoundedReceiver<T>) {
    let (tx, rx) = sync_channel(capacity);
    let stats = Arc::new(ChannelStats::default());
    (
        BoundedSender {
            tx,
            stats: Arc::clone(&stats),
        },
        BoundedReceiver { rx, stats },
    )
}

impl<T> BoundedSender<T> {
    /// Blocking send; records time spent blocked when the queue is full.
    /// Returns Err when the receiver is gone.
    pub fn send(&self, value: T) -> Result<(), T> {
        match self.tx.try_send(value) {
            Ok(()) => {
                // lint-allow: relaxed-ordering monotonic telemetry counter; no ordering protocol
                self.stats.sent.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(TrySendError::Disconnected(v)) => Err(v),
            Err(TrySendError::Full(v)) => {
                let start = Instant::now();
                let res = self.tx.send(v).map_err(|e| e.0);
                self.stats
                    .send_blocked_ns
                    // lint-allow: relaxed-ordering monotonic telemetry counter; no ordering protocol
                    .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if res.is_ok() {
                    // lint-allow: relaxed-ordering monotonic telemetry counter; no ordering protocol
                    self.stats.sent.fetch_add(1, Ordering::Relaxed);
                }
                res
            }
        }
    }

    pub fn stats(&self) -> Arc<ChannelStats> {
        Arc::clone(&self.stats)
    }
}

impl<T> BoundedReceiver<T> {
    pub fn recv(&self) -> Result<T, RecvError> {
        let v = self.rx.recv()?;
        // lint-allow: relaxed-ordering monotonic telemetry counter; no ordering protocol
        self.stats.received.fetch_add(1, Ordering::Relaxed);
        Ok(v)
    }

    /// Drain into an iterator until all senders hang up.
    pub fn iter(&self) -> impl Iterator<Item = T> + '_ {
        std::iter::from_fn(move || self.recv().ok())
    }

    pub fn stats(&self) -> Arc<ChannelStats> {
        Arc::clone(&self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sends_and_receives_in_order() {
        let (tx, rx) = bounded::<u32>(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let got: Vec<u32> = (0..4).map(|_| rx.recv().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(rx.stats().sent.load(Ordering::Relaxed), 4);
        assert_eq!(rx.stats().received.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn blocked_time_is_recorded_under_backpressure() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let h = std::thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until the receiver drains
            tx.stats().send_blocked_secs()
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        let blocked = h.join().unwrap();
        assert!(blocked > 0.010, "blocked={blocked}");
    }

    #[test]
    fn send_fails_when_receiver_dropped() {
        let (tx, rx) = bounded::<u32>(1);
        drop(rx);
        assert!(tx.send(1).is_err());
    }

    #[test]
    fn iter_drains_until_senders_gone() {
        let (tx, rx) = bounded::<u32>(8);
        let tx2 = tx.clone();
        std::thread::spawn(move || {
            for i in 0..5 {
                tx.send(i).unwrap();
            }
        });
        std::thread::spawn(move || {
            for i in 5..10 {
                tx2.send(i).unwrap();
            }
        });
        let mut got: Vec<u32> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert_eq!(rx.stats().in_flight(), 0);
    }
}

/// Loom models (CI loom job, `RUSTFLAGS="--cfg loom"`). `std::mpsc` is
/// not modelable, so the models drive [`ChannelStats`] directly — the
/// counters are the only lock-free state this module owns.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;

    /// `in_flight` reads two relaxed counters with no snapshot; a reader
    /// may observe `received` ahead of `sent` (its increments are not
    /// ordered for other threads). The gauge must stay in range under
    /// every interleaving — `saturating_sub` is what absorbs the skew.
    #[test]
    fn in_flight_never_underflows() {
        loom::model(|| {
            let stats = Arc::new(ChannelStats::default());
            let writer_stats = Arc::clone(&stats);
            let writer = loom::thread::spawn(move || {
                // lint-allow: relaxed-ordering the model under test IS the relaxed protocol
                writer_stats.sent.fetch_add(1, Ordering::Relaxed);
                // lint-allow: relaxed-ordering the model under test IS the relaxed protocol
                writer_stats.received.fetch_add(1, Ordering::Relaxed);
            });
            let snap = stats.in_flight();
            assert!(snap <= 1, "in-flight gauge out of range: {snap}");
            writer.join().unwrap();
            assert_eq!(stats.in_flight(), 0);
        });
    }
}
