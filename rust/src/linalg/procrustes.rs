//! Orthogonal Procrustes alignment (Schönemann 1966).
//!
//! Given source rows M and target rows Y (same shape), find the orthogonal
//! W minimizing ‖M·W − Y‖_F: W = U·Vᵀ where M ᵀY = U·Σ·Vᵀ. This is the
//! inner step of ALiR — each sub-model is rotated into the consensus frame,
//! and the *same* rotation is then used to reconstruct missing rows.

use super::mat::Mat;
use super::svd::svd;

/// Solve min_W ‖M·W − Y‖_F s.t. WᵀW = I. M, Y are n×d with n ≥ 1.
pub fn orthogonal_procrustes(m: &Mat, y: &Mat) -> Mat {
    assert_eq!(m.rows(), y.rows());
    assert_eq!(m.cols(), y.cols());
    let cross = m.t_matmul(y); // d × d
    let s = svd(&cross);
    s.u.matmul(&s.v.transpose())
}

/// Alignment residual ‖M·W − Y‖_F, normalized by sqrt(n·d) (the paper's
/// displacement-norm convergence metric).
pub fn alignment_residual(m: &Mat, w: &Mat, y: &Mat) -> f64 {
    let aligned = m.matmul(w);
    let diff = aligned.sub(y);
    diff.frobenius_norm() / ((m.rows() * m.cols()) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_rotation(rng: &mut Pcg64, d: usize) -> Mat {
        // QR-free: orthogonalize a random matrix via procrustes against I
        let a = Mat::from_vec(d, d, (0..d * d).map(|_| rng.gen_gauss()).collect());
        let s = svd(&a);
        s.u.matmul(&s.v.transpose())
    }

    fn assert_orthogonal(w: &Mat, tol: f64) {
        let g = w.t_matmul(w);
        assert!(g.max_abs_diff(&Mat::identity(w.cols())) < tol);
    }

    #[test]
    fn recovers_planted_rotation_exactly() {
        let mut rng = Pcg64::new(31);
        for d in [2, 4, 8, 16] {
            let r = random_rotation(&mut rng, d);
            let m = Mat::from_vec(50, d, (0..50 * d).map(|_| rng.gen_gauss()).collect());
            let y = m.matmul(&r);
            let w = orthogonal_procrustes(&m, &y);
            assert!(w.max_abs_diff(&r) < 1e-8, "failed at d={d}");
            assert!(alignment_residual(&m, &w, &y) < 1e-10);
        }
    }

    #[test]
    fn result_is_orthogonal_even_under_noise() {
        let mut rng = Pcg64::new(32);
        let d = 6;
        let r = random_rotation(&mut rng, d);
        let m = Mat::from_vec(100, d, (0..100 * d).map(|_| rng.gen_gauss()).collect());
        let mut y = m.matmul(&r);
        for i in 0..y.rows() {
            for j in 0..d {
                y[(i, j)] += 0.05 * rng.gen_gauss();
            }
        }
        let w = orthogonal_procrustes(&m, &y);
        assert_orthogonal(&w, 1e-9);
        // still close to the planted rotation
        assert!(w.max_abs_diff(&r) < 0.1);
    }

    #[test]
    fn alignment_beats_identity_for_rotated_data() {
        let mut rng = Pcg64::new(33);
        let d = 8;
        let r = random_rotation(&mut rng, d);
        let m = Mat::from_vec(64, d, (0..64 * d).map(|_| rng.gen_gauss()).collect());
        let y = m.matmul(&r);
        let w = orthogonal_procrustes(&m, &y);
        let res_aligned = alignment_residual(&m, &w, &y);
        let res_identity = alignment_residual(&m, &Mat::identity(d), &y);
        assert!(res_aligned < res_identity * 0.01);
    }

    #[test]
    fn sign_flip_case() {
        // the classic averaging-failure example from the paper §3.3.1:
        // model 2 is model 1 mirrored; procrustes must recover the mirror
        let m1 = Mat::from_rows(&[vec![1.0, 1.0], vec![99.0, 0.0], vec![1.0, -1.0]]);
        let m2 = Mat::from_rows(&[vec![-1.0, 1.0], vec![-99.0, 0.0], vec![-1.0, -1.0]]);
        let w = orthogonal_procrustes(&m2, &m1);
        let aligned = m2.matmul(&w);
        assert!(aligned.max_abs_diff(&m1) < 1e-9);
    }
}
