//! Dense row-major f64 matrices.
//!
//! The merge phase (Concat/PCA/ALiR) does all its math in f64 for numerical
//! headroom; embeddings are converted from f32 at the merge boundary. The
//! matmul is cache-blocked with a transposed-B inner kernel — enough to keep
//! the merge phase a small fraction of training time (Table 4's claim),
//! without pulling in BLAS.

use crate::kernels;
use std::ops::{Index, IndexMut};

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Cache-blocked matmul: C = A · B.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "dim mismatch {}x{} · {}x{}", self.rows, self.cols, b.rows, b.cols);
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut out = Mat::zeros(m, n);
        const BK: usize = 64;
        // loop order i-kk-k-j over B rows gives sequential access to both
        // B and C rows — effectively a transpose-free SAXPY kernel.
        for i in 0..m {
            let a_row = self.row(i);
            for kk in (0..k).step_by(BK) {
                let k_hi = (kk + BK).min(k);
                let out_row = out.row_mut(i);
                for kx in kk..k_hi {
                    let a = a_row[kx];
                    let b_row = b.row(kx);
                    kernels::axpy64(a, &b_row[..n], &mut out_row[..n]);
                }
            }
        }
        out
    }

    /// A^T · B without materializing the transpose.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows);
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut out = Mat::zeros(m, n);
        for kx in 0..k {
            let a_row = self.row(kx);
            let b_row = b.row(kx);
            for i in 0..m {
                let a = a_row[i];
                kernels::axpy64(a, &b_row[..n], &mut out.row_mut(i)[..n]);
            }
        }
        out
    }

    pub fn scale(&mut self, s: f64) {
        kernels::scale64(&mut self.data, s);
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        kernels::axpy64(1.0, &other.data, &mut self.data);
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    pub fn frobenius_norm(&self) -> f64 {
        kernels::norm_sq64(&self.data).sqrt()
    }

    /// Mean of each column.
    pub fn col_means(&self) -> Vec<f64> {
        let mut means = vec![0.0; self.cols];
        for i in 0..self.rows {
            kernels::axpy64(1.0, self.row(i), &mut means);
        }
        kernels::scale64(&mut means, 1.0 / self.rows.max(1) as f64);
        means
    }

    /// Subtract a row vector from every row.
    pub fn center_cols(&mut self, means: &[f64]) {
        assert_eq!(means.len(), self.cols);
        for i in 0..self.rows {
            for (v, m) in self.row_mut(i).iter_mut().zip(means) {
                *v -= m;
            }
        }
    }

    /// Horizontal concatenation [A | B].
    pub fn hcat(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows);
        let mut out = Mat::zeros(self.rows, self.cols + b.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(b.row(i));
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.gen_gauss()).collect())
    }

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[vec![19.0, 22.0], vec![43.0, 50.0]]));
    }

    #[test]
    fn matmul_identity_is_noop() {
        let mut rng = Pcg64::new(1);
        let a = random_mat(&mut rng, 7, 7);
        let i = Mat::identity(7);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn matmul_matches_naive_on_random() {
        let mut rng = Pcg64::new(2);
        let a = random_mat(&mut rng, 13, 70);
        let b = random_mat(&mut rng, 70, 9);
        let fast = a.matmul(&b);
        let mut naive = Mat::zeros(13, 9);
        for i in 0..13 {
            for j in 0..9 {
                let mut s = 0.0;
                for k in 0..70 {
                    s += a[(i, k)] * b[(k, j)];
                }
                naive[(i, j)] = s;
            }
        }
        assert!(fast.max_abs_diff(&naive) < 1e-10);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Pcg64::new(3);
        let a = random_mat(&mut rng, 40, 6);
        let b = random_mat(&mut rng, 40, 5);
        let viat = a.transpose().matmul(&b);
        let fused = a.t_matmul(&b);
        assert!(viat.max_abs_diff(&fused) < 1e-10);
    }

    #[test]
    fn center_cols_zeroes_means() {
        let mut rng = Pcg64::new(4);
        let mut a = random_mat(&mut rng, 50, 4);
        let means = a.col_means();
        a.center_cols(&means);
        for m in a.col_means() {
            assert!(m.abs() < 1e-12);
        }
    }

    #[test]
    fn hcat_concatenates() {
        let a = Mat::from_rows(&[vec![1.0], vec![2.0]]);
        let b = Mat::from_rows(&[vec![3.0, 4.0], vec![5.0, 6.0]]);
        let c = a.hcat(&b);
        assert_eq!(c.row(0), &[1.0, 3.0, 4.0]);
        assert_eq!(c.row(1), &[2.0, 5.0, 6.0]);
    }

    #[test]
    fn frobenius_norm_known() {
        let a = Mat::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0]]);
        assert!((a.frobenius_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn f32_roundtrip() {
        let a = Mat::from_f32(2, 2, &[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(a.to_f32(), vec![1.0f32, 2.0, 3.0, 4.0]);
    }
}
