//! Principal component analysis for the PCA merge strategy.
//!
//! The merge input is the concatenated matrix X of shape |V'| × (n·d); the
//! target dimensionality is d. We never form the |V'|×|V'| Gram — the
//! covariance XᵀX is (n·d)², a few-hundred-squared, and its
//! eigendecomposition gives the principal axes directly.

use super::eig;
use super::mat::Mat;

pub struct Pca {
    /// Column means used for centering (length = input cols).
    pub means: Vec<f64>,
    /// Projection matrix, input_cols × k (columns = principal axes).
    pub components: Mat,
    /// Explained variance per component, descending.
    pub explained: Vec<f64>,
}

/// Fit a k-component PCA on X (rows = samples) and return the fit.
///
/// Perf note (EXPERIMENTS.md §Perf): only the top-k eigenpairs of the
/// covariance are needed, so large covariances use subspace iteration
/// (`eig_sym_topk`, O(m²k)/iter) instead of full Jacobi (O(m³)/sweep) —
/// this took the n=10 merge-phase PCA from ~1.4 s to tens of ms.
pub fn fit(x: &Mat, k: usize) -> Pca {
    let k = k.min(x.cols());
    let mut centered = x.clone();
    let means = centered.col_means();
    centered.center_cols(&means);
    let mut cov = centered.t_matmul(&centered);
    let denom = (x.rows().max(2) - 1) as f64;
    cov.scale(1.0 / denom);
    let e = eig::eig_sym_topk(&cov, k, 0x9CA);
    let mut components = Mat::zeros(x.cols(), k);
    for j in 0..k {
        for i in 0..x.cols() {
            components[(i, j)] = e.vectors[(i, j)];
        }
    }
    Pca {
        means,
        components,
        explained: e.values[..k].to_vec(),
    }
}

impl Pca {
    /// Project rows of X (centering with the fit's means).
    pub fn transform(&self, x: &Mat) -> Mat {
        let mut centered = x.clone();
        centered.center_cols(&self.means);
        centered.matmul(&self.components)
    }
}

/// Fit + transform in one call: the top-k representation of X.
pub fn project(x: &Mat, k: usize) -> Mat {
    fit(x, k).transform(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_dominant_direction() {
        // points along (1,1) with small orthogonal noise
        let mut rng = Pcg64::new(21);
        let mut rows = Vec::new();
        for _ in 0..300 {
            let t = rng.gen_gauss() * 10.0;
            let n = rng.gen_gauss() * 0.1;
            rows.push(vec![t + n, t - n]);
        }
        let x = Mat::from_rows(&rows);
        let p = fit(&x, 1);
        let c = (p.components[(0, 0)], p.components[(1, 0)]);
        let dot = (c.0 + c.1).abs() / (2.0f64).sqrt();
        assert!(dot > 0.999, "first PC should be ±(1,1)/√2, got {c:?}");
        assert!(p.explained[0] > 90.0);
    }

    #[test]
    fn projection_preserves_pairwise_distances_when_full_rank() {
        let mut rng = Pcg64::new(22);
        let x = Mat::from_vec(40, 5, (0..200).map(|_| rng.gen_gauss()).collect());
        let y = project(&x, 5); // full-dim projection = rotation
        for i in 0..10 {
            for j in 0..10 {
                let dx: f64 = (0..5)
                    .map(|k| (x[(i, k)] - x[(j, k)]).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let dy: f64 = (0..5)
                    .map(|k| (y[(i, k)] - y[(j, k)]).powi(2))
                    .sum::<f64>()
                    .sqrt();
                assert!((dx - dy).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn explained_variance_descends_and_sums_to_total() {
        let mut rng = Pcg64::new(23);
        let x = Mat::from_vec(100, 6, (0..600).map(|_| rng.gen_gauss()).collect());
        let p = fit(&x, 6);
        for w in p.explained.windows(2) {
            assert!(w[0] >= w[1] - 1e-10);
        }
        // total variance = sum of per-column variances
        let mut centered = x.clone();
        let means = centered.col_means();
        centered.center_cols(&means);
        let total: f64 = (0..6)
            .map(|j| {
                (0..100).map(|i| centered[(i, j)].powi(2)).sum::<f64>() / 99.0
            })
            .sum();
        let sum: f64 = p.explained.iter().sum();
        assert!((total - sum).abs() < 1e-8);
    }

    #[test]
    fn transform_uses_fit_means() {
        let x = Mat::from_rows(&[vec![1.0, 0.0], vec![3.0, 0.0]]);
        let p = fit(&x, 1);
        let y = p.transform(&x);
        // centered values ±1 along the first axis
        assert!((y[(0, 0)].abs() - 1.0).abs() < 1e-9);
        assert!((y[(1, 0)].abs() - 1.0).abs() < 1e-9);
        assert!((y[(0, 0)] + y[(1, 0)]).abs() < 1e-9);
    }
}
