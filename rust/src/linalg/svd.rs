//! Singular value decomposition via one-sided Jacobi rotations.
//!
//! Sizes here are small — Procrustes needs the SVD of a d×d cross-
//! covariance (d = embedding dim, ≤ a few hundred) — so the simple,
//! numerically robust one-sided Jacobi method (Hestenes) is the right
//! tool: it orthogonalizes the columns of A by plane rotations, yielding
//! A·V = U·Σ with machine-precision orthogonality.

use super::mat::Mat;

pub struct Svd {
    pub u: Mat,     // m × n, orthonormal columns
    pub sigma: Vec<f64>, // n singular values, descending
    pub v: Mat,     // n × n orthogonal
}

/// One-sided Jacobi SVD of an m×n matrix with m ≥ n.
pub fn svd(a: &Mat) -> Svd {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "svd expects m >= n (got {m}x{n}); pass the transpose");
    let mut u = a.clone();
    let mut v = Mat::identity(n);
    let eps = 1e-13;
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for the (p, q) column pair
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                off = off.max(apq.abs() / (app.sqrt() * aqq.sqrt() + 1e-300));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation that zeroes the (p,q) Gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - s * uq;
                    u[(i, q)] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // column norms = singular values; normalize U
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigma = vec![0.0; n];
    for j in 0..n {
        let norm: f64 = (0..m).map(|i| u[(i, j)] * u[(i, j)]).sum::<f64>().sqrt();
        sigma[j] = norm;
    }
    order.sort_by(|&a, &b| sigma[b].partial_cmp(&sigma[a]).unwrap());
    let mut u_sorted = Mat::zeros(m, n);
    let mut v_sorted = Mat::zeros(n, n);
    let mut sigma_sorted = vec![0.0; n];
    for (dst, &src) in order.iter().enumerate() {
        sigma_sorted[dst] = sigma[src];
        let inv = if sigma[src] > 1e-300 { 1.0 / sigma[src] } else { 0.0 };
        for i in 0..m {
            u_sorted[(i, dst)] = u[(i, src)] * inv;
        }
        for i in 0..n {
            v_sorted[(i, dst)] = v[(i, src)];
        }
    }
    Svd {
        u: u_sorted,
        sigma: sigma_sorted,
        v: v_sorted,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn reconstruct(s: &Svd) -> Mat {
        let n = s.sigma.len();
        let mut us = s.u.clone();
        for i in 0..us.rows() {
            for j in 0..n {
                us[(i, j)] *= s.sigma[j];
            }
        }
        us.matmul(&s.v.transpose())
    }

    fn assert_orthonormal_cols(m: &Mat, tol: f64) {
        let g = m.t_matmul(m);
        let eye = Mat::identity(m.cols());
        assert!(
            g.max_abs_diff(&eye) < tol,
            "not orthonormal: err={}",
            g.max_abs_diff(&eye)
        );
    }

    #[test]
    fn svd_diagonal_matrix() {
        let a = Mat::from_rows(&[vec![3.0, 0.0], vec![0.0, -2.0], vec![0.0, 0.0]]);
        let s = svd(&a);
        assert!((s.sigma[0] - 3.0).abs() < 1e-10);
        assert!((s.sigma[1] - 2.0).abs() < 1e-10);
        assert!(reconstruct(&s).max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn svd_reconstructs_random_matrices() {
        let mut rng = Pcg64::new(5);
        for (m, n) in [(4, 4), (10, 3), (20, 8), (6, 6)] {
            let a = Mat::from_vec(m, n, (0..m * n).map(|_| rng.gen_gauss()).collect());
            let s = svd(&a);
            assert!(
                reconstruct(&s).max_abs_diff(&a) < 1e-9,
                "reconstruction failed for {m}x{n}"
            );
            assert_orthonormal_cols(&s.u, 1e-9);
            assert_orthonormal_cols(&s.v, 1e-9);
            // descending order
            for w in s.sigma.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn svd_rank_deficient() {
        // rank-1 matrix: second singular value must be ~0
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0], vec![3.0, 6.0]]);
        let s = svd(&a);
        assert!(s.sigma[1].abs() < 1e-10);
        assert!(reconstruct(&s).max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn svd_matches_known_frobenius_identity() {
        let mut rng = Pcg64::new(6);
        let a = Mat::from_vec(12, 5, (0..60).map(|_| rng.gen_gauss()).collect());
        let s = svd(&a);
        let fro2: f64 = s.sigma.iter().map(|x| x * x).sum();
        assert!((fro2 - a.frobenius_norm().powi(2)).abs() < 1e-8);
    }
}
