//! Symmetric eigendecomposition via the classical (cyclic) Jacobi method.
//!
//! Used by PCA on Gram matrices of size (n·d) — a few hundred to ~2k for
//! realistic merge configurations. Jacobi is O(n³) per sweep but converges
//! in a handful of sweeps and is unconditionally stable on symmetric input.

use super::mat::Mat;

pub struct Eig {
    /// Eigenvalues, descending.
    pub values: Vec<f64>,
    /// Column j of `vectors` is the eigenvector for `values[j]`.
    pub vectors: Mat,
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
pub fn eig_sym(a: &Mat) -> Eig {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eig_sym needs a square matrix");
    let mut m = a.clone();
    let mut v = Mat::identity(n);
    let eps = 1e-12;
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < eps * (1.0 + m.frobenius_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // A <- Jᵀ A J applied to rows/cols p and q
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&a, &b| diag[b].partial_cmp(&diag[a]).unwrap());
    let mut values = vec![0.0; n];
    let mut vectors = Mat::zeros(n, n);
    for (dst, &src) in order.iter().enumerate() {
        values[dst] = diag[src];
        for i in 0..n {
            vectors[(i, dst)] = v[(i, src)];
        }
    }
    Eig { values, vectors }
}

/// Top-k eigenpairs of a symmetric PSD matrix by subspace (block power)
/// iteration with Gram–Schmidt re-orthogonalization.
///
/// PCA only needs the leading d components of an (n·d)² Gram matrix, and
/// full Jacobi is O(m³) per sweep — for the merge phase's m ≈ n·d ≈ 320
/// this dominated the whole merge (see EXPERIMENTS.md §Perf). Subspace
/// iteration costs O(m²k) per iteration and converges geometrically with
/// the eigenvalue gap.
pub fn eig_sym_topk(a: &Mat, k: usize, seed: u64) -> Eig {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eig_sym_topk needs a square matrix");
    let k = k.min(n);
    // small problems: exact Jacobi is already fast and unconditionally robust
    if n <= 64 || k * 3 >= n {
        let full = eig_sym(a);
        let mut vectors = Mat::zeros(n, k);
        for j in 0..k {
            for i in 0..n {
                vectors[(i, j)] = full.vectors[(i, j)];
            }
        }
        return Eig {
            values: full.values[..k].to_vec(),
            vectors,
        };
    }
    // oversample the subspace: boundary eigenpairs converge ∝ (λ_p+1/λ_j)^t,
    // so iterating with a buffer of extra columns sharpens the k-th pair
    let p = (k + 8).min(n);
    let mut rng = crate::util::rng::Pcg64::new_stream(seed, 0x6569); // "ei"
    let mut q = Mat::zeros(n, p);
    for i in 0..n {
        for j in 0..p {
            q[(i, j)] = rng.gen_gauss();
        }
    }
    orthonormalize_cols(&mut q);
    let mut prev_trace = f64::NEG_INFINITY;
    for _ in 0..150 {
        // one matmul per iteration: z = A·Q serves both as the next iterate
        // and as the Rayleigh-trace source (trace(QᵀAQ) = Σ q_ij·z_ij)
        let mut z = a.matmul(&q);
        let mut trace = 0.0;
        for j in 0..p {
            for i in 0..n {
                trace += q[(i, j)] * z[(i, j)];
            }
        }
        let converged = (trace - prev_trace).abs() <= 1e-8 * trace.abs().max(1.0);
        prev_trace = trace;
        orthonormalize_cols(&mut z);
        q = z;
        if converged {
            break;
        }
    }
    // Rayleigh–Ritz: project A into the subspace, solve the small problem,
    // keep the leading k pairs
    let aq = a.matmul(&q);
    let small = q.t_matmul(&aq); // p × p
    let small_eig = eig_sym(&small);
    let ritz = q.matmul(&small_eig.vectors);
    let mut vectors = Mat::zeros(n, k);
    for j in 0..k {
        for i in 0..n {
            vectors[(i, j)] = ritz[(i, j)];
        }
    }
    Eig {
        values: small_eig.values[..k].to_vec(),
        vectors,
    }
}

/// Modified Gram–Schmidt on the columns of Q (in place).
fn orthonormalize_cols(q: &mut Mat) {
    let (n, k) = (q.rows(), q.cols());
    for j in 0..k {
        for prev in 0..j {
            let mut dot = 0.0;
            for i in 0..n {
                dot += q[(i, j)] * q[(i, prev)];
            }
            for i in 0..n {
                q[(i, j)] -= dot * q[(i, prev)];
            }
        }
        let norm: f64 = (0..n).map(|i| q[(i, j)] * q[(i, j)]).sum::<f64>().sqrt();
        if norm > 1e-300 {
            for i in 0..n {
                q[(i, j)] /= norm;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_symmetric(rng: &mut Pcg64, n: usize) -> Mat {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.gen_gauss();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn eig_diagonal() {
        let a = Mat::from_rows(&[vec![2.0, 0.0], vec![0.0, 5.0]]);
        let e = eig_sym(&a);
        assert!((e.values[0] - 5.0).abs() < 1e-10);
        assert!((e.values[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn eig_reconstructs_and_is_orthogonal() {
        let mut rng = Pcg64::new(9);
        for n in [2, 5, 12, 30] {
            let a = random_symmetric(&mut rng, n);
            let e = eig_sym(&a);
            // A V = V Λ
            let av = a.matmul(&e.vectors);
            let mut vl = e.vectors.clone();
            for i in 0..n {
                for j in 0..n {
                    vl[(i, j)] *= e.values[j];
                }
            }
            assert!(av.max_abs_diff(&vl) < 1e-8, "AV != VΛ at n={n}");
            // Vᵀ V = I
            let g = e.vectors.t_matmul(&e.vectors);
            assert!(g.max_abs_diff(&Mat::identity(n)) < 1e-9);
            // descending
            for w in e.values.windows(2) {
                assert!(w[0] >= w[1] - 1e-10);
            }
        }
    }

    #[test]
    fn eig_trace_preserved() {
        let mut rng = Pcg64::new(10);
        let a = random_symmetric(&mut rng, 8);
        let e = eig_sym(&a);
        let tr: f64 = (0..8).map(|i| a[(i, i)]).sum();
        let sum: f64 = e.values.iter().sum();
        assert!((tr - sum).abs() < 1e-9);
    }

    #[test]
    fn eig_known_2x2() {
        // [[0,1],[1,0]] has eigenvalues ±1
        let a = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let e = eig_sym(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-10);
        assert!((e.values[1] + 1.0).abs() < 1e-10);
    }

    #[test]
    fn topk_matches_full_jacobi_on_psd() {
        let mut rng = Pcg64::new(41);
        // PSD Gram matrix, 200×200 (forces the iterative path)
        let x = Mat::from_vec(300, 200, (0..60_000).map(|_| rng.gen_gauss()).collect());
        let g = x.t_matmul(&x);
        let full = eig_sym(&g);
        let top = eig_sym_topk(&g, 8, 1);
        for j in 0..8 {
            let rel = (top.values[j] - full.values[j]).abs() / full.values[j].abs().max(1.0);
            assert!(rel < 1e-6, "eigenvalue {j}: {} vs {}", top.values[j], full.values[j]);
            // eigenvector match up to sign
            let dot: f64 = (0..200)
                .map(|i| top.vectors[(i, j)] * full.vectors[(i, j)])
                .sum();
            assert!(dot.abs() > 0.999, "eigenvector {j} misaligned: |dot|={}", dot.abs());
        }
    }

    #[test]
    fn topk_small_matrix_falls_back_to_jacobi() {
        let mut rng = Pcg64::new(42);
        let a = random_symmetric(&mut rng, 10);
        let full = eig_sym(&a);
        let top = eig_sym_topk(&a, 3, 2);
        for j in 0..3 {
            assert!((top.values[j] - full.values[j]).abs() < 1e-9);
        }
        assert_eq!(top.vectors.cols(), 3);
    }

    #[test]
    fn eig_psd_gram_matrix_nonnegative() {
        let mut rng = Pcg64::new(11);
        let x = Mat::from_vec(20, 6, (0..120).map(|_| rng.gen_gauss()).collect());
        let g = x.t_matmul(&x);
        let e = eig_sym(&g);
        for v in &e.values {
            assert!(*v > -1e-9, "PSD matrix produced negative eigenvalue {v}");
        }
    }
}
