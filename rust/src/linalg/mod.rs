//! Self-contained dense linear algebra for the merge phase.
pub mod eig;
pub mod mat;
pub mod pca;
pub mod procrustes;
pub mod svd;
