//! dw2v — the leader binary.
//!
//! Subcommands:
//!   pipeline        full divide → train → merge → eval run (the paper system)
//!   pipeline-procs  the same pipeline with one OS process per sub-model,
//!                   trained over a persisted shard directory
//!   train-worker    train ONE sub-model in this process (what
//!                   pipeline-procs spawns; rarely typed by hand)
//!   shard-server    serve a shard dir to (and collect uploads from)
//!                   remote train-workers over TCP (`--connect`)
//!   hogwild         single-node lock-free baseline (paper's comparator)
//!   mllib           parameter-averaging distributed baseline
//!   kl              Figure-1 distribution statistics for the dividers
//!   gen-corpus      generate (synthetic) or ingest (`--text`) + persist a corpus
//!   serve           ANN-indexed query engine over a saved embedding
//!                   (`--model model.bin [--vocab vocab.tsv] [--queries f]`)
//!   artifacts       show the AOT artifact manifest
//!
//! Every flag maps to a key of `ExperimentConfig`; `--config file.json`
//! loads a base config that individual flags then override.
//!
//! ## Multi-process training (`pipeline-procs` / `train-worker`)
//!
//! The in-process `pipeline` realizes the paper's asynchrony with reducer
//! threads; `pipeline-procs` promotes it to OS processes with the
//! persisted shard files as the *only* exchange medium:
//!
//! 1. persist a corpus: `dw2v gen-corpus --out DIR` (synthetic) or
//!    `--text file --out DIR` (ingestion) — both leave `shard_*.bin` +
//!    `vocab.tsv` in `DIR`;
//! 2. `dw2v pipeline-procs --shard-dir DIR --rate r ...` spawns `100/r`
//!    `train-worker` processes. Each worker streams sentences one at a
//!    time from the shard files (peak corpus memory: one sentence),
//!    routes them with the stateless counter-based divider — workers
//!    need **zero** training-time communication, only the shared
//!    `(seed, strategy, rate, epoch)` — and publishes its sub-model as a
//!    versioned artifact (`submodel_<s>.dwsm`, write-then-rename);
//! 3. the coordinator monitors the workers, collects whatever artifacts
//!    came back, and runs the same merge + eval tail as `pipeline`.
//!
//! **Failure semantics:** the coordinator supervises its workers through
//! per-worker heartbeat *beacons* (`beacon_<s>.json`, rewritten
//! atomically every `--beacon-interval-ms`, default 250 ms; any byte
//! change counts as liveness). A worker is **healthy** while its beacon
//! keeps changing, **stalled** once it hasn't within
//! `--worker-stall-timeout` seconds (stalled workers are killed), and
//! **dead** when its process exits without a valid artifact. What happens
//! next is `--on-worker-failure`:
//!
//! * `retry` (default) — respawn the worker after a capped exponential
//!   backoff (200 ms · 2^attempt, capped at 5 s), up to
//!   `--max-worker-retries` times. Workers checkpoint at every epoch
//!   boundary (`submodel_<s>.ckpt`: packed trainer state + exact f64
//!   counters, write-then-rename), so a respawn resumes at the last
//!   finished epoch — and, because routing is stateless and the batch
//!   RNG never advances, finishes **bitwise identical** to an
//!   uninterrupted run on the native backend. Retries exhausted ⇒ the
//!   worker degrades (below).
//! * `degrade` — abandon the worker; the merge proceeds over the
//!   survivors and the failure is reported in the worker table. The run
//!   only errors when *no* worker survives.
//! * `fail-fast` — kill the remaining pool and exit non-zero.
//!
//! With `--mappers 1` a multi-process run reproduces the in-process
//! `pipeline` sub-models bitwise (native backend).
//!
//! **Ingest-while-training overlap (`--overlap`):**
//! `dw2v pipeline-procs --overlap --text FILE --shard-dir DIR ...` runs
//! the raw-text ingest *concurrently* with the worker fleet: the ingest
//! publishes each shard atomically plus a manifest (`shards.json`), and
//! workers follow the growing directory, beaconing a `waiting` phase
//! while blocked on the next shard (the stall detector reads that as
//! healthy). Because the ingest publishes the exact sentence total and
//! lr-schedule denominator in the manifest *before* the first shard,
//! the overlapped run merges **bitwise identical** to running ingest
//! and `pipeline-procs` back to back (native backend, `--mappers 1`).
//! `--min-count` / `--max-vocab` / `--shard-tokens` shape the ingest.
//!
//! **Fault injection (tests / chaos drills):** set `DW2V_FAULT` in the
//! coordinator's environment; each worker parses it at startup. Grammar:
//! `spec := clause (';' clause)*`, `clause := action ('@' key '=' value)*`
//! with actions `crash@pairs=N` (exit once N pairs trained; one-shot per
//! artifact dir), `stall@epoch=K` (hang before epoch K; one-shot),
//! `corrupt-artifact` (truncate the artifact, exit 0), and
//! `slow@factor=F` (sleep F µs per sentence). Add `@submodel=S` to aim a
//! clause at one worker. Example:
//! `DW2V_FAULT='crash@pairs=5000@submodel=1;slow@factor=100'`.
//!
//! ## Corpus sources (`--text`)
//!
//! Every experiment subcommand trains from one of two corpus sources:
//!
//! * **synthetic** (default) — the planted-ground-truth generator
//!   (`--sentences`/`--vocab`/... knobs), evaluated on the gold benchmark
//!   suite;
//! * **raw text** (`--text file`) — the file is streamed through the
//!   two-pass ingestion pipeline (`text::ingest`: parallel tokenize +
//!   vocab count, then id-encode into binary corpus shards; memory stays
//!   bounded by chunk/shard size, not corpus size). `--min-count` /
//!   `--max-vocab` control the vocabulary, `--eval questions-words.txt`
//!   supplies a real analogy benchmark, and `--shard-dir` persists the
//!   shard + vocab.tsv layout for reuse.
//!
//! ## Backend selection (`--backend auto|native|xla`)
//!
//! Training dispatches run on a compute backend (see
//! `dw2v::runtime::backend`):
//!
//! | `--backend` | engine            | requirements                         |
//! |-------------|-------------------|--------------------------------------|
//! | `auto`      | xla when loadable, else native | none (the default)      |
//! | `native`    | pure-rust kernels | none — runs everywhere               |
//! | `xla`       | PJRT AOT bridge   | `--features xla` + `make artifacts`  |
//!
//! `auto` tries to resolve `--artifact-dir` and compile the PJRT
//! executables; any failure (feature not compiled, no manifest, no
//! fitting artifact) logs the reason and falls back to the native
//! backend, so `dw2v pipeline` completes on a machine with no XLA
//! toolchain at all.

#![allow(clippy::field_reassign_with_default)]

use dw2v::coordinator::divider::Divider;
use dw2v::coordinator::leader;
use dw2v::coordinator::stats::{bigram_kl, unigram_kl, vocab_coverage, DistStats};
use dw2v::eval::report::{self, evaluate_suite};
use dw2v::runtime::artifacts::Manifest;
use dw2v::runtime::{load_backend, Backend};
use dw2v::sgns::hogwild;
use dw2v::util::cli::Command;
use dw2v::util::config::ExperimentConfig;
use dw2v::util::logging::{self, Timer};
use dw2v::world::{build_world, TextWorldOptions, World};

fn main() {
    if let Err(e) = logging::level_from_env() {
        // a garbage DW2V_LOG means the user's filtering intent can't be
        // honored — fail loudly up front instead of silently logging at
        // the default level for the whole run
        eprintln!("{e}");
        std::process::exit(2);
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("pipeline") => cmd_pipeline(&argv[1..]),
        Some("pipeline-procs") => cmd_pipeline_procs(&argv[1..]),
        Some("train-worker") => cmd_train_worker(&argv[1..]),
        Some("shard-server") => cmd_shard_server(&argv[1..]),
        Some("hogwild") => cmd_hogwild(&argv[1..]),
        Some("mllib") => cmd_mllib(&argv[1..]),
        Some("kl") => cmd_kl(&argv[1..]),
        Some("gen-corpus") => cmd_gen_corpus(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("status") => cmd_status(&argv[1..]),
        Some("report") => cmd_report(&argv[1..]),
        Some("artifacts") => cmd_artifacts(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            eprintln!("{USAGE}\n\nenvironment knobs:\n{}", dw2v::util::env::knob_table());
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand '{other}'\n\n{USAGE}")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        1
    });
    std::process::exit(code);
}

const USAGE: &str = "dw2v — asynchronous word-embedding training (WSDM'19 reproduction)

subcommands:
  pipeline        divide -> train -> merge -> eval (the paper's system)
  pipeline-procs  the same pipeline with one OS process per sub-model over
                  a persisted shard dir (gen-corpus / --text --shard-dir);
                  workers are supervised via heartbeat beacons and recovered
                  per --on-worker-failure retry|degrade|fail-fast (retry
                  respawns from epoch-boundary checkpoints)
  train-worker    train ONE sub-model from shard files in this process
                  (spawned by pipeline-procs); --connect HOST:PORT trains
                  against a shard-server instead of the local filesystem
  shard-server    serve a shard dir to remote train-workers over TCP and
                  mirror their uploads (artifacts, beacons, journals) into
                  a local run dir that status/report read unchanged
  hogwild         single-node lock-free baseline
  mllib           parameter-averaging distributed baseline
  kl              figure-1 KL-divergence statistics for the dividers
  gen-corpus      generate (synthetic) or ingest (--text) + persist a corpus
  serve           ANN-indexed query engine over a saved embedding
  status RUN_DIR  live per-worker progress table for a pipeline-procs run
                  (tails the heartbeat beacons; --once for one snapshot)
  report RUN_DIR  aggregate a run's event journals + beacons into
                  run_report.json + a self-contained run_report.html
  artifacts       show the AOT artifact manifest

corpus sources (pipeline / hogwild / mllib / kl / gen-corpus):
  default      synthetic planted-ground-truth generator (--sentences ...)
  --text FILE  stream a raw text file through the two-pass ingestion
               pipeline (tokenize -> parallel vocab -> binary shards);
               tune with --min-count / --max-vocab, benchmark with
               --eval questions-words.txt, persist with --shard-dir

backends (--backend auto|native|xla):
  auto         use the PJRT/XLA artifacts when they load, else fall back
               to the pure-rust native backend (default)
  native       pure-rust CPU kernels — no artifacts, runs everywhere
  xla          PJRT AOT bridge — needs --features xla and `make artifacts`

run `dw2v <subcommand> --help` for flags; `dw2v --help` lists the
DW2V_* environment knobs.";

/// Flags shared by every experiment-driving subcommand.
fn experiment_command(name: &str, about: &str) -> Command {
    Command::new(name, about)
        .flag("config", None, "JSON config file to start from")
        .flag("set", None, "comma-separated key=value config overrides")
        .flag("seed", None, "root RNG seed")
        .flag("sentences", None, "synthetic corpus size")
        .flag("vocab", None, "vocabulary size")
        .flag("dim", None, "embedding dimensionality")
        .flag("epochs", None, "training epochs")
        .flag("strategy", None, "divider: equal | random | shuffle")
        .flag("rate", None, "sampling rate r% (submodels = 100/r)")
        .flag("merge", None, "merge: concat | pca | alir_rand | alir_pca | single")
        .flag("mappers", None, "mapper threads")
        .flag("backend", None, "compute backend: auto | native | xla")
        .flag("artifact-dir", None, "AOT artifact directory")
        .flag("text", None, "raw text file to ingest instead of the synthetic corpus")
        .flag("min-count", Some("5"), "(--text) drop words seen fewer times")
        .flag("max-vocab", Some("1000000"), "(--text) keep at most this many words")
        .flag("eval", None, "(--text) questions-words.txt analogy benchmark file")
        .flag("shard-dir", None, "(--text) persist ingested shards + vocab.tsv here")
}

/// Corpus source dispatch: `--text file` streams a raw text file through
/// the two-pass ingestion pipeline (`text::ingest`); otherwise the
/// synthetic generator builds the world from `cfg`.
fn load_world(cfg: &ExperimentConfig, args: &dw2v::util::cli::Args) -> Result<World, String> {
    let Some(path) = args.get("text") else {
        // catch the classic slip of passing ingestion flags without the
        // corpus they configure — a synthetic run would otherwise
        // silently score the gold suite instead of the requested file
        if args.get("eval").is_some() || args.get("shard-dir").is_some() {
            return Err("--eval/--shard-dir configure raw-text ingestion; add --text FILE".into());
        }
        return Ok(build_world(cfg));
    };
    let mut opts = TextWorldOptions::default();
    if let Some(mc) = args.get_u64("min-count").map_err(|e| e.to_string())? {
        opts.ingest.min_count = mc;
    }
    if let Some(mv) = args.get_usize("max-vocab").map_err(|e| e.to_string())? {
        opts.ingest.max_vocab = mv;
    }
    opts.ingest.workers = cfg.mappers.max(1);
    opts.shard_dir = args.get("shard-dir").map(std::path::PathBuf::from);
    opts.questions = args.get("eval").map(std::path::PathBuf::from);
    let (world, stats) = World::from_text(std::path::Path::new(path), &opts)?;
    println!("{}", stats.summary());
    if world.suite.is_empty() {
        eprintln!("note: no benchmark suite for --text (pass --eval questions-words.txt)");
    }
    Ok(world)
}

fn parse_experiment(args: &dw2v::util::cli::Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(sets) = args.get("set") {
        for kv in sets.split(',') {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("--set expects key=value, got '{kv}'"))?;
            cfg.apply(k.trim(), v.trim())?;
        }
    }
    for (flag, key) in [
        ("seed", "seed"),
        ("sentences", "sentences"),
        ("vocab", "vocab"),
        ("dim", "dim"),
        ("epochs", "epochs"),
        ("strategy", "strategy"),
        ("rate", "rate_percent"),
        ("merge", "merge"),
        ("mappers", "mappers"),
        ("backend", "backend"),
        ("artifact-dir", "artifact_dir"),
    ] {
        if let Some(v) = args.get(flag) {
            cfg.apply(key, v)?;
        }
    }
    Ok(cfg)
}

fn cmd_pipeline(argv: &[String]) -> Result<(), String> {
    let cmd = experiment_command("pipeline", "full divide → train → merge → eval run");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let cfg = parse_experiment(&args)?;

    let t_setup = Timer::start("setup");
    let world = load_world(&cfg, &args)?;
    let backend = load_backend(&cfg, world.vocab.len())?;
    println!(
        "setup: corpus {} sentences / {} tokens, vocab {}, backend {} ({:.1}s)",
        world.corpus.len(),
        world.corpus.total_tokens(),
        world.vocab.len(),
        backend.name(),
        t_setup.stop_quiet()
    );

    let rep = leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, &backend)?;
    println!(
        "train {:.2}s ({} pairs, {} dispatches) | merge {:.2}s | eval {:.2}s",
        rep.train.train_secs, rep.train.pairs, rep.train.dispatches, rep.merge_secs, rep.eval_secs
    );
    println!("merged vocab: {} / {}", rep.merged_vocab, world.vocab.len());
    for (s, losses) in rep.train.epoch_loss.iter().enumerate().take(4) {
        let fmt: Vec<String> = losses.iter().map(|l| format!("{l:.4}")).collect();
        println!("submodel {s} epoch losses: [{}]", fmt.join(", "));
    }
    println!("\n{}", report::format_header(&rep.scores));
    println!(
        "{}",
        report::format_row(
            &format!(
                "{} {}% + {}",
                cfg.strategy.name(),
                cfg.rate_percent,
                cfg.merge.name()
            ),
            &rep.scores
        )
    );
    Ok(())
}

/// The flags shared by the two multi-process subcommands: the experiment
/// knobs that shape training (no corpus-generation or ingestion flags —
/// the corpus is whatever the shard directory holds).
fn procs_experiment_command(name: &str, about: &str) -> Command {
    Command::new(name, about)
        .flag("config", None, "JSON config file to start from")
        .flag("set", None, "comma-separated key=value config overrides")
        .flag("seed", None, "root RNG seed")
        .flag("dim", None, "embedding dimensionality")
        .flag("epochs", None, "training epochs")
        .flag("strategy", None, "divider: equal | random | shuffle")
        .flag("rate", None, "sampling rate r% (submodels = 100/r)")
        .flag("merge", None, "merge: concat | pca | alir_rand | alir_pca | single")
        .flag("mappers", None, "mapper threads per worker")
        .flag("backend", None, "compute backend: auto | native | xla")
        .flag("artifact-dir", None, "AOT artifact directory")
        .flag("shard-dir", None, "directory of shard_*.bin + vocab.tsv [required]")
}

fn required_flag<'a>(
    args: &'a dw2v::util::cli::Args,
    name: &str,
    cmd: &Command,
) -> Result<&'a str, String> {
    args.get(name)
        .ok_or_else(|| format!("--{name} is required\n\n{}", cmd.usage()))
}

fn cmd_train_worker(argv: &[String]) -> Result<(), String> {
    let cmd = procs_experiment_command(
        "train-worker",
        "train ONE sub-model in this process from on-disk shards",
    )
    .flag("submodel", None, "sub-model index to train (0-based) [required]")
    .flag("out", None, "artifact output path (.dwsm) [required]")
    .flag(
        "connect",
        None,
        "HOST:PORT of a dw2v shard-server — stream shards from and publish \
         artifacts/beacons to it instead of the local filesystem",
    );
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let cfg = parse_experiment(&args)?;
    let shard_dir = required_flag(&args, "shard-dir", &cmd)?;
    let out = required_flag(&args, "out", &cmd)?;
    let submodel = args
        .get_usize("submodel")
        .map_err(|e| e.to_string())?
        .ok_or_else(|| format!("--submodel is required\n\n{}", cmd.usage()))?;
    let spec = dw2v::coordinator::procs::WorkerSpec {
        shard_dir: std::path::PathBuf::from(shard_dir),
        submodel,
        out: std::path::PathBuf::from(out),
        connect: args.get("connect").map(String::from),
    };
    dw2v::coordinator::procs::run_worker(&cfg, &spec)
}

/// `dw2v shard-server` — the server half of the TCP transport
/// (`dw2v::transport`): serve a shard directory read-only and mirror
/// worker uploads into a run dir as ordinary run-dir files, so
/// `status`/`report` and the supervisor read a remote fleet unchanged.
fn cmd_shard_server(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new(
        "shard-server",
        "serve shards to (and collect uploads from) remote train-workers",
    )
    .flag("shard-dir", None, "directory of shard_*.bin + vocab.tsv to serve [required]")
    .flag(
        "out-dir",
        None,
        "run dir uploads are mirrored into (default: <shard-dir>/submodels); point the \
         coordinator's --out-dir at the same directory for a loopback deployment",
    )
    .flag("host", Some("127.0.0.1"), "address to bind")
    .flag("port", Some("0"), "port to bind (0 = ephemeral; the bound address is printed)");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let shard_dir = std::path::PathBuf::from(required_flag(&args, "shard-dir", &cmd)?);
    let out_dir = args
        .get("out-dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| shard_dir.join("submodels"));
    let host = args.get_str("host", "127.0.0.1");
    let port = args.get_u64("port").map_err(|e| e.to_string())?.unwrap_or(0);
    let server =
        dw2v::transport::server::ShardServer::bind(&format!("{host}:{port}"), &shard_dir, &out_dir)?;
    println!("shard-server listening on {}", server.local_addr()?);
    println!("  serving shards from {}", shard_dir.display());
    println!("  mirroring uploads into {}", out_dir.display());
    server.run();
    Ok(())
}

fn cmd_pipeline_procs(argv: &[String]) -> Result<(), String> {
    use dw2v::coordinator::procs::{self, ProcsOptions};
    use dw2v::coordinator::supervisor::{self, FailurePolicy, SupervisorOptions};

    let cmd = procs_experiment_command(
        "pipeline-procs",
        "multi-process divide → train → merge → eval over a persisted shard dir",
    )
    .flag("eval", None, "questions-words.txt analogy benchmark file")
    .flag("out-dir", None, "worker artifact directory (default: <shard-dir>/submodels)")
    .flag("worker-exe", None, "dw2v binary to spawn (default: this executable)")
    .flag("save-model", None, "save the merged consensus embedding here")
    .bool_flag(
        "overlap",
        "ingest --text into --shard-dir concurrently: workers start training as soon \
         as the first shard is published (bitwise identical to ingest-then-train)",
    )
    .flag("text", None, "(--overlap) raw text file to ingest while training")
    .flag("min-count", Some("5"), "(--overlap) drop words seen fewer times")
    .flag("max-vocab", Some("1000000"), "(--overlap) keep at most this many words")
    .flag("shard-tokens", None, "(--overlap) target encoded tokens per shard file")
    .flag(
        "on-worker-failure",
        Some("retry"),
        "failed/stalled worker policy: retry | degrade | fail-fast",
    )
    .flag(
        "max-worker-retries",
        Some("2"),
        "respawns per worker before it degrades (retry policy)",
    )
    .flag(
        "worker-stall-timeout",
        Some("300"),
        "seconds without beacon progress before a worker counts as stalled",
    )
    .flag(
        "beacon-interval-ms",
        Some("250"),
        "worker heartbeat publish interval (milliseconds)",
    )
    .flag(
        "connect",
        None,
        "HOST:PORT of a dw2v shard-server — workers fetch shards from and upload \
         artifacts to it; the server must mirror into this run's --out-dir",
    );
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let cfg = parse_experiment(&args)?;
    let shard_dir = std::path::PathBuf::from(required_flag(&args, "shard-dir", &cmd)?);
    let overlap = args.get_bool("overlap");
    if args.get("text").is_some() && !overlap {
        return Err("--text is the overlap ingest input; add --overlap".into());
    }

    let worker_exe = match args.get("worker-exe") {
        Some(p) => std::path::PathBuf::from(p),
        None => procs::find_worker_exe()?,
    };
    let out_dir = args
        .get("out-dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| shard_dir.join("submodels"));
    let opts = ProcsOptions {
        worker_exe,
        shard_dir,
        out_dir,
        extra_env: Vec::new(),
        connect: args.get("connect").map(String::from),
    };
    let mut sup = SupervisorOptions {
        policy: FailurePolicy::parse(&args.get_str("on-worker-failure", "retry"))?,
        ..Default::default()
    };
    if let Some(r) = args.get_usize("max-worker-retries").map_err(|e| e.to_string())? {
        sup.max_retries = r;
    }
    if let Some(secs) = args.get_f64("worker-stall-timeout").map_err(|e| e.to_string())? {
        if !secs.is_finite() || secs <= 0.0 {
            return Err(format!("--worker-stall-timeout must be positive, got {secs}"));
        }
        sup.stall_timeout = std::time::Duration::from_secs_f64(secs);
    }
    if let Some(ms) = args.get_u64("beacon-interval-ms").map_err(|e| e.to_string())? {
        sup.beacon_interval_ms = ms;
    }

    let (vocab, rep) = if overlap {
        use dw2v::coordinator::overlap::{run_overlapped, OverlapRunOptions};
        let text = required_flag(&args, "text", &cmd)?;
        let mut icfg = dw2v::text::ingest::IngestConfig {
            workers: cfg.mappers.max(1),
            ..Default::default()
        };
        if let Some(mc) = args.get_u64("min-count").map_err(|e| e.to_string())? {
            icfg.min_count = mc;
        }
        if let Some(mv) = args.get_usize("max-vocab").map_err(|e| e.to_string())? {
            icfg.max_vocab = mv;
        }
        if let Some(st) = args.get_u64("shard-tokens").map_err(|e| e.to_string())? {
            icfg.shard_tokens = st;
        }
        let scfg = dw2v::coordinator::leader::sgns_config(&cfg);
        let mut ocfg = dw2v::text::ingest::OverlapOptions::new(scfg.window, scfg.subsample_t);
        // test hook: throttle shard publication so e2e tests can prove the
        // workers trained while shards were still being written
        if let Some(ms) = dw2v::util::env::ingest_shard_delay_ms()? {
            ocfg.shard_delay = std::time::Duration::from_millis(ms);
        }
        let ov = OverlapRunOptions {
            input: std::path::PathBuf::from(text),
            ingest: icfg,
            overlap: ocfg,
            eval: args.get("eval").map(std::path::PathBuf::from),
            feed: dw2v::text::feed::FeedOptions::default(),
        };
        let rep = run_overlapped(&cfg, &opts, &sup, &ov)?;
        println!("{}", rep.ingest.stats.summary());
        (rep.vocab, rep.sup)
    } else {
        let (vocab, suite) = World::vocab_and_suite_from_shards(
            &opts.shard_dir,
            args.get("eval").map(std::path::Path::new),
        )?;
        let rep = supervisor::run_supervised(&cfg, &suite, &opts, &sup)?;
        (vocab, rep)
    };

    println!(
        "\nworkers ({} spawned, {} survived; {} failures, {} stalls, {} respawns):",
        rep.outcomes.len(),
        rep.survivors(),
        rep.stats.failures_seen,
        rep.stats.stalls_detected,
        rep.stats.respawns
    );
    for o in &rep.outcomes {
        match &o.artifact {
            Some(a) => println!(
                "  worker {:>3}: {} ({:.2}s, {} pairs, final-epoch loss {:.4})",
                o.submodel,
                o.fate,
                o.secs,
                a.meta.pairs,
                a.meta.epoch_loss.last().copied().unwrap_or(f64::NAN)
            ),
            None => println!("  worker {:>3}: {} ({:.2}s)", o.submodel, o.fate, o.secs),
        }
    }
    println!(
        "train (multi-process) {:.2}s | merge {:.2}s | eval {:.2}s",
        rep.train_secs, rep.tail.merged.seconds, rep.tail.eval_secs
    );
    println!(
        "merged vocab: {} / {}",
        rep.tail.merged.embedding.present_count(),
        vocab.len()
    );
    if let Some(path) = args.get("save-model") {
        rep.tail
            .merged
            .embedding
            .save(std::path::Path::new(path))
            .map_err(|e| format!("save {path}: {e}"))?;
        println!("merged model saved to {path}");
    }
    if rep.tail.scores.is_empty() {
        eprintln!("note: no benchmark suite (pass --eval questions-words.txt)");
    } else {
        println!("\n{}", report::format_header(&rep.tail.scores));
        println!(
            "{}",
            report::format_row(
                &format!(
                    "procs {} {}% + {}",
                    cfg.strategy.name(),
                    cfg.rate_percent,
                    cfg.merge.name()
                ),
                &rep.tail.scores
            )
        );
    }
    Ok(())
}

fn cmd_hogwild(argv: &[String]) -> Result<(), String> {
    let cmd = experiment_command("hogwild", "single-node lock-free baseline")
        .flag("threads", Some("4"), "hogwild threads");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let cfg = parse_experiment(&args)?;
    let threads = args
        .get_usize("threads")
        .map_err(|e| e.to_string())?
        .unwrap_or(4);
    let world = load_world(&cfg, &args)?;
    let scfg = leader::sgns_config(&cfg);
    let (emb, stats) = hogwild::train(&world.corpus, &world.vocab, &scfg, threads, cfg.seed);
    println!(
        "hogwild: {:.2}s, {} pairs, final lr {:.5}, final-epoch loss {:.4}",
        stats.seconds, stats.pairs, stats.final_lr, stats.final_epoch_loss
    );
    let scores = evaluate_suite(&emb, &world.suite, cfg.seed);
    println!("\n{}", report::format_header(&scores));
    println!("{}", report::format_row("Hogwild", &scores));
    Ok(())
}

fn cmd_mllib(argv: &[String]) -> Result<(), String> {
    let cmd = experiment_command("mllib", "parameter-averaging distributed baseline")
        .flag("executors", Some("10"), "synchronized executors");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let cfg = parse_experiment(&args)?;
    let executors = args
        .get_usize("executors")
        .map_err(|e| e.to_string())?
        .unwrap_or(10);
    let world = load_world(&cfg, &args)?;
    let scfg = leader::sgns_config(&cfg);
    let backend = load_backend(&cfg, world.vocab.len())?;
    let (emb, stats) = dw2v::baselines::param_avg::train(
        &world.corpus,
        &world.vocab,
        &scfg,
        &backend,
        executors,
        cfg.seed,
    )?;
    println!(
        "mllib-style: {:.2}s, {} pairs, {} sync rounds",
        stats.seconds, stats.pairs, stats.sync_rounds
    );
    let scores = evaluate_suite(&emb, &world.suite, cfg.seed);
    println!("\n{}", report::format_header(&scores));
    println!(
        "{}",
        report::format_row(&format!("MLlib, {executors} executors"), &scores)
    );
    Ok(())
}

fn cmd_kl(argv: &[String]) -> Result<(), String> {
    let cmd = experiment_command("kl", "figure-1 KL statistics (divider quality)")
        .flag("samples", Some("10"), "sub-corpora to average over");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let cfg = parse_experiment(&args)?;
    let samples = args
        .get_usize("samples")
        .map_err(|e| e.to_string())?
        .unwrap_or(10);
    let world = load_world(&cfg, &args)?;
    let corpus = &world.corpus;
    let full = DistStats::from_corpus(corpus);
    println!("strategy       unigram-KL   bigram-KL   union-cov  inter-cov");
    for strategy in [
        dw2v::util::config::DivideStrategy::EqualPartitioning,
        dw2v::util::config::DivideStrategy::RandomSampling,
        dw2v::util::config::DivideStrategy::Shuffle,
    ] {
        let divider = Divider::new(strategy.clone(), cfg.rate_percent, cfg.seed, corpus.len())?;
        let take = samples.min(divider.num_submodels);
        let mut subs = Vec::new();
        let mut buf = Vec::new();
        for s in 0..take {
            let mut st = DistStats::default();
            for (i, sent) in corpus.sentences.iter().enumerate() {
                divider.targets(0, i, &mut buf);
                if buf.contains(&s) {
                    st.add_sentence(sent);
                }
            }
            subs.push(st);
        }
        let ukl: f64 = subs.iter().map(|s| unigram_kl(s, &full)).sum::<f64>() / take as f64;
        let bkl: f64 = subs.iter().map(|s| bigram_kl(s, &full)).sum::<f64>() / take as f64;
        let (union, inter) = vocab_coverage(&subs, &full);
        println!(
            "{:<14} {ukl:>10.4} {bkl:>11.4} {union:>10.3} {inter:>10.3}",
            strategy.name()
        );
    }
    Ok(())
}

fn cmd_gen_corpus(argv: &[String]) -> Result<(), String> {
    let cmd = experiment_command(
        "gen-corpus",
        "generate (synthetic) or ingest (--text) + persist a corpus",
    )
    .flag("out", Some("corpus_out"), "output directory")
    .flag("shards", Some("4"), "number of shard files (synthetic source)");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let cfg = parse_experiment(&args)?;
    let out = args.get_str("out", "corpus_out");
    let dir = std::path::Path::new(&out);

    // the inherited experiment flags that make no sense here are rejected
    // rather than silently ignored, with or without --text
    if args.get("shard-dir").is_some() {
        return Err("gen-corpus writes shards to --out; use --out, not --shard-dir".into());
    }
    if args.get("eval").is_some() {
        return Err(
            "gen-corpus only ingests; evaluate with `dw2v pipeline --text ... --eval ...`".into(),
        );
    }

    // raw-text source: pure ingestion run, shard count follows shard_tokens
    if let Some(text) = args.get("text") {
        let mut icfg = dw2v::text::ingest::IngestConfig {
            workers: cfg.mappers.max(1),
            ..Default::default()
        };
        if let Some(mc) = args.get_u64("min-count").map_err(|e| e.to_string())? {
            icfg.min_count = mc;
        }
        if let Some(mv) = args.get_usize("max-vocab").map_err(|e| e.to_string())? {
            icfg.max_vocab = mv;
        }
        let result = dw2v::text::ingest::ingest_file(std::path::Path::new(text), dir, &icfg)?;
        println!("{}", result.stats.summary());
        println!(
            "wrote {} shards + vocab.tsv to {out}",
            result.shard_paths.len()
        );
        return Ok(());
    }

    let shards = args
        .get_usize("shards")
        .map_err(|e| e.to_string())?
        .unwrap_or(4);
    let world = build_world(&cfg);
    world
        .corpus
        .write_sharded(dir, shards)
        .map_err(|e| format!("write corpus: {e}"))?;
    std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).map_err(|e| e.to_string())?;
    println!(
        "wrote {} sentences / {} tokens in {shards} shards + vocab.tsv to {out}",
        world.corpus.len(),
        world.corpus.total_tokens()
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<(), String> {
    use dw2v::serve::{Query, QueryResult, ServeConfig, ServeEngine};

    let cmd = Command::new(
        "serve",
        "ANN-indexed nearest-neighbor / analogy queries over a saved embedding",
    )
    .flag("model", None, "saved embedding file (Embedding::save format) [required]")
    .flag("vocab", None, "vocab.tsv (word<TAB>count); without it queries address word ids")
    .flag("queries", None, "query file, one per line (default: interactive stdin loop)")
    .flag("k", Some("10"), "neighbors per query")
    .flag("ef-search", None, "ANN beam width — higher = better recall, slower")
    .flag("m", None, "HNSW out-degree per layer")
    .flag("workers", Some("4"), "worker threads for batched --queries mode")
    .bool_flag("no-quant", "score on f32 rows instead of the int8 quantized store")
    .bool_flag("exact", "print the exact-scan answer next to the ANN answer");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;

    let model_path = args
        .get("model")
        .ok_or_else(|| format!("serve: --model is required\n\n{}", cmd.usage()))?;
    let emb = dw2v::embedding::Embedding::load(std::path::Path::new(model_path))
        .map_err(|e| format!("load {model_path}: {e}"))?;
    let vocab = match args.get("vocab") {
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| format!("read {p}: {e}"))?;
            Some(dw2v::text::vocab::Vocab::from_tsv(&text)?)
        }
        None => None,
    };

    let mut cfg = ServeConfig::default();
    if let Some(ef) = args.get_usize("ef-search").map_err(|e| e.to_string())? {
        cfg.ann.ef_search = ef;
    }
    if let Some(m) = args.get_usize("m").map_err(|e| e.to_string())? {
        cfg.ann.m = m;
    }
    if let Some(w) = args.get_usize("workers").map_err(|e| e.to_string())? {
        cfg.workers = w;
    }
    cfg.quantize = !args.get_bool("no-quant");
    let k = args
        .get_usize("k")
        .map_err(|e| e.to_string())?
        .unwrap_or(10);
    let show_exact = args.get_bool("exact");

    let t = Timer::start("serve setup");
    let engine = ServeEngine::new(emb, vocab, cfg);
    eprintln!(
        "serving {} words (dim {}) — {} index, {} store, ef_search {} ({:.2}s build)",
        engine.index().len(),
        engine.index().dim(),
        if engine.index().is_brute_force() { "exact-scan" } else { "HNSW" },
        if engine.config().quantize { "int8" } else { "f32" },
        engine.config().ann.ef_search,
        t.stop_quiet()
    );

    let print_result = |line: &str, res: &QueryResult| match res {
        Ok(ns) => {
            let cells: Vec<String> =
                ns.iter().map(|n| format!("{} {:.3}", n.word, n.score)).collect();
            println!("{line} -> {}", cells.join("  "));
        }
        Err(e) => println!("{line} -> error: {e}"),
    };

    // a line is either `word` (nearest) or `a b c` (analogy a : b :: c : ?)
    let parse = |line: &str| -> Option<Query> {
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.as_slice() {
            [w] => Some(Query::Nearest { word: w.to_string(), k }),
            [a, b, c] => Some(Query::Analogy {
                a: a.to_string(),
                b: b.to_string(),
                c: c.to_string(),
                k,
            }),
            _ => None,
        }
    };

    match args.get("queries") {
        Some(path) => {
            // batch mode: all queries fanned out across the worker pool
            let text =
                std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
            let lines: Vec<&str> = text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .collect();
            let queries: Vec<Query> = lines
                .iter()
                .map(|l| {
                    parse(l).ok_or_else(|| {
                        format!("bad query line '{l}' (want `word` or `a b c`)")
                    })
                })
                .collect::<Result<_, _>>()?;
            let t = Timer::start("serve batch");
            let results = engine.batch(&queries);
            let secs = t.stop_quiet();
            for ((line, q), res) in lines.iter().zip(&queries).zip(&results) {
                print_result(line, res);
                if show_exact {
                    print_result(&format!("{line} [exact]"), &engine.exact_answer(q));
                }
            }
            eprintln!(
                "{} queries in {:.3}s ({:.0} qps)",
                queries.len(),
                secs,
                queries.len() as f64 / secs.max(1e-9)
            );
        }
        None => {
            // interactive loop: one query per stdin line
            use std::io::BufRead;
            eprintln!("enter `word` or `a b c` per line (ctrl-d to quit):");
            let stdin = std::io::stdin();
            let mut line = String::new();
            loop {
                line.clear();
                if stdin.lock().read_line(&mut line).map_err(|e| e.to_string())? == 0 {
                    break;
                }
                let trimmed = line.trim();
                if trimmed.is_empty() || trimmed.starts_with('#') {
                    continue;
                }
                match parse(trimmed) {
                    Some(q) => {
                        print_result(trimmed, &engine.answer(&q));
                        if show_exact {
                            print_result(&format!("{trimmed} [exact]"), &engine.exact_answer(&q));
                        }
                    }
                    None => println!("bad query '{trimmed}' (want `word` or `a b c`)"),
                }
            }
        }
    }
    Ok(())
}

/// `dw2v status RUN_DIR` — live per-worker progress table for a
/// pipeline-procs run. Tails the heartbeat beacons (and the shard
/// manifest, when the run dir sits inside a shard dir) and refreshes
/// until every worker beacons `done`, or once with `--once`.
fn cmd_status(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("status", "live per-worker progress for a pipeline-procs run dir")
        .flag("interval-ms", Some("1000"), "refresh cadence in milliseconds")
        .bool_flag("once", "print one snapshot and exit instead of watching");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let dir = run_dir_arg(&args, &cmd)?;
    let interval = std::time::Duration::from_millis(
        args.get_u64("interval-ms").map_err(|e| e.to_string())?.unwrap_or(1000).max(50),
    );
    let once = args.get_bool("once");

    // pairs/s needs two sightings of each beacon; remember the last one
    let mut prev = std::collections::BTreeMap::new();
    loop {
        let (table, all_done) = dw2v::obs::report::render_status(&dir, &mut prev)?;
        println!("{table}");
        if all_done {
            eprintln!("all workers done");
            return Ok(());
        }
        if once {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// `dw2v report RUN_DIR` — aggregate the run's event journals, beacons,
/// feed stats and config into `run_report.json` + `run_report.html`.
fn cmd_report(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new(
        "report",
        "aggregate a run dir's journals + beacons into run_report.json/.html",
    );
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let dir = run_dir_arg(&args, &cmd)?;
    let path = dw2v::obs::report::write_report(&dir)?;
    println!("{}", path.display());
    println!("{}", dir.join(dw2v::obs::report::REPORT_HTML_FILE).display());
    Ok(())
}

/// The one positional argument `status`/`report` take: the run directory
/// (a pipeline-procs `--out-dir`, or a shard dir's `submodels/`).
fn run_dir_arg(
    args: &dw2v::util::cli::Args,
    cmd: &Command,
) -> Result<std::path::PathBuf, String> {
    match args.positional() {
        [dir] => Ok(std::path::PathBuf::from(dir)),
        [] => Err(format!("missing RUN_DIR argument\n\n{}", cmd.usage())),
        more => Err(format!("expected one RUN_DIR argument, got {}", more.len())),
    }
}

fn cmd_artifacts(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("artifacts", "show the AOT artifact manifest")
        .flag("artifact-dir", Some("artifacts"), "artifact directory");
    let args = cmd.parse(argv).map_err(|e| e.to_string())?;
    let dir = args.get_str("artifact-dir", "artifacts");
    let manifest = Manifest::load(std::path::Path::new(&dir))?;
    println!(
        "{:<28} {:>8} {:>6} {:>6} {:>4} {:>6} {:>12}",
        "name", "vocab", "dim", "batch", "k", "steps", "vmem/block"
    );
    for c in &manifest.configs {
        println!(
            "{:<28} {:>8} {:>6} {:>6} {:>4} {:>6} {:>10}KB",
            c.name,
            c.vocab,
            c.dim,
            c.batch,
            c.negatives,
            c.steps,
            c.vmem_block_bytes / 1024
        );
    }
    Ok(())
}
