//! The merge phase: combining asynchronously trained sub-models into one
//! consensus embedding (paper §3.3).
//!
//! * [`concat`] — column concatenation over the common vocabulary (baseline)
//! * [`pca_merge`] — PCA of the concatenation back to d dims (baseline)
//! * [`alir`] — ALiR, the paper's Procrustes-style method over the union
//!   vocabulary with missing-row reconstruction
//! * [`average`] — naive element-wise averaging (the §3.3.1 counter-example;
//!   kept as an ablation)

pub mod align;
pub mod alir;
pub mod average;
pub mod concat;
pub mod pca_merge;

use crate::embedding::Embedding;
use crate::util::config::MergeMethod;
use crate::util::logging::Timer;

/// Outcome of a merge: the consensus embedding + bookkeeping for Table 4.
pub struct MergeResult {
    pub embedding: Embedding,
    pub method: MergeMethod,
    pub seconds: f64,
    /// ALiR only: rounds executed and displacement trace
    pub alir_rounds: usize,
    pub alir_displacement: Vec<f64>,
}

/// Dispatch a merge method over trained sub-models.
pub fn merge_models(
    models: &[Embedding],
    method: &MergeMethod,
    alir_opts: &alir::AlirOptions,
    seed: u64,
) -> MergeResult {
    assert!(!models.is_empty());
    let timer = Timer::start(&format!("merge/{}", method.name()));
    let target_dim = models[0].dim;
    let (embedding, rounds, disp) = match method {
        MergeMethod::Concat => (concat::merge(models), 0, Vec::new()),
        MergeMethod::Pca => (pca_merge::merge(models, target_dim).0, 0, Vec::new()),
        MergeMethod::AlirRand => {
            let opts = alir::AlirOptions {
                init: alir::AlirInit::Random,
                ..alir_opts.clone()
            };
            let (e, r) = alir::merge(models, &opts, seed);
            (e, r.rounds, r.displacement)
        }
        MergeMethod::AlirPca => {
            let opts = alir::AlirOptions {
                init: alir::AlirInit::Pca,
                ..alir_opts.clone()
            };
            let (e, r) = alir::merge(models, &opts, seed);
            (e, r.rounds, r.displacement)
        }
        MergeMethod::Single => (models[0].clone(), 0, Vec::new()),
    };
    MergeResult {
        embedding,
        method: method.clone(),
        seconds: timer.stop_quiet(),
        alir_rounds: rounds,
        alir_displacement: disp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn models() -> Vec<Embedding> {
        let mut rng = Pcg64::new(5);
        (0..3)
            .map(|_| {
                let data = (0..40).map(|_| rng.gen_gauss() as f32).collect();
                Embedding::from_rows(10, 4, data)
            })
            .collect()
    }

    #[test]
    fn dispatch_produces_expected_dims() {
        let ms = models();
        assert_eq!(merge_models(&ms, &MergeMethod::Concat, &Default::default(), 1).embedding.dim, 12);
        assert_eq!(merge_models(&ms, &MergeMethod::Pca, &Default::default(), 1).embedding.dim, 4);
        let alir = merge_models(&ms, &MergeMethod::AlirPca, &Default::default(), 1);
        assert_eq!(alir.embedding.dim, 4);
        assert!(alir.alir_rounds > 0);
        assert_eq!(merge_models(&ms, &MergeMethod::Single, &Default::default(), 1).embedding.dim, 4);
    }

    #[test]
    fn timing_is_recorded() {
        let r = merge_models(&models(), &MergeMethod::Concat, &Default::default(), 1);
        assert!(r.seconds >= 0.0);
    }
}
