//! Concat merge: the literature's standard baseline (paper §3.3.1).
//!
//! Over the intersection vocabulary V', the merged representation is the
//! column concatenation `[M_1 | M_2 | … | M_n]` of dimension |V'| × n·d.
//! Effective (it preserves every sub-model's geometry exactly) but
//! impractical for many sub-models — dimensionality and memory grow with
//! n, and any word missing from even one sub-model is dropped entirely.

use super::align::intersection_vocab;
use crate::embedding::Embedding;

/// Concatenate sub-models over their common vocabulary.
pub fn merge(models: &[Embedding]) -> Embedding {
    assert!(!models.is_empty(), "no sub-models to merge");
    let vocab = models[0].vocab;
    let d = models[0].dim;
    let n = models.len();
    let common = intersection_vocab(models);
    let out_dim = n * d;
    let mut out = Embedding {
        vocab,
        dim: out_dim,
        data: vec![0.0; vocab * out_dim],
        present: vec![false; vocab],
    };
    for &w in &common {
        out.present[w as usize] = true;
        for (i, m) in models.iter().enumerate() {
            out.row_mut(w)[i * d..(i + 1) * d].copy_from_slice(m.row(w));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(vals: &[(u32, [f32; 2])], vocab: usize, missing: &[u32]) -> Embedding {
        let mut e = Embedding::zeros(vocab, 2);
        for (w, v) in vals {
            e.row_mut(*w).copy_from_slice(v);
        }
        for &w in missing {
            e.present[w as usize] = false;
        }
        e
    }

    #[test]
    fn concatenates_in_model_order() {
        let m1 = model(&[(0, [1.0, 2.0]), (1, [3.0, 4.0])], 2, &[]);
        let m2 = model(&[(0, [5.0, 6.0]), (1, [7.0, 8.0])], 2, &[]);
        let merged = merge(&[m1, m2]);
        assert_eq!(merged.dim, 4);
        assert_eq!(merged.row(0), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(merged.row(1), &[3.0, 4.0, 7.0, 8.0]);
        assert_eq!(merged.present_count(), 2);
    }

    #[test]
    fn drops_words_missing_anywhere() {
        let m1 = model(&[(0, [1.0, 0.0])], 3, &[2]);
        let m2 = model(&[(0, [0.0, 1.0])], 3, &[1]);
        let merged = merge(&[m1, m2]);
        assert!(merged.is_present(0));
        assert!(!merged.is_present(1));
        assert!(!merged.is_present(2));
    }

    #[test]
    fn preserves_per_model_similarity_structure() {
        // cosine in the concat space is the norm-weighted average of the
        // sub-model cosines; identical sub-models => identical cosine
        let m = model(&[(0, [1.0, 0.0]), (1, [0.0, 1.0]), (2, [1.0, 0.1])], 3, &[]);
        let merged = merge(&[m.clone(), m.clone()]);
        let a = m.cosine(0, 2).unwrap();
        let b = merged.cosine(0, 2).unwrap();
        assert!((a - b).abs() < 1e-9);
    }
}
