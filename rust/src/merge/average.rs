//! Naive element-wise averaging — the counter-example from paper §3.3.1.
//!
//! Averaging unaligned embeddings destroys similarity structure because
//! independently trained models live in arbitrarily rotated/reflected
//! spaces (the paper's 3-word example: word 1 is closest to word 3 in both
//! sub-models but not in their average). Kept as an ablation so the
//! table-3 bench can demonstrate *why* alignment (ALiR) is necessary.

use crate::embedding::Embedding;
use crate::kernels;

/// Element-wise mean over models where each word is present.
pub fn merge(models: &[Embedding]) -> Embedding {
    assert!(!models.is_empty());
    let vocab = models[0].vocab;
    let d = models[0].dim;
    let mut out = Embedding {
        vocab,
        dim: d,
        data: vec![0.0; vocab * d],
        present: vec![false; vocab],
    };
    for w in 0..vocab as u32 {
        let mut count = 0.0f32;
        for m in models {
            if m.is_present(w) {
                count += 1.0;
                kernels::axpy(1.0, m.row(w), out.row_mut(w));
            }
        }
        if count > 0.0 {
            out.present[w as usize] = true;
            kernels::scale(out.row_mut(w), 1.0 / count);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_counterexample_breaks_similarity() {
        // the exact §3.3.1 example: two mirrored sub-models
        let mut m1 = Embedding::zeros(3, 2);
        m1.row_mut(0).copy_from_slice(&[1.0, 1.0]);
        m1.row_mut(1).copy_from_slice(&[99.0, 0.0]);
        m1.row_mut(2).copy_from_slice(&[1.0, -1.0]);
        let mut m2 = Embedding::zeros(3, 2);
        m2.row_mut(0).copy_from_slice(&[-1.0, 1.0]);
        m2.row_mut(1).copy_from_slice(&[-99.0, 0.0]);
        m2.row_mut(2).copy_from_slice(&[-1.0, -1.0]);
        // both sub-models agree: cos(word0, word2) = 0 (orthogonal)
        let before1 = m1.cosine(0, 2).unwrap();
        let before2 = m2.cosine(0, 2).unwrap();
        assert!(before1.abs() < 1e-9 && before2.abs() < 1e-9);
        let avg = merge(&[m1, m2]);
        // after averaging: row0=[0,1], row2=[0,-1] — antipodal. The
        // similarity structure both sub-models agreed on is destroyed.
        assert!(avg.cosine(0, 2).unwrap() < -0.9);
    }

    #[test]
    fn averages_only_present_models() {
        let mut m1 = Embedding::zeros(2, 1);
        m1.row_mut(0).copy_from_slice(&[2.0]);
        m1.row_mut(1).copy_from_slice(&[4.0]);
        let mut m2 = Embedding::zeros(2, 1);
        m2.row_mut(0).copy_from_slice(&[6.0]);
        m2.present[1] = false;
        let avg = merge(&[m1, m2]);
        assert_eq!(avg.row(0), &[4.0]); // (2+6)/2
        assert_eq!(avg.row(1), &[4.0]); // m1 only
        assert!(avg.is_present(1));
    }
}
