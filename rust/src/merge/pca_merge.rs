//! PCA merge: top-d principal components of the concatenated matrix
//! (paper §3.3.1), restoring the original dimensionality while keeping
//! most of the concatenation's variance.

use super::align::{embedding_from_rows, intersection_vocab};
use super::concat;
use crate::embedding::Embedding;
use crate::linalg::mat::Mat;
use crate::linalg::pca;

/// PCA-merge to `target_dim` dimensions over the common vocabulary.
/// Returns the merged embedding and the explained-variance spectrum.
pub fn merge(models: &[Embedding], target_dim: usize) -> (Embedding, Vec<f64>) {
    assert!(!models.is_empty(), "no sub-models to merge");
    let vocab = models[0].vocab;
    let common = intersection_vocab(models);
    if common.is_empty() {
        // nothing survives the intersection (e.g. disjoint sub-model
        // vocabularies — the Fig-3 stress case): like Concat, PCA can only
        // drop every word; return an all-absent embedding rather than
        // fitting a PCA on zero samples
        let mut out = Embedding::zeros(vocab, target_dim);
        out.present = vec![false; vocab];
        return (out, Vec::new());
    }
    let cat = concat::merge(models);
    // extract the common rows of the concat matrix into f64
    let mut x = Mat::zeros(common.len(), cat.dim);
    for (i, &w) in common.iter().enumerate() {
        for (j, &v) in cat.row(w).iter().enumerate() {
            x[(i, j)] = v as f64;
        }
    }
    let fit = pca::fit(&x, target_dim);
    let projected = fit.transform(&x);
    (
        embedding_from_rows(vocab, &common, &projected),
        fit.explained,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_model(vocab: usize, d: usize, seed: u64) -> Embedding {
        let mut rng = Pcg64::new(seed);
        let data = (0..vocab * d).map(|_| rng.gen_gauss() as f32).collect();
        Embedding::from_rows(vocab, d, data)
    }

    #[test]
    fn output_has_target_dim_over_common_vocab() {
        let mut m1 = random_model(20, 4, 1);
        let m2 = random_model(20, 4, 2);
        m1.present[5] = false;
        let (merged, explained) = merge(&[m1, m2], 4);
        assert_eq!(merged.dim, 4);
        assert!(!merged.is_present(5));
        assert_eq!(merged.present_count(), 19);
        assert_eq!(explained.len(), 4);
        for w in explained.windows(2) {
            assert!(w[0] >= w[1] - 1e-10);
        }
    }

    #[test]
    fn identical_submodels_preserve_structure() {
        // n identical copies: PCA back to d must preserve pairwise
        // distances up to rotation (cosine structure preserved)
        let m = random_model(30, 6, 3);
        let (merged, _) = merge(&[m.clone(), m.clone(), m.clone()], 6);
        let mut diffs = 0.0;
        let mut count = 0;
        // centering shifts cosines, so compare distance ratios instead
        let dist = |e: &Embedding, a: u32, b: u32| {
            e.row(a)
                .iter()
                .zip(e.row(b))
                .map(|(x, y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        for a in 0..10u32 {
            for b in (a + 1)..10u32 {
                let da = dist(&m, a, b);
                let db = dist(&merged, a, b) / (3.0f64).sqrt();
                diffs += (da - db).abs();
                count += 1;
            }
        }
        let avg_diff = diffs / count as f64;
        assert!(avg_diff < 1e-5, "avg distance distortion {avg_diff}");
    }

    #[test]
    fn reduces_dim_of_concat() {
        let models: Vec<Embedding> = (0..5).map(|i| random_model(15, 3, i)).collect();
        let (merged, _) = merge(&models, 3);
        assert_eq!(merged.dim, 3); // not 15 = 5 × 3
    }
}
