//! ALiR — Alternating Linear Regression (the paper's merge contribution).
//!
//! A Generalized-Procrustes-style iteration over the **union** vocabulary
//! that tolerates missing rows (paper §3.3.2):
//!
//! 1. *Estimate translation*: for each sub-model `M_i`, align its present
//!    rows to the consensus: `W_i = argmin ‖M_i' W − Y'‖` (orthogonal
//!    Procrustes).
//! 2. *Estimate missing values*: reconstruct `M_i* = Y* W_iᵀ` — valid
//!    because `W_i` is orthogonal, so `Y = M W ⇒ M = Y Wᵀ`.
//! 3. *Update joint embedding*: `Y ← mean_i (M_i W_i)`. A reconstructed
//!    row contributes `Y* W_iᵀ W_i = Y*`, i.e. exactly the current
//!    consensus, so the update equals the mean over models where the word
//!    is *actually present* — which is how we compute it.
//!
//! Convergence is declared when the change in the average normalized
//! Frobenius displacement `(1/n) Σ ‖Y − M_i W_i‖_F / √(|V|·d)` falls below
//! `tol`, or after `max_rounds` (the paper uses 3 epochs).

use super::align::{embedding_from_rows, extract_rows, gather_rows, present_positions, union_vocab};
use super::pca_merge;
use crate::embedding::Embedding;
use crate::linalg::mat::Mat;
use crate::linalg::procrustes::orthogonal_procrustes;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub enum AlirInit {
    Random,
    Pca,
}

#[derive(Clone, Debug)]
pub struct AlirOptions {
    pub init: AlirInit,
    pub max_rounds: usize,
    pub tol: f64,
}

impl Default for AlirOptions {
    fn default() -> Self {
        Self {
            init: AlirInit::Pca,
            max_rounds: 3,
            tol: 1e-4,
        }
    }
}

#[derive(Debug)]
pub struct AlirReport {
    pub rounds: usize,
    /// avg normalized displacement after each round
    pub displacement: Vec<f64>,
}

/// Run ALiR over the union vocabulary of `models`. The output embedding has
/// dimension d (same as the inputs) and presence = union.
pub fn merge(models: &[Embedding], opts: &AlirOptions, seed: u64) -> (Embedding, AlirReport) {
    assert!(!models.is_empty(), "no sub-models to merge");
    let vocab = models[0].vocab;
    let d = models[0].dim;
    let union: Vec<u32> = union_vocab(models);
    let nu = union.len();
    assert!(nu > 0, "union vocabulary is empty");

    // per-model: positions into `union` that the model actually has, and
    // the extracted present-row matrices
    let positions: Vec<Vec<usize>> = models
        .iter()
        .map(|m| present_positions(m, &union))
        .collect();
    let rows: Vec<Mat> = models
        .iter()
        .zip(&positions)
        .map(|(m, pos)| {
            let words: Vec<u32> = pos.iter().map(|&p| union[p]).collect();
            extract_rows(m, &words)
        })
        .collect();

    // ---- initialization ---------------------------------------------------
    let mut y = match opts.init {
        AlirInit::Random => {
            let mut rng = Pcg64::new_stream(seed, 0x616C); // "al"
            let mut y = Mat::zeros(nu, d);
            // scale matches word2vec init so the first Procrustes is sane
            for i in 0..nu {
                for j in 0..d {
                    y[(i, j)] = rng.gen_gauss() / d as f64;
                }
            }
            y
        }
        AlirInit::Pca => {
            // PCA over the concatenated intersection rows gives consensus
            // coordinates for the words every model has; the rest start at
            // the mean of whatever models do have them (coarse but fine —
            // one ALiR round re-estimates them through the rotations).
            let (pca_emb, _) = pca_merge::merge(models, d);
            let mut y = Mat::zeros(nu, d);
            let mut rng = Pcg64::new_stream(seed, 0x616C);
            for (i, &w) in union.iter().enumerate() {
                if pca_emb.is_present(w) {
                    for (j, &v) in pca_emb.row(w).iter().enumerate() {
                        y[(i, j)] = v as f64;
                    }
                } else {
                    for j in 0..d {
                        y[(i, j)] = rng.gen_gauss() / d as f64;
                    }
                }
            }
            y
        }
    };

    // ---- alternate ---------------------------------------------------------
    let n = models.len();
    let norm = ((nu * d) as f64).sqrt();
    let mut report = AlirReport {
        rounds: 0,
        displacement: Vec::new(),
    };
    let mut prev_disp = f64::INFINITY;
    for _round in 0..opts.max_rounds {
        let mut sum = Mat::zeros(nu, d);
        let mut count = vec![0.0f64; nu];
        let mut disp = 0.0;
        for i in 0..n {
            let y_present = gather_rows(&y, &positions[i]);
            // (1) translation
            let w_i = orthogonal_procrustes(&rows[i], &y_present);
            // displacement over present rows
            let aligned = rows[i].matmul(&w_i);
            disp += aligned.sub(&y_present).frobenius_norm() / norm;
            // (3) mean update contribution (present rows only — see module
            // docs for why reconstructed rows are a no-op in the mean)
            for (local, &pos) in positions[i].iter().enumerate() {
                count[pos] += 1.0;
                for j in 0..d {
                    sum[(pos, j)] += aligned[(local, j)];
                }
            }
        }
        for p in 0..nu {
            if count[p] > 0.0 {
                for j in 0..d {
                    y[(p, j)] = sum[(p, j)] / count[p];
                }
            }
            // count == 0 cannot happen: union vocabulary
        }
        disp /= n as f64;
        report.rounds += 1;
        report.displacement.push(disp);
        if (prev_disp - disp).abs() < opts.tol {
            break;
        }
        prev_disp = disp;
    }

    (embedding_from_rows(vocab, &union, &y), report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build `n` sub-models that are random rotations (+noise) of one
    /// ground-truth matrix, with optional missing words.
    fn rotated_models(
        n: usize,
        vocab: usize,
        d: usize,
        noise: f64,
        missing: &[(usize, Vec<u32>)],
        seed: u64,
    ) -> (Mat, Vec<Embedding>) {
        let mut rng = Pcg64::new(seed);
        let truth = Mat::from_vec(
            vocab,
            d,
            (0..vocab * d).map(|_| rng.gen_gauss()).collect(),
        );
        let models = (0..n)
            .map(|i| {
                // random rotation via procrustes of random matrix onto identity
                let a = Mat::from_vec(d, d, (0..d * d).map(|_| rng.gen_gauss()).collect());
                let s = crate::linalg::svd::svd(&a);
                let rot = s.u.matmul(&s.v.transpose());
                let mut m = truth.matmul(&rot);
                for r in 0..vocab {
                    for c in 0..d {
                        m[(r, c)] += noise * rng.gen_gauss();
                    }
                }
                let mut e = Embedding::from_rows(vocab, d, m.to_f32());
                if let Some((_, words)) = missing.iter().find(|(mi, _)| *mi == i) {
                    for &w in words {
                        e.present[w as usize] = false;
                        e.row_mut(w).fill(0.0);
                    }
                }
                e
            })
            .collect();
        (truth, models)
    }

    fn consensus_vs_truth_correlation(y: &Embedding, truth: &Mat, words: &[u32]) -> f64 {
        // compare cosine-similarity structure: corr of pairwise sims
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for (ai, &a) in words.iter().enumerate() {
            for &b in &words[ai + 1..] {
                let (ta, tb) = (truth.row(a as usize), truth.row(b as usize));
                let dot: f64 = ta.iter().zip(tb).map(|(x, y)| x * y).sum();
                let na: f64 = ta.iter().map(|x| x * x).sum::<f64>().sqrt();
                let nb: f64 = tb.iter().map(|x| x * x).sum::<f64>().sqrt();
                xs.push(dot / (na * nb));
                ys.push(y.cosine(a, b).unwrap());
            }
        }
        correlation(&xs, &ys)
    }

    fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
        let n = xs.len() as f64;
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let (mut cov, mut vx, mut vy) = (0.0, 0.0, 0.0);
        for (x, y) in xs.iter().zip(ys) {
            cov += (x - mx) * (y - my);
            vx += (x - mx) * (x - mx);
            vy += (y - my) * (y - my);
        }
        cov / (vx.sqrt() * vy.sqrt()).max(1e-12)
    }

    #[test]
    fn recovers_consensus_from_rotated_copies() {
        let (truth, models) = rotated_models(4, 30, 6, 0.01, &[], 1);
        let (merged, report) = merge(&models, &AlirOptions::default(), 1);
        assert_eq!(merged.present_count(), 30);
        let words: Vec<u32> = (0..30).collect();
        let corr = consensus_vs_truth_correlation(&merged, &truth, &words);
        assert!(corr > 0.99, "corr={corr}");
        assert!(report.rounds >= 1);
    }

    #[test]
    fn reconstructs_missing_words() {
        // word 3 missing from model 0, word 7 from model 1
        let missing = vec![(0usize, vec![3u32]), (1usize, vec![7u32])];
        let (truth, models) = rotated_models(4, 30, 6, 0.01, &missing, 2);
        let (merged, _) = merge(&models, &AlirOptions::default(), 2);
        // union covers everything
        assert_eq!(merged.present_count(), 30);
        let words: Vec<u32> = (0..30).collect();
        let corr = consensus_vs_truth_correlation(&merged, &truth, &words);
        assert!(corr > 0.98, "corr={corr}");
    }

    #[test]
    fn word_present_in_single_model_survives() {
        // word 5 present ONLY in model 2
        let missing = vec![
            (0usize, vec![5u32]),
            (1usize, vec![5u32]),
            (3usize, vec![5u32]),
        ];
        let (truth, models) = rotated_models(4, 20, 5, 0.02, &missing, 3);
        let (merged, _) = merge(&models, &AlirOptions::default(), 3);
        assert!(merged.is_present(5));
        let words: Vec<u32> = (0..20).collect();
        let corr = consensus_vs_truth_correlation(&merged, &truth, &words);
        assert!(corr > 0.95, "corr={corr}");
    }

    #[test]
    fn random_init_also_converges() {
        let (truth, models) = rotated_models(3, 25, 5, 0.01, &[], 4);
        let opts = AlirOptions {
            init: AlirInit::Random,
            max_rounds: 10,
            tol: 1e-6,
        };
        let (merged, report) = merge(&models, &opts, 4);
        let words: Vec<u32> = (0..25).collect();
        let corr = consensus_vs_truth_correlation(&merged, &truth, &words);
        assert!(corr > 0.98, "corr={corr}");
        // displacement should be non-increasing (up to numerical fuzz)
        for w in report.displacement.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "displacement increased: {:?}", report.displacement);
        }
    }

    #[test]
    fn displacement_shrinks_relative_to_first_round() {
        let (_, models) = rotated_models(5, 40, 8, 0.05, &[], 5);
        let opts = AlirOptions {
            init: AlirInit::Random,
            max_rounds: 8,
            tol: 0.0,
        };
        let (_, report) = merge(&models, &opts, 5);
        let first = report.displacement[0];
        let last = *report.displacement.last().unwrap();
        assert!(last < first, "no progress: {:?}", report.displacement);
    }
}
