//! Vocabulary bookkeeping for the merge phase.
//!
//! Sub-models live in a shared global id space with per-word presence
//! masks; merging needs the *intersection* vocabulary (Concat/PCA operate
//! there) and the *union* vocabulary (ALiR reconstructs everything in it).

use crate::embedding::Embedding;
use crate::kernels;
use crate::linalg::mat::Mat;

/// Word ids present in every sub-model.
pub fn intersection_vocab(models: &[Embedding]) -> Vec<u32> {
    if models.is_empty() {
        return Vec::new();
    }
    (0..models[0].vocab as u32)
        .filter(|&w| models.iter().all(|m| m.is_present(w)))
        .collect()
}

/// Word ids present in at least one sub-model.
pub fn union_vocab(models: &[Embedding]) -> Vec<u32> {
    if models.is_empty() {
        return Vec::new();
    }
    (0..models[0].vocab as u32)
        .filter(|&w| models.iter().any(|m| m.is_present(w)))
        .collect()
}

/// Extract rows `words` of a sub-model as an f64 matrix (absent rows are
/// the caller's responsibility — use `present_positions` to avoid them).
pub fn extract_rows(model: &Embedding, words: &[u32]) -> Mat {
    let mut out = Mat::zeros(words.len(), model.dim);
    for (i, &w) in words.iter().enumerate() {
        kernels::widen(out.row_mut(i), model.row(w));
    }
    out
}

/// Positions (into `words`) whose word is present in `model`.
pub fn present_positions(model: &Embedding, words: &[u32]) -> Vec<usize> {
    words
        .iter()
        .enumerate()
        .filter(|(_, &w)| model.is_present(w))
        .map(|(i, _)| i)
        .collect()
}

/// Gather a row subset of a matrix.
pub fn gather_rows(m: &Mat, rows: &[usize]) -> Mat {
    let mut out = Mat::zeros(rows.len(), m.cols());
    for (i, &r) in rows.iter().enumerate() {
        out.row_mut(i).copy_from_slice(m.row(r));
    }
    out
}

/// Build the final `Embedding` over the full global vocab from a matrix
/// whose rows correspond to `words` (everything else marked absent).
pub fn embedding_from_rows(vocab: usize, words: &[u32], rows: &Mat) -> Embedding {
    assert_eq!(words.len(), rows.rows());
    let dim = rows.cols();
    let mut out = Embedding {
        vocab,
        dim,
        data: vec![0.0; vocab * dim],
        present: vec![false; vocab],
    };
    for (i, &w) in words.iter().enumerate() {
        out.present[w as usize] = true;
        kernels::narrow(out.row_mut(w), rows.row(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(present: &[bool]) -> Embedding {
        let v = present.len();
        let mut e = Embedding::zeros(v, 2);
        e.present = present.to_vec();
        for w in 0..v as u32 {
            let val = w as f32 + 1.0;
            e.row_mut(w).copy_from_slice(&[val, -val]);
        }
        e
    }

    #[test]
    fn intersection_and_union() {
        let m1 = model(&[true, true, false, true]);
        let m2 = model(&[true, false, true, true]);
        assert_eq!(intersection_vocab(&[m1.clone(), m2.clone()]), vec![0, 3]);
        assert_eq!(union_vocab(&[m1, m2]), vec![0, 1, 2, 3]);
        assert!(intersection_vocab(&[]).is_empty());
    }

    #[test]
    fn extract_and_rebuild_roundtrip() {
        let m = model(&[true, true, true]);
        let words = vec![0u32, 2];
        let mat = extract_rows(&m, &words);
        assert_eq!(mat[(1, 0)], 3.0);
        let back = embedding_from_rows(3, &words, &mat);
        assert!(back.is_present(0));
        assert!(!back.is_present(1));
        assert_eq!(back.row(2), &[3.0f32, -3.0]);
    }

    #[test]
    fn present_positions_filter() {
        let m = model(&[true, false, true, false]);
        let words = vec![0u32, 1, 2, 3];
        assert_eq!(present_positions(&m, &words), vec![0, 2]);
    }

    #[test]
    fn gather_rows_subset() {
        let m = Mat::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let g = gather_rows(&m, &[2, 0]);
        assert_eq!(g.row(0), &[3.0]);
        assert_eq!(g.row(1), &[1.0]);
    }
}
