//! Experiment "world" construction: corpus + vocabulary + benchmark suite
//! from either of the two corpus sources, all derived deterministically so
//! that rows of the same table are always measured against the same data.
//!
//! * [`build_world`] — the synthetic generator: planted ground truth,
//!   Zipf corpus, gold benchmark suite (every bench harness and the
//!   default CLI path).
//! * [`World::from_text`] — raw-text ingestion: a real text file is
//!   streamed through [`crate::text::ingest`] (two-pass vocab + binary
//!   shards), optionally scored against a `questions-words.txt` analogy
//!   file. No planted ground truth exists, so `gt` is `None`.

use crate::gen::benchmarks::{build_suite, Benchmark};
use crate::gen::corpus::{
    build_ground_truth, generate_corpus, vocab_of, GeneratorConfig, GroundTruth,
};
use crate::text::corpus::Corpus;
use crate::text::ingest::{ingest_file_and_load, ingest_to_corpus, IngestConfig, IngestStats};
use crate::text::vocab::Vocab;
use crate::util::config::ExperimentConfig;
use std::path::{Path, PathBuf};

pub struct World {
    /// planted ground truth — `Some` only for the synthetic generator
    pub gt: Option<GroundTruth>,
    pub corpus: Corpus,
    pub vocab: Vocab,
    pub suite: Vec<Benchmark>,
}

/// Options for [`World::from_text`].
#[derive(Clone, Debug, Default)]
pub struct TextWorldOptions {
    pub ingest: IngestConfig,
    /// where to persist the binary shards + vocab.tsv; with `None`
    /// nothing touches disk — pass 2 streams the id corpus straight into
    /// memory
    pub shard_dir: Option<PathBuf>,
    /// optional `questions-words.txt` analogy file to evaluate against
    pub questions: Option<PathBuf>,
}

/// Build the full synthetic world for a config.
pub fn build_world(cfg: &ExperimentConfig) -> World {
    let gcfg = GeneratorConfig {
        vocab: cfg.vocab,
        clusters: cfg.clusters,
        truth_dim: cfg.truth_dim,
        zipf_exponent: cfg.zipf_exponent,
        avg_sentence_len: cfg.avg_sentence_len,
        ..Default::default()
    };
    let gt = build_ground_truth(&gcfg, cfg.seed);
    let corpus = generate_corpus(&gt, cfg.sentences, cfg.seed ^ 0xC0);
    let vocab = vocab_of(&corpus, cfg.vocab);
    let suite = build_suite(&gt, cfg.seed ^ 0xBE);
    World {
        gt: Some(gt),
        corpus,
        vocab,
        suite,
    }
}

impl World {
    /// Build a world from a raw text file: two-pass streaming ingestion
    /// (memory bounded by chunk size + the compact id corpus, never the
    /// raw text). With `shard_dir` set the binary shard + `vocab.tsv`
    /// layout is persisted there while the same sentences stream into
    /// memory; otherwise pass 2 feeds the corpus directly into memory
    /// with no disk I/O at all. Returns the world plus the ingestion
    /// report.
    pub fn from_text(
        text: &Path,
        opts: &TextWorldOptions,
    ) -> Result<(World, IngestStats), String> {
        let (vocab, corpus, stats) = match &opts.shard_dir {
            Some(dir) => {
                // tee: shards are persisted while the same sentences land
                // in memory, so training doesn't re-read what pass 2
                // just wrote
                let (out, corpus) = ingest_file_and_load(text, dir, &opts.ingest)?;
                (out.vocab, corpus, out.stats)
            }
            None => ingest_to_corpus(text, &opts.ingest)?,
        };
        if vocab.is_empty() {
            return Err(format!(
                "ingest of {} produced an empty vocabulary (min_count {} too high, \
                 or no tokenizable text)",
                text.display(),
                opts.ingest.min_count
            ));
        }
        let suite = match &opts.questions {
            Some(q) => {
                let qw = crate::eval::questions::load_questions_words(q, &vocab)?;
                crate::info!("{}", qw.summary());
                qw.suite
            }
            None => Vec::new(),
        };
        Ok((
            World {
                gt: None,
                corpus,
                vocab,
                suite,
            },
            stats,
        ))
    }

    /// Coordinator-side view of a persisted shard directory: the
    /// `vocab.tsv` (id order preserved — shard token ids are encoded
    /// against it) plus an optional `questions-words.txt` benchmark
    /// suite. The corpus itself deliberately stays on disk — in the
    /// multi-process pipeline only the workers stream it, so the
    /// coordinator's memory is independent of corpus size.
    pub fn vocab_and_suite_from_shards(
        dir: &Path,
        questions: Option<&Path>,
    ) -> Result<(Vocab, Vec<Benchmark>), String> {
        let vocab_path = dir.join("vocab.tsv");
        let text = std::fs::read_to_string(&vocab_path)
            .map_err(|e| format!("read {}: {e}", vocab_path.display()))?;
        let vocab = Vocab::from_tsv(&text)?;
        if vocab.is_empty() {
            return Err(format!("{} holds an empty vocabulary", vocab_path.display()));
        }
        let suite = match questions {
            Some(q) => {
                let qw = crate::eval::questions::load_questions_words(q, &vocab)?;
                crate::info!("{}", qw.summary());
                qw.suite
            }
            None => Vec::new(),
        };
        Ok((vocab, suite))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_is_deterministic_and_consistent() {
        let mut cfg = ExperimentConfig::default();
        cfg.sentences = 200;
        cfg.vocab = 150;
        cfg.clusters = 6;
        let w1 = build_world(&cfg);
        let w2 = build_world(&cfg);
        assert_eq!(w1.corpus, w2.corpus);
        assert_eq!(w1.vocab.len(), 150);
        assert_eq!(w1.suite.len(), 8);
        assert!(w1.gt.is_some());
        // corpus tokens all within vocab
        for s in &w1.corpus.sentences {
            assert!(s.iter().all(|&t| (t as usize) < 150));
        }
    }

    #[test]
    fn different_seeds_different_worlds() {
        let mut cfg = ExperimentConfig::default();
        cfg.sentences = 100;
        cfg.vocab = 100;
        cfg.clusters = 4;
        let w1 = build_world(&cfg);
        cfg.seed = 999;
        let w2 = build_world(&cfg);
        assert_ne!(w1.corpus, w2.corpus);
    }

    #[test]
    fn from_text_builds_a_trainable_world() {
        let dir = std::env::temp_dir().join(format!(
            "dw2v_world_text_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("corpus.txt");
        let mut text = String::new();
        for i in 0..50 {
            text.push_str(&format!(
                "the quick fox number {} jumps over the lazy dog.\n",
                i % 5
            ));
        }
        std::fs::write(&input, &text).unwrap();
        let questions = dir.join("questions.txt");
        std::fs::write(&questions, ": pets\nfox dog quick lazy\n").unwrap();

        let mut opts = TextWorldOptions::default();
        opts.ingest.min_count = 1;
        opts.ingest.workers = 2;
        opts.questions = Some(questions);
        let (world, stats) = World::from_text(&input, &opts).unwrap();
        assert!(world.gt.is_none());
        assert_eq!(world.corpus.len(), 50);
        assert_eq!(stats.lines, 50);
        assert!(world.vocab.id("fox").is_some());
        assert_eq!(world.suite.len(), 1, "questions file becomes the suite");
        assert_eq!(world.suite[0].name, "qw-pets");
        // id-encoded corpus round-trips through the vocab
        let first = &world.corpus.sentences[0];
        assert_eq!(world.vocab.word(first[0]), "the");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn from_text_persists_shards_when_asked() {
        let dir = std::env::temp_dir().join(format!(
            "dw2v_world_persist_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("corpus.txt");
        std::fs::write(&input, "alpha beta gamma.\nbeta gamma delta.\n").unwrap();
        let shards = dir.join("shards");
        let mut opts = TextWorldOptions::default();
        opts.ingest.min_count = 1;
        opts.shard_dir = Some(shards.clone());
        let (world, _) = World::from_text(&input, &opts).unwrap();
        assert!(shards.join("shard_0.bin").exists());
        assert!(shards.join("vocab.tsv").exists());
        let reloaded = Corpus::read_sharded(&shards).unwrap();
        assert_eq!(reloaded, world.corpus);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn vocab_and_suite_from_shards_loads_coordinator_inputs() {
        let dir = std::env::temp_dir().join(format!(
            "dw2v_world_shards_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.sentences = 120;
        cfg.vocab = 80;
        cfg.clusters = 4;
        let world = build_world(&cfg);
        world.corpus.write_sharded(&dir, 2).unwrap();
        std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).unwrap();
        let (vocab, suite) = World::vocab_and_suite_from_shards(&dir, None).unwrap();
        assert_eq!(vocab.len(), world.vocab.len());
        // id mapping must be exactly the one the shards were encoded with
        for id in [0u32, 7, 79] {
            assert_eq!(vocab.word(id), world.vocab.word(id));
        }
        assert!(suite.is_empty());
        // a directory without vocab.tsv is an error, not a panic
        let empty = dir.join("nothing_here");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(World::vocab_and_suite_from_shards(&empty, None).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn from_text_rejects_empty_vocab() {
        let dir = std::env::temp_dir().join(format!(
            "dw2v_world_empty_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("corpus.txt");
        std::fs::write(&input, "a b c\n").unwrap();
        let mut opts = TextWorldOptions::default();
        opts.ingest.min_count = 100; // everything dropped
        let err = World::from_text(&input, &opts).unwrap_err();
        assert!(err.contains("empty vocabulary"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
