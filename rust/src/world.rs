//! Experiment "world" construction: the synthetic corpus, its vocabulary,
//! the planted ground truth and the gold benchmark suite, all derived
//! deterministically from one `ExperimentConfig`. Shared by the CLI, the
//! examples and every bench harness so that rows of the same table are
//! always measured against the same data.

use crate::gen::benchmarks::{build_suite, Benchmark};
use crate::gen::corpus::{
    build_ground_truth, generate_corpus, vocab_of, GeneratorConfig, GroundTruth,
};
use crate::text::corpus::Corpus;
use crate::text::vocab::Vocab;
use crate::util::config::ExperimentConfig;

pub struct World {
    pub gt: GroundTruth,
    pub corpus: Corpus,
    pub vocab: Vocab,
    pub suite: Vec<Benchmark>,
}

/// Build the full synthetic world for a config.
pub fn build_world(cfg: &ExperimentConfig) -> World {
    let gcfg = GeneratorConfig {
        vocab: cfg.vocab,
        clusters: cfg.clusters,
        truth_dim: cfg.truth_dim,
        zipf_exponent: cfg.zipf_exponent,
        avg_sentence_len: cfg.avg_sentence_len,
        ..Default::default()
    };
    let gt = build_ground_truth(&gcfg, cfg.seed);
    let corpus = generate_corpus(&gt, cfg.sentences, cfg.seed ^ 0xC0);
    let vocab = vocab_of(&corpus, cfg.vocab);
    let suite = build_suite(&gt, cfg.seed ^ 0xBE);
    World {
        gt,
        corpus,
        vocab,
        suite,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_is_deterministic_and_consistent() {
        let mut cfg = ExperimentConfig::default();
        cfg.sentences = 200;
        cfg.vocab = 150;
        cfg.clusters = 6;
        let w1 = build_world(&cfg);
        let w2 = build_world(&cfg);
        assert_eq!(w1.corpus, w2.corpus);
        assert_eq!(w1.vocab.len(), 150);
        assert_eq!(w1.suite.len(), 8);
        // corpus tokens all within vocab
        for s in &w1.corpus.sentences {
            assert!(s.iter().all(|&t| (t as usize) < 150));
        }
    }

    #[test]
    fn different_seeds_different_worlds() {
        let mut cfg = ExperimentConfig::default();
        cfg.sentences = 100;
        cfg.vocab = 100;
        cfg.clusters = 4;
        let w1 = build_world(&cfg);
        cfg.seed = 999;
        let w2 = build_world(&cfg);
        assert_ne!(w1.corpus, w2.corpus);
    }
}
