// One-off probe for the AOT bridge invariants the runtime relies on:
//  1. a single-array-output HLO comes back as exactly one chainable buffer
//  2. execute_b can feed that buffer straight back in (device-resident state)
//  3. int32 index inputs + scatter-add lower and run on xla_extension 0.5.1
//  4. copy_raw_to_host_sync with an offset reads just the metrics row
//
// Requires the `xla` feature (the probe talks to the real bridge).
#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!(
        "bridge_probe requires the PJRT bridge: rebuild with `cargo run \
         --features xla --bin bridge_probe`"
    );
    std::process::exit(1);
}

#[cfg(feature = "xla")]
fn main() -> Result<(), Box<dyn std::error::Error>> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file("/tmp/bridge_test/step2.hlo.txt")?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    const V: usize = 8;
    const D: usize = 4;
    let state_host = vec![0f32; (2 * V + 1) * D];
    let mut state = client.buffer_from_host_buffer(&state_host, &[2 * V + 1, D], None)?;
    let idx: Vec<i32> = vec![1, 1, 7]; // duplicate index: scatter-add must accumulate
    let delta = vec![1f32; 3 * D];
    for step in 0..3 {
        let idx_b = client.buffer_from_host_buffer(&idx, &[3], None)?;
        let delta_b = client.buffer_from_host_buffer(&delta, &[3, D], None)?;
        let mut out = exe.execute_b(&[&state, &idx_b, &delta_b])?;
        let row = out.remove(0).remove(0);
        println!("step {step}: outputs chained ok, shape={:?}", row.on_device_shape()?);
        state = row;
    }
    // read only the metrics row via a tiny on-device slice executable
    // (CopyRawToHost is unimplemented on the CPU PJRT client)
    let mproto = xla::HloModuleProto::from_text_file("/tmp/bridge_test/metrics.hlo.txt")?;
    let mexe = client.compile(&xla::XlaComputation::from_proto(&mproto))?;
    let metrics = mexe.execute_b(&[&state])?[0][0].to_literal_sync()?.to_vec::<f32>()?;
    println!("metrics row = {metrics:?}");
    let full = state.to_literal_sync()?.to_vec::<f32>()?;
    // after 3 steps: row1 += 2 per step -> 6, row7 += 1 per step -> 3
    assert_eq!(full[D], 6.0, "duplicate-index scatter-add accumulates");
    assert_eq!(full[7 * D], 3.0);
    assert_eq!(metrics[0], 12.0, "loss = sum(delta^2) = 12");
    println!("bridge_probe OK");
    Ok(())
}
