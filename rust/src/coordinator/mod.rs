//! Layer-3 coordination: the paper's divide / train / merge pipeline.
//!
//! * [`divider`] — EqualPartitioning / RandomSampling / Shuffle (divide phase)
//! * [`mapper`] / [`reducer`] — the MapReduce roles (train phase)
//! * [`leader`] — end-to-end orchestration + phase timing (in-process)
//! * [`procs`] — multi-process training: one OS process per sub-model
//!   over on-disk shard files, with fault-tolerant artifact collection
//! * [`supervisor`] — worker supervision: heartbeat beacons, stall/crash
//!   detection, checkpoint-backed respawn, deterministic fault injection
//! * [`overlap`] — ingest-while-training: run the raw-text ingest and
//!   the supervised fleet concurrently over one growing shard dir,
//!   bitwise identical to a back-to-back run
//! * [`stats`] — unigram/bigram KL divergence (Figure 1) + vocab coverage
pub mod divider;
pub mod leader;
pub mod mapper;
pub mod overlap;
pub mod procs;
pub mod reducer;
pub mod stats;
pub mod supervisor;
