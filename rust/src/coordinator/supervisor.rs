//! Worker supervision for multi-process training: heartbeat beacons,
//! stall/crash detection, policy-driven recovery with capped exponential
//! backoff, and the deterministic fault-injection harness the chaos e2e
//! tests drive.
//!
//! The paper's zero-synchronization design makes recovery purely
//! artifact-level: a worker owns one sub-model, its progress beacon and
//! checkpoint live next to its artifact, and the coordinator never has
//! parameter state to reconcile. [`run_supervised`] wraps the PR-5
//! spawn/collect machinery (`super::procs`) in a poll loop that
//! classifies each worker as **healthy** (beacon bytes changed
//! recently), **stalled** (no beacon progress within the configured
//! timeout) or **dead** (process exited without a valid artifact), and
//! applies the configured [`FailurePolicy`]:
//!
//! * `retry` — kill/reap if needed, then respawn after
//!   `backoff_base · 2^k` (capped) up to `max_retries` times; the
//!   respawned worker finds its epoch-boundary checkpoint in the
//!   artifact dir and resumes, bitwise identical on the native backend;
//! * `degrade` — abandon the worker and merge the survivors (PR 5's
//!   SIGKILL semantics, now explicit);
//! * `fail-fast` — kill the remaining pool and error out.
//!
//! Fault injection ([`FaultSpec`]) is parsed from `DW2V_FAULT` inside
//! the worker, so the chaos tests exercise the *real* worker binary
//! through the *real* supervisor with zero test-only control channels.

use super::leader;
use super::procs::{self, ProcsOptions, WorkerFate, WorkerOutcome};
use crate::gen::benchmarks::Benchmark;
use crate::info;
use crate::obs::journal::Journal;
use crate::transport::{ControlPlane, Transport};
use crate::util::config::ExperimentConfig;
use crate::util::json::{inum, num, obj, s};
use std::process::Child;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Exit code a `crash@pairs=N` fault terminates the worker with —
/// distinct from error exits (1) so the chaos tests can tell an injected
/// crash from a genuine worker failure.
pub const CRASH_EXIT_CODE: i32 = 102;

// Re-exported from the transport layer, where the run-dir naming now
// lives; kept here so existing `supervisor::beacon_path` callers hold.
pub use crate::transport::fs::beacon_path;

/// Heartbeat/progress publisher — the worker half of the supervision
/// protocol, writing through the transport's [`ControlPlane`].
///
/// Over the filesystem transport each write lands as a whole file via
/// write-to-temp + rename (the same idiom as the sub-model artifact),
/// so the coordinator never reads a torn beacon; over TCP the same
/// bytes are shipped to the shard server, which mirrors them into the
/// run dir. The payload is a small JSON object:
///
/// ```text
/// { "submodel": 1, "phase": "start|estimate|waiting|train|done",
///   "epoch": 0, "sentences": "412", "pairs": "99321",
///   "seq": "17", "unix_ms": "1754500000000" }
/// ```
///
/// `u64` counters ride as decimal strings (the artifact-meta convention);
/// `seq` increments per write so consecutive beacons always differ —
/// the supervisor treats **any byte change** as progress and needs no
/// clock agreement with the worker. That is also what makes feed-mode
/// `waiting` beacons (worker blocked on a shard ingest hasn't published
/// yet; `sentences` carries the awaited shard index, `pairs` the count
/// published so far) read as *healthy*: the seq bump changes the bytes
/// on every write even when nothing else moved, so a worker parked
/// behind a slow ingest is never mistaken for a stalled one. A dead
/// ingest is caught by the feed's own progress timeout (a loud worker
/// error), not by the stall detector.
pub struct BeaconWriter {
    control: Arc<dyn ControlPlane>,
    submodel: usize,
    interval: Duration,
    last: Option<Instant>,
    seq: u64,
}

impl BeaconWriter {
    pub fn new(control: Arc<dyn ControlPlane>, submodel: usize, interval_ms: u64) -> Self {
        Self {
            control,
            submodel,
            interval: Duration::from_millis(interval_ms.max(1)),
            last: None,
            seq: 0,
        }
    }

    /// Publish if the configured interval elapsed since the last write.
    /// The common case is one `Instant` comparison — cheap enough for the
    /// per-sentence hot path.
    pub fn maybe_write(&mut self, phase: &str, epoch: usize, sentences: u64, pairs: u64) {
        if self.last.is_some_and(|t| t.elapsed() < self.interval) {
            return;
        }
        self.write_now(phase, epoch, sentences, pairs);
    }

    /// Unconditional publish (startup, epoch barriers).
    pub fn write_now(&mut self, phase: &str, epoch: usize, sentences: u64, pairs: u64) {
        self.seq += 1;
        let unix_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let body = obj(vec![
            ("submodel", inum(self.submodel)),
            ("phase", s(phase)),
            ("epoch", inum(epoch)),
            ("sentences", s(&sentences.to_string())),
            ("pairs", s(&pairs.to_string())),
            ("seq", s(&self.seq.to_string())),
            ("unix_ms", s(&unix_ms.to_string())),
        ])
        .to_string();
        self.control.publish_beacon(self.submodel, &body);
        self.last = Some(Instant::now());
    }
}

/// Deterministic fault-injection spec, parsed from `DW2V_FAULT` inside
/// the worker (children inherit the coordinator's environment, so one
/// variable reaches the whole fleet; `@submodel=` aims a clause).
///
/// Grammar:
///
/// ```text
/// spec    := clause (';' clause)*
/// clause  := action ('@' key '=' value)*
/// action  := 'crash' | 'stall' | 'corrupt-artifact' | 'slow'
/// ```
///
/// * `crash@pairs=N[@submodel=S]` — exit with [`CRASH_EXIT_CODE`] once
///   the trainer has emitted ≥ N pairs. One-shot per artifact dir (a
///   `fault_<s>_crash.fired` marker records the firing), so a respawned
///   worker runs clean — the crash→retry→bitwise-equal e2e depends on
///   that.
/// * `stall@epoch=K[@submodel=S]` — hang forever just before epoch K
///   starts (also one-shot, marker `fault_<s>_stall.fired`).
/// * `corrupt-artifact[@submodel=S]` — truncate the artifact temp file
///   before the publishing rename; the worker still exits 0, so only
///   coordinator-side validation can catch it.
/// * `slow@factor=F[@submodel=S]` — sleep F µs per routed sentence (a
///   deterministic straggler).
///
/// A malformed spec is a worker startup error (non-zero exit), never a
/// silently ignored fault.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub crash_at_pairs: Option<u64>,
    pub stall_at_epoch: Option<usize>,
    pub corrupt_artifact: bool,
    pub slow_factor_us: Option<u64>,
}

impl FaultSpec {
    pub fn is_empty(&self) -> bool {
        *self == FaultSpec::default()
    }

    /// Parse a spec, keeping only the clauses addressed to `submodel`
    /// (clauses without `@submodel=` address everyone). Syntax errors are
    /// reported even for clauses aimed elsewhere — a typo'd spec must
    /// never pass silently.
    pub fn parse(spec: &str, submodel: usize) -> Result<FaultSpec, String> {
        let mut out = FaultSpec::default();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let mut parts = clause.split('@').map(str::trim);
            let action = parts.next().unwrap_or_default();
            let mut kv = std::collections::BTreeMap::new();
            for p in parts {
                let (k, v) = p.split_once('=').ok_or_else(|| {
                    format!("fault clause '{clause}': expected key=value, got '{p}'")
                })?;
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
            let target: Option<usize> = match kv.remove("submodel") {
                Some(v) => Some(
                    v.parse()
                        .map_err(|_| format!("fault clause '{clause}': bad submodel '{v}'"))?,
                ),
                None => None,
            };
            let mut take_u64 = |key: &str| -> Result<u64, String> {
                let v = kv
                    .remove(key)
                    .ok_or_else(|| format!("fault clause '{clause}': missing '{key}='"))?;
                v.parse()
                    .map_err(|_| format!("fault clause '{clause}': bad {key} '{v}'"))
            };
            let applies = match target {
                Some(t) => t == submodel,
                None => true,
            };
            match action {
                "crash" => {
                    let n = take_u64("pairs")?;
                    if applies {
                        out.crash_at_pairs = Some(n);
                    }
                }
                "stall" => {
                    let k = take_u64("epoch")?;
                    if applies {
                        out.stall_at_epoch = Some(k as usize);
                    }
                }
                "corrupt-artifact" => {
                    if applies {
                        out.corrupt_artifact = true;
                    }
                }
                "slow" => {
                    let f = take_u64("factor")?;
                    if applies {
                        out.slow_factor_us = Some(f);
                    }
                }
                other => {
                    return Err(format!(
                        "unknown fault action '{other}' in clause '{clause}' \
                         (expected crash | stall | corrupt-artifact | slow)"
                    ))
                }
            }
            if !kv.is_empty() {
                let extra: Vec<String> = kv.into_keys().collect();
                return Err(format!(
                    "fault clause '{clause}': unknown keys {extra:?}"
                ));
            }
        }
        Ok(out)
    }
}

/// Worker-side runtime for a [`FaultSpec`]: fires each fault at its
/// trigger point. Crash and stall are one-shot per run dir via marker
/// records published through the [`ControlPlane`] *before* firing, so a
/// respawned worker sees the marker and proceeds normally.
pub struct ArmedFaults {
    spec: FaultSpec,
    control: Arc<dyn ControlPlane>,
    submodel: usize,
    crash_armed: bool,
}

impl ArmedFaults {
    pub fn new(spec: FaultSpec, control: Arc<dyn ControlPlane>, submodel: usize) -> Self {
        Self {
            spec,
            control,
            submodel,
            crash_armed: true,
        }
    }

    /// Per-routed-sentence hook: apply `slow`, then fire `crash` once the
    /// cumulative pair counter crosses its threshold. The marker check
    /// only happens at the first crossing; afterwards the fault disarms
    /// in-memory, so the hot path stays two integer comparisons.
    pub fn on_progress(&mut self, pairs: u64) {
        if let Some(us) = self.spec.slow_factor_us {
            std::thread::sleep(Duration::from_micros(us));
        }
        if let Some(n) = self.spec.crash_at_pairs {
            if self.crash_armed && pairs >= n {
                if self.control.fault_marker_fired(self.submodel, "crash") {
                    self.crash_armed = false; // fired in a previous incarnation
                    return;
                }
                self.control.record_fault_marker(self.submodel, "crash");
                info!(
                    "fault injection: worker {} crashing at {pairs} pairs (>= {n})",
                    self.submodel
                );
                std::process::exit(CRASH_EXIT_CODE);
            }
        }
    }

    /// Pre-epoch hook: `stall@epoch=K` hangs forever before epoch K.
    pub fn maybe_stall(&mut self, epoch: usize) {
        if self.spec.stall_at_epoch == Some(epoch) {
            if self.control.fault_marker_fired(self.submodel, "stall") {
                return;
            }
            self.control.record_fault_marker(self.submodel, "stall");
            info!(
                "fault injection: worker {} stalling before epoch {epoch}",
                self.submodel
            );
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
    }

    /// Publish-time hook: should the artifact temp file be truncated?
    pub fn corrupt_artifact(&self) -> bool {
        self.spec.corrupt_artifact
    }
}

/// What the coordinator does with a worker that died, stalled, or
/// published a bad artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailurePolicy {
    /// respawn from the last checkpoint, capped-exponential backoff
    Retry,
    /// abandon the worker, merge the survivors (PR 5's SIGKILL semantics)
    Degrade,
    /// kill the remaining pool and error out
    FailFast,
}

impl FailurePolicy {
    pub fn parse(text: &str) -> Result<Self, String> {
        match text {
            "retry" => Ok(Self::Retry),
            "degrade" => Ok(Self::Degrade),
            "fail-fast" => Ok(Self::FailFast),
            other => Err(format!(
                "unknown failure policy '{other}' (expected retry | degrade | fail-fast)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Retry => "retry",
            Self::Degrade => "degrade",
            Self::FailFast => "fail-fast",
        }
    }
}

/// Supervision knobs, deliberately separate from [`ProcsOptions`] (which
/// existing callers build as a struct literal).
pub struct SupervisorOptions {
    pub policy: FailurePolicy,
    /// respawns allowed per worker under `retry`
    pub max_retries: usize,
    /// a worker whose beacon bytes don't change for this long is stalled
    pub stall_timeout: Duration,
    /// supervisor poll cadence
    pub poll_interval: Duration,
    /// respawn backoff: `backoff_base · 2^(attempt-1)`, capped below
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// beacon publish interval handed to the workers (milliseconds)
    pub beacon_interval_ms: u64,
}

impl Default for SupervisorOptions {
    fn default() -> Self {
        Self {
            policy: FailurePolicy::Retry,
            max_retries: 2,
            stall_timeout: Duration::from_secs(300),
            poll_interval: Duration::from_millis(20),
            backoff_base: Duration::from_millis(200),
            backoff_cap: Duration::from_secs(5),
            beacon_interval_ms: 250,
        }
    }
}

/// Counters the supervisor accumulated over a run.
#[derive(Clone, Debug, Default)]
pub struct SupervisorStats {
    /// workers respawned (each implies a failure that was retried)
    pub respawns: usize,
    /// stalls detected via beacon timeout (subset of failures)
    pub stalls_detected: usize,
    /// total failures observed (exits, stalls, bad artifacts)
    pub failures_seen: usize,
}

/// Result of a supervised multi-process run — [`procs::ProcsReport`]
/// plus the supervision counters.
pub struct SupervisedReport {
    /// per-worker fates, in sub-model order — failures included
    pub outcomes: Vec<WorkerOutcome>,
    /// wall-clock from first spawn to last worker resolution
    pub train_secs: f64,
    pub stats: SupervisorStats,
    /// the shared merge + eval tail over the surviving sub-models
    pub tail: leader::MergeEvalOutput,
}

impl SupervisedReport {
    pub fn survivors(&self) -> usize {
        self.outcomes.iter().filter(|o| o.survived()).count()
    }

    pub fn failures(&self) -> impl Iterator<Item = &WorkerOutcome> {
        self.outcomes.iter().filter(|o| !o.survived())
    }
}

enum SlotState {
    Running(Child),
    Backoff { until: Instant },
    Done,
}

/// One supervised worker seat: the current incarnation (if any), its
/// liveness bookkeeping, and the final outcome once resolved.
struct Slot {
    submodel: usize,
    state: SlotState,
    last_beacon: Vec<u8>,
    last_progress: Instant,
    retries_used: usize,
    outcome: Option<WorkerOutcome>,
}

/// Resolve one failure according to the policy. Returns a fail-fast
/// reason when the whole run must abort; otherwise the slot is either
/// parked in backoff (retry) or finalized as failed (degrade /
/// exhausted retries).
fn register_failure(
    slot: &mut Slot,
    why: String,
    sup: &SupervisorOptions,
    stats: &mut SupervisorStats,
    started: Instant,
    journal: &Journal,
) -> Option<String> {
    stats.failures_seen += 1;
    match sup.policy {
        FailurePolicy::FailFast => Some(format!("worker {}: {why}", slot.submodel)),
        FailurePolicy::Retry if slot.retries_used < sup.max_retries => {
            slot.retries_used += 1;
            let exp = (slot.retries_used - 1).min(16) as u32;
            let backoff = (sup.backoff_base * 2u32.pow(exp)).min(sup.backoff_cap);
            info!(
                "supervisor: worker {} failed ({why}); retry {}/{} in {:.1}s",
                slot.submodel,
                slot.retries_used,
                sup.max_retries,
                backoff.as_secs_f64()
            );
            journal.event(
                "worker_backoff",
                vec![
                    ("submodel", inum(slot.submodel)),
                    ("attempt", inum(slot.retries_used)),
                    ("backoff_ms", inum(backoff.as_millis())),
                    ("why", s(&why)),
                ],
            );
            slot.state = SlotState::Backoff {
                until: Instant::now() + backoff,
            };
            None
        }
        _ => {
            let why = if sup.policy == FailurePolicy::Retry {
                format!("{why} (after {} retries)", slot.retries_used)
            } else {
                why
            };
            info!("supervisor: worker {} abandoned — {why}", slot.submodel);
            journal.event(
                "worker_failed",
                vec![("submodel", inum(slot.submodel)), ("why", s(&why))],
            );
            slot.outcome = Some(WorkerOutcome {
                submodel: slot.submodel,
                secs: started.elapsed().as_secs_f64(),
                fate: WorkerFate::Failed(why),
                artifact: None,
            });
            slot.state = SlotState::Done;
            None
        }
    }
}

fn kill_remaining(slots: &mut [Slot]) {
    for slot in slots.iter_mut() {
        if let SlotState::Running(child) = &mut slot.state {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// The supervised multi-process pipeline: spawn `100/r` workers, poll
/// their beacons and exit statuses, recover per the [`FailurePolicy`],
/// then merge + eval whatever survived. Replaces
/// [`procs::run_multiprocess`] as the `dw2v pipeline-procs` engine; the
/// unsupervised path remains for tests and benches.
pub fn run_supervised(
    cfg: &ExperimentConfig,
    suite: &[Benchmark],
    opts: &ProcsOptions,
    sup: &SupervisorOptions,
) -> Result<SupervisedReport, String> {
    let (n, config_path) = procs::prepare_run(cfg, opts)?;
    // everything the supervisor reads or writes below goes through the
    // transport: beacons, artifacts, journals. The loop itself never
    // touches the run dir directly, which is what lets a TCP fleet
    // (whose server mirrors uploads into the same run dir) reuse it
    // without modification.
    let transport = Transport::fs(&opts.shard_dir, &opts.out_dir);
    let journal = transport.control.journal("coordinator");
    journal.event(
        "run_start",
        vec![
            ("submodels", inum(n)),
            ("policy", s(sup.policy.name())),
        ],
    );
    let beacon_env = vec![(
        crate::util::env::BEACON_INTERVAL_MS.to_string(),
        sup.beacon_interval_ms.to_string(),
    )];
    info!(
        "supervisor: policy {}, stall timeout {:.1}s, max {} retries, beacon every {}ms",
        sup.policy.name(),
        sup.stall_timeout.as_secs_f64(),
        sup.max_retries,
        sup.beacon_interval_ms
    );
    let started = Instant::now();
    let mut stats = SupervisorStats::default();
    let mut slots: Vec<Slot> = Vec::with_capacity(n);
    for submodel in 0..n {
        let child = match procs::spawn_one_worker(cfg, opts, &config_path, submodel, &beacon_env)
        {
            Ok(c) => c,
            Err(e) => {
                // don't leak the workers already launched
                kill_remaining(&mut slots);
                return Err(e);
            }
        };
        journal.event("worker_spawn", vec![("submodel", inum(submodel))]);
        slots.push(Slot {
            submodel,
            state: SlotState::Running(child),
            last_beacon: Vec::new(),
            last_progress: Instant::now(),
            retries_used: 0,
            outcome: None,
        });
    }

    loop {
        let mut fail_fast: Option<String> = None;
        for slot in slots.iter_mut() {
            match &mut slot.state {
                SlotState::Done => {}
                SlotState::Backoff { until } => {
                    if Instant::now() >= *until {
                        match procs::spawn_one_worker(
                            cfg,
                            opts,
                            &config_path,
                            slot.submodel,
                            &beacon_env,
                        ) {
                            Ok(child) => {
                                stats.respawns += 1;
                                info!(
                                    "supervisor: respawned worker {} (retry {}/{})",
                                    slot.submodel, slot.retries_used, sup.max_retries
                                );
                                journal.event(
                                    "worker_respawn",
                                    vec![
                                        ("submodel", inum(slot.submodel)),
                                        ("attempt", inum(slot.retries_used)),
                                    ],
                                );
                                slot.last_beacon.clear();
                                slot.last_progress = Instant::now();
                                slot.state = SlotState::Running(child);
                            }
                            Err(e) => {
                                fail_fast = register_failure(
                                    slot, e, sup, &mut stats, started, &journal,
                                );
                            }
                        }
                    }
                }
                SlotState::Running(child) => match child.try_wait() {
                    Ok(Some(status)) => {
                        let secs = started.elapsed().as_secs_f64();
                        info!(
                            "supervisor: worker {} exited after {secs:.2}s ({})",
                            slot.submodel,
                            procs::describe_status(&status)
                        );
                        if status.success() {
                            match transport.artifacts.collect_artifact(
                                slot.submodel,
                                cfg.seed,
                                n,
                            ) {
                                Ok(artifact) => {
                                    journal.event(
                                        "worker_exit",
                                        vec![
                                            ("submodel", inum(slot.submodel)),
                                            ("secs", num(secs)),
                                        ],
                                    );
                                    slot.outcome = Some(WorkerOutcome {
                                        submodel: slot.submodel,
                                        secs,
                                        fate: WorkerFate::Completed,
                                        artifact: Some(artifact),
                                    });
                                    slot.state = SlotState::Done;
                                }
                                Err(why) => {
                                    // a rejected artifact must not linger: a
                                    // retried worker republishes, a degraded
                                    // one must leave nothing collectible
                                    transport.artifacts.discard_artifact(slot.submodel);
                                    journal.event(
                                        "worker_crash",
                                        vec![
                                            ("submodel", inum(slot.submodel)),
                                            ("why", s(&why)),
                                        ],
                                    );
                                    fail_fast = register_failure(
                                        slot, why, sup, &mut stats, started, &journal,
                                    );
                                }
                            }
                        } else {
                            let why = procs::describe_status(&status);
                            journal.event(
                                "worker_crash",
                                vec![
                                    ("submodel", inum(slot.submodel)),
                                    ("why", s(&why)),
                                ],
                            );
                            fail_fast = register_failure(
                                slot, why, sup, &mut stats, started, &journal,
                            );
                        }
                    }
                    Ok(None) => {
                        // liveness: any beacon byte change counts as progress
                        if let Some(bytes) = transport.control.poll_beacon(slot.submodel) {
                            if bytes != slot.last_beacon {
                                slot.last_beacon = bytes;
                                slot.last_progress = Instant::now();
                            }
                        }
                        if slot.last_progress.elapsed() > sup.stall_timeout {
                            stats.stalls_detected += 1;
                            let why = format!(
                                "stalled: no beacon progress within {:.1}s",
                                sup.stall_timeout.as_secs_f64()
                            );
                            info!(
                                "supervisor: worker {} {why} — killing it",
                                slot.submodel
                            );
                            journal.event(
                                "stall_detected",
                                vec![
                                    ("submodel", inum(slot.submodel)),
                                    (
                                        "silent_secs",
                                        num(slot.last_progress.elapsed().as_secs_f64()),
                                    ),
                                ],
                            );
                            let _ = child.kill();
                            let _ = child.wait();
                            fail_fast = register_failure(
                                slot, why, sup, &mut stats, started, &journal,
                            );
                        }
                    }
                    Err(e) => {
                        let why = format!("wait failed: {e}");
                        fail_fast = register_failure(
                            slot, why, sup, &mut stats, started, &journal,
                        );
                    }
                },
            }
            if fail_fast.is_some() {
                break;
            }
        }
        if let Some(reason) = fail_fast {
            kill_remaining(&mut slots);
            journal.event("run_aborted", vec![("why", s(&reason))]);
            return Err(format!("fail-fast: {reason}"));
        }
        if slots.iter().all(|s| s.outcome.is_some()) {
            break;
        }
        std::thread::sleep(sup.poll_interval);
    }

    let train_secs = started.elapsed().as_secs_f64();
    let mut outcomes: Vec<WorkerOutcome> = slots
        .into_iter()
        .map(|s| s.outcome.expect("every slot resolved"))
        .collect();
    if stats.failures_seen > 0 {
        info!(
            "supervisor: {} failures, {} stalls, {} respawns over {train_secs:.2}s",
            stats.failures_seen, stats.stalls_detected, stats.respawns
        );
    }
    journal.event(
        "fleet_done",
        vec![
            ("secs", num(train_secs)),
            ("respawns", inum(stats.respawns)),
            ("stalls", inum(stats.stalls_detected)),
            ("failures", inum(stats.failures_seen)),
        ],
    );
    let tail = procs::merge_survivor_tail(cfg, suite, &mut outcomes)?;
    journal.event(
        "merge_done",
        vec![("secs", num(tail.merged.seconds))],
    );
    journal.event("eval_done", vec![("secs", num(tail.eval_secs))]);
    journal.event(
        "metrics",
        vec![("snapshot", crate::obs::metrics::global().snapshot())],
    );
    Ok(SupervisedReport {
        outcomes,
        train_secs,
        stats,
        tail,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_spec_parses_every_action() {
        let f = FaultSpec::parse("crash@pairs=500", 0).unwrap();
        assert_eq!(f.crash_at_pairs, Some(500));
        let f = FaultSpec::parse("stall@epoch=2", 3).unwrap();
        assert_eq!(f.stall_at_epoch, Some(2));
        let f = FaultSpec::parse("corrupt-artifact", 1).unwrap();
        assert!(f.corrupt_artifact);
        let f = FaultSpec::parse("slow@factor=250", 1).unwrap();
        assert_eq!(f.slow_factor_us, Some(250));
        assert!(FaultSpec::parse("", 0).unwrap().is_empty());
    }

    #[test]
    fn fault_spec_targets_one_submodel() {
        let spec = "crash@pairs=100@submodel=1;slow@factor=50@submodel=2";
        let w0 = FaultSpec::parse(spec, 0).unwrap();
        assert!(w0.is_empty());
        let w1 = FaultSpec::parse(spec, 1).unwrap();
        assert_eq!(w1.crash_at_pairs, Some(100));
        assert_eq!(w1.slow_factor_us, None);
        let w2 = FaultSpec::parse(spec, 2).unwrap();
        assert_eq!(w2.slow_factor_us, Some(50));
        assert_eq!(w2.crash_at_pairs, None);
    }

    #[test]
    fn fault_spec_rejects_malformed_input() {
        // errors fire even when the clause targets another sub-model
        for bad in [
            "explode@pairs=1",
            "crash",
            "crash@pairs=abc",
            "crash@pairs",
            "stall@epoch=1@bogus=2",
            "crash@pairs=1@submodel=x",
            "slow@factor=1@submodel=9;stall",
        ] {
            assert!(FaultSpec::parse(bad, 0).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn failure_policy_round_trips() {
        for (text, want) in [
            ("retry", FailurePolicy::Retry),
            ("degrade", FailurePolicy::Degrade),
            ("fail-fast", FailurePolicy::FailFast),
        ] {
            let p = FailurePolicy::parse(text).unwrap();
            assert_eq!(p, want);
            assert_eq!(p.name(), text);
        }
        assert!(FailurePolicy::parse("panic").is_err());
    }

    #[test]
    fn beacon_writer_publishes_atomically_and_throttles() {
        let dir = std::env::temp_dir().join(format!("dw2v_beacon_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let control = Transport::fs(&dir, &dir).control;
        let path = beacon_path(&dir, 3);
        // a long interval: the first write lands, the second is throttled
        let mut w = BeaconWriter::new(control, 3, 60_000);
        w.maybe_write("train", 1, 10, 100);
        let first = std::fs::read(&path).unwrap();
        let j = crate::util::json::Json::parse(std::str::from_utf8(&first).unwrap()).unwrap();
        assert_eq!(j.get("submodel").as_usize(), Some(3));
        assert_eq!(j.get("phase").as_str(), Some("train"));
        assert_eq!(j.get("epoch").as_usize(), Some(1));
        assert_eq!(j.get("pairs").as_str(), Some("100"));
        assert_eq!(j.get("seq").as_str(), Some("1"));
        w.maybe_write("train", 1, 20, 200);
        assert_eq!(std::fs::read(&path).unwrap(), first, "interval must throttle");
        // force-write always lands and bumps seq, so the bytes change
        w.write_now("train", 2, 30, 300);
        let second = std::fs::read(&path).unwrap();
        assert_ne!(second, first);
        let j = crate::util::json::Json::parse(std::str::from_utf8(&second).unwrap()).unwrap();
        assert_eq!(j.get("seq").as_str(), Some("2"));
        assert!(!path.with_extension("json.tmp").exists(), "tmp must be renamed away");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn armed_faults_one_shot_via_marker() {
        let dir = std::env::temp_dir().join(format!("dw2v_fault_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let control = Transport::fs(&dir, &dir).control;
        // a pre-recorded marker disarms the stall (the crash path exits the
        // process, so only stall is testable in-process)
        let spec = FaultSpec {
            stall_at_epoch: Some(1),
            ..Default::default()
        };
        control.record_fault_marker(4, "stall");
        assert!(control.fault_marker_fired(4, "stall"));
        let mut armed = ArmedFaults::new(spec, Arc::clone(&control), 4);
        armed.maybe_stall(1); // would hang forever if the marker were ignored
        // epochs other than the target never stall regardless of markers
        let mut fresh = ArmedFaults::new(
            FaultSpec {
                stall_at_epoch: Some(7),
                ..Default::default()
            },
            control,
            4,
        );
        fresh.maybe_stall(0);
        fresh.maybe_stall(6);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
